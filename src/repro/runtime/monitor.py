"""Failure detection and straggler mitigation (1000-node posture).

On a real cluster every host runs this; in the CPU container the same code
paths run with host count 1 (and the tests spin up fake peers by writing
heartbeat files).  Nothing here imports device state.

* :class:`Heartbeat` — each host touches ``<dir>/host_<id>.hb`` with a
  monotonic timestamp + step; ``dead_peers()`` reports hosts whose file is
  stale.  The trainer polls it between steps and raises
  :class:`PeerFailure` so the restart loop re-meshes (elastic restore).
* :class:`StragglerMonitor` — per-step wall-time EWMA + variance; a step
  slower than ``threshold × EWMA`` is flagged.  Mitigation hook: the
  trainer records flagged steps and (at scale) re-balances microbatches
  away from the slow host — here it logs the decision (there is exactly
  one host), which the straggler test asserts on.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class PeerFailure(RuntimeError):
    def __init__(self, dead: list[str]):
        super().__init__(f"dead peers: {dead}")
        self.dead = dead


class Heartbeat:
    def __init__(self, directory: str, host_id: int, *,
                 timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.dir = directory
        self.host_id = host_id
        self.timeout_s = timeout_s
        self.clock = clock
        os.makedirs(directory, exist_ok=True)

    def _path(self, host_id: int) -> str:
        return os.path.join(self.dir, f"host_{host_id:05d}.hb")

    def beat(self, step: int):
        tmp = self._path(self.host_id) + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"t": self.clock(), "step": step}, f)
        os.replace(tmp, self._path(self.host_id))

    def peers(self) -> dict[int, dict]:
        out = {}
        for fn in os.listdir(self.dir):
            if fn.startswith("host_") and fn.endswith(".hb"):
                try:
                    with open(os.path.join(self.dir, fn)) as f:
                        out[int(fn[5:10])] = json.load(f)
                except (OSError, json.JSONDecodeError, ValueError):
                    continue
        return out

    def dead_peers(self) -> list[int]:
        now = self.clock()
        return sorted(h for h, rec in self.peers().items()
                      if now - rec["t"] > self.timeout_s)

    def check(self):
        dead = self.dead_peers()
        if dead:
            raise PeerFailure([f"host_{h:05d}" for h in dead])


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker with slow-step flagging."""
    alpha: float = 0.1
    threshold: float = 2.0
    warmup: int = 3                 # first steps include compile; skip them
    ewma: Optional[float] = None
    count: int = 0
    flagged: list = field(default_factory=list)
    log: Callable[[str], None] = print

    def record(self, step: int, dt: float) -> bool:
        """Returns True if the step was flagged as a straggler."""
        self.count += 1
        if self.count <= self.warmup:
            return False
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.threshold * self.ewma
        if slow:
            self.flagged.append((step, dt, self.ewma))
            self.log(f"[straggler] step {step}: {dt*1e3:.1f} ms vs EWMA "
                     f"{self.ewma*1e3:.1f} ms — rebalance hook engaged")
            # mitigation hook: at scale, shift microbatch rows away from
            # the slow host next step; single-host runs only log.
        else:
            self.ewma = self.alpha * dt + (1 - self.alpha) * self.ewma
        return slow
