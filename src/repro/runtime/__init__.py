"""Distributed runtime: step builders, fault tolerance, monitoring."""
from .steps import build_train_step, build_serve_steps, TrainHParams
from .monitor import Heartbeat, StragglerMonitor
from .trainer import Trainer, TrainerConfig

__all__ = [
    "build_train_step", "build_serve_steps", "TrainHParams",
    "Heartbeat", "StragglerMonitor", "Trainer", "TrainerConfig",
]
