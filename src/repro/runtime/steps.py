"""train_step / serve_step builders.

``build_train_step`` assembles, from plain pieces, the jit-able function
``(params, opt_state, batch[, ef]) -> (params, opt_state, metrics[, ef])``:

* **microbatching / gradient accumulation** — the global batch is cut into
  ``grad_accum`` microbatches scanned sequentially; gradients accumulate in
  fp32.  Under GSPMD each microbatch's DP psum overlaps the next
  microbatch's compute (the scheduler interleaves the scan body's collective
  with the following iteration — the standard accumulate-overlap trick).
* **remat** — ``ctx.remat="block"`` checkpoints each layer-program unit.
* **cross-pod gradient compression** — optional: gradients are computed
  *pod-locally* under a partial-manual ``shard_map`` (manual over ``pod``,
  auto over ``data``/``model``), then EF-int8 reduced over the pod (DCN)
  axis (:mod:`repro.optim.compress`).

``build_serve_steps`` returns (prefill_fn, decode_fn) with KV-cache
handling, greedy/temperature sampling, and flash-decoding sequence-sharded
caches when ``ctx.seq_shard_decode``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.context import ExecContext
from repro.optim import AdamWConfig, adamw_update, compressed_psum_mean
from repro.optim.schedule import warmup_cosine


@dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_accum: int = 1
    mtp_weight: float = 0.3
    compress_pod: bool = False
    pod_axis: str = "pod"
    ef_dtype: str = "bfloat16"   # error-feedback buffer dtype


def _microbatch(batch: dict, n: int) -> dict:
    """(B, ...) leaves → (n, B/n, ...) with microbatch rows **strided**:
    microbatch j = rows {i·n + j}.

    The stride matters for sharding: the global batch is sharded over the
    data axis in contiguous blocks, so cutting contiguous microbatches
    puts the *sharded* dimension on the scan axis — each scan iteration's
    rows then live on one chip and GSPMD replicates the step across the
    rest (a measured 16× traffic/FLOP blow-up on every train cell).
    Strided cutting keeps every microbatch spread over all data shards.

    ``positions3`` carries batch on dim 1 (M-RoPE's (3, B, S) layout).
    """
    def cut(x, bdim=0):
        b = x.shape[bdim]
        assert b % n == 0, f"global batch {b} not divisible by accum {n}"
        shp = x.shape[:bdim] + (b // n, n) + x.shape[bdim + 1:]
        return jnp.moveaxis(x.reshape(shp), bdim + 1, 0)
    return {k: cut(v, 1 if k == "positions3" else 0)
            for k, v in batch.items()}


def _grads_of(cfg: ModelConfig, ctx: ExecContext, hp: TrainHParams):
    """(params, batch) → (loss, grads) with microbatch accumulation."""
    def loss_fn(p, b):
        return lm.loss_fn(p, b, cfg, ctx, mtp_weight=hp.mtp_weight)[0]

    vg = jax.value_and_grad(loss_fn)

    if hp.grad_accum == 1:
        return vg

    def accum(params, batch):
        mb = _microbatch(batch, hp.grad_accum)

        def body(carry, b):
            acc_l, acc_g = carry
            l, g = vg(params, b)
            acc_g = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), acc_g, g)
            return (acc_l + l, acc_g), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zero_g), mb,
                                        length=hp.grad_accum)
        inv = 1.0 / hp.grad_accum
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return accum


def build_train_step(cfg: ModelConfig, ctx: ExecContext,
                     opt_cfg: AdamWConfig, hp: TrainHParams) -> Callable:
    """Returns ``train_step(params, opt_state, batch[, ef])``."""
    grads_of = _grads_of(cfg, ctx, hp)

    def schedule(step):
        return warmup_cosine(step, peak_lr=hp.peak_lr,
                             warmup_steps=hp.warmup_steps,
                             total_steps=hp.total_steps)

    if not hp.compress_pod:
        def train_step(params, opt_state, batch):
            loss, grads = grads_of(params, batch)
            lr = schedule(opt_state["step"])
            params, opt_state, om = adamw_update(params, grads, opt_state,
                                                 opt_cfg, lr=lr)
            return params, opt_state, {"loss": loss, **om}
        return train_step

    # --- compressed cross-pod variant -----------------------------------
    axis = hp.pod_axis
    ef_dtype = jnp.dtype(hp.ef_dtype)
    # inside the pod-manual shard_map, 'pod' is a manual axis: the inner
    # model code (sharding constraints, nested shard_maps) must not name
    # it — rebuild the grad closure with it stripped from batch_axes
    data_axes = tuple(a for a in ctx.batch_axes if a != axis)
    partial_manual = compat.supports_partial_manual()
    if partial_manual:
        inner_ctx = ctx.with_(batch_axes=data_axes)
    else:
        # Fully-manual fallback: old XLA CHECK-crashes on partial-manual
        # regions, so the body goes manual over *every* mesh axis — batch
        # sharded over pod+data explicitly, model compute replicated
        # shard-locally (mesh=None strips nested constraints/shard_maps) and
        # the in-pod data reduction done with explicit pmeans.
        inner_ctx = ctx.with_(mesh=None, batch_axes=())
    grads_of_inner = _grads_of(cfg, inner_ctx, hp)

    def train_step(params, opt_state, batch, ef):
        if ctx.mesh is None or axis not in ctx.mesh.axis_names:
            raise ValueError(f"compress_pod needs mesh axis {axis!r}")

        def pod_body(p, b, e):
            loss, grads = grads_of_inner(p, b)
            if not partial_manual:
                for a in data_axes:        # exact in-pod (ICI) mean
                    loss = jax.lax.pmean(loss, a)
                    grads = jax.tree.map(
                        lambda g, a=a: jax.lax.pmean(g, a), grads)
            e32 = jax.tree.map(lambda x: x.astype(jnp.float32), e)
            grads, e32 = compressed_psum_mean(grads, e32, axis)
            new_e = jax.tree.map(lambda x: x.astype(ef_dtype), e32)
            return jax.lax.pmean(loss, axis), grads, new_e

        pspec = jax.tree.map(lambda _: P(), params)
        batch_spec = P(axis) if partial_manual else P((axis,) + data_axes)
        bspec = {k: batch_spec for k in batch}
        espec = jax.tree.map(lambda _: P(), ef)
        gspec = jax.tree.map(lambda _: P(), params)
        fn = compat.shard_map(pod_body, mesh=ctx.mesh,
                           in_specs=(pspec, bspec, espec),
                           out_specs=(P(), gspec, espec),
                           axis_names={axis} if partial_manual else None,
                           check_vma=False)
        loss, grads, ef = fn(params, batch, ef)
        lr = schedule(opt_state["step"])
        params, opt_state, om = adamw_update(params, grads, opt_state,
                                             opt_cfg, lr=lr)
        return params, opt_state, {"loss": loss, **om}, ef

    return train_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def sample_logits(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    """logits (B, 1, V) → tokens (B, 1) int32."""
    lg = logits[:, -1, :].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
    lg = lg / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)[:, None]


def _pad_caches(caches, cfg: ModelConfig, max_len: int):
    """Grow every seq-extent cache leaf to ``max_len`` (zero-fill tail)."""
    def pad_leaf(key_name, t):
        if key_name in ("k", "v"):              # (k, B, Hkv, S, dh)
            s = t.shape[3]
            if s >= max_len:
                return t
            return jnp.pad(t, ((0, 0),) * 3 + ((0, max_len - s), (0, 0)))
        if key_name in ("c_kv", "k_rope"):      # (k, B, S, R)
            s = t.shape[2]
            if s >= max_len:
                return t
            return jnp.pad(t, ((0, 0), (0, 0), (0, max_len - s), (0, 0)))
        return t                                 # conv/ssm/xk/xv: fixed size

    def walk(c):
        if isinstance(c, dict):
            return {k: (walk(v) if isinstance(v, dict) else pad_leaf(k, v))
                    for k, v in c.items()}
        if isinstance(c, list):
            return [walk(v) for v in c]
        return c

    return walk(caches)


def build_serve_steps(cfg: ModelConfig, ctx: ExecContext, *,
                      max_len: int, temperature: float = 0.0,
                      top_k: int = 0):
    """Returns (prefill_step, decode_step).

    prefill_step(params, batch, key) -> (token, caches, length, enc_out)
    decode_step(params, token, caches, length, key[, enc_out])
        -> (next_token, caches, length+1)
    """
    def prefill_step(params, batch, key):
        logits, caches, enc_out = lm.prefill(params, batch, cfg, ctx)
        caches = _pad_caches(caches, cfg, max_len)
        tok = sample_logits(logits, key, temperature=temperature, top_k=top_k)
        length = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
        return tok, caches, length, enc_out

    def decode_step(params, token, caches, length, key):
        logits, caches = lm.decode_step(params, token, caches, length, cfg,
                                        ctx)
        tok = sample_logits(logits, key, temperature=temperature, top_k=top_k)
        return tok, caches, length + 1

    return prefill_step, decode_step
