"""The fault-tolerant training driver.

Composition of everything below it::

    mesh → plan → shardings → params/opt init (or elastic restore)
         → jit(train_step, in/out_shardings) → loop:
               heartbeat · straggler monitor · periodic async checkpoint
         → on failure: restart loop reloads latest checkpoint, possibly on
           a different mesh (elastic), and continues from the same data
           position (stateless loader).

The Trainer is deliberately process-shaped (no globals): tests drive it
with tiny configs, inject failures, kill and resurrect it, and assert
bit-exact continuation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager, latest_step
from repro.data import SyntheticConfig, make_batch_loader
from repro.models import params as params_lib
from repro.models.config import ModelConfig
from repro.models.context import ExecContext
from repro.optim import AdamWConfig, adamw_init, compress_init
from repro.sharding import make_plan, sharding_for_tree, batch_specs
from .monitor import Heartbeat, StragglerMonitor, PeerFailure
from .steps import TrainHParams, build_train_step


@dataclass
class TrainerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    hb_dir: Optional[str] = None
    hb_timeout_s: float = 60.0
    log_every: int = 10
    seed: int = 0
    param_dtype: str = "float32"
    fsdp: bool = True
    max_restarts: int = 3
    log: Callable[[str], None] = print


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh: Optional[Mesh],
                 data_cfg: SyntheticConfig, opt_cfg: AdamWConfig,
                 hp: TrainHParams, tc: TrainerConfig, *,
                 ctx: Optional[ExecContext] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.hp = hp
        self.tc = tc

        batch_axes = tuple(a for a in ("pod", "data")
                           if mesh is not None and a in mesh.axis_names)
        model_axis = ("model" if mesh is not None and
                      "model" in mesh.axis_names and
                      mesh.shape["model"] > 1 else None)
        self.ctx = ctx or ExecContext(
            mesh=mesh, batch_axes=batch_axes, model_axis=model_axis,
            remat="block")

        self.ckpt = CheckpointManager(tc.ckpt_dir, keep=tc.keep)
        self.hb = (Heartbeat(tc.hb_dir, host_id=0,
                             timeout_s=tc.hb_timeout_s)
                   if tc.hb_dir else None)
        self.monitor = StragglerMonitor(log=tc.log)
        self.metrics_history: list[dict] = []

        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, mesh = self.cfg, self.mesh
        dtype = jnp.dtype(self.tc.param_dtype)
        key = jax.random.PRNGKey(self.tc.seed)

        if mesh is not None:
            if self.hp.compress_pod and self.tc.fsdp:
                # XLA's SPMD partitioner CHECK-fails when FSDP-sharded
                # (d_model-over-data) parameters enter a partial-manual
                # (pod) shard_map (spmd_partitioner_util.cc:504, verified
                # on jax 0.8.2).  Compression targets the DCN DP axis;
                # run it with TP-only sharding until the upstream fix.
                raise ValueError(
                    "compress_pod currently requires TrainerConfig("
                    "fsdp=False) — see the note in runtime/trainer.py")
            plan = make_plan(cfg, mode="train", fsdp=self.tc.fsdp)
            # init on device with the final shardings (jit init → no host
            # round-trip; at 671B scale this is mandatory)
            axes_box = {}

            def _init_p(k):
                p, ax = params_lib.init_params(cfg, k, dtype)
                axes_box["ax"] = ax
                return p

            jax.eval_shape(_init_p, key)
            axes = axes_box["ax"]
            self.param_shardings = sharding_for_tree(axes, plan, mesh)
            init = jax.jit(
                lambda k: params_lib.init_params(cfg, k, dtype)[0],
                out_shardings=self.param_shardings)
            self.params = init(key)
        else:
            self.params, _ = params_lib.init_params(cfg, key, dtype)
            self.param_shardings = None

        self.opt_state = adamw_init(self.params, self.opt_cfg)
        self.ef = (compress_init(self.params)
                   if self.hp.compress_pod else None)
        self.step = 0

        # data loader with batch sharding
        if mesh is not None:
            bspecs = batch_specs(self.ctx.batch_axes, mesh,
                                 {"tokens": ("batch", "seq"),
                                  "labels": ("batch", "seq")})
        else:
            bspecs = None
        self.loader = make_batch_loader(self.data_cfg, sharding=bspecs)

        step_fn = build_train_step(cfg, self.ctx, self.opt_cfg, self.hp)
        if mesh is not None:
            self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        else:
            self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def _state_tree(self):
        t = {"params": self.params, "opt": self.opt_state}
        if self.ef is not None:
            t["ef"] = self.ef
        return t

    def save(self, blocking: bool = False):
        self.ckpt.save(self.step, self._state_tree(),
                       extra={"step": self.step,
                              "arch": self.cfg.name,
                              "data_seed": self.data_cfg.seed},
                       blocking=blocking)

    def restore_latest(self) -> bool:
        """Elastic restore: loads the newest checkpoint onto the *current*
        mesh (which may differ from the writer's).  Returns True if one
        was found."""
        if latest_step(self.tc.ckpt_dir) is None:
            return False
        shardings = None
        if self.param_shardings is not None:
            opt_sh = {
                "m": self.param_shardings, "v": self.param_shardings,
                "step": NamedSharding(self.mesh, P()),
            }
            if self.opt_cfg.quantize_moments:
                # QTensor leaves (codes/scales) don't mirror param shapes;
                # replicate them (they're 4× smaller than fp32 moments).
                opt_sh = jax.tree.map(
                    lambda _: NamedSharding(self.mesh, P()), self.opt_state)
            shardings = {"params": self.param_shardings, "opt": opt_sh}
            if self.ef is not None:
                shardings["ef"] = self.param_shardings
        tree, extra, step = self.ckpt.restore_latest(
            self._state_tree(), shardings=shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.ef = tree.get("ef", self.ef)
        self.step = int(extra.get("step", step))
        self.tc.log(f"[trainer] restored step {self.step} from checkpoint")
        return True

    # ------------------------------------------------------------------
    def train_steps(self, n: int, *, failure_hook: Optional[Callable] = None):
        """Run ``n`` steps from the current position (one restart body)."""
        for _ in range(n):
            batch = self.loader(self.step)
            t0 = time.monotonic()
            if self.ef is None:
                self.params, self.opt_state, metrics = self._jit_step(
                    self.params, self.opt_state, batch)
            else:
                self.params, self.opt_state, metrics, self.ef = \
                    self._jit_step(self.params, self.opt_state, batch,
                                   self.ef)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            self.step += 1
            self.monitor.record(self.step, dt)
            if self.hb:
                self.hb.beat(self.step)
                self.hb.check()
            if self.step % self.tc.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                self.metrics_history.append({"step": self.step, **m})
                self.tc.log(f"[trainer] step {self.step} "
                            f"loss {m['loss']:.4f} "
                            f"gnorm {m.get('grad_norm', 0):.3f} {dt*1e3:.0f} ms")
            if self.step % self.tc.ckpt_every == 0:
                self.save()
            if failure_hook is not None:
                failure_hook(self)
        self.ckpt.wait()

    def run(self, total_steps: int, **kw):
        """Restart loop: survive PeerFailure / injected faults by reloading
        the newest checkpoint and continuing."""
        self.restore_latest()
        restarts = 0
        while self.step < total_steps:
            try:
                self.train_steps(total_steps - self.step, **kw)
            except PeerFailure as e:
                restarts += 1
                self.tc.log(f"[trainer] {e}; restart {restarts}")
                if restarts > self.tc.max_restarts:
                    raise
                self.ckpt.wait()
                if not self.restore_latest():
                    self.tc.log("[trainer] no checkpoint; restarting fresh")
        self.save(blocking=True)
        return self.metrics_history
