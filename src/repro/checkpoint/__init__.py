"""Fault-tolerant checkpointing: atomic, async, manifested, elastic."""
from .store import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    verify_checkpoint,
)

__all__ = [
    "CheckpointManager", "latest_step", "restore_checkpoint",
    "save_checkpoint", "verify_checkpoint",
]
