"""Fault-tolerant checkpointing: atomic, async, manifested, elastic."""
from .store import (
    CheckpointManager,
    checkpoint_steps,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    verify_checkpoint,
)

__all__ = [
    "CheckpointManager", "checkpoint_steps", "latest_step",
    "restore_checkpoint", "save_checkpoint", "verify_checkpoint",
]
