"""Sharded checkpoint store: atomic, async, checksummed, elastic.

Layout (one directory per step)::

    <root>/step_000001230/
        manifest.json      # tree structure, shapes, dtypes, shard files,
                           # sha256 per file, step, wall time
        arr_00000.npy      # one file per leaf *shard* (axis-0 split across
        arr_00001.npy      #  writer slots — stands in for per-host files)
        ...

Guarantees:

* **Atomicity** — written into ``<dir>.tmp`` then ``os.replace``d; a crash
  mid-save never corrupts the latest complete checkpoint, and
  :func:`latest_step` only ever sees complete directories.
* **Integrity** — per-file SHA-256 in the manifest; :func:`verify_checkpoint`
  and restore both check.
* **Elastic restore** — leaves are stored as *logical* arrays (shard files
  concatenate on axis 0), so a checkpoint written on an N-chip mesh
  restores onto any M-chip mesh: pass new ``shardings`` and each leaf is
  ``device_put`` with the new layout.  Re-sharding is a placement decision,
  not a data transform.
* **Async** — :class:`CheckpointManager` snapshots to host memory
  synchronously (cheap) and writes in a background thread, overlapping the
  next training steps; ``wait()`` joins before the next save or exit.
* **Retention** — keep the newest ``keep`` checkpoints (always ≥1).

QTensor optimizer leaves (8-bit moments) are plain NamedTuples of arrays —
the pytree machinery below handles them transparently.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


_MANIFEST = "manifest.json"


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


# Leaves that are plain python values (a step counter, a bucket id, a
# flag) round-trip through the manifest itself ("py" entries) instead of
# .npy files: np.asarray would turn a str into a numpy 'U' array (whose
# dtype name resolves through neither np.sctypeDict nor ml_dtypes on
# restore) and an int into a 0-d array (restored as a jax scalar — a
# type infidelity for metadata like fleet ticket step counters).
_PY_LEAF_TYPES = (str, bool, int, float)


def _is_py_leaf(leaf: Any) -> bool:
    return isinstance(leaf, _PY_LEAF_TYPES) and not isinstance(
        leaf, np.generic)


def _to_host(leaf: Any):
    """Host snapshot of one leaf: arrays device_get, python scalars and
    strings pass through untouched (type-faithful round-trip)."""
    if _is_py_leaf(leaf):
        return leaf
    return np.asarray(jax.device_get(leaf))


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _step_dir(root: str, step: int) -> str:
    return os.path.join(root, f"step_{step:012d}")


def save_checkpoint(root: str, step: int, tree, *, extra: dict | None = None,
                    nshards: int = 4) -> str:
    """Synchronous atomic save.  Returns the final directory path."""
    keys, leaves, treedef = _tree_paths(tree)
    host_leaves = [_to_host(l) for l in leaves]

    final = _step_dir(root, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    entries = []
    fid = 0
    for key, arr in zip(keys, host_leaves):
        if _is_py_leaf(arr):
            entries.append({"key": key, "py": arr,
                            "pytype": type(arr).__name__, "files": []})
            continue
        # non-native dtypes (bfloat16, fp8, ...) are stored as raw bytes;
        # the manifest keeps the true dtype for reconstruction
        raw = arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict
        store = (np.frombuffer(np.ascontiguousarray(arr).tobytes(),
                               np.uint8) if raw else arr)
        # split big leaves across writer slots (per-host files at scale)
        n0 = store.shape[0] if store.ndim else 1
        cuts = min(nshards, n0) if store.ndim and \
            store.nbytes > (1 << 20) else 1
        bounds = np.linspace(0, n0, cuts + 1, dtype=int) if cuts > 1 else None
        files = []
        for s in range(cuts):
            part = store if cuts == 1 else store[bounds[s]:bounds[s + 1]]
            fname = f"arr_{fid:05d}.npy"
            fid += 1
            np.save(os.path.join(tmp, fname), part)
            files.append({"file": fname,
                          "sha256": _sha256(os.path.join(tmp, fname))})
        entries.append({"key": key, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "raw": bool(raw),
                        "files": files})

    manifest = {
        "version": 1,
        "step": int(step),
        "time": time.time(),
        "extra": extra or {},
        "leaves": entries,
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _load_manifest(path: str) -> dict:
    with open(os.path.join(path, _MANIFEST)) as f:
        return json.load(f)


def verify_checkpoint(path: str) -> bool:
    try:
        man = _load_manifest(path)
    except (OSError, json.JSONDecodeError):
        return False
    for e in man["leaves"]:
        for fl in e.get("files", []):
            fp = os.path.join(path, fl["file"])
            if not os.path.exists(fp) or _sha256(fp) != fl["sha256"]:
                return False
    return True


def checkpoint_steps(root: str) -> list[int]:
    """All complete checkpoint steps under ``root``, oldest first.
    "Complete" = the directory has a manifest (atomic ``os.replace``
    means a directory either fully exists or doesn't) — contents may
    still be damaged; pair with :func:`verify_checkpoint` to find the
    newest *valid* one."""
    if not os.path.isdir(root):
        return []
    return sorted(
        int(d[len("step_"):]) for d in os.listdir(root)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(root, d, _MANIFEST)))


def latest_step(root: str) -> Optional[int]:
    steps = checkpoint_steps(root)
    return steps[-1] if steps else None


def restore_checkpoint(root: str, tree_like, *, step: Optional[int] = None,
                       shardings=None, verify: bool = True):
    """Restore into the structure of ``tree_like`` (shapes are trusted from
    the manifest).  ``shardings``: optional twin pytree of NamedShardings —
    this is the **elastic** path: any mesh, any layout.
    ``verify`` (default on) checks every shard's sha256 against the
    manifest before loading and raises ``IOError`` on a mismatch — pass
    ``verify=False`` only when the caller already verified (or wants a
    best-effort read of a known-damaged snapshot).
    Returns (tree, manifest_extra, step).
    """
    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    path = _step_dir(root, step)
    if verify and not verify_checkpoint(path):
        raise IOError(f"checkpoint {path} failed integrity check")
    man = _load_manifest(path)
    by_key = {e["key"]: e for e in man["leaves"]}

    keys, leaves, treedef = _tree_paths(tree_like)
    shard_leaves = (None,) * len(leaves)
    if shardings is not None:
        skeys, shard_leaves, _ = _tree_paths(shardings)
        assert skeys == keys, "shardings tree does not match target tree"

    out = []
    for key, like, shard in zip(keys, leaves, shard_leaves):
        e = by_key.get(key)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        if "py" in e:
            # python-scalar/str leaf: the manifest IS the storage; JSON
            # already preserves str/bool/int/float exactly
            out.append(e["py"])
            continue
        parts = [np.load(os.path.join(path, fl["file"])) for fl in e["files"]]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        if e.get("raw"):
            import ml_dtypes
            dt = np.dtype(getattr(ml_dtypes, e["dtype"], None) or e["dtype"])
            arr = np.frombuffer(arr.tobytes(), dt).reshape(e["shape"])
        if list(arr.shape) != list(e["shape"]):
            raise IOError(f"shape mismatch for {key}: {arr.shape} vs manifest")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return treedef.unflatten(out), man.get("extra", {}), step


def _prune(root: str, keep: int):
    steps = checkpoint_steps(root)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(root, s), ignore_errors=True)


class CheckpointManager:
    """Async save orchestration + retention.

    ``save()`` device_gets synchronously (the only part that must see
    consistent device state) and writes files on a daemon thread.
    """

    def __init__(self, root: str, *, keep: int = 3, nshards: int = 4):
        self.root = root
        self.keep = keep
        self.nshards = nshards
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = False):
        self.wait()
        keys, leaves, treedef = _tree_paths(tree)
        host = [_to_host(l) for l in leaves]
        snapshot = treedef.unflatten(host)

        def work():
            try:
                save_checkpoint(self.root, step, snapshot, extra=extra,
                                nshards=self.nshards)
                _prune(self.root, self.keep)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_latest(self, tree_like, *, shardings=None, verify=True):
        return restore_checkpoint(self.root, tree_like, shardings=shardings,
                                  verify=verify)
