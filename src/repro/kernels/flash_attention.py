"""Blocked (flash) attention — Pallas TPU kernel with explicit VMEM tiling.

Supports the attention variants the assigned architectures need:

* causal and non-causal (whisper encoder / cross-attention) masks,
* GQA (kv head = q head // group, folded into the BlockSpec index map),
* sliding-window attention (gemma2/gemma3 local layers),
* logit soft-capping (gemma2), applied before masking,
* arbitrary softmax scale (gemma query_pre_attn_scalar, MLA scale).

Structure: grid ``(batch·heads, q blocks, k blocks)`` with the k axis
innermost and sequential; online-softmax accumulators (running max m,
normaliser l, weighted-value acc) live in VMEM scratch and the output block
is written once at the final k step.  Block extents ``block_q``/``block_k``
are the kernel's VVL analogue — tunable, MXU-aligned multiples of 128.

VMEM per step ≈ (BQ·Dh + 2·BK·Dh + BQ·BK + BQ·Dh) · 4 B; BQ=BK=512, Dh=128
→ ~1.8 MiB.  Out-of-window/causal-dead k blocks short-circuit via
``pl.when`` (the DMA still lands, the FLOPs are skipped; see §Perf for the
fused-skip variant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_body(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int, softcap: float,
               block_q: int, block_k: int, kv_len: int, num_kb: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.iota(jnp.int32, block_q)            # (BQ,)
    k_pos = ik * block_k + jax.lax.iota(jnp.int32, block_k)            # (BK,)

    # Block-level liveness: skip the math for blocks that are fully masked.
    blk_alive = jnp.asarray(True)
    if causal:
        blk_alive = blk_alive & (ik * block_k <= iq * block_q + block_q - 1)
    if window > 0:
        blk_alive = blk_alive & ((ik + 1) * block_k - 1 >= iq * block_q - window + 1)

    @pl.when(blk_alive)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                               # (BQ, Dh)
        k = k_ref[0].astype(jnp.float32)                               # (BK, Dh)
        v = v_ref[0].astype(jnp.float32)                               # (BK, Dh)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)    # (BQ, BK)
        s = s * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        mask = k_pos[None, :] < kv_len
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                            # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                                         # (BQ, BK)
        alpha = jnp.exp(m_prev - m_new)                                # (BQ, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == num_kb - 1)
    def _finalize():
        l = l_scr[...]
        # Fully-masked rows (can happen for padded queries) get zero output.
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           softcap: float = 0.0, scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """Attention for ``q:(B,Hq,Sq,Dh)``, ``k,v:(B,Hkv,Sk,Dh)`` → ``(B,Hq,Sq,Dh)``.

    ``window=0`` disables sliding-window; ``softcap=0`` disables capping.
    """
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    if hq % hkv != 0:
        raise ValueError(f"GQA requires Hq % Hkv == 0, got {hq} % {hkv}")
    group = hq // hkv
    scale = float(scale) if scale is not None else dh ** -0.5

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    sq_pad = -(-sq // block_q) * block_q
    sk_pad = -(-sk // block_k) * block_k

    def pad_seq(x, s_to):
        s = x.shape[2]
        return jnp.pad(x, ((0, 0), (0, 0), (0, s_to - s), (0, 0))) if s_to != s else x

    qp = pad_seq(q, sq_pad).reshape(b * hq, sq_pad, dh)
    kp = pad_seq(k, sk_pad).reshape(b * hkv, sk_pad, dh)
    vp = pad_seq(v, sk_pad).reshape(b * hkv, sk_pad, dh)

    num_qb = sq_pad // block_q
    num_kb = sk_pad // block_k

    body = functools.partial(
        _attn_body, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k,
        kv_len=sk, num_kb=num_kb)

    from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

    out = pl.pallas_call(
        body,
        grid=(b * hq, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
        name=f"flash_attn_bq{block_q}_bk{block_k}",
    )(qp, kp, vp)

    return out.reshape(b, hq, sq_pad, dh)[:, :, :sq]
