"""D3Q19 binary-fluid lattice-Boltzmann collision — the paper's benchmark.

This is the "binary collision" kernel of §IV: a BGK collision of two
distributions (f for the fluid, g for the composition order parameter φ)
with a free-energy force, site-local over 19+19+5 components per site.

Physics (force-based binary model; Swift/Kendon-family, Guo forcing):

* moments:        ρ = Σᵢ fᵢ,   ρu = Σᵢ fᵢcᵢ + F/2,   φ = Σᵢ gᵢ
* free energy:    μ = -A φ + B φ³ - κ ∇²φ      (symmetric double well)
* force:          F = μ ∇φ
* equilibria:     fᵢᵉq = wᵢ ρ (1 + 3cᵢ·u + 9/2 (cᵢ·u)² - 3/2 u²)
                  gᵢᵉq = wᵢ (3Γμ + 3φ cᵢ·u)  (i≥1);  g₀ᵉq = φ - Σ_{i≥1} gᵢᵉq
* collision:      fᵢ' = fᵢ - (fᵢ - fᵢᵉq)/τ + (1 - 1/2τ) wᵢ (3(cᵢ-u) + 9cᵢ(cᵢ·u))·F
                  gᵢ' = gᵢ - (gᵢ - gᵢᵉq)/τ_φ

Mass (Σf) is conserved exactly; momentum changes by exactly F per site;
Σg = φ is conserved exactly — tests assert all three.

The paper's point: the innermost model-dictated extents (19 momenta,
3 dimensions) do not fill vector hardware; the site-chunk axis (VVL) does.
Here the kernel body operates on ``(ncomp, VVL)`` chunks — every op
vectorises over the trailing VVL lanes; the 19/3-extent contractions become
small ``(19,3)``-matrix ops on sublanes.

Three realisations, single source:
  * :func:`collision_site_kernel` — the targetDP site kernel (runs under the
    generic jnp and Pallas executors);
  * :func:`lb_collision_pallas` — dedicated ``pl.pallas_call`` with explicit
    BlockSpecs and the chemical potential **fused** into the collision
    (one HBM round-trip saved: μ never materialises);
  * ``repro.kernels.ref.lb_collision_ref`` — pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# ---------------------------------------------------------------------------
# D3Q19 velocity set
# ---------------------------------------------------------------------------
# index 0: rest; 1..6: axis vectors; 7..18: face diagonals.

CV = np.array(
    [[0, 0, 0],
     [1, 0, 0], [-1, 0, 0], [0, 1, 0], [0, -1, 0], [0, 0, 1], [0, 0, -1],
     [1, 1, 0], [1, -1, 0], [-1, 1, 0], [-1, -1, 0],
     [1, 0, 1], [1, 0, -1], [-1, 0, 1], [-1, 0, -1],
     [0, 1, 1], [0, 1, -1], [0, -1, 1], [0, -1, -1]],
    dtype=np.float64,
)
WEIGHTS = np.array([1.0 / 3.0] + [1.0 / 18.0] * 6 + [1.0 / 36.0] * 12,
                   dtype=np.float64)
NVEL = 19
NDIM = 3

assert CV.shape == (NVEL, NDIM)
assert abs(WEIGHTS.sum() - 1.0) < 1e-15
assert np.allclose(WEIGHTS @ CV, 0.0)
assert np.allclose(np.einsum("qa,qb,q->ab", CV, CV, WEIGHTS), np.eye(3) / 3.0)


# ---------------------------------------------------------------------------
# single-source site kernel (targetDP)
# ---------------------------------------------------------------------------

def collision_site_kernel(f, g, phi, gradphi, del2phi, *,
                          w=None, c=None, A=0.0625, B=0.0625, kappa=0.04,
                          tau=1.0, tau_phi=1.0, gamma=1.0):
    """Binary collision over one VVL chunk.

    Args:
      f: (19, V) fluid distribution chunk.
      g: (19, V) order-parameter distribution chunk.
      phi: (1, V) order parameter (Σg, precomputed by the moment pass).
      gradphi: (3, V) ∇φ (stencil pass).
      del2phi: (1, V) ∇²φ (stencil pass).
      w, c: TARGET_CONST weight vector (19,) and velocity set (19, 3).
      A, B, kappa, tau, tau_phi, gamma: scalar TARGET_CONSTs.

    Returns:
      (f', g') chunks, both (19, V).
    """
    dt = f.dtype
    w = w.astype(dt)[:, None]                      # (19, 1)
    c = c.astype(dt)                               # (19, 3)
    phi_ = phi[0]                                  # (V,)
    d2 = del2phi[0]

    # chemical potential (fused — μ never touches HBM)
    mu = -A * phi_ + B * phi_ * phi_ * phi_ - kappa * d2      # (V,)
    force = mu[None, :] * gradphi                              # (3, V)

    rho = jnp.sum(f, axis=0)                                   # (V,)
    mom = jnp.einsum("qd,qv->dv", c, f)                        # (3, V)
    u = (mom + 0.5 * force) / rho[None, :]                     # (3, V)

    cu = jnp.einsum("qd,dv->qv", c, u)                         # (19, V)
    usq = jnp.sum(u * u, axis=0)                               # (V,)
    feq = w * rho[None, :] * (1.0 + 3.0 * cu + 4.5 * cu * cu - 1.5 * usq[None, :])

    cf = jnp.einsum("qd,dv->qv", c, force)                     # (19, V)
    uf = jnp.sum(u * force, axis=0)                            # (V,)
    fterm = (1.0 - 0.5 / tau) * w * (3.0 * (cf - uf[None, :]) + 9.0 * cu * cf)
    f_out = f - (f - feq) / tau + fterm

    gt = w * (3.0 * gamma * mu[None, :] + 3.0 * phi_[None, :] * cu)  # (19, V)
    g0 = phi_ - (jnp.sum(gt, axis=0) - gt[0])                  # rest population
    geq = jnp.concatenate([g0[None, :], gt[1:]], axis=0)
    g_out = g - (g - geq) / tau_phi
    return f_out, g_out


collision_site_kernel.__tdp_site_kernel__ = True


# ---------------------------------------------------------------------------
# dedicated Pallas kernel (explicit BlockSpec VMEM tiling)
# ---------------------------------------------------------------------------

def _collision_body(f_ref, g_ref, phi_ref, gphi_ref, d2_ref, w_ref, c_ref,
                    fout_ref, gout_ref, *, scalars):
    f_out, g_out = collision_site_kernel(
        f_ref[...], g_ref[...], phi_ref[...], gphi_ref[...], d2_ref[...],
        w=w_ref[...].reshape(NVEL), c=c_ref[...], **scalars)
    fout_ref[...] = f_out.astype(fout_ref.dtype)
    gout_ref[...] = g_out.astype(gout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("vvl", "interpret", "A", "B",
                                             "kappa", "tau", "tau_phi", "gamma"))
def lb_collision_pallas(f, g, phi, gradphi, del2phi, *, vvl: int = 128,
                        interpret: bool = False,
                        A: float = 0.0625, B: float = 0.0625,
                        kappa: float = 0.04, tau: float = 1.0,
                        tau_phi: float = 1.0, gamma: float = 1.0):
    """Fused binary collision over SoA arrays ``(ncomp, nsites)``.

    VMEM per grid step ≈ (19+19+1+3+1+19+19)·VVL·4 B ≈ 324·VVL B:
    VVL=4096 → ~1.3 MiB, comfortably inside 16 MiB VMEM with double
    buffering; the benchmark sweeps VVL (the paper's tuning experiment).
    """
    n = f.shape[-1]
    n_pad = -(-n // vvl) * vvl
    nchunks = n_pad // vvl
    dt = f.dtype

    def pad(x):
        if n_pad == n:
            return x
        # Pad with safe values: rho=Σf=19 on w-weighted unit f keeps the
        # 1/rho finite in the padded region (results are sliced away).
        fill = 1.0 if x is f else 0.0
        return jnp.pad(x, ((0, 0), (0, n_pad - n)), constant_values=fill)

    fp, gp, php, gpp, d2p = (pad(x) for x in (f, g, phi, gradphi, del2phi))
    w_arr = jnp.asarray(WEIGHTS, dtype=dt).reshape(1, NVEL)
    c_arr = jnp.asarray(CV, dtype=dt)

    scalars = dict(A=A, B=B, kappa=kappa, tau=tau, tau_phi=tau_phi, gamma=gamma)
    body = functools.partial(_collision_body, scalars=scalars)

    site_block = lambda ncomp: pl.BlockSpec((ncomp, vvl), lambda i: (0, i))
    const_block = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))

    fo, go = pl.pallas_call(
        body,
        grid=(nchunks,),
        in_specs=[site_block(NVEL), site_block(NVEL), site_block(1),
                  site_block(NDIM), site_block(1),
                  const_block((1, NVEL)), const_block((NVEL, NDIM))],
        out_specs=[site_block(NVEL), site_block(NVEL)],
        out_shape=[jax.ShapeDtypeStruct((NVEL, n_pad), dt),
                   jax.ShapeDtypeStruct((NVEL, n_pad), dt)],
        interpret=interpret,
        name=f"lb_collision_d3q19_vvl{vvl}",
    )(fp, gp, php, gpp, d2p, w_arr, c_arr)
    return fo[:, :n], go[:, :n]
