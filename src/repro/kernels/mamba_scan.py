"""Mamba-1 selective scan — Pallas TPU kernel, chunked over time.

The CUDA reference fuses the recurrence into one kernel to avoid
materialising the ``(L, d_inner, N)`` hidden-state tensor.  The TPU
adaptation keeps the same insight with a different decomposition
(DESIGN.md §2): the state ``h: (block_d, N)`` lives in **VMEM scratch** and
persists across the sequential time-chunk grid axis; channels ride the lane
axis (``block_d`` lanes — the VVL analogue), the small state dimension
(N=16) rides sublanes, and time is a ``fori_loop`` inside each chunk.
Nothing of size L·d·N ever touches HBM.

Recurrence (per channel d, state n):
    h_t = exp(Δ_t · A) ⊙ h_{t-1} + (Δ_t · x_t) · B_t
    y_t = (h_t · C_t) + D ⊙ x_t

Inputs are pre-activated: Δ already softplus(dt_proj(·)+bias).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_body(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref,
               y_ref, hout_ref, h_scr, *, block_t: int, num_tb: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)          # (block_d, N)
    d_skip = d_ref[...].astype(jnp.float32)     # (1, block_d)
    x = x_ref[0].astype(jnp.float32)            # (block_t, block_d)
    dt = dt_ref[0].astype(jnp.float32)          # (block_t, block_d)
    bmat = b_ref[0].astype(jnp.float32)         # (block_t, N)
    cmat = c_ref[0].astype(jnp.float32)         # (block_t, N)

    def step(t, h):
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)      # (1, block_d)
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)    # (1, block_d)
        b_t = jax.lax.dynamic_slice_in_dim(bmat, t, 1, 0)   # (1, N)
        c_t = jax.lax.dynamic_slice_in_dim(cmat, t, 1, 0)   # (1, N)
        decay = jnp.exp(dt_t.T * a)                         # (block_d, N)
        h = h * decay + (dt_t * x_t).T * b_t                # (block_d, N)
        y_t = jnp.sum(h * c_t, axis=1)[None, :] + d_skip * x_t
        y_ref[0, t, :] = y_t[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(it == num_tb - 1)
    def _emit_state():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_d", "block_t", "interpret"))
def mamba_scan_pallas(x: jax.Array, dt: jax.Array, b: jax.Array,
                      c: jax.Array, a: jax.Array, d: jax.Array, *,
                      block_d: int = 128, block_t: int = 128,
                      interpret: bool = False):
    """Selective scan.

    Args:
      x, dt: ``(batch, L, d_inner)``; b, c: ``(batch, L, N)``;
      a: ``(d_inner, N)`` (negative — already ``-exp(A_log)``); d: ``(d_inner,)``.

    Returns:
      ``(y, h_final)`` with ``y: (batch, L, d_inner)``,
      ``h_final: (batch, d_inner, N)`` (for decode hand-off).
    """
    batch, L, d_inner = x.shape
    n = a.shape[-1]
    block_d = min(block_d, d_inner)
    block_t = min(block_t, L)
    if d_inner % block_d != 0:
        raise ValueError(f"d_inner {d_inner} % block_d {block_d} != 0")
    l_pad = -(-L // block_t) * block_t

    def pad_t(arr):
        if l_pad == L:
            return arr
        return jnp.pad(arr, ((0, 0), (0, l_pad - L), (0, 0)))

    xp, dtp, bp, cp = pad_t(x), pad_t(dt), pad_t(b), pad_t(c)
    d2 = d.reshape(1, d_inner)
    num_db = d_inner // block_d
    num_tb = l_pad // block_t

    y, h_final = pl.pallas_call(
        functools.partial(_scan_body, block_t=block_t, num_tb=num_tb),
        grid=(batch, num_db, num_tb),
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_t, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((block_d, n), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((1, block_d), lambda bi, di, ti: (0, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, l_pad, d_inner), x.dtype),
            jax.ShapeDtypeStruct((batch, d_inner, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
        name=f"mamba_scan_bd{block_d}_bt{block_t}",
    )(xp, dtp, bp, cp, a, d2)

    return y[:, :L, :], h_final
