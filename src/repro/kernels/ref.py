"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for the allclose test sweeps, *and* the compute
path used by the CPU dry-run (Pallas TPU kernels do not lower to the CPU
backend; the single-source site-kernel bodies guarantee the math is
identical — that equivalence is what the kernel test sweeps pin down).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .lb_collision import CV, NVEL, WEIGHTS

# ---------------------------------------------------------------------------
# lattice Boltzmann binary collision
# ---------------------------------------------------------------------------


def lb_collision_ref(f, g, phi, gradphi, del2phi, *,
                     A=0.0625, B=0.0625, kappa=0.04,
                     tau=1.0, tau_phi=1.0, gamma=1.0):
    """Oracle over full SoA arrays ``(ncomp, nsites)``; mirrors
    :func:`repro.kernels.lb_collision.collision_site_kernel` — written
    independently (einsum over the whole lattice at once) but keeping the
    site kernel's exact accumulation/association order (``cu * cu``, not
    ``cu ** 2``; ``φ·φ·φ``), so the two are **bit-identical** on the xla
    executor.  The Program-based driver leans on this: the unfused
    pipeline's collide stage (``COLLIDE_SPEC`` → the site kernel) must
    reproduce the historical ``ops.lb_collision`` trajectory bit-for-bit
    (pinned by ``tests/test_program.py``)."""
    dt = f.dtype
    w = jnp.asarray(WEIGHTS, dt)[:, None]
    c = jnp.asarray(CV, dt)
    phi_ = phi[0]
    mu = -A * phi_ + B * phi_ * phi_ * phi_ - kappa * del2phi[0]
    force = mu[None, :] * gradphi

    rho = f.sum(0)
    u = (jnp.einsum("qd,qv->dv", c, f) + 0.5 * force) / rho[None, :]
    cu = jnp.einsum("qd,dv->qv", c, u)
    usq = (u * u).sum(0)
    feq = w * rho[None, :] * (1.0 + 3.0 * cu + 4.5 * cu * cu
                              - 1.5 * usq[None, :])
    cf = jnp.einsum("qd,dv->qv", c, force)
    uf = (u * force).sum(0)
    fterm = (1.0 - 0.5 / tau) * w * (3.0 * (cf - uf[None, :])
                                     + 9.0 * cu * cf)
    f_out = f - (f - feq) / tau + fterm

    gt = w * (3.0 * gamma * mu[None, :] + 3.0 * phi_[None, :] * cu)
    g0 = phi_ - (gt.sum(0) - gt[0])
    geq = jnp.concatenate([g0[None, :], gt[1:]], axis=0)
    g_out = g - (g - geq) / tau_phi
    return f_out, g_out


# ---------------------------------------------------------------------------
# LM pointwise
# ---------------------------------------------------------------------------

def rmsnorm_ref(x, weight, *, eps=1e-6, scale_offset=0.0):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * inv * (weight.astype(jnp.float32) + scale_offset)).astype(x.dtype)


def gated_act_ref(u, v=None, *, kind="swiglu"):
    uf = u.astype(jnp.float32)
    if kind in ("swiglu", "silu"):
        a = uf * jax.nn.sigmoid(uf)
    elif kind in ("geglu", "gelu"):
        a = jax.nn.gelu(uf, approximate=True)
    elif kind == "relu2":
        r = jnp.maximum(uf, 0.0)
        a = r * r
    else:
        raise ValueError(kind)
    out = a if v is None else a * v.astype(jnp.float32)
    return out.astype(u.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
                  kv_len=None):
    """Oracle attention: q (B,Hq,Sq,Dh), k/v (B,Hkv,Sk,Dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    kv_len = sk if kv_len is None else kv_len

    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = k_pos < kv_len
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no live keys: softmax of all -1e30 is uniform; zero them.
    alive = mask.any(-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return jnp.where(alive, out, 0.0).astype(q.dtype)


def _blk_scores(qblk, kr, i, bq, sk, *, causal, window, softcap, scale,
                q_offset=0):
    """(scores, mask) for one q block — shared by fwd and recompute-bwd.

    ``q_offset``: int, or ``(axis_name, s_local)`` for sequence-parallel
    callers — the offset is then ``axis_index(axis)·s_local``, resolved
    inside the shard_map body (static under SPMD).  K stays in its input
    dtype (bf16 on the real path) with fp32 accumulation — pre-casting
    K/V to fp32 doubled the dominant decode/train buffers."""
    s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kr,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if isinstance(q_offset, tuple):
        axis_name, s_local = q_offset
        q_offset = jax.lax.axis_index(axis_name) * s_local
    q_pos = q_offset + i * bq + jnp.arange(bq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((bq, sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    return jnp.where(mask[None, None], s, -1e30), mask


def _chunk_fwd(q, k, v, cfg):
    """Returns (out, lse).  lse is per-row logsumexp (B, Hq, Sq_padded)."""
    causal, window, softcap, scale, block_q, q_offset = cfg
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    bq = min(block_q, sq)
    npad = -(-sq // bq) * bq - sq
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, npad), (0, 0))) if npad else q
    nblk = qp.shape[2] // bq
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)

    def body(_, qi):
        qblk, i = qi
        s, mask = _blk_scores(qblk, kr, i, bq, sk, causal=causal,
                              window=window, softcap=softcap, scale=scale,
                              q_offset=q_offset)
        m = jnp.max(s, axis=-1, keepdims=True)
        m_safe = jnp.where(m <= -1e29, 0.0, m)
        pt = jnp.exp(s - m_safe)
        l = pt.sum(-1, keepdims=True)
        alive = mask.any(-1)[None, None, :, None]
        o = jnp.einsum("bhqk,bhkd->bhqd", pt, vr,
                       preferred_element_type=jnp.float32) \
            / jnp.maximum(l, 1e-30)
        lse = jnp.where(alive[..., 0], m_safe[..., 0] + jnp.log(
            jnp.maximum(l[..., 0], 1e-30)), -1e30)
        return None, (jnp.where(alive, o, 0.0).astype(q.dtype), lse)

    qs = jnp.moveaxis(qp.reshape(b, hq, nblk, bq, dh), 2, 0)
    _, (os_, lses) = jax.lax.scan(body, None, (qs, jnp.arange(nblk)))
    out = jnp.moveaxis(os_, 0, 2).reshape(b, hq, nblk * bq, dh)
    lse = jnp.moveaxis(lses, 0, 2).reshape(b, hq, nblk * bq)
    return out[:, :, :sq], lse[:, :, :sq]


def _chunk_bwd(cfg, res, dout):
    """Flash-style backward: recompute per-block probabilities from the
    saved logsumexp instead of saving S² probabilities — this is the
    memory behaviour of the real TPU kernel (and removes the dominant
    traffic term the dry-run measured on every train cell)."""
    causal, window, softcap, scale, block_q, q_offset = cfg
    q, k, v, out, lse = res
    b, hq, sq, dh = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    bq = min(block_q, sq)
    npad = -(-sq // bq) * bq - sq

    def pad_q(x):
        return jnp.pad(x, ((0, 0), (0, 0), (0, npad)) +
                       ((0, 0),) * (x.ndim - 3)) if npad else x

    qp, outp, doutp = pad_q(q), pad_q(out), pad_q(dout)
    lsep = pad_q(lse)
    nblk = qp.shape[2] // bq
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    # D_i = Σ_d dout·out per row — the softmax-jacobian diagonal term
    Dp = (doutp.astype(jnp.float32) * outp.astype(jnp.float32)).sum(-1)

    def body(carry, qi):
        dkr_acc, dvr_acc = carry
        qblk, doblk, dblk, lseblk, i = qi
        s, mask = _blk_scores(qblk, kr, i, bq, sk, causal=causal,
                              window=window, softcap=softcap, scale=scale,
                              q_offset=q_offset)
        p = jnp.exp(s - lseblk[..., None])            # normalised probs
        p = jnp.where(mask[None, None], p, 0.0)
        do = doblk.astype(jnp.float32)
        dvr_acc = dvr_acc + jnp.einsum("bhqk,bhqd->bhkd", p, do)
        dp = jnp.einsum("bhqd,bhkd->bhqk", do, vr,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - dblk[..., None])               # d(softcapped scores)
        if softcap > 0:
            # s here is post-cap; d(raw) = d(capped)·(1 - (s/c)²)
            ds = ds * (1.0 - jnp.square(
                jnp.where(mask[None, None], s, 0.0) / softcap))
        ds = jnp.where(mask[None, None], ds, 0.0)
        dq_blk = jnp.einsum("bhqk,bhkd->bhqd", ds, kr,
                            preferred_element_type=jnp.float32) * scale
        dkr_acc = dkr_acc + jnp.einsum(
            "bhqk,bhqd->bhkd", ds, qblk.astype(jnp.float32)) * scale
        return (dkr_acc, dvr_acc), dq_blk

    qs = jnp.moveaxis(qp.reshape(b, hq, nblk, bq, dh), 2, 0)
    dos = jnp.moveaxis(doutp.reshape(b, hq, nblk, bq, dh), 2, 0)
    Ds = jnp.moveaxis(Dp.reshape(b, hq, nblk, bq), 2, 0)
    lses = jnp.moveaxis(lsep.reshape(b, hq, nblk, bq), 2, 0)
    zero_k = jnp.zeros((b, hq, sk, dh), jnp.float32)
    (dkr, dvr), dqs = jax.lax.scan(
        body, (zero_k, zero_k), (qs, dos, Ds, lses, jnp.arange(nblk)))
    dq = jnp.moveaxis(dqs, 0, 2).reshape(b, hq, nblk * bq, dh)[:, :, :sq]
    # fold grouped-query heads back onto their kv head
    dk = dkr.reshape(b, hkv, group, sk, dh).sum(2)
    dv = dvr.reshape(b, hkv, group, sk, dh).sum(2)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _chunked_attention(q, k, v, cfg):
    return _chunk_fwd(q, k, v, cfg)[0]


def _chunked_attention_fwd(q, k, v, cfg):
    out, lse = _chunk_fwd(q, k, v, cfg)
    return out, (q, k, v, out, lse)


_chunked_attention.defvjp(_chunked_attention_fwd, _chunk_bwd)


def attention_chunked_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                          scale=None, block_q=512, q_offset=0):
    """Memory-bounded oracle: identical math to :func:`attention_ref`, but
    the query axis is processed in ``block_q`` chunks under ``lax.scan``
    (live score buffer (B, H, block_q, Sk), not (B, H, Sq, Sk)) **and**
    the backward recomputes block probabilities from a saved logsumexp
    (flash-attention backward) instead of saving them.

    This is the compute path the dry-run cells lower — it reproduces the
    memory behaviour of the real Pallas TPU kernel on any backend.
    ``q_offset`` shifts the causal/window masks for sequence-parallel
    callers whose local block holds global positions [offset, offset+Sq).
    """
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    off = (q_offset if isinstance(q_offset, tuple)
           else int(q_offset))                       # hashable → static
    cfg = (bool(causal), int(window), float(softcap), float(scale),
           int(block_q), off)
    return _chunked_attention(q, k, v, cfg)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

def mamba_scan_ref(x, dt, b, c, a, d):
    """Step-by-step lax.scan oracle.  Shapes as mamba_scan_pallas."""
    batch, L, d_inner = x.shape
    n = a.shape[-1]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[..., None] * a[None])          # (batch, d_inner, N)
        h = h * decay + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = (h * c_t[:, None, :]).sum(-1) + d[None] * x_t
        return h, y_t

    h0 = jnp.zeros((batch, d_inner, n), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final
