"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ``<name>.py`` contains the ``pl.pallas_call`` + ``BlockSpec``
implementation; :mod:`repro.kernels.ops` exposes jit'd wrappers with a
backend switch; :mod:`repro.kernels.ref` holds the pure-jnp oracles used by
tests and by the CPU/dry-run path.

Kernels:
  tdp_pointwise     generic targetDP site-kernel executor (the paper's core)
  lb_collision      D3Q19 binary-fluid LB collision (the paper's benchmark)
  rmsnorm           fused RMSNorm over the token lattice
  swiglu            fused SwiGLU / squared-ReLU activation
  flash_attention   blocked causal/windowed/softcapped attention
  mamba_scan        Mamba-1 selective-scan (chunked, state in VMEM)
"""
