"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ``<name>.py`` contains the ``pl.pallas_call`` + ``BlockSpec``
implementation; :mod:`repro.kernels.ops` exposes jit'd wrappers with a
backend switch; :mod:`repro.kernels.ref` holds the pure-jnp oracles used by
tests and by the CPU/dry-run path.

Kernels:
  tdp_pointwise     generic targetDP site-kernel executor (the paper's core)
  tdp_windowed      gather-free windowed stencil executor (SoA / AoSoA)
  lb_collision      D3Q19 binary-fluid LB collision (the paper's benchmark)
  lm                rmsnorm / gated activations / mamba scan as KernelSpecs
                    on the shared executors (ISSUE 10 — the beyond-the-
                    lattice proof; replaced the hand-written rmsnorm.py,
                    swiglu.py and mamba_scan.py modules)
  flash_attention   blocked causal/windowed/softcapped attention
"""
