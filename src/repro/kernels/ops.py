"""jit'd public wrappers for the kernel layer, Target-dispatched.

Every op takes a ``target=`` — a :class:`repro.core.Target` descriptor
(executor name, VVL, interpret flag, per-op ``tuning`` knobs) — the
paper's build switch as an exchangeable value.  Backend-name strings are
accepted only through the :func:`repro.core.as_target` coercion helper
(``target="pallas"`` works; so does the legacy ``backend="pallas"``
kwarg, which coerces through the same helper).  Builtin executor names:

  * ``"xla"``              — pure-jnp oracle path (CPU, dry-run, debugging)
  * ``"pallas"``           — Pallas TPU kernels (the deployment target)
  * ``"pallas_interpret"`` — Pallas semantics executed on CPU (validation)
  * ``"pallas_windowed"``  — gather-free windowed stencil executor
    (*stencil launches only*: ops that dispatch through ``tdp.launch``
    accept it — e.g. :func:`lb_fused_step` — while pointwise ops with
    hand-written Pallas kernels take the three builtins above)

Every wrapper takes the same arguments on every target — single source at
the call site, exactly the paper's portability contract.  Per-op block
sizes may ride in ``Target.tuning`` (e.g. ``Target("pallas",
tuning={"block_q": 64})``) instead of being threaded by hand.

The LM pointwise ops (``rmsnorm``, ``gated_act``, ``mamba_scan``) are
**ported onto the core** (:mod:`repro.kernels.lm`): they declare a
:class:`~repro.core.KernelSpec` and dispatch through ``tdp.launch`` on
*every* backend — including ``"xla"`` — so the shared executors, the
``Target.layout`` AoSoA axis, and ``tdp.autotune`` all apply with zero
op-specific executor code.  ``flash_attention`` and ``lb_collision``
keep their hand-written dispatch (attention's softmax streaming does
not decompose into independent sites).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import Target, as_target
from repro.core.api import launch as _tdp_launch

from . import flash_attention as _fa
from . import lb_collision as _lb
from . import lm as _lm
from . import ref as _ref

VALID_BACKENDS = ("xla", "pallas", "pallas_interpret")

#: ``Target.tuning`` keys each op consults on its Pallas path — the
#: op-layer half of the registry's ``executor_tunables`` contract
#: (``register_executor(..., tunables=...)``): a tuned Target produced
#: by ``tdp.autotune`` rides these knobs into the hand-written kernels
#: with no per-op plumbing at the call site.  The ops ported onto
#: ``tdp.launch`` (rmsnorm / gated_act / mamba_scan — see
#: :mod:`repro.kernels.lm`) have no hand-written knobs left: their
#: tunables are the Target-level ``vvl`` / ``layout`` axes the shared
#: executors and ``tdp.autotune`` already own.
TUNABLES: dict[str, tuple[str, ...]] = {
    "flash_attention": ("block_q", "block_k"),
}


def op_tunables(op: str) -> tuple[str, ...]:
    """The ``Target.tuning`` keys ``ops.<op>`` consults (empty for ops
    whose only knob is the VVL)."""
    return TUNABLES.get(op, ())


def op_target(target: Target | str | None = None,
              backend: str | None = None,
              vvl: int | None = None, *,
              default_vvl: int | None = None) -> Target:
    """Resolve an op's target from the accepted spellings.

    ``target=`` (Target or string, via :func:`as_target`) is the first-
    class form; ``backend=``/``vvl=`` are the legacy kwargs.  Passing both
    ``target`` and ``backend`` is an error.  ``default_vvl`` fills the
    op's historical VVL default when neither the target nor ``vvl`` set
    one.
    """
    if target is not None and backend is not None:
        raise ValueError("pass either target= or the legacy backend=, "
                         "not both")
    t = as_target(target if target is not None else backend, vvl=vvl)
    if t.vvl is None and default_vvl is not None:
        t = t.with_(vvl=default_vvl)
    return t


def _check_pallas(t: Target) -> bool:
    """True → dispatch to the op's hand-written Pallas kernel."""
    if t.executor not in VALID_BACKENDS:
        raise ValueError(
            f"this op only supports the builtin executors "
            f"{VALID_BACKENDS}, got {t.executor!r}")
    return t.backend != "xla"


def lb_collision(f, g, phi, gradphi, del2phi, *, target=None, backend=None,
                 vvl=None, **phys):
    t = op_target(target, backend, vvl, default_vvl=128)
    if _check_pallas(t):
        return _lb.lb_collision_pallas(f, g, phi, gradphi, del2phi,
                                       vvl=t.vvl, interpret=t.interpret,
                                       **phys)
    return _ref.lb_collision_ref(f, g, phi, gradphi, del2phi, **phys)


def lb_fused_step(f, g, *, grid_shape, halo=0, mode="one_launch",
                  target=None, backend=None, vvl=None, **phys):
    """One fused stream→gradient→collide step over SoA arrays (19, nsites).

    ``f``/``g`` are *pre-stream* populations over ``grid_shape`` (extended
    by ``halo`` ghost planes per dimension where non-zero — the sharded
    path; 0 → fully periodic).  Returns the next pre-stream state over the
    interior.  Single source across targets via ``tdp.launch``.

    ``mode`` selects the fusion strategy (both bit-for-bit the same math):

    * ``"one_launch"`` — the whole step as one stencil launch over the
      radius-2 composed g-neighbourhood (``STENCIL_FUSED_G``, 57·19
      gathered rows).
    * ``"two_launch"`` — ROADMAP stencil-memory stage (a): a first launch
      streams g's moments into a **1-component** φ intermediate, then a
      second launch (radius-1 stencils only) streams/collides reading φ
      through the 7-point gradient star — the gathered-stack footprint
      drops from ``(19 + 57)·19`` rows to ``2·19·19 + 7`` rows and no
      ``(noffsets, ncomp, nsites)`` g-stack is ever materialised.

    Both strategies are declared as :class:`repro.core.Program` step
    graphs (:mod:`repro.lb.programs`); this wrapper runs one eager
    :meth:`Program.execute` step with the caller-managed ghost planes
    (two_launch's φ ghost ring is recomputed locally by the program's
    halo schedule — no extra communication for the intermediate).
    """
    from repro.core.api import _normalize_halo
    from repro.lb import programs as _lbp   # lazy: avoids kernels↔lb cycle

    t = op_target(target, backend, vvl, default_vvl=128)
    shape = tuple(int(s) for s in grid_shape)
    h = _normalize_halo(halo, len(shape))
    prog = _lbp.fused_program(
        mode, _lbp.collision_consts(dtype=f.dtype, **phys))
    ext = tuple(s + 2 * hh for s, hh in zip(shape, h))
    out = prog.execute(t, {"f": f.reshape(_lb.NVEL, *ext),
                           "g": g.reshape(_lb.NVEL, *ext)},
                       grid_shape=shape, halo=h)
    return (out["f"].reshape(_lb.NVEL, -1),
            out["g"].reshape(_lb.NVEL, -1))


def rmsnorm(x, weight, *, target=None, backend=None, vvl=None, eps=1e-6,
            scale_offset=0.0):
    """RMSNorm of ``x: (tokens, d)`` with ``weight: (d,)`` through
    ``tdp.launch`` — site = token, features on the component axis
    (:func:`repro.kernels.lm.rmsnorm_spec`).  ``scale_offset=1.0`` gives
    the Gemma convention ``x · rms · (1 + w)``.  All executors, layouts
    and VVLs of the shared registry apply; gradients flow through
    ``weight`` (a dynamic array const)."""
    t = op_target(target, backend, vvl, default_vvl=256)
    _check_pallas(t)
    spec = _lm.rmsnorm_spec(int(x.shape[-1]))
    out = _tdp_launch(spec, t, x.T,
                      consts={"weight": weight, "eps": float(eps),
                              "scale_offset": float(scale_offset)})
    return out.T


def gated_act(u, v=None, *, kind="swiglu", target=None, backend=None,
              vvl=None, block_f=None):
    """Gated activation ``act(u) · v`` (or plain ``act(u)`` when ``v`` is
    ``None``) through ``tdp.launch`` — site = flattened element
    (:func:`repro.kernels.lm.gated_act_spec`).  ``block_f`` is accepted
    for call-site compatibility with the retired hand-written kernel;
    the shared executors chunk by the Target's ``vvl`` instead."""
    del block_f
    t = op_target(target, backend, vvl, default_vvl=256)
    _check_pallas(t)
    spec = _lm.gated_act_spec(str(kind), v is not None)
    args = (u.reshape(1, -1),) if v is None else (u.reshape(1, -1),
                                                  v.reshape(1, -1))
    return _tdp_launch(spec, t, *args).reshape(u.shape)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, target=None, backend=None, block_q=None,
                    block_k=None, impl="ref", q_offset=0):
    """``impl`` selects the xla-target oracle: "ref" (whole-S² scores) or
    "chunked" (q-block scan + flash backward, memory-bounded — the
    dry-run path).  ``q_offset``: global position of q[...,0,:] for
    sequence-parallel callers (chunked impl only)."""
    t = op_target(target, backend)
    block_q = block_q if block_q is not None else t.tune("block_q", 128)
    block_k = block_k if block_k is not None else t.tune("block_k", 128)
    if _check_pallas(t):
        if q_offset:
            raise NotImplementedError("q_offset on the Pallas path is a "
                                      "grid-offset BlockSpec change (TPU)")
        return _fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, block_k=block_k,
            interpret=t.interpret)
    if impl == "chunked":
        return _ref.attention_chunked_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, q_offset=q_offset)
    if q_offset:
        raise NotImplementedError("q_offset requires impl='chunked'")
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)


def mamba_scan(x, dt, b, c, a, d, *, target=None, backend=None,
               block_d=None, block_t=None, vvl=None):
    """Selective state-space scan through ``tdp.launch`` — site =
    channel, time on the component axis
    (:func:`repro.kernels.lm.mamba_scan_spec`).

    Shapes: ``x``/``dt`` ``(batch, L, d_inner)``, ``b``/``c``
    ``(batch, L, N)``, ``a`` ``(d_inner, N)``, ``d`` ``(d_inner,)``.
    Returns ``(y (batch, L, d_inner), h_final (batch, d_inner, N))``.

    ``block_d`` (the retired hand-written kernel's channel block) maps
    onto the Target's ``vvl`` — both mean "channels per chunk";
    ``block_t`` is accepted and ignored (the recurrence is sequential
    in time on every executor)."""
    del block_t
    t = op_target(target, backend, vvl,
                  default_vvl=int(block_d) if block_d is not None else 128)
    _check_pallas(t)
    batch, length, d_inner = (int(s) for s in x.shape)
    nstate = int(a.shape[-1])
    spec = _lm.mamba_scan_spec(length, nstate)
    a_soa = a.T                                    # (N, d_inner)
    d_soa = d.reshape(1, d_inner)
    ys, hs = [], []
    for i in range(batch):
        y_i, h_i = _tdp_launch(spec, t, x[i], dt[i], a_soa, d_soa,
                               consts={"b": b[i], "c": c[i]})
        ys.append(y_i)
        hs.append(h_i.T)                           # (d_inner, N)
    return jnp.stack(ys), jnp.stack(hs)
