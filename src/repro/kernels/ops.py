"""jit'd public wrappers for the kernel layer, with a backend switch.

``backend`` values (the paper's build switch, runtime-selectable):
  * ``"xla"``              — pure-jnp oracle path (CPU, dry-run, debugging)
  * ``"pallas"``           — Pallas TPU kernels (the deployment target)
  * ``"pallas_interpret"`` — Pallas semantics executed on CPU (validation)

Every wrapper takes the same arguments on every backend — single source at
the call site, exactly the paper's portability contract.
"""
from __future__ import annotations

import jax

from . import flash_attention as _fa
from . import lb_collision as _lb
from . import mamba_scan as _ms
from . import ref as _ref
from . import rmsnorm as _rn
from . import swiglu as _sg

VALID_BACKENDS = ("xla", "pallas", "pallas_interpret")


def _check(backend: str) -> bool:
    if backend not in VALID_BACKENDS:
        raise ValueError(f"backend must be one of {VALID_BACKENDS}, got {backend!r}")
    return backend != "xla"


def _interp(backend: str) -> bool:
    return backend == "pallas_interpret"


def lb_collision(f, g, phi, gradphi, del2phi, *, backend="xla", vvl=128, **phys):
    if _check(backend):
        return _lb.lb_collision_pallas(f, g, phi, gradphi, del2phi, vvl=vvl,
                                       interpret=_interp(backend), **phys)
    return _ref.lb_collision_ref(f, g, phi, gradphi, del2phi, **phys)


def lb_fused_step(f, g, *, grid_shape, halo=0, backend="xla", vvl=128,
                  **phys):
    """One fused stream→gradient→collide step over SoA arrays (19, nsites).

    ``f``/``g`` are *pre-stream* populations over ``grid_shape`` (extended
    by ``halo`` ghost planes per dimension where non-zero — the sharded
    path; 0 → fully periodic).  Returns the next pre-stream state over the
    interior.  Single source across backends via ``launch_stencil``.
    """
    from repro.core import Lattice, TargetConst, launch_stencil
    from repro.lb import stencil as _lbst   # lazy: avoids kernels↔lb cycle

    _check(backend)
    lat = Lattice(tuple(int(s) for s in grid_shape))
    consts = dict(w=TargetConst(_lb.WEIGHTS.astype(f.dtype)),
                  c=TargetConst(_lb.CV.astype(f.dtype)), **phys)
    return launch_stencil(
        _lbst.fused_site_kernel, lat, [f, g],
        stencil=(_lbst.STENCIL_D3Q19_PULL, _lbst.STENCIL_FUSED_G),
        out_ncomp=(_lb.NVEL, _lb.NVEL), consts=consts, vvl=vvl,
        backend=backend, halo=halo)


def rmsnorm(x, weight, *, backend="xla", vvl=256, eps=1e-6, scale_offset=0.0):
    if _check(backend):
        return _rn.rmsnorm_pallas(x, weight, vvl=vvl, eps=eps,
                                  scale_offset=scale_offset,
                                  interpret=_interp(backend))
    return _ref.rmsnorm_ref(x, weight, eps=eps, scale_offset=scale_offset)


def gated_act(u, v=None, *, kind="swiglu", backend="xla", vvl=256, block_f=512):
    if _check(backend):
        return _sg.gated_act_pallas(u, v, kind=kind, vvl=vvl, block_f=block_f,
                                    interpret=_interp(backend))
    return _ref.gated_act_ref(u, v, kind=kind)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, backend="xla", block_q=128, block_k=128,
                    impl="ref", q_offset=0):
    """``impl`` selects the xla-backend oracle: "ref" (whole-S² scores) or
    "chunked" (q-block scan + flash backward, memory-bounded — the
    dry-run path).  ``q_offset``: global position of q[...,0,:] for
    sequence-parallel callers (chunked impl only)."""
    if _check(backend):
        if q_offset:
            raise NotImplementedError("q_offset on the Pallas path is a "
                                      "grid-offset BlockSpec change (TPU)")
        return _fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, block_k=block_k,
            interpret=_interp(backend))
    if impl == "chunked":
        return _ref.attention_chunked_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, q_offset=q_offset)
    if q_offset:
        raise NotImplementedError("q_offset requires impl='chunked'")
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)


def mamba_scan(x, dt, b, c, a, d, *, backend="xla", block_d=128, block_t=128):
    if _check(backend):
        return _ms.mamba_scan_pallas(x, dt, b, c, a, d, block_d=block_d,
                                     block_t=block_t,
                                     interpret=_interp(backend))
    return _ref.mamba_scan_ref(x, dt, b, c, a, d)
