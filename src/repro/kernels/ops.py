"""jit'd public wrappers for the kernel layer, Target-dispatched.

Every op takes a ``target=`` — a :class:`repro.core.Target` descriptor
(executor name, VVL, interpret flag, per-op ``tuning`` knobs) — the
paper's build switch as an exchangeable value.  Backend-name strings are
accepted only through the :func:`repro.core.as_target` coercion helper
(``target="pallas"`` works; so does the legacy ``backend="pallas"``
kwarg, which coerces through the same helper).  Builtin executor names:

  * ``"xla"``              — pure-jnp oracle path (CPU, dry-run, debugging)
  * ``"pallas"``           — Pallas TPU kernels (the deployment target)
  * ``"pallas_interpret"`` — Pallas semantics executed on CPU (validation)
  * ``"pallas_windowed"``  — gather-free windowed stencil executor
    (*stencil launches only*: ops that dispatch through ``tdp.launch``
    accept it — e.g. :func:`lb_fused_step` — while pointwise ops with
    hand-written Pallas kernels take the three builtins above)

Every wrapper takes the same arguments on every target — single source at
the call site, exactly the paper's portability contract.  Per-op block
sizes may ride in ``Target.tuning`` (e.g. ``Target("pallas",
tuning={"block_f": 512})``) instead of being threaded by hand.
"""
from __future__ import annotations

from repro.core import Target, as_target

from . import flash_attention as _fa
from . import lb_collision as _lb
from . import mamba_scan as _ms
from . import ref as _ref
from . import rmsnorm as _rn
from . import swiglu as _sg

VALID_BACKENDS = ("xla", "pallas", "pallas_interpret")

#: ``Target.tuning`` keys each op consults on its Pallas path — the
#: op-layer half of the registry's ``executor_tunables`` contract
#: (``register_executor(..., tunables=...)``): a tuned Target produced
#: by ``tdp.autotune`` rides these knobs into the hand-written kernels
#: with no per-op plumbing at the call site.
TUNABLES: dict[str, tuple[str, ...]] = {
    "gated_act": ("block_f",),
    "flash_attention": ("block_q", "block_k"),
    "mamba_scan": ("block_d", "block_t"),
}


def op_tunables(op: str) -> tuple[str, ...]:
    """The ``Target.tuning`` keys ``ops.<op>`` consults (empty for ops
    whose only knob is the VVL)."""
    return TUNABLES.get(op, ())


def op_target(target: Target | str | None = None,
              backend: str | None = None,
              vvl: int | None = None, *,
              default_vvl: int | None = None) -> Target:
    """Resolve an op's target from the accepted spellings.

    ``target=`` (Target or string, via :func:`as_target`) is the first-
    class form; ``backend=``/``vvl=`` are the legacy kwargs.  Passing both
    ``target`` and ``backend`` is an error.  ``default_vvl`` fills the
    op's historical VVL default when neither the target nor ``vvl`` set
    one.
    """
    if target is not None and backend is not None:
        raise ValueError("pass either target= or the legacy backend=, "
                         "not both")
    t = as_target(target if target is not None else backend, vvl=vvl)
    if t.vvl is None and default_vvl is not None:
        t = t.with_(vvl=default_vvl)
    return t


def _check_pallas(t: Target) -> bool:
    """True → dispatch to the op's hand-written Pallas kernel."""
    if t.executor not in VALID_BACKENDS:
        raise ValueError(
            f"this op only supports the builtin executors "
            f"{VALID_BACKENDS}, got {t.executor!r}")
    return t.backend != "xla"


def lb_collision(f, g, phi, gradphi, del2phi, *, target=None, backend=None,
                 vvl=None, **phys):
    t = op_target(target, backend, vvl, default_vvl=128)
    if _check_pallas(t):
        return _lb.lb_collision_pallas(f, g, phi, gradphi, del2phi,
                                       vvl=t.vvl, interpret=t.interpret,
                                       **phys)
    return _ref.lb_collision_ref(f, g, phi, gradphi, del2phi, **phys)


def lb_fused_step(f, g, *, grid_shape, halo=0, mode="one_launch",
                  target=None, backend=None, vvl=None, **phys):
    """One fused stream→gradient→collide step over SoA arrays (19, nsites).

    ``f``/``g`` are *pre-stream* populations over ``grid_shape`` (extended
    by ``halo`` ghost planes per dimension where non-zero — the sharded
    path; 0 → fully periodic).  Returns the next pre-stream state over the
    interior.  Single source across targets via ``tdp.launch``.

    ``mode`` selects the fusion strategy (both bit-for-bit the same math):

    * ``"one_launch"`` — the whole step as one stencil launch over the
      radius-2 composed g-neighbourhood (``STENCIL_FUSED_G``, 57·19
      gathered rows).
    * ``"two_launch"`` — ROADMAP stencil-memory stage (a): a first launch
      streams g's moments into a **1-component** φ intermediate, then a
      second launch (radius-1 stencils only) streams/collides reading φ
      through the 7-point gradient star — the gathered-stack footprint
      drops from ``(19 + 57)·19`` rows to ``2·19·19 + 7`` rows and no
      ``(noffsets, ncomp, nsites)`` g-stack is ever materialised.

    Both strategies are declared as :class:`repro.core.Program` step
    graphs (:mod:`repro.lb.programs`); this wrapper runs one eager
    :meth:`Program.execute` step with the caller-managed ghost planes
    (two_launch's φ ghost ring is recomputed locally by the program's
    halo schedule — no extra communication for the intermediate).
    """
    from repro.core.api import _normalize_halo
    from repro.lb import programs as _lbp   # lazy: avoids kernels↔lb cycle

    t = op_target(target, backend, vvl, default_vvl=128)
    shape = tuple(int(s) for s in grid_shape)
    h = _normalize_halo(halo, len(shape))
    prog = _lbp.fused_program(
        mode, _lbp.collision_consts(dtype=f.dtype, **phys))
    ext = tuple(s + 2 * hh for s, hh in zip(shape, h))
    out = prog.execute(t, {"f": f.reshape(_lb.NVEL, *ext),
                           "g": g.reshape(_lb.NVEL, *ext)},
                       grid_shape=shape, halo=h)
    return (out["f"].reshape(_lb.NVEL, -1),
            out["g"].reshape(_lb.NVEL, -1))


def rmsnorm(x, weight, *, target=None, backend=None, vvl=None, eps=1e-6,
            scale_offset=0.0):
    t = op_target(target, backend, vvl, default_vvl=256)
    if _check_pallas(t):
        return _rn.rmsnorm_pallas(x, weight, vvl=t.vvl, eps=eps,
                                  scale_offset=scale_offset,
                                  interpret=t.interpret)
    return _ref.rmsnorm_ref(x, weight, eps=eps, scale_offset=scale_offset)


def gated_act(u, v=None, *, kind="swiglu", target=None, backend=None,
              vvl=None, block_f=None):
    t = op_target(target, backend, vvl, default_vvl=256)
    if _check_pallas(t):
        return _sg.gated_act_pallas(
            u, v, kind=kind, vvl=t.vvl,
            block_f=block_f if block_f is not None
            else t.tune("block_f", 512),
            interpret=t.interpret)
    return _ref.gated_act_ref(u, v, kind=kind)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=None, target=None, backend=None, block_q=None,
                    block_k=None, impl="ref", q_offset=0):
    """``impl`` selects the xla-target oracle: "ref" (whole-S² scores) or
    "chunked" (q-block scan + flash backward, memory-bounded — the
    dry-run path).  ``q_offset``: global position of q[...,0,:] for
    sequence-parallel callers (chunked impl only)."""
    t = op_target(target, backend)
    block_q = block_q if block_q is not None else t.tune("block_q", 128)
    block_k = block_k if block_k is not None else t.tune("block_k", 128)
    if _check_pallas(t):
        if q_offset:
            raise NotImplementedError("q_offset on the Pallas path is a "
                                      "grid-offset BlockSpec change (TPU)")
        return _fa.flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, block_k=block_k,
            interpret=t.interpret)
    if impl == "chunked":
        return _ref.attention_chunked_ref(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, block_q=block_q, q_offset=q_offset)
    if q_offset:
        raise NotImplementedError("q_offset requires impl='chunked'")
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)


def mamba_scan(x, dt, b, c, a, d, *, target=None, backend=None,
               block_d=None, block_t=None):
    t = op_target(target, backend)
    if _check_pallas(t):
        return _ms.mamba_scan_pallas(
            x, dt, b, c, a, d,
            block_d=block_d if block_d is not None
            else t.tune("block_d", 128),
            block_t=block_t if block_t is not None
            else t.tune("block_t", 128),
            interpret=t.interpret)
    return _ref.mamba_scan_ref(x, dt, b, c, a, d)
