"""Pallas executor for targetDP site kernels — the "CUDA implementation".

The paper's CUDA build of the macros assigns each thread a VVL-sized chunk of
sites (`TARGET_TLP`) and loops the innermost op over the chunk
(`TARGET_ILP`).  The TPU-native equivalent:

* the ``pallas_call`` **grid** plays the role of the CUDA thread grid: one
  grid step per VVL-chunk of sites;
* each input/output block is an explicit VMEM tile — ``(ncomp, VVL)`` for
  pointwise fields, ``(noffsets, ncomp, VVL)`` for stencil fields (the
  centre row plus one halo row per neighbour offset) — sites on the
  **lane** axis (SoA!), components on sublanes, so every jnp op inside the
  kernel body vectorises over lanes exactly as the strip-mined ILP loop
  vectorises over AVX lanes;
* ``VVL`` is the tunable block extent.  Multiples of 128 fill lane rows;
  larger values amortise HBM→VMEM latency (the paper's "m>1 can be faster"
  observation) at the cost of VMEM footprint:
  ``vmem_bytes ≈ sum_i(noffsets_i * ncomp_i * VVL * itemsize)`` which must
  stay ≲ 16 MiB (:func:`vmem_bytes_estimate`).

:func:`pallas_execute` is the registry executor behind
``Target("pallas")`` / ``Target("pallas", interpret=True)`` — registered
by :mod:`repro.core.api`, dispatched through
:func:`repro.core.registry.get_executor`.  ``interpret=True`` runs the
same kernel body on CPU for validation — this container has no TPU; tests
exercise the Pallas path through interpret mode and assert allclose
against the jnp executor (the "C implementation").
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def vmem_bytes_estimate(in_ncomp: Sequence[int], out_ncomp: Sequence[int],
                        vvl: int, in_noffsets: Sequence[int] | None = None,
                        itemsize: int = 4) -> int:
    """Static VMEM footprint of one grid step (inputs + outputs).

    ``in_noffsets[i]``: neighbour count of input i — 1 (default) for
    pointwise inputs, ``stencil.noffsets`` for stencil inputs (the halo
    rows each add a block row; see docs/stencil.md).  The stencil module
    (:mod:`repro.kernels.tdp_stencil`) re-exports this single rule.
    """
    if in_noffsets is None:
        in_noffsets = [1] * len(in_ncomp)
    in_rows = sum(int(o) * int(c) for o, c in zip(in_noffsets, in_ncomp))
    return (in_rows + sum(out_ncomp)) * vvl * itemsize


def _canonicalize_consts(consts: dict):
    """Split TARGET_CONST parameters into literal scalars (closed over — XLA
    inlines them) and array constants (side inputs: Pallas kernels may not
    capture traced values, so small read-only arrays ride along as full-block
    VMEM operands — the TPU analogue of ``__constant__`` memory)."""
    scalars, arrays = {}, {}
    for k, v in consts.items():
        if isinstance(v, (int, float, bool)):
            scalars[k] = v
        else:
            arr = jnp.asarray(v)
            orig_shape = arr.shape
            if arr.ndim == 0:
                arr2 = arr.reshape(1, 1)
            elif arr.ndim == 1:
                arr2 = arr.reshape(1, -1)
            else:
                arr2 = arr.reshape(arr.shape[0], -1)
            arrays[k] = (orig_shape, arr2)
    return scalars, arrays


def _run_pallas(kernel: Callable, vvl: int, with_site_index: bool,
                out_ncomp: tuple[int, ...], consts: dict, interpret: bool,
                gathered: Sequence[jax.Array], name: str,
                layout: str = "soa"):
    """Map ``kernel`` over VVL site chunks with explicit VMEM blocks.

    ``gathered``: per input, ``(noffsets, ncomp, n)`` for stencil fields or
    ``(ncomp, n)`` for pointwise ones — the output of the shared gather
    prologue in :mod:`repro.core.api`.  Grid = one step per VVL chunk.

    ``layout="aosoa"``: operands are reordered to the paper's AoSoA
    ``[site-block][component][lane]`` ordering
    (:func:`repro.core.layout.soa_to_aosoa`) and each grid step DMAs one
    *contiguous* block — for SoA the per-chunk BlockSpec strides across
    ``ncomp`` separate rows of HBM, for AoSoA it is a single dense tile.
    The kernel body still sees ``(ncomp, VVL)`` / ``(noffsets, ncomp,
    VVL)`` chunks with identical contents, so site kernels stay
    single-source and outputs are bit-identical across layouts.
    """
    from repro.core.api import pad_sites
    from repro.core.layout import aosoa_to_soa, soa_to_aosoa

    n = gathered[0].shape[-1]
    n_pad = -(-n // vvl) * vvl
    nchunks = n_pad // vvl
    dtype = gathered[0].dtype
    aosoa = layout == "aosoa"

    if aosoa:
        padded = tuple(soa_to_aosoa(x, vvl) for x in gathered)
    else:
        padded = tuple(pad_sites(x, vvl) for x in gathered)
    scalar_consts, array_consts = _canonicalize_consts(consts)
    const_names = list(array_consts)
    const_vals = [array_consts[k][1] for k in const_names]

    def body(*refs):
        in_refs = refs[:len(padded)]
        cref0 = len(padded)
        const_refs = refs[cref0:cref0 + len(const_names)]
        out_refs = refs[cref0 + len(const_names):]
        if aosoa:
            # (1, ..., ncomp, vvl) block → the site-kernel chunk shape
            chunks = [r[...].reshape(r.shape[1:]) for r in in_refs]
        else:
            chunks = [r[...] for r in in_refs]
        if with_site_index:
            # global site index of each lane in this chunk (TARGET_ILP offset
            # + baseIndex), computed from the grid position.
            base = pl.program_id(0) * vvl
            chunks.append(base + jax.lax.iota(jnp.int32, vvl))
        kw = dict(scalar_consts)
        for cname, cref in zip(const_names, const_refs):
            orig_shape, _ = array_consts[cname]
            kw[cname] = cref[...].reshape(orig_shape)
        vals = kernel(*chunks, **kw)
        vals = (vals,) if not isinstance(vals, tuple) else vals
        for r, v in zip(out_refs, vals):
            r[...] = v.reshape(r.shape).astype(r.dtype)

    def site_spec(x):
        if aosoa:
            return pl.BlockSpec((1, *x.shape[1:]),
                                lambda i: (i,) + (0,) * (x.ndim - 1))
        if x.ndim == 3:       # (noffsets, ncomp, vvl) halo block
            return pl.BlockSpec((x.shape[0], x.shape[1], vvl),
                                lambda i: (0, 0, i))
        return pl.BlockSpec((x.shape[0], vvl), lambda i: (0, i))

    in_specs = [site_spec(x) for x in padded] + [
        pl.BlockSpec(c.shape, lambda i: (0, 0)) for c in const_vals
    ]
    if aosoa:
        out_specs = [pl.BlockSpec((1, c, vvl), lambda i: (i, 0, 0))
                     for c in out_ncomp]
        out_shape = [jax.ShapeDtypeStruct((nchunks, c, vvl), dtype)
                     for c in out_ncomp]
    else:
        out_specs = [pl.BlockSpec((c, vvl), lambda i: (0, i))
                     for c in out_ncomp]
        out_shape = [jax.ShapeDtypeStruct((c, n_pad), dtype)
                     for c in out_ncomp]

    outs = pl.pallas_call(
        body,
        grid=(nchunks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        name=name,
    )(*padded, *const_vals)

    if aosoa:
        return tuple(aosoa_to_soa(o, n) for o in outs)
    return tuple(o[:, :n] for o in outs)


def pallas_execute(plan, gathered: Sequence[jax.Array]):
    """Registry executor entry (see :mod:`repro.core.registry` for the
    ``executor(plan, gathered)`` contract)."""
    return _run_pallas(
        plan.kernel, plan.vvl, plan.with_site_index, tuple(plan.out_ncomp),
        plan.consts, plan.interpret, gathered,
        name=f"tdp_{plan.name}_vvl{plan.vvl}_{plan.layout}",
        layout=plan.layout)


def pallas_launch(kernel: Callable, vvl: int, with_site_index: bool,
                  out_ncomp: tuple[int, ...], consts: dict, interpret: bool,
                  inputs: tuple[jax.Array, ...]):
    """Pre-registry entry point, kept for direct callers."""
    outs = _run_pallas(
        kernel, vvl, with_site_index, tuple(out_ncomp), consts, interpret,
        inputs, name=f"tdp_{getattr(kernel, '__name__', 'site_kernel')}"
                     f"_vvl{vvl}")
    return outs[0] if len(outs) == 1 else outs
