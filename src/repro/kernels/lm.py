"""LM kernels on the targetDP core — rmsnorm, gated activations, mamba.

These used to be three hand-written Pallas modules (``rmsnorm.py``,
``swiglu.py``, ``mamba_scan.py``) with their own grids, BlockSpecs,
padding and dispatch — a parallel executor stack the Target/layout/
autotune machinery couldn't reach.  ISSUE 10 ports them onto
:class:`~repro.core.KernelSpec` + :func:`repro.core.api.launch`, which
proves the abstraction *beyond the lattice*: the "site" is whatever
axis the op is independent over, and the single-source kernel body
then rides every executor, layout (``soa``/``aosoa``), VVL, and
``tdp.autotune`` candidate space for free.

Site-axis choices (the targetDP view of each op):

* **rmsnorm** — site = token.  The SoA field is ``(d, tokens)`` (the
  transpose of the usual ``(tokens, d)`` activation), so the per-token
  feature reduction is a reduction over *components* inside one chunk;
  the weight rides as a dynamic array const (gradients flow).
* **gated activations** — site = flattened element.  Pure pointwise:
  ``(tokens, d_ff)`` flattens to one 1-component field of
  ``tokens·d_ff`` sites.
* **mamba selective scan** — site = channel (``d_inner``).  The scan
  is sequential in time but independent per channel, so time lives on
  the *component* axis (``(L, channels)`` fields), the recurrence is a
  ``lax.scan`` inside the kernel body, and chunking/layout apply to
  the channel axis.  ``B``/``C`` have no channel axis — dynamic array
  consts.

Specs are built per shape signature and cached (``lru_cache``) so the
launch-plan cache keys stay stable across calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import FieldSpec, KernelSpec

#: gated_act kinds (same table as repro.kernels.ref.gated_act_ref)
GATED_KINDS = ("swiglu", "silu", "geglu", "gelu", "relu2")


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def rmsnorm_spec(d: int) -> KernelSpec:
    """RMSNorm over ``(d, tokens)`` SoA: per-site (= per-token) feature
    reduction across the ``d`` components of one chunk."""

    def rmsnorm_site(x, *, weight, eps, scale_offset):
        xf = x.astype(jnp.float32)                        # (d, V)
        inv = jax.lax.rsqrt(
            jnp.mean(xf * xf, axis=0, keepdims=True) + eps)
        w = weight.astype(jnp.float32).reshape(d, 1) + scale_offset
        return (xf * inv * w).astype(x.dtype)

    return KernelSpec(rmsnorm_site, fields=(FieldSpec(d, name="x"),),
                      out=(d,), consts=("weight", "eps", "scale_offset"),
                      name=f"rmsnorm_d{d}")


# ---------------------------------------------------------------------------
# gated activations
# ---------------------------------------------------------------------------

def _act(kind: str, uf):
    if kind in ("swiglu", "silu"):
        return uf * jax.nn.sigmoid(uf)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(uf, approximate=True)
    if kind == "relu2":
        r = jnp.maximum(uf, 0.0)
        return r * r
    raise ValueError(kind)


@functools.lru_cache(maxsize=None)
def gated_act_spec(kind: str, gated: bool) -> KernelSpec:
    """Elementwise activation (optionally × a gate field) over flattened
    1-component sites."""
    if kind not in GATED_KINDS:
        raise ValueError(f"kind must be one of {GATED_KINDS}, got {kind!r}")

    if gated:
        def gated_site(u, v):
            return (_act(kind, u.astype(jnp.float32))
                    * v.astype(jnp.float32)).astype(u.dtype)
        fields = (FieldSpec(1, name="u"), FieldSpec(1, name="v"))
        fn = gated_site
    else:
        def act_site(u):
            return _act(kind, u.astype(jnp.float32)).astype(u.dtype)
        fields = (FieldSpec(1, name="u"),)
        fn = act_site

    return KernelSpec(fn, fields=fields, out=(1,),
                      name=f"gated_{kind}{'' if gated else '_ungated'}")


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def mamba_scan_spec(length: int, nstate: int) -> KernelSpec:
    """Selective state-space scan, site = channel.

    Chunk shapes inside the body: ``x``/``dt`` ``(L, V)``, ``a``
    ``(N, V)``, ``d`` ``(1, V)``; ``b``/``c`` are ``(L, N)`` dynamic
    array consts (no channel axis).  Outputs ``y (L, V)`` and the final
    state ``h (N, V)`` — the recurrence itself is a ``lax.scan`` over
    the component (time) axis, so every executor/layout runs the same
    sequential-in-time, parallel-in-channel schedule.
    """

    def mamba_site(x, dt, a, d, *, b, c):
        xf = x.astype(jnp.float32)
        dtf = dt.astype(jnp.float32)
        af = a.astype(jnp.float32)
        df = d.astype(jnp.float32)
        bf = b.astype(jnp.float32)
        cf = c.astype(jnp.float32)

        def step(h, inp):
            x_t, dt_t, b_t, c_t = inp          # (V,), (V,), (N,), (N,)
            decay = jnp.exp(dt_t[None, :] * af)            # (N, V)
            h = h * decay + (dt_t * x_t)[None, :] * b_t[:, None]
            y_t = (h * c_t[:, None]).sum(0) + df[0] * x_t
            return h, y_t

        h0 = jnp.zeros((nstate, xf.shape[-1]), jnp.float32)
        h_final, ys = jax.lax.scan(step, h0, (xf, dtf, bf, cf))
        return ys.astype(x.dtype), h_final

    return KernelSpec(
        mamba_site,
        fields=(FieldSpec(length, name="x"), FieldSpec(length, name="dt"),
                FieldSpec(nstate, name="a"), FieldSpec(1, name="d")),
        out=(length, nstate), consts=("b", "c"),
        name=f"mamba_scan_L{length}_n{nstate}")
