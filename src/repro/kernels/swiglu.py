"""Fused gated-activation kernels (SwiGLU / squared-ReLU / GeGLU).

Site-local over the token lattice: out = act(u) ⊙ v (gated) or act(u)
(ungated, e.g. nemotron's squared ReLU).  Fusing the activation with the
gate multiply saves one d_ff-wide HBO round-trip between the up- and
down-projections — the targetDP "ILP exposure" story applied to the MLP
hot path.

Grid is 2-D: (token chunks of VVL) × (d_ff blocks), so the kernel scales to
d_ff up to 24576 (nemotron) without exceeding VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTIVATIONS = ("swiglu", "geglu", "relu2", "silu", "gelu")


def _act(u, kind: str):
    if kind in ("swiglu", "silu"):
        return u * jax.nn.sigmoid(u)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(u, approximate=True)
    if kind == "relu2":
        r = jnp.maximum(u, 0.0)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


def _gated_body(u_ref, v_ref, o_ref, *, kind: str):
    u = u_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    o_ref[...] = (_act(u, kind) * v).astype(o_ref.dtype)


def _plain_body(u_ref, o_ref, *, kind: str):
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = _act(u, kind).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("kind", "vvl", "block_f", "interpret"))
def gated_act_pallas(u: jax.Array, v: jax.Array | None = None, *,
                     kind: str = "swiglu", vvl: int = 256,
                     block_f: int = 512, interpret: bool = False) -> jax.Array:
    """``act(u) * v`` (or ``act(u)`` when v is None) for ``(tokens, d_ff)``."""
    t, f = u.shape
    block_f = min(block_f, f)
    if f % block_f != 0:
        block_f = f  # fall back to one block across features
    t_pad = -(-t // vvl) * vvl

    def pad(x):
        return jnp.pad(x, ((0, t_pad - t), (0, 0))) if t_pad != t else x

    grid = (t_pad // vvl, f // block_f)
    spec = pl.BlockSpec((vvl, block_f), lambda i, j: (i, j))
    if v is None:
        out = pl.pallas_call(
            functools.partial(_plain_body, kind=kind),
            grid=grid, in_specs=[spec], out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((t_pad, f), u.dtype),
            interpret=interpret, name=f"act_{kind}_vvl{vvl}",
        )(pad(u))
    else:
        out = pl.pallas_call(
            functools.partial(_gated_body, kind=kind),
            grid=grid, in_specs=[spec, spec], out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((t_pad, f), u.dtype),
            interpret=interpret, name=f"gated_{kind}_vvl{vvl}",
        )(pad(u), pad(v))
    return out[:t]
