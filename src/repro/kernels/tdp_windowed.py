"""Gather-free windowed Pallas stencil executor — ROADMAP stage (b).

The ``"pallas"`` executor receives stencil fields as pre-gathered
``(noffsets, ncomp, nsites)`` stacks: correct, but the gather
re-materialises every stencil field ``noffsets`` times in HBM (19× for
streaming, 57× for the fused LB g-neighbourhood) — the amplification the
paper's follow-up (arXiv:1609.01479) and Alpaka (arXiv:1602.08477) avoid
by serving stencil neighbourhoods from on-chip memory.

This executor declares ``wants="halo_extended"`` in the registry, so the
launch prologue hands it each stencil field **once**, as a halo-extended
grid ``(ncomp, X+2r₀, Y+2r₁, ...)`` (periodic dims wrap-padded, sharded
dims reusing the caller's ghost planes).  Execution is an **x-plane
grid**: step *i* computes ``plane_block`` output planes, and for each
stencil field loads only the ``plane_block + 2·r₀`` x-planes its stencil
can reach into VMEM.  Neighbour offsets are resolved *in-kernel* from the
:class:`~repro.core.lattice.Stencil` descriptor by static plane selection
(the x component) and static y/z slices of the extended planes — the
``(noffsets, ncomp, V)`` chunk every site kernel already expects is
assembled in fast memory and never exists in HBM.  Site kernels stay
single-source; bit-identity with the ``"xla"`` executor is pinned by
``tests/test_windowed.py``.

Mechanically, the window is expressed through Pallas block indexing with
no overlap tricks: the extended array is passed once per window plane
(operands alias one HBM buffer — XLA sees one value used W times), each
with a depth-1 BlockSpec ``lambda i: (0, i·plane_block + j, 0, ...)``, so
every grid step DMAs exactly its window into VMEM.

Memory model (vs the gathered path, per ``LaunchPlan`` estimates):

  HBM   Σ_i ncomp_i · prod(shape_d + 2r_d)      [was noffsets_i × interior]
  VMEM  Σ_i ncomp_i · (plane_block + 2r₀) · prod(ext_rest)   per grid step

— the ``noffsets×`` term is gone from both; large grids (≥64³) that OOM
under the 57× fused gather fit comfortably.

Tuning (``Target.tuning``): ``plane_block`` — output x-planes per grid
step (TLP chunk; window depth is ``plane_block + 2r₀``).  Default 1.

Layout axis (``Target.layout``): under ``"aosoa"`` every x-plane of
every *operand* is regrouped into vvl-site blocks
(:func:`repro.core.layout.plane_to_aosoa`), so each grid step's VMEM
window is a stack of **dense** ``(plane_block + 2r, nblk, ncomp, vvl)``
tiles instead of ``ncomp`` strided plane rows.  The in-kernel
un-interleave restores ``(ncomp, *ext_rest)`` planes before the offset
resolution, so site kernels are untouched.  Outputs are written as
plain SoA plane blocks in **both** layouts: re-interleaving the result
in-kernel feeds a transpose into the fused site-math cluster, and XLA
then contracts the arithmetic's mul+add chains into FMAs differently
per vvl — trading a dense output store for broken bit-identity.  With
SoA output blocks every layout×vvl point is bit-identical to the SoA
path (pinned by ``tests/test_layout.py``).  ``vvl`` must divide the
*interior* plane site count exactly — validated at plan-build time by
:func:`repro.core.api.launch`; the halo-extended stencil operand planes
are zero-padded to a vvl multiple here and the pad lanes sliced away
in-kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.layout import plane_to_aosoa

from .tdp_pointwise import _canonicalize_consts


def _prod(xs) -> int:
    out = 1
    for s in xs:
        out *= int(s)
    return out


def windowed_execute(plan, extended):
    """Registry executor entry (``wants="halo_extended"`` — see
    :mod:`repro.core.registry`).

    ``extended``: one array per field — ``(ncomp, *ext_shape)`` halo-
    extended grids for stencil fields (ghost width = the stencil's
    per-dim radius, prepared by :func:`repro.core.api.halo_extend`),
    ``(ncomp, nsites)`` for pointwise fields.
    """
    shape = plan.shape
    if shape is None:
        raise ValueError(
            f"windowed executor needs lattice geometry; kernel "
            f"{plan.name!r} was launched without a lattice")
    ndim = len(shape)
    stencils = plan.stencils
    p = int(plan.target.tune("plane_block", 1))
    if p <= 0:
        raise ValueError(f"plane_block must be positive, got {p}")
    X, rest = shape[0], tuple(shape[1:])
    rest_n = _prod(rest)
    nwin = -(-X // p)
    x_pad = nwin * p - X
    chunk = p * rest_n
    dtype = extended[0].dtype
    aosoa = plan.layout == "aosoa"
    vvl = int(plan.vvl)

    operands, in_specs, field_meta = [], [], []
    for x, s in zip(extended, stencils):
        ncomp = int(x.shape[0])
        if s is None:
            grid_x = x.reshape(ncomp, X, *rest)
            if x_pad:
                grid_x = jnp.pad(grid_x, [(0, 0), (0, x_pad)]
                                 + [(0, 0)] * (ndim - 1))
            if aosoa:
                # (X, nblk, ncomp, vvl): per-plane vvl-site tiles
                operands.append(plane_to_aosoa(grid_x, vvl))
                nblk = rest_n // vvl
                in_specs.append(pl.BlockSpec(
                    (p, nblk, ncomp, vvl), lambda i: (i, 0, 0, 0)))
            else:
                operands.append(grid_x)
                in_specs.append(pl.BlockSpec(
                    (ncomp, p, *rest),
                    lambda i: (0, i, *([0] * (ndim - 1)))))
            field_meta.append(("pointwise", ncomp, None, None))
        else:
            r = s.radius_per_dim()
            ext = tuple(sd + 2 * rd for sd, rd in zip(shape, r))
            if x.shape[1:] != ext:
                raise ValueError(
                    f"stencil field of kernel {plan.name!r} is not halo-"
                    f"extended to radius {r}: got {tuple(x.shape[1:])}, "
                    f"want {ext}")
            if x_pad:
                x = jnp.pad(x, [(0, 0), (0, x_pad)]
                            + [(0, 0)] * (ndim - 1))
            window = p + 2 * r[0]
            if aosoa:
                # flatten the extended rest dims and zero-pad each plane
                # to a vvl multiple (the interior-divisibility contract
                # doesn't extend to halo-widened planes); the in-kernel
                # unpack slices the pad lanes away
                xf = x.reshape(ncomp, int(x.shape[1]), -1)
                pad = (-int(xf.shape[-1])) % vvl
                if pad:
                    xf = jnp.pad(xf, [(0, 0), (0, 0), (0, pad)])
                x = plane_to_aosoa(xf, vvl)  # (Xext, nblk_e, ncomp, vvl)
                nblk_e = int(x.shape[1])
            # One depth-1 plane ref per window slot: operand j of this
            # field is the extended array blocked at x-plane i·p + j.
            # All window operands alias one HBM value — the only copies
            # are the per-step HBM→VMEM window loads.
            for j in range(window):
                operands.append(x)
                if aosoa:
                    in_specs.append(pl.BlockSpec(
                        (1, nblk_e, ncomp, vvl),
                        lambda i, j=j: (i * p + j, 0, 0, 0)))
                else:
                    in_specs.append(pl.BlockSpec(
                        (ncomp, 1, *ext[1:]),
                        lambda i, j=j: (0, i * p + j,
                                        *([0] * (ndim - 1)))))
            field_meta.append(("stencil", ncomp, s, r))

    scalar_consts, array_consts = _canonicalize_consts(plan.consts)
    const_names = list(array_consts)
    const_vals = [array_consts[k][1] for k in const_names]
    in_specs += [pl.BlockSpec(c.shape, lambda i: (0, 0)) for c in const_vals]

    out_ncomp = tuple(plan.out_ncomp)
    out_specs = [pl.BlockSpec((c, p, *rest),
                              lambda i: (0, i, *([0] * (ndim - 1))))
                 for c in out_ncomp]
    out_shape = [jax.ShapeDtypeStruct((c, X + x_pad, *rest), dtype)
                 for c in out_ncomp]

    def body(*refs):
        it = iter(refs[:len(operands)])
        cref0 = len(operands)
        const_refs = refs[cref0:cref0 + len(const_names)]
        out_refs = refs[cref0 + len(const_names):]

        def unpack_plane(blk, ncomp, rest_shape):
            # (nplanes, nblk, ncomp, vvl) AoSoA tile → SoA planes
            # (ncomp, nplanes, *rest_shape); extended planes may carry
            # trailing vvl-alignment pad lanes — sliced away here
            npl = int(blk.shape[0])
            y = jnp.transpose(blk, (2, 0, 1, 3))
            y = y.reshape(ncomp, npl, -1)
            rn = _prod(rest_shape)
            if int(y.shape[-1]) != rn:
                y = y[..., :rn]
            return y.reshape(ncomp, npl, *rest_shape)

        chunks = []
        for kind, ncomp, s, r in field_meta:
            if kind == "pointwise":
                blk = next(it)[...]
                if aosoa:
                    blk = unpack_plane(blk, ncomp, rest)
                chunks.append(blk.reshape(ncomp, chunk))
                continue
            ext_rest = tuple(sd + 2 * rd
                             for sd, rd in zip(shape[1:], r[1:]))
            planes = [next(it)[...] for _ in range(p + 2 * r[0])]
            if aosoa:
                planes = [unpack_plane(pp, ncomp, ext_rest)
                          for pp in planes]
            nb = []
            for off in s.offsets:
                rows = []
                for xl in range(p):
                    # plane (local x = xl) + offset: window slot is static
                    sl = planes[xl + r[0] + off[0]][:, 0]
                    for d in range(1, ndim):
                        start = r[d] + off[d]
                        sl = jax.lax.slice_in_dim(
                            sl, start, start + shape[d], axis=d)
                    rows.append(sl.reshape(ncomp, rest_n))
                nb.append(rows[0] if p == 1
                          else jnp.concatenate(rows, axis=-1))
            chunks.append(jnp.stack(nb))          # (noffsets, ncomp, V)

        if plan.with_site_index:
            base = pl.program_id(0) * chunk
            chunks.append(base + jax.lax.iota(jnp.int32, chunk))
        kw = dict(scalar_consts)
        for cname, cref in zip(const_names, const_refs):
            orig_shape, _ = array_consts[cname]
            kw[cname] = cref[...].reshape(orig_shape)
        vals = plan.kernel(*chunks, **kw)
        vals = (vals,) if not isinstance(vals, tuple) else vals
        for ref, v in zip(out_refs, vals):
            ref[...] = v.reshape(ref.shape).astype(ref.dtype)

    outs = pl.pallas_call(
        body,
        grid=(nwin,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=plan.interpret,
        name=f"tdp_windowed_{plan.name}_p{p}_{plan.layout}",
    )(*operands, *const_vals)

    n = X * rest_n
    return tuple(o.reshape(o.shape[0], -1)[:, :n] for o in outs)
