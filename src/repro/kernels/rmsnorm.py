"""Fused RMSNorm over the token lattice (Pallas, VVL-token blocks).

targetDP view: the token lattice's sites are chunked by VVL onto the grid
(TLP); inside a block every op vectorises over the feature (lane) axis —
for LM fields the feature extent d ≥ 1024 fills the 128-lane rows perfectly,
so the ILP axis is the feature axis and VVL counts *tokens per block*
(sublane rows).  This is the layout-adapted dual of the LB kernels (19
components → sites must ride the lanes); see DESIGN.md §2.

VMEM per step ≈ 2 · VVL · d · itemsize + d · 4; with d=8192, bf16, VVL=256:
~8.4 MiB — the ops-level wrapper auto-shrinks VVL to fit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_EPS = 1e-6


def _rmsnorm_body(x_ref, w_ref, o_ref, *, eps: float, scale_offset: float):
    x = x_ref[...].astype(jnp.float32)                 # (VVL, d)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps)
    w = w_ref[...].astype(jnp.float32) + scale_offset  # (1, d)
    o_ref[...] = (x * inv * w).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("vvl", "interpret", "eps", "scale_offset"))
def rmsnorm_pallas(x: jax.Array, weight: jax.Array, *, vvl: int = 256,
                   interpret: bool = False, eps: float = DEFAULT_EPS,
                   scale_offset: float = 0.0) -> jax.Array:
    """RMSNorm of ``x: (tokens, d)`` with ``weight: (d,)``.

    ``scale_offset=1.0`` gives the Gemma convention ``x * rms * (1 + w)``.
    """
    t, d = x.shape
    t_pad = -(-t // vvl) * vvl
    xp = jnp.pad(x, ((0, t_pad - t), (0, 0))) if t_pad != t else x
    w2 = weight.reshape(1, d)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_body, eps=eps, scale_offset=scale_offset),
        grid=(t_pad // vvl,),
        in_specs=[pl.BlockSpec((vvl, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((vvl, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t_pad, d), x.dtype),
        interpret=interpret,
        name=f"rmsnorm_vvl{vvl}_d{d}",
    )(xp, w2)
    return out[:t]
