"""Pallas stencil support — re-exports of the unified Pallas executor.

Since the executor-registry redesign the pointwise and stencil Pallas
paths share one implementation (:func:`repro.kernels.tdp_pointwise
.pallas_execute`): every stencil-carrying input simply contributes a
``(noffsets, ncomp, VVL)`` VMEM block per grid step — the centre row plus
one halo row per neighbour offset — while pointwise inputs stay
``(ncomp, VVL)``.  The neighbour gather itself (periodic rolls and ghost-
plane window slices) runs as XLA ops in the jitted prologue
(:func:`repro.core.api.gather_neighbors`, shared by *all* executors); on
TPU it fuses into the surrounding copy, and the pallas_call sees plain
dense operands with a static leading offset axis.

VMEM budgeting (see docs/stencil.md): the pointwise rule
``sum_i(ncomp_i · VVL · itemsize)`` picks up a ``noffsets_i`` factor per
stencil input —

  ``vmem_bytes ≈ Σ_i noffsets_i · ncomp_i · VVL · b  +  Σ_o ncomp_o · VVL · b``

which for the fused D3Q19 stream+collide launch (19·19 + 57·19 rows) caps
VVL two binary orders below the pointwise collision kernel's sweet spot —
the two-launch fused mode (``ops.lb_fused_step(mode="two_launch")``)
shrinks that stack, and the gather-free ``"pallas_windowed"`` executor
(:mod:`repro.kernels.tdp_windowed`, ``wants="halo_extended"``) eliminates
it: no ``(noffsets, ncomp, nsites)`` stack is ever built, offsets resolve
in-kernel from x-plane windows.  :func:`vmem_bytes_estimate` computes the
gathered rule; :meth:`repro.core.api.LaunchPlan.vmem_bytes_estimate` /
``hbm_bytes_estimate`` model both regimes.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax

from .tdp_pointwise import (  # noqa: F401 — canonical implementations
    pallas_execute,
    vmem_bytes_estimate,
)

__all__ = ["pallas_stencil_launch", "pallas_execute", "vmem_bytes_estimate"]


def pallas_stencil_launch(kernel: Callable, vvl: int,
                          out_ncomp: tuple[int, ...], consts: dict,
                          interpret: bool,
                          gathered: Sequence[jax.Array]):
    """Pre-registry entry point, kept for direct callers: map ``kernel``
    over VVL site chunks of pre-gathered neighbour stacks."""
    from .tdp_pointwise import _run_pallas

    outs = _run_pallas(
        kernel, vvl, False, tuple(out_ncomp), consts, interpret, gathered,
        name=f"tdp_stencil_{getattr(kernel, '__name__', 'site_kernel')}"
             f"_vvl{vvl}")
    return outs[0] if len(outs) == 1 else outs
