"""Pallas executor for targetDP *stencil* site kernels.

Extends the pointwise executor (:mod:`repro.kernels.tdp_pointwise`) to
halo-aware kernels: every stencil-carrying input contributes a
``(noffsets, ncomp, VVL)`` VMEM block per grid step — the centre row plus
one halo row per neighbour offset, materialised into VMEM so the kernel
body (the *same* single-source body the jnp executor vmaps) computes
entirely on-chip.  The neighbour gather itself — periodic rolls and ghost-
plane window slices — runs as XLA ops in the jitted prologue
(:func:`repro.core.execute.gather_neighbors`); on TPU it fuses into the
surrounding copy, and the pallas_call sees plain dense operands with a
static leading offset axis.

VMEM budgeting (see docs/stencil.md): the pointwise rule
``sum_i(ncomp_i · VVL · itemsize)`` picks up a ``noffsets_i`` factor per
stencil input —

  ``vmem_bytes ≈ Σ_i noffsets_i · ncomp_i · VVL · b  +  Σ_o ncomp_o · VVL · b``

which for the fused D3Q19 stream+collide launch (19·19 + 57·19 rows) caps
VVL two binary orders below the pointwise collision kernel's sweet spot.
:func:`vmem_bytes_estimate` computes the rule.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
from jax.experimental import pallas as pl

from .tdp_pointwise import _canonicalize_consts, vmem_bytes_estimate

__all__ = ["pallas_stencil_launch", "vmem_bytes_estimate"]


def pallas_stencil_launch(kernel: Callable, vvl: int,
                          out_ncomp: tuple[int, ...], consts: dict,
                          interpret: bool,
                          gathered: Sequence[jax.Array]):
    """Map ``kernel`` over VVL site chunks of pre-gathered neighbour stacks.

    ``gathered``: per input, ``(noffsets, ncomp, n)`` for stencil inputs or
    ``(ncomp, n)`` for pointwise ones — the output of the shared gather
    prologue.  Grid = one step per VVL chunk of interior sites.
    """
    from repro.core.execute import pad_sites

    n = gathered[0].shape[-1]
    n_pad = -(-n // vvl) * vvl
    nchunks = n_pad // vvl
    dtype = gathered[0].dtype

    padded = tuple(pad_sites(x, vvl) for x in gathered)
    scalar_consts, array_consts = _canonicalize_consts(consts)
    const_names = list(array_consts)
    const_vals = [array_consts[k][1] for k in const_names]
    n_out = len(out_ncomp)

    def body(*refs):
        in_refs = refs[:len(padded)]
        cref0 = len(padded)
        const_refs = refs[cref0:cref0 + len(const_names)]
        out_refs = refs[cref0 + len(const_names):]
        chunks = [r[...] for r in in_refs]
        kw = dict(scalar_consts)
        for name, cref in zip(const_names, const_refs):
            orig_shape, _ = array_consts[name]
            kw[name] = cref[...].reshape(orig_shape)
        vals = kernel(*chunks, **kw)
        vals = (vals,) if not isinstance(vals, tuple) else vals
        for r, v in zip(out_refs, vals):
            r[...] = v.astype(r.dtype)

    def site_spec(x):
        if x.ndim == 3:       # (noffsets, ncomp, vvl) halo block
            return pl.BlockSpec((x.shape[0], x.shape[1], vvl),
                                lambda i: (0, 0, i))
        return pl.BlockSpec((x.shape[0], vvl), lambda i: (0, i))

    in_specs = [site_spec(x) for x in padded] + [
        pl.BlockSpec(c.shape, lambda i: (0, 0)) for c in const_vals
    ]
    out_specs = [pl.BlockSpec((c, vvl), lambda i: (0, i)) for c in out_ncomp]
    out_shape = [jax.ShapeDtypeStruct((c, n_pad), dtype) for c in out_ncomp]

    outs = pl.pallas_call(
        body,
        grid=(nchunks,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        name=f"tdp_stencil_{getattr(kernel, '__name__', 'site_kernel')}"
             f"_vvl{vvl}",
    )(*padded, *const_vals)

    outs = tuple(o[:, :n] for o in outs)
    return outs[0] if n_out == 1 else outs
