"""``tdp.health`` — numerical health guards for long-running programs.

A production lattice service (the paper's Ludwig deployments run for
days) dies two ways: a *fault* (an executor raises) and a *divergence*
(a trajectory silently fills with NaN and keeps burning device hours).
This module handles the second: opt-in, host-side per-chunk checks that
turn "the fields are garbage" into a diagnosis — **which field**, what
**kind** of violation (``nan`` / ``inf`` / norm blow-up), which
**member** of an ensemble, over which **step range**.

The policy is a frozen value object::

    policy = tdp.HealthPolicy(fields=("g",), max_norm=1e3, every=4)
    state = compiled.run(state, 1000, health=policy)      # raises HealthError
    state = fleet.run(state, 1000, health=policy)         # member-attributed

and the same object plugs into the service loop
(``tdp.FleetDriver(..., health=policy)``), where a diagnosed member is
*quarantined* — its ticket fails (or retries from its last snapshot)
while every healthy member keeps the exact result of the shared vmapped
launch (checks read state, they never modify it, so guarded trajectories
stay bit-identical to unguarded ones).

Cost model: each check is one ``O(state)`` reduction per guarded field
per ``every`` member steps — ``every=1`` bounds the blast radius to one
chunk, larger ``every`` amortises the guard under the scan
(``benchmarks/run.py`` records the measured overhead as
``health_check_overhead`` in ``BENCH_fleet.json``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Mapping, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np


__all__ = ["HealthPolicy", "HealthError", "Diagnosis", "check", "diagnose"]


class Diagnosis(NamedTuple):
    """One member's first health violation: the offending field, the
    violation kind (``"nan"`` / ``"inf"`` / ``"norm"``), and the largest
    finite ``|x|`` observed (``None`` for nan/inf diagnoses)."""
    field: str
    kind: str
    value: float | None


class HealthError(RuntimeError):
    """A numerical health check failed.

    Carries the structured diagnosis alongside the message: ``field``,
    ``kind`` (``"nan"``/``"inf"``/``"norm"``), ``value`` (the offending
    finite max-``|x|`` for norm violations), ``member`` (ensemble slot,
    ``None`` for single-member states), ``step_range`` (the half-open
    member-step interval the divergence appeared in) and ``ticket``
    (the fleet ticket id, when raised by the service driver).
    """

    def __init__(self, message: str, *, field: str | None = None,
                 kind: str | None = None, value: float | None = None,
                 member: int | None = None,
                 step_range: tuple[int, int] | None = None,
                 ticket: str | None = None):
        super().__init__(message)
        self.field = field
        self.kind = kind
        self.value = value
        self.member = member
        self.step_range = step_range
        self.ticket = ticket

    @classmethod
    def of(cls, diag: Diagnosis, *, member: int | None = None,
           step_range: tuple[int, int] | None = None,
           ticket: str | None = None, where: str | None = None,
           others: int = 0) -> "HealthError":
        """Build the human-facing message from a :class:`Diagnosis`."""
        what = (f"max |x| = {diag.value:.6g} exceeds max_norm"
                if diag.kind == "norm" else
                {"nan": "contains NaN", "inf": "contains Inf"}[diag.kind])
        ctx = []
        if member is not None:
            ctx.append(f"member {member}")
        if ticket is not None:
            ctx.append(f"ticket {ticket}")
        if step_range is not None:
            ctx.append(f"steps [{step_range[0]}, {step_range[1]})")
        msg = (f"numerical health check failed"
               f"{' for ' + where if where else ''}: "
               f"field {diag.field!r} {what}"
               f"{' (' + ', '.join(ctx) + ')' if ctx else ''}"
               + (f"; {others} other member(s) also diverged"
                  if others else ""))
        return cls(msg, field=diag.field, kind=diag.kind, value=diag.value,
                   member=member, step_range=step_range, ticket=ticket)


@dataclass(frozen=True)
class HealthPolicy:
    """What to guard and how often.

    Args:
      fields: field names to check (``None`` = every field present).
      nan / inf: flag non-finite values (both default on).
      max_norm: additionally flag any finite ``|x|`` above this bound
        (the norm-blow-up guard; ``None`` = off).
      every: check cadence in member steps — runs split into
        ``every``-sized scan chunks with one host-side check between
        chunks, so a diagnosis localises the divergence to an
        ``every``-wide step range.
    """

    fields: tuple[str, ...] | None = None
    nan: bool = True
    inf: bool = True
    max_norm: float | None = None
    every: int = 1

    def __post_init__(self):
        if self.fields is not None:
            object.__setattr__(self, "fields",
                               tuple(str(f) for f in self.fields))
        if int(self.every) < 1:
            raise ValueError(f"HealthPolicy.every must be >= 1, "
                             f"got {self.every}")
        object.__setattr__(self, "every", int(self.every))
        if self.max_norm is not None and not float(self.max_norm) > 0:
            raise ValueError(f"HealthPolicy.max_norm must be positive, "
                             f"got {self.max_norm}")
        if not (self.nan or self.inf or self.max_norm is not None):
            raise ValueError("HealthPolicy enables no checks (nan=False, "
                             "inf=False, max_norm=None) — it would pass "
                             "everything")

    def select_fields(self, available: Sequence[str]) -> list[str]:
        """The guarded subset of ``available``, in ``available`` order;
        raises when the policy names a field that does not exist."""
        avail = list(available)
        if self.fields is None:
            return avail
        missing = sorted(set(self.fields) - set(avail))
        if missing:
            raise ValueError(
                f"HealthPolicy names field(s) {missing} that the state "
                f"does not carry; present: {sorted(avail)}")
        want = set(self.fields)
        return [f for f in avail if f in want]


@functools.partial(jax.jit, static_argnums=1)
def _field_stats(a, ensemble: bool):
    """Per-member (nan?, inf?, finite max|x|) for one field array —
    a single fused reduction per guarded field."""
    x = a.reshape((a.shape[0], -1) if ensemble else (1, -1))
    absx = jnp.abs(x)
    return (jnp.any(jnp.isnan(x), axis=1),
            jnp.any(jnp.isinf(x), axis=1),
            jnp.max(jnp.where(jnp.isfinite(x), absx, 0.0), axis=1))


def diagnose(policy: HealthPolicy, state: Mapping[str, Any], *,
             ensemble: int | None = None) -> dict[int, Diagnosis]:
    """Check ``state`` against ``policy``; returns ``{member_index:
    Diagnosis}`` for every unhealthy member (empty dict = healthy).

    ``ensemble``: the leading ensemble extent of the field arrays, or
    ``None`` for single-member states (which report under index 0).
    Per member, the *first* guarded field in state order wins, with
    kind priority nan > inf > norm.  Host-side and read-only — the
    state is never modified.
    """
    out: dict[int, Diagnosis] = {}
    nmembers = 1 if ensemble is None else int(ensemble)
    for f in policy.select_fields(list(state)):
        a = jnp.asarray(state[f])
        if ensemble is not None and (a.ndim < 1 or
                                     int(a.shape[0]) != nmembers):
            raise ValueError(
                f"health check: field {f!r} has leading extent "
                f"{a.shape[0] if a.ndim else '(scalar)'}, expected the "
                f"ensemble extent {nmembers}")
        nan, inf, fmax = (np.asarray(v) for v in
                          _field_stats(a, ensemble is not None))
        if len(out) == nmembers:
            break
        for i in range(nmembers):
            if i in out:
                continue
            if policy.nan and bool(nan[i]):
                out[i] = Diagnosis(f, "nan", None)
            elif policy.inf and bool(inf[i]):
                out[i] = Diagnosis(f, "inf", None)
            elif policy.max_norm is not None and \
                    float(fmax[i]) > float(policy.max_norm):
                out[i] = Diagnosis(f, "norm", float(fmax[i]))
    return out


def check(policy: HealthPolicy, state: Mapping[str, Any], *,
          ensemble: int | None = None,
          step_range: tuple[int, int] | None = None,
          where: str | None = None) -> None:
    """Raise :class:`HealthError` (diagnosing the lowest unhealthy
    member) when ``state`` violates ``policy``; no-op when healthy."""
    diag = diagnose(policy, state, ensemble=ensemble)
    if not diag:
        return
    member, d = min(diag.items())
    raise HealthError.of(
        d, member=member if ensemble is not None else None,
        step_range=step_range, where=where, others=len(diag) - 1)
