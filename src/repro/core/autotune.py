"""``tdp.autotune`` — close the paper's tuning loop over ``Target.tuning``.

The paper's portability claim is explicitly *tuned* portability: one
source, with per-platform decomposition knobs (TLP/ILP split, SIMD
vector length) chosen to fit the hardware, and its sequel ("A
Lightweight Approach to Performance Portability with targetDP",
1609.01479) states that those knobs must be re-chosen per device.  This
framework exposes the knobs — the executor choice, ``vvl``,
``Target.tuning["plane_block"]``, the pointwise block sizes — but until
now choosing them was manual (``benchmarks/run.py --sweep``).
:func:`autotune` closes the loop:

1. **enumerate** a candidate space of :class:`Candidate` assignments,
   derived from the program/spec and the launch geometry unless given
   explicitly: the executor axis comes from
   :func:`repro.core.registry.compatible_executors` (capability-checked
   against the spec's stencil needs), ``plane_block`` sweeps the
   *divisors* of the launch's x-plane count for ``wants="halo_extended"``
   executors, and the pointwise Pallas block knobs sweep
   :data:`POINTWISE_TUNABLE_VALUES` where the executor declares them;
2. **prune** infeasible candidates up front — a candidate whose
   :meth:`~repro.core.api.LaunchPlan.vmem_bytes_estimate` (max over
   stages, for a Program) exceeds ``vmem_limit`` is never measured;
3. **measure** each survivor with a pluggable ``timer`` (median over
   ``reps`` calls of a ``measure_steps``-step run; real wall clock by
   default, injectable fake for deterministic tests);
4. **return** a frozen tuned :class:`~repro.core.target.Target` (the
   base target with the winning candidate's backend + merged tuning)
   plus a :class:`TuneReport` (per-candidate medians, the pruned list,
   the cache key).

Correctness is decoupled from tuning by construction — candidates only
permute *how* the same launches execute, never *what* they compute; the
optional ``check_identical=True`` verifies this at tune time by
comparing every candidate's output bit-for-bit against the default
target's (mismatches are pruned, not chosen).  The base target is always
candidate 0, so the tuned median can never exceed the default median.

Results persist in an on-disk cache (``results/tuning/`` by default)
keyed by (program/spec digest, grid, backend family, device kind) —
repeated runs skip measurement entirely and reproduce the same choice
(``TuneReport.cache_hit``).

Two extensions ride on :mod:`repro.core.costmodel`:

* **predictor-guided search** — every candidate is scored by the
  analytical roofline model before measurement (``scorer=`` overrides
  the default :func:`repro.core.costmodel.predict` scorer); with
  ``top_k=K`` only the base target plus the K best-predicted candidates
  are measured (at most K+1 measurements), the rest recorded in
  ``report.pruned`` with a ``model-pruned`` reason.  Every measured
  candidate records ``predicted_s`` and the ``predicted_vs_measured``
  relative error, and the report carries the Spearman
  ``rank_correlation`` between predicted and measured over the measured
  set — the running proof (or refutation) that the model ranks.
* **per-stage tuning** (``per_stage=True``) — program-level candidates
  may assign a distinct ``plane_block`` per windowed :class:`Stage` via
  the reserved ``Target.tuning`` keys ``"stage:<name>"`` (value: a
  frozen tuple of ``(knob, value)`` pairs, merged over the flat tuning
  by :func:`repro.core.program.resolve_stage_target`).  Cache entries
  are schema-versioned (:data:`SCHEMA_VERSION`): older entries replay,
  entries from a future schema miss cleanly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import tempfile
import time
from typing import Any, Callable, Mapping, NamedTuple, Sequence

import jax
import numpy as np

from . import costmodel as _costmodel
from .api import launch as _launch
from .api import launch_plan as _launch_plan
from .costmodel import DEFAULT_VMEM_LIMIT  # noqa: F401  (re-export)
from .lattice import Lattice
from .program import CompiledProgram, Program
from .registry import (
    compatible_executors,
    executor_tunables,
    executor_wants,
)
from .spec import KernelSpec
from .target import Target, as_target

#: on-disk cache entry schema.  v1: PR 5 entries (no predictor fields,
#: no per-stage tuning).  v2: adds ``schema``, per-candidate
#: ``predicted_s`` / ``predicted_vs_measured``, report-level
#: ``rank_correlation``, and nested ``stage:<name>`` tuning values.
#: v3: adds the per-candidate ``vvl`` / ``layout`` axes (ISSUE 10 —
#: the AoSoA layout sweep); absent fields replay as ``None`` (inherit
#: the base target), so v1/v2 entries keep replaying.
#: Older entries replay (missing fields default); entries written by a
#: *future* schema are a cache miss, never a parse error.
SCHEMA_VERSION = 3

#: default candidate values for the pointwise Pallas block knobs
#: (consulted per executor: only keys the executor *declares* via
#: ``register_executor(..., tunables=...)`` are swept).
POINTWISE_TUNABLE_VALUES: dict[str, tuple[int, ...]] = {
    "block_f": (256, 512, 1024),
    "block_q": (64, 128, 256),
    "block_k": (64, 128, 256),
    "block_d": (64, 128),
    "block_t": (64, 128),
}


# ---------------------------------------------------------------------------
# candidates
# ---------------------------------------------------------------------------

def _freeze_value(v):
    """Hashable, canonical form of one tuning value.  Nested mappings
    (and JSON round-tripped lists of pairs) become sorted tuples of
    pairs — the per-stage ``"stage:<name>"`` values."""
    if isinstance(v, Mapping):
        return tuple(sorted((str(k), _freeze_value(x))
                            for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        if v and all(isinstance(x, (list, tuple)) and len(x) == 2
                     and isinstance(x[0], str) for x in v):
            return tuple(sorted((str(k), _freeze_value(x)) for k, x in v))
        return tuple(_freeze_value(x) for x in v)
    return v


def _freeze_items(mapping) -> tuple[tuple[str, Any], ...]:
    if not mapping:
        return ()
    items = (mapping.items() if isinstance(mapping, Mapping)
             else (tuple(kv) for kv in mapping))
    return tuple(sorted((str(k), _freeze_value(v)) for k, v in items))


def _is_pairs(v) -> bool:
    return (isinstance(v, tuple) and len(v) > 0
            and all(isinstance(x, tuple) and len(x) == 2
                    and isinstance(x[0], str) for x in v))


def _json_value(v):
    """The JSON-serialisable form of a frozen tuning value (inverse of
    :func:`_freeze_value` up to key order)."""
    if _is_pairs(v):
        return {k: _json_value(x) for k, x in v}
    if isinstance(v, tuple):
        return [_json_value(x) for x in v]
    return v


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the tuning space: an executor assignment plus the
    ``Target.tuning`` knobs to merge in.

    ``backend`` is a registry name (the ``"..._interpret"`` spellings
    canonicalise through :class:`Target` as usual); ``tuning`` is merged
    into — never replaces — the base target's tuning, so unrelated knobs
    ride through unchanged.  ``vvl`` / ``layout`` (schema v3, ISSUE 10)
    are the Target-level memory axes: ``None`` inherits the base
    target's value, a set value overrides it (``layout="aosoa"``
    candidates sweep the paper's AoSoA ordering; ``vvl`` both sets the
    gathered chunk size and the AoSoA inner block width).
    """

    backend: str
    interpret: bool = False
    tuning: tuple[tuple[str, Any], ...] = ()
    vvl: int | None = None
    layout: str | None = None

    def __post_init__(self):
        object.__setattr__(self, "tuning", _freeze_items(self.tuning))
        if self.vvl is not None:
            object.__setattr__(self, "vvl", int(self.vvl))
        if self.layout is not None and self.layout not in ("soa", "aosoa"):
            raise ValueError(f"layout must be 'soa', 'aosoa' or None "
                             f"(inherit), got {self.layout!r}")

    def target_from(self, base: Target) -> Target:
        t = base.with_(backend=self.backend, interpret=self.interpret)
        if self.vvl is not None:
            t = t.with_(vvl=self.vvl)
        if self.layout is not None:
            t = t.with_(layout=self.layout)
        return t.with_tuning(dict(self.tuning)) if self.tuning else t

    @property
    def label(self) -> str:
        name = self.backend
        if self.interpret and not name.endswith("_interpret"):
            name += "_interpret"
        knobs = []
        if self.layout is not None:
            knobs.append(f"layout={self.layout}")
        if self.vvl is not None:
            knobs.append(f"vvl={self.vvl}")
        knobs += [(f"{k}{{{','.join(f'{ik}={iv}' for ik, iv in v)}}}"
                   if _is_pairs(v) else f"{k}={v}")
                  for k, v in self.tuning]
        return f"{name}[{','.join(knobs)}]" if knobs else name

    def as_dict(self) -> dict:
        return {"backend": self.backend, "interpret": self.interpret,
                "tuning": {k: _json_value(v) for k, v in self.tuning},
                "vvl": self.vvl, "layout": self.layout}

    @classmethod
    def from_dict(cls, d: Mapping) -> "Candidate":
        vvl = d.get("vvl")
        return cls(d["backend"], bool(d.get("interpret", False)),
                   _freeze_items(d.get("tuning") or {}),
                   None if vvl is None else int(vvl),
                   d.get("layout"))

    @classmethod
    def of(cls, target: Target) -> "Candidate":
        """The candidate that reproduces ``target``'s dispatch.

        ``vvl`` / ``layout`` stay ``None`` (inherit) deliberately:
        candidate 0 must dispatch *exactly* as the base target does,
        including a ``vvl=None`` target re-resolving the process default
        at launch time."""
        return cls(target.backend, target.interpret, target.tuning)


def _divisors(n: int) -> list[int]:
    n = int(n)
    small, large = [], []
    for d in range(1, int(math.isqrt(n)) + 1):
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
    return small + large[::-1]


def _vvl_values(n: int, *, lo: int = 8, hi: int = 8192,
                max_values: int = 6) -> list[int]:
    """The vvl sweep for a launch over ``n`` sites (or, for the windowed
    AoSoA path, ``n`` sites per x-plane): divisors of ``n`` in
    ``[lo, hi]``, thinned to at most ``max_values`` evenly spaced points
    (keeping the extremes) so a highly composite site count doesn't
    explode the space."""
    n = int(n)
    if n <= 0:
        return []
    vals = [d for d in _divisors(n) if lo <= d <= hi]
    if not vals:
        return [n] if n < lo else []
    if len(vals) > max_values:
        idx = np.linspace(0, len(vals) - 1, max_values).round().astype(int)
        vals = sorted({vals[i] for i in idx})
    return vals


def plane_block_candidates(spec: KernelSpec, target: Target | str | None,
                           lattice: Lattice, *, halo=None, consts=None,
                           vmem_limit: int = DEFAULT_VMEM_LIMIT):
    """The ``plane_block`` axis for one ``wants="halo_extended"`` launch.

    Emits the divisors of the launch's x-plane count (``plan.shape[0]``
    — for a Program stage that is the *extended* plane count, interior +
    recompute ring) whose windowed-executor VMEM model fits
    ``vmem_limit``.  Divisors, not every integer: the executor pads the
    grid to a ``plane_block`` multiple, so non-divisors waste whole
    padded planes per step.

    Returns ``(feasible, pruned)`` — ``feasible`` the surviving
    ``plane_block`` values, ``pruned`` a list of ``(value, reason)``.
    """
    tgt = as_target(target)
    feasible: list[int] = []
    pruned: list[tuple[int, str]] = []
    base_plan = _launch_plan(spec, tgt, lattice=lattice, halo=halo,
                             consts=consts)
    for p in _divisors(base_plan.shape[0]):
        plan = _launch_plan(spec, tgt.with_tuning(plane_block=p),
                            lattice=lattice, halo=halo, consts=consts)
        vmem = plan.vmem_bytes_estimate()
        if vmem <= vmem_limit:
            feasible.append(p)
        else:
            pruned.append((p, f"vmem estimate {vmem} > limit {vmem_limit}"))
    return feasible, pruned


def _program_plane_counts(program: Program, target: Target,
                          grid_shape) -> list[int]:
    """x-plane counts of every stage a ``halo_extended`` executor would
    actually run (stencil stages; pointwise stages route to xla)."""
    pplan = program.plan(target, grid_shape=grid_shape)
    return [p.shape[0] for _, p in pplan.stages
            if p.wants == "halo_extended" and p.shape is not None]


def default_space(program_or_spec, target: Target | str | None = None, *,
                  grid_shape: Sequence[int] | None = None,
                  lattice: Lattice | None = None, halo=None, consts=None,
                  executors: Sequence[str] | None = None,
                  vmem_limit: int = DEFAULT_VMEM_LIMIT,
                  per_stage: bool = False,
                  site_count: int | None = None):
    """Derive the default candidate space for :func:`autotune`.

    Axes (the candidate-space table in docs/targetdp_api.md):

    * the **base target itself** — always candidate 0, so the tuned
      median is ≤ the default median by construction;
    * the **executor axis** — ``executors`` if given, else the base
      executor + ``"xla"``, intersected with
      :func:`~repro.core.registry.compatible_executors` for the spec's
      capability needs (a pointwise-only spec never meets a
      ``halo_extended`` executor);
    * per ``wants="halo_extended"`` executor, the **plane_block
      divisor sweep** (:func:`plane_block_candidates`), VMEM-filtered;
    * per executor that declares pointwise block knobs
      (``executor_tunables``), one candidate per value in
      :data:`POINTWISE_TUNABLE_VALUES`;
    * per ``wants="gathered"`` executor, the **vvl sweep**
      (:func:`_vvl_values` — divisors of the launch's site count,
      thinned and VMEM-filtered; needs ``site_count`` / ``lattice`` /
      ``grid_shape`` to know the count) and the **layout axis**: one
      ``layout="aosoa"`` candidate per surviving vvl (gathered AoSoA
      pads remainder sites, so every vvl is valid);
    * per ``wants="halo_extended"`` executor, the **layout axis**:
      ``layout="aosoa"`` candidates over vvl divisors of the (gcd of
      the windowed stages') interior x-plane site count — the windowed
      AoSoA validity contract (:func:`repro.core.api.launch`),
      VMEM-filtered;
    * with ``per_stage=True``, for programs with **more than one**
      windowed stage, an independent per-stage ``plane_block`` sweep:
      one candidate per (stage, divisor-of-that-stage's-plane-count)
      under the reserved tuning key ``"stage:<name>"`` (a single
      windowed stage makes per-stage ≡ global, so the axis is skipped).

    Returns ``(candidates, pruned)`` where ``pruned`` is a list of
    ``(label, reason)`` for space points rejected before measurement.
    """
    base = as_target(target)
    is_program = isinstance(program_or_spec, Program)
    if is_program:
        has_stencil = any(st.spec.has_stencil
                          for st in program_or_spec.stages)
        if grid_shape is None:
            raise ValueError("default_space over a Program needs "
                             "grid_shape")
    elif isinstance(program_or_spec, KernelSpec):
        has_stencil = program_or_spec.has_stencil
        if has_stencil and lattice is None:
            raise ValueError("default_space over a stencil KernelSpec "
                             "needs the lattice")
    else:
        raise TypeError(f"expected a Program or KernelSpec, got "
                        f"{type(program_or_spec).__name__}")

    ok = set(compatible_executors(stencil=has_stencil))
    if executors is None:
        names = [base.executor, "xla"]
    else:
        names = [str(n) for n in executors]
    pruned: list[tuple[str, str]] = []
    seen: set[str] = set()
    axis: list[Candidate] = []
    for n in names:
        t = as_target(n)
        # inherit the base interpret flag when staying in the base's
        # backend family (a CPU host tuning pallas_windowed_interpret
        # must not emit the un-runnable hardware spelling)
        interpret = t.interpret or (base.interpret
                                    and t.backend == base.backend)
        cand = Candidate(t.backend, interpret)
        if cand.label in seen:
            continue
        seen.add(cand.label)
        if cand.backend not in ok:
            reason = ("not registered" if cand.backend not in
                      set(compatible_executors(stencil=True))
                      else "wants='halo_extended' but the launch has no "
                           "stencil field")
            pruned.append((cand.label, reason))
            continue
        axis.append(cand)

    candidates: list[Candidate] = [Candidate.of(base)]
    cand_seen = {candidates[0].label}

    def add(c: Candidate):
        if c.label not in cand_seen:
            cand_seen.add(c.label)
            candidates.append(c)

    if is_program:
        nsites = math.prod(int(s) for s in grid_shape)
    elif lattice is not None:
        nsites = math.prod(int(s) for s in lattice.shape)
    else:
        nsites = None if site_count is None else int(site_count)

    def vmem_of(c: Candidate) -> int:
        t = c.target_from(base)
        if is_program:
            return program_or_spec.plan(
                t, grid_shape=grid_shape).vmem_bytes_estimate()
        return _launch_plan(program_or_spec, t, lattice=lattice,
                            halo=halo, consts=consts).vmem_bytes_estimate()

    def add_vmem_checked(c: Candidate):
        try:
            vmem = vmem_of(c)
        except Exception as e:  # noqa: BLE001 — unplannable space point
            pruned.append((c.label, f"error: {type(e).__name__}: {e}"))
            return
        if vmem <= vmem_limit:
            add(c)
        else:
            pruned.append(
                (c.label, f"vmem estimate {vmem} > limit {vmem_limit}"))

    for cand in axis:
        add(cand)
        probe = cand.target_from(base)
        if executor_wants(cand.backend) == "halo_extended":
            if is_program:
                # divisors of every windowed stage's (extended) plane
                # count ≡ divisors of their gcd; feasibility is the
                # aggregated ProgramPlan VMEM model (max over stages)
                counts = _program_plane_counts(program_or_spec, probe,
                                               grid_shape)
                if not counts:
                    continue
                values = []
                for v in _divisors(math.gcd(*counts)):
                    pplan = program_or_spec.plan(
                        probe.with_tuning(plane_block=v),
                        grid_shape=grid_shape)
                    vmem = pplan.vmem_bytes_estimate()
                    if vmem <= vmem_limit:
                        values.append(v)
                    else:
                        pruned.append(
                            (f"{cand.label}[plane_block={v}]",
                             f"vmem estimate {vmem} > limit "
                             f"{vmem_limit}"))
            else:
                values, pr = plane_block_candidates(
                    program_or_spec, probe, lattice, halo=halo,
                    consts=consts, vmem_limit=vmem_limit)
                for v, why in pr:
                    pruned.append((f"{cand.label}[plane_block={v}]", why))
            for v in values:
                add(Candidate(cand.backend, cand.interpret,
                              ((("plane_block", int(v)),))))
            if per_stage and is_program:
                pplan0 = program_or_spec.plan(probe,
                                              grid_shape=grid_shape)
                stages_w = [(n, p.shape[0]) for n, p in pplan0.stages
                            if p.wants == "halo_extended"
                            and p.shape is not None]
                # one windowed stage: per-stage ≡ the global sweep
                if len(stages_w) > 1:
                    for sname, count in stages_w:
                        skey = f"stage:{sname}"
                        for v in _divisors(count):
                            if v == 1:
                                continue    # ≡ the default plane_block
                            nested = (("plane_block", int(v)),)
                            pplan = program_or_spec.plan(
                                probe.with_tuning({skey: nested}),
                                grid_shape=grid_shape)
                            vmem = pplan.vmem_bytes_estimate()
                            if vmem <= vmem_limit:
                                add(Candidate(cand.backend,
                                              cand.interpret,
                                              ((skey, nested),)))
                            else:
                                pruned.append(
                                    (f"{cand.label}[{skey}"
                                     f"{{plane_block={v}}}]",
                                     f"vmem estimate {vmem} > limit "
                                     f"{vmem_limit}"))
        elif not has_stencil:
            # pointwise launches: the block knobs the executor declares
            # (stencil programs route pointwise stages to xla, so the
            # knobs would be dead weight there)
            for key in executor_tunables(cand.backend):
                for v in POINTWISE_TUNABLE_VALUES.get(key, ()):
                    add(Candidate(cand.backend, cand.interpret,
                                  (((key, int(v)),))))

        # --- layout × vvl axes (ISSUE 10) -----------------------------
        if executor_wants(cand.backend) == "halo_extended":
            # windowed AoSoA: vvl must divide each windowed stage's
            # interior x-plane site count (plan-build contract in
            # repro.core.api._validate_layout) — sweep divisors of
            # their gcd
            if is_program:
                pplan = program_or_spec.plan(probe, grid_shape=grid_shape)
                counts = [
                    math.prod(int(s) for s in p.shape[1:])
                    for _, p in pplan.stages
                    if p.wants == "halo_extended" and p.shape is not None]
            elif lattice is not None:
                counts = [math.prod(int(s) for s in lattice.shape[1:])]
            else:
                counts = []
            counts = [c for c in counts if c > 0]
            if counts:
                for v in _vvl_values(math.gcd(*counts)):
                    add_vmem_checked(Candidate(cand.backend,
                                               cand.interpret,
                                               vvl=v, layout="aosoa"))
        elif nsites is not None:
            # gathered executors: the vvl sweep (SoA) plus one AoSoA
            # candidate per vvl — remainder sites pad, so every divisor
            # is valid
            for v in _vvl_values(nsites):
                if v != probe.resolve_vvl():   # ≡ the bare executor cand
                    add_vmem_checked(Candidate(cand.backend,
                                               cand.interpret, vvl=v))
                add_vmem_checked(Candidate(cand.backend, cand.interpret,
                                           vvl=v, layout="aosoa"))
    return candidates, pruned


# ---------------------------------------------------------------------------
# timers
# ---------------------------------------------------------------------------

def wall_clock_timer(candidate: Target, run: Callable[[], Any]) -> float:
    """The default timer: execute ``run`` once, block on its outputs,
    return elapsed wall-clock seconds.  The ``timer`` protocol — any
    ``(candidate_target, run) -> seconds`` callable — is the injection
    point for deterministic tests (a fake can script per-candidate costs
    and never execute anything)."""
    t0 = time.perf_counter()
    jax.block_until_ready(run())
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------

class CandidateResult(NamedTuple):
    """One measured point: the candidate, its median, the raw samples,
    and (when a scorer ran) the model's prediction — ``predicted_s``
    seconds and ``predicted_vs_measured`` = (predicted − measured) /
    measured (positive: the model overestimates)."""

    candidate: Candidate
    median_s: float
    times_s: tuple[float, ...]
    predicted_s: float | None = None
    predicted_vs_measured: float | None = None

    def as_dict(self) -> dict:
        return {**self.candidate.as_dict(), "label": self.candidate.label,
                "median_s": self.median_s, "times_s": list(self.times_s),
                "predicted_s": self.predicted_s,
                "predicted_vs_measured": self.predicted_vs_measured}


@dataclasses.dataclass(frozen=True)
class TuneReport:
    """What :func:`autotune` measured and chose.

    ``results`` holds one :class:`CandidateResult` per measured
    candidate (measurement order; the base target is always first);
    ``pruned`` the ``(label, reason)`` pairs rejected before or during
    measurement; ``best`` the winning candidate; ``cache_hit`` whether
    the choice was replayed from the on-disk cache without measuring.
    """

    name: str
    grid: tuple[int, ...]
    device: str
    results: tuple[CandidateResult, ...]
    pruned: tuple[tuple[str, str], ...]
    best: Candidate
    default_median_s: float
    cache_key: str
    cache_hit: bool = False
    measure_steps: int = 1
    rank_correlation: float | None = None
    schema: int = SCHEMA_VERSION

    @property
    def best_median_s(self) -> float:
        for r in self.results:
            if r.candidate == self.best:
                return r.median_s
        raise ValueError(f"best candidate {self.best.label!r} has no "
                         f"measurement")

    def as_dict(self) -> dict:
        return {
            "schema": self.schema,
            "name": self.name, "grid": list(self.grid),
            "device": self.device,
            "measure_steps": self.measure_steps,
            "cache_key": self.cache_key, "cache_hit": self.cache_hit,
            "best": {**self.best.as_dict(), "label": self.best.label,
                     "median_s": self.best_median_s},
            "default_median_s": self.default_median_s,
            "rank_correlation": self.rank_correlation,
            "candidates": [r.as_dict() for r in self.results],
            "pruned": [{"label": l, "reason": r} for l, r in self.pruned],
        }

    @classmethod
    def from_dict(cls, d: Mapping, *, cache_hit: bool = False):
        def _opt(v):
            return None if v is None else float(v)

        return cls(
            name=d["name"], grid=tuple(d["grid"]), device=d["device"],
            results=tuple(
                CandidateResult(Candidate.from_dict(c),
                                float(c["median_s"]),
                                tuple(float(t) for t in c["times_s"]),
                                _opt(c.get("predicted_s")),
                                _opt(c.get("predicted_vs_measured")))
                for c in d["candidates"]),
            pruned=tuple((p["label"], p["reason"]) for p in d["pruned"]),
            best=Candidate.from_dict(d["best"]),
            default_median_s=float(d["default_median_s"]),
            cache_key=d["cache_key"], cache_hit=cache_hit,
            measure_steps=int(d.get("measure_steps", 1)),
            rank_correlation=_opt(d.get("rank_correlation")),
            schema=int(d.get("schema", 1)))


class TuneResult(NamedTuple):
    """``(target, report)`` — tuple-unpackable."""

    target: Target
    report: TuneReport


# ---------------------------------------------------------------------------
# cache (results/tuning/)
# ---------------------------------------------------------------------------

def _stencil_sig(s) -> str:
    return "-" if s is None else f"{s.name}:{s.offsets}"


def _spec_digest(spec: KernelSpec) -> str:
    """Stable (cross-process — no Python string hashing) identity of a
    spec's *launch shape*: roles, stencil geometry, outputs.  The kernel
    body is identified by name — tuning choices depend on the launch
    structure, not the arithmetic."""
    parts = [spec.name, repr(spec.out), repr(spec.site_index),
             repr(spec.consts)]
    for fs in spec.fields:
        parts.append(f"{fs.ncomp}|{fs.halo}|{_stencil_sig(fs.stencil)}")
    return hashlib.sha256("&".join(parts).encode()).hexdigest()[:16]


def _subject_digest(program_or_spec) -> tuple[str, str]:
    if isinstance(program_or_spec, Program):
        parts = [program_or_spec.name]
        for st in program_or_spec.stages:
            parts.append(f"{st.name}|{_spec_digest(st.spec)}|"
                         f"{st.reads}|{st.writes}")
        digest = hashlib.sha256("&".join(parts).encode()).hexdigest()[:16]
        return program_or_spec.name, digest
    return program_or_spec.name, _spec_digest(program_or_spec)


def _device_kind() -> str:
    d = jax.devices()[0]
    return f"{d.platform}:{getattr(d, 'device_kind', '?')}"


def cache_key(program_or_spec, target: Target,
              grid: tuple[int, ...]) -> str:
    """The cache-key anatomy (docs/targetdp_api.md, "Autotuning"):
    ``<name>-<subject digest>-g<grid>-<base executor>-<device kind>``,
    filesystem-safe.  Deliberately *excludes* the tuning values being
    searched — the key identifies the question, the cached file holds
    the answer."""
    name, digest = _subject_digest(program_or_spec)
    grid_s = "x".join(str(int(s)) for s in grid)
    dev = _device_kind().replace(" ", "_").replace("/", "_")
    # Candidate.label spells interpret mode for every backend family
    # (Target.executor only does so for "pallas") — interpreter-measured
    # and compiled tuning runs must never share a cache entry.
    mode = Candidate(target.backend, target.interpret).label
    return f"{name}-{digest}-g{grid_s}-{mode}-{dev}"


def _cache_path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"{key}.json")


def load_cached(cache_dir: str, key: str) -> TuneReport | None:
    """The stored :class:`TuneReport` for ``key``, or ``None`` on miss /
    unreadable file (a corrupt cache entry is a miss, not an error)."""
    path = _cache_path(cache_dir, key)
    try:
        with open(path) as fh:
            data = json.load(fh)
        if data.get("cache_key") != key:
            return None
        # schema gate: v1 (pre-predictor) entries replay with defaulted
        # fields; an entry written by a *newer* schema than this process
        # understands is a miss, not a parse error
        if int(data.get("schema", 1)) > SCHEMA_VERSION:
            return None
        return TuneReport.from_dict(data, cache_hit=True)
    except (OSError, ValueError, KeyError, TypeError):
        return None


def store_cached(cache_dir: str, report: TuneReport) -> str:
    """Atomically persist ``report`` under its cache key.

    The entry is serialised to a private tempfile in ``cache_dir`` (same
    filesystem, so the final rename is atomic) and ``os.replace``\\ d
    into place: an interrupted run can never leave a truncated entry
    behind, and concurrent writers (two bench processes sharing
    ``results/tuning/``) each land a complete file — last one wins."""
    os.makedirs(cache_dir, exist_ok=True)
    path = _cache_path(cache_dir, report.cache_key)
    fd, tmp = tempfile.mkstemp(dir=cache_dir,
                               prefix=f".{report.cache_key}-",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(report.as_dict(), fh, indent=1, default=str)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------

def _as_candidates(space) -> list[Candidate]:
    out = []
    for c in space:
        if isinstance(c, Candidate):
            out.append(c)
        elif isinstance(c, Target):
            out.append(Candidate.of(c))
        elif isinstance(c, str):
            out.append(Candidate.of(as_target(c)))
        else:
            raise TypeError(f"space entries must be Candidate, Target or "
                            f"backend string; got {type(c).__name__}")
    return out


def _rank_correlation(results: Sequence[CandidateResult]) -> float | None:
    """Spearman rank correlation between predicted and measured seconds
    over the measured set (``None`` with <2 scored points or a
    degenerate ranking)."""
    pts = [(r.predicted_s, r.median_s) for r in results
           if r.predicted_s is not None]
    if len(pts) < 2:
        return None
    pred = np.asarray([p for p, _ in pts], dtype=float)
    meas = np.asarray([m for _, m in pts], dtype=float)
    rp = np.argsort(np.argsort(pred)).astype(float)
    rm = np.argsort(np.argsort(meas)).astype(float)
    if rp.std() == 0 or rm.std() == 0:
        return None
    return float(np.corrcoef(rp, rm)[0, 1])


def _default_scorer(program_or_spec, is_program: bool, grid, *,
                    lattice, halo, consts,
                    profile) -> Callable[[Target], float | None]:
    """The costmodel-backed candidate scorer: plan the subject under the
    candidate target, :func:`repro.core.costmodel.predict` the plan.
    ``profile=None`` resolves per candidate (interpret candidates score
    against the interpret profile, compiled ones against the compiled
    profile — the honest-profile rule).  Returns ``None`` for
    candidates the model cannot score."""

    def scorer(tgt: Target) -> float | None:
        try:
            if is_program:
                plan = program_or_spec.plan(
                    tgt.with_(mesh=None, shard_axis=None),
                    grid_shape=grid)
            else:
                plan = _launch_plan(program_or_spec, tgt, lattice=lattice,
                                    halo=halo, consts=consts)
            return float(_costmodel.predict(plan, profile=profile).seconds)
        except Exception:
            return None

    return scorer


def autotune(program_or_spec, target: Target | str | None = None,
             example_state=None, *,
             space: Sequence | None = None,
             budget: int | None = None,
             measure_steps: int = 3,
             reps: int = 3, warmup: int = 1,
             timer: Callable[[Target, Callable[[], Any]], float] | None
             = None,
             grid_shape: Sequence[int] | None = None,
             lattice: Lattice | None = None, halo=None, consts=None,
             executors: Sequence[str] | None = None,
             vmem_limit: int = DEFAULT_VMEM_LIMIT,
             check_identical: bool = False,
             scorer: Callable[[Target], float | None] | None = None,
             top_k: int | None = None,
             profile=None,
             per_stage: bool = False,
             cache_dir: str | None = "results/tuning") -> TuneResult:
    """Choose ``Target.tuning`` (and the executor) empirically.

    Args:
      program_or_spec: a :class:`Program`, :class:`CompiledProgram` (its
        program/target/grid are reused), or :class:`KernelSpec`.
      target: the base target — always measured as candidate 0, so the
        returned target's median is ≤ the default-tuning median.  For a
        ``CompiledProgram``, defaults to its compile target.
      example_state: what one measurement runs on — a ``{field: (ncomp,
        *grid)}`` mapping for programs, a sequence of ``(ncomp, nsites)``
        SoA arrays for specs.
      space: explicit candidate list (:class:`Candidate` / ``Target`` /
        backend strings); ``None`` derives :func:`default_space`.
      budget: measure at most this many candidates (the base target is
        always kept; the rest are taken in space order).
      measure_steps: steps per timed call — ``Program`` candidates run
        ``measure_steps`` compiled steps per sample, specs launch
        ``measure_steps`` times.
      reps / warmup: samples per candidate (median taken) / discarded
        leading calls (compile + cache warm).
      timer: ``(candidate_target, run) -> seconds``; default
        :func:`wall_clock_timer`.  Inject a fake for deterministic tests.
      grid_shape / lattice / halo / consts: launch geometry (programs
        infer ``grid_shape`` from ``example_state``).
      executors / vmem_limit: forwarded to :func:`default_space`.
      check_identical: additionally run every candidate once and prune
        any whose outputs are not bit-identical to the base target's
        (tuning must never change results; a mismatch is an executor
        bug, surfaced in ``report.pruned``, never silently chosen).
      scorer: ``(candidate_target) -> predicted seconds | None`` — the
        analytical model ranking the space.  Defaults to the
        :mod:`repro.core.costmodel` roofline predictor.  Every measured
        candidate records its prediction (``predicted_s``,
        ``predicted_vs_measured``) and the report the Spearman
        ``rank_correlation`` over the measured set.
      top_k: measure only the base target plus the ``top_k``
        best-predicted candidates (at most ``top_k + 1`` measurements);
        the rest land in ``report.pruned`` with a ``model-pruned``
        reason — recorded, never silently dropped.  Candidate 0 (the
        base target) is always measured regardless of its score.
      profile: the :class:`repro.core.costmodel.MachineProfile` for the
        default scorer (``None`` resolves per candidate — interpret
        candidates against the interpret profile).
      per_stage: also sweep per-stage ``plane_block`` assignments for
        programs with more than one windowed stage (the reserved
        ``"stage:<name>"`` tuning keys; see :func:`default_space`).
      cache_dir: on-disk cache directory (``None`` disables).  A hit
        replays the stored choice without measuring.

    Returns a :class:`TuneResult` ``(tuned_target, report)``.
    """
    if isinstance(program_or_spec, CompiledProgram):
        if target is None:
            target = program_or_spec.target
        if grid_shape is None:
            grid_shape = program_or_spec.grid_shape
        program_or_spec = program_or_spec.program
    base = as_target(target)

    is_program = isinstance(program_or_spec, Program)
    if is_program:
        if example_state is None:
            raise ValueError("autotune over a Program needs example_state "
                             "({field: (ncomp, *grid) array})")
        state = {f: example_state[f] for f in program_or_spec.fields}
        if grid_shape is None:
            grid_shape = tuple(
                int(s) for s in next(iter(state.values())).shape[1:])
        grid = tuple(int(s) for s in grid_shape)
    elif isinstance(program_or_spec, KernelSpec):
        if example_state is None:
            raise ValueError("autotune over a KernelSpec needs "
                             "example_state (the launch arrays)")
        arrays = tuple(example_state)
        if program_or_spec.has_stencil and lattice is None:
            raise ValueError("autotune over a stencil KernelSpec needs "
                             "the lattice")
        grid = (tuple(lattice.shape) if lattice is not None
                else (int(arrays[0].shape[-1]),))
    else:
        raise TypeError(f"autotune expects a Program, CompiledProgram or "
                        f"KernelSpec; got {type(program_or_spec).__name__}")

    key = cache_key(program_or_spec, base, grid)
    if cache_dir is not None:
        cached = load_cached(cache_dir, key)
        if cached is not None:
            return TuneResult(cached.best.target_from(base), cached)

    if space is None:
        candidates, pruned = default_space(
            program_or_spec, base, grid_shape=grid if is_program else None,
            lattice=lattice, halo=halo, consts=consts,
            executors=executors, vmem_limit=vmem_limit,
            per_stage=per_stage,
            site_count=None if is_program else int(arrays[0].shape[-1]))
    else:
        pruned = []
        base_cand = Candidate.of(base)
        # the base target is always candidate 0 (the default-median
        # baseline, the check_identical reference, the must-run entry) —
        # even when an explicit space lists it elsewhere
        candidates = [base_cand] + [c for c in _as_candidates(space)
                                    if c != base_cand]
    if budget is not None and len(candidates) > max(1, int(budget)):
        kept = candidates[:max(1, int(budget))]
        for c in candidates[len(kept):]:
            pruned.append((c.label, f"over budget={budget}"))
        candidates = kept

    # -- predictor pass: score every candidate (the predictions annotate
    # every cache entry even without top_k; an unscoreable candidate is
    # None, never an error) --------------------------------------------
    if scorer is None:
        scorer = _default_scorer(program_or_spec, is_program, grid,
                                 lattice=lattice, halo=halo,
                                 consts=consts, profile=profile)
    scores: dict[str, float | None] = {}
    for c in candidates:
        try:
            s = scorer(c.target_from(base))
        except Exception:  # noqa: BLE001 — a scorer failure never blocks
            s = None
        scores[c.label] = None if s is None else float(s)

    if top_k is not None:
        k = max(0, int(top_k))
        rest = candidates[1:]       # candidate 0 is never model-pruned
        ranked = sorted((c for c in rest if scores[c.label] is not None),
                        key=lambda c: scores[c.label])
        keep = {c.label for c in ranked[:k]}
        for rank, c in enumerate(ranked[k:], start=k + 1):
            pruned.append(
                (c.label, f"model-pruned: predicted rank {rank} > "
                          f"top_k={k} ({scores[c.label]:.3g}s)"))
        for c in rest:
            if scores[c.label] is None:
                pruned.append((c.label, "model-pruned: scorer returned "
                                        "no estimate"))
        candidates = [candidates[0]] + [c for c in rest
                                        if c.label in keep]

    timer = timer if timer is not None else wall_clock_timer
    n_steps = max(1, int(measure_steps))

    def runner(tgt: Target) -> Callable[[], Any]:
        if is_program:
            exe = program_or_spec.compile(
                tgt.with_(mesh=None, shard_axis=None), grid_shape=grid)
            return lambda: exe.run(state, n_steps)

        def run():
            out = None
            for _ in range(n_steps):
                out = _launch(program_or_spec, tgt, *arrays,
                              lattice=lattice, halo=halo,
                              consts=dict(consts or {}))
            return out
        return run

    ref_out = None
    results: list[CandidateResult] = []
    pruned = list(pruned)
    default_median = None
    for i, cand in enumerate(candidates):
        tgt = cand.target_from(base)
        try:
            run = runner(tgt)
            if check_identical:
                out = run()
                flat = jax.tree_util.tree_leaves(out)
                if i == 0:
                    ref_out = [np.asarray(x) for x in flat]
                elif (len(flat) != len(ref_out)
                      or not all(np.array_equal(a, np.asarray(b))
                                 for a, b in zip(ref_out, flat))):
                    pruned.append((cand.label,
                                   "output not bit-identical to the "
                                   "default target"))
                    continue
            for _ in range(max(0, int(warmup))):
                timer(tgt, run)
            times = tuple(float(timer(tgt, run))
                          for _ in range(max(1, int(reps))))
        except Exception as e:  # noqa: BLE001 — an unrunnable candidate
            # (e.g. real-Pallas on a CPU host) is pruned, not fatal...
            if i == 0:
                raise   # ...but the *base* target must be runnable.
            pruned.append((cand.label, f"error: {type(e).__name__}: {e}"))
            continue
        median = float(np.median(times))
        if i == 0:
            default_median = median
        predicted = scores.get(cand.label)
        pvm = ((predicted - median) / median
               if predicted is not None and median > 0 else None)
        results.append(CandidateResult(cand, median, times, predicted,
                                       pvm))

    if not results:
        raise RuntimeError(
            f"autotune({key}): no candidate survived measurement "
            f"(pruned: {[p[0] for p in pruned]})")
    # min() keeps the *first* minimum, and the base target is always
    # measured first — exact ties go to candidate 0, so a tuned target
    # never trades the default dispatch for an equally-fast exotic one
    best = min(results, key=lambda r: r.median_s).candidate
    report = TuneReport(
        name=_subject_digest(program_or_spec)[0], grid=grid,
        device=_device_kind(), results=tuple(results),
        pruned=tuple(pruned), best=best,
        default_median_s=float(default_median),
        cache_key=key, cache_hit=False, measure_steps=n_steps,
        rank_correlation=_rank_correlation(results))
    if cache_dir is not None:
        store_cached(cache_dir, report)
    return TuneResult(best.target_from(base), report)
