"""Lattice descriptors — the structured grids targetDP operates over.

A :class:`Lattice` is a static description of a structured grid of *sites*.
It carries no data; :class:`repro.core.field.Field` attaches per-site values.

Two lattice families appear in this framework:

* the 3-D fluid lattice used by the Ludwig binary-fluid application
  (``Lattice(shape=(Lx, Ly, Lz), halo=1)``), and
* the flattened *token lattice* used by the LM substrate
  (``Lattice(shape=(batch, seq))``) — every token position is a site.

Following the paper (§III-C), launched kernels iterate over sites in chunks
of a tunable *virtual vector length* (VVL).  The site count is padded up to a
multiple of the VVL at launch time; :meth:`Lattice.padded_nsites` gives the
padded extent for a given VVL.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import reduce
from operator import mul


def _prod(xs) -> int:
    return reduce(mul, xs, 1)


@dataclass(frozen=True)
class Lattice:
    """A static structured grid of sites.

    Args:
      shape: per-dimension site extents (excluding halo).
      halo: halo width in every dimension (0 for the token lattice; >=1 for
        stencil codes such as lattice Boltzmann streaming).
    """

    shape: tuple[int, ...]
    halo: int = 0

    def __post_init__(self):
        if not self.shape:
            raise ValueError("lattice must have at least one dimension")
        if any(int(s) <= 0 for s in self.shape):
            raise ValueError(f"lattice extents must be positive, got {self.shape}")
        if self.halo < 0:
            raise ValueError("halo must be non-negative")
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nsites(self) -> int:
        """Number of interior (non-halo) sites."""
        return _prod(self.shape)

    @property
    def halo_shape(self) -> tuple[int, ...]:
        """Per-dimension extents including halo."""
        return tuple(s + 2 * self.halo for s in self.shape)

    @property
    def nsites_with_halo(self) -> int:
        return _prod(self.halo_shape)

    def padded_nsites(self, vvl: int) -> int:
        """Site count rounded up to a multiple of ``vvl`` (paper §III-C:
        the TLP loop strides in steps of VVL, so the site extent must be a
        whole number of chunks)."""
        if vvl <= 0:
            raise ValueError("vvl must be positive")
        return math.ceil(self.nsites / vvl) * vvl

    def nchunks(self, vvl: int) -> int:
        """Number of VVL-sized site chunks (the TLP grid extent)."""
        return self.padded_nsites(vvl) // vvl

    def interior_slices(self) -> tuple[slice, ...]:
        """Slices selecting the interior of a halo-padded array."""
        if self.halo == 0:
            return tuple(slice(None) for _ in self.shape)
        return tuple(slice(self.halo, self.halo + s) for s in self.shape)


def token_lattice(batch: int, seq: int) -> Lattice:
    """The LM token lattice: one site per (batch, position) pair."""
    return Lattice(shape=(batch, seq), halo=0)
