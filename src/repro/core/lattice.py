"""Lattice descriptors — the structured grids targetDP operates over.

A :class:`Lattice` is a static description of a structured grid of *sites*.
It carries no data; :class:`repro.core.field.Field` attaches per-site values.

Two lattice families appear in this framework:

* the 3-D fluid lattice used by the Ludwig binary-fluid application
  (``Lattice(shape=(Lx, Ly, Lz), halo=1)``), and
* the flattened *token lattice* used by the LM substrate
  (``Lattice(shape=(batch, seq))``) — every token position is a site.

Following the paper (§III-C), launched kernels iterate over sites in chunks
of a tunable *virtual vector length* (VVL).  The site count is padded up to a
multiple of the VVL at launch time; :meth:`Lattice.padded_nsites` gives the
padded extent for a given VVL.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import reduce
from operator import mul


def _prod(xs) -> int:
    return reduce(mul, xs, 1)


@dataclass(frozen=True)
class Lattice:
    """A static structured grid of sites.

    Args:
      shape: per-dimension site extents (excluding halo).
      halo: halo width in every dimension (0 for the token lattice; >=1 for
        stencil codes such as lattice Boltzmann streaming).
    """

    shape: tuple[int, ...]
    halo: int = 0

    def __post_init__(self):
        if not self.shape:
            raise ValueError("lattice must have at least one dimension")
        if any(int(s) <= 0 for s in self.shape):
            raise ValueError(f"lattice extents must be positive, got {self.shape}")
        if self.halo < 0:
            raise ValueError("halo must be non-negative")
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def nsites(self) -> int:
        """Number of interior (non-halo) sites."""
        return _prod(self.shape)

    @property
    def halo_shape(self) -> tuple[int, ...]:
        """Per-dimension extents including halo."""
        return tuple(s + 2 * self.halo for s in self.shape)

    @property
    def nsites_with_halo(self) -> int:
        return _prod(self.halo_shape)

    def padded_nsites(self, vvl: int) -> int:
        """Site count rounded up to a multiple of ``vvl`` (paper §III-C:
        the TLP loop strides in steps of VVL, so the site extent must be a
        whole number of chunks)."""
        if vvl <= 0:
            raise ValueError("vvl must be positive")
        return math.ceil(self.nsites / vvl) * vvl

    def nchunks(self, vvl: int) -> int:
        """Number of VVL-sized site chunks (the TLP grid extent)."""
        return self.padded_nsites(vvl) // vvl

    def interior_slices(self) -> tuple[slice, ...]:
        """Slices selecting the interior of a halo-padded array."""
        if self.halo == 0:
            return tuple(slice(None) for _ in self.shape)
        return tuple(slice(self.halo, self.halo + s) for s in self.shape)


def token_lattice(batch: int, seq: int) -> Lattice:
    """The LM token lattice: one site per (batch, position) pair."""
    return Lattice(shape=(batch, seq), halo=0)


# ---------------------------------------------------------------------------
# stencils — first-class neighbourhood descriptors
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Stencil:
    """A static set of neighbour offsets a site kernel reads.

    ``launch_stencil`` gathers, for every input field carrying a stencil,
    one ``(noffsets, ncomp, VVL)`` chunk per site chunk: slot ``i`` holds
    the field value at ``site + offsets[i]``.  Offsets are ordered — kernels
    address slots by :meth:`index` (resolved at trace time, so the lookup
    costs nothing at run time).

    The descriptor is the single source of truth for the halo the launch
    needs (:attr:`radius`) and for the VMEM footprint of the Pallas
    executor (one block row per offset).
    """

    name: str
    offsets: tuple[tuple[int, ...], ...]

    def __post_init__(self):
        offs = tuple(tuple(int(c) for c in o) for o in self.offsets)
        if not offs:
            raise ValueError("stencil needs at least one offset")
        ndims = {len(o) for o in offs}
        if len(ndims) != 1:
            raise ValueError(f"offsets disagree on dimensionality: {offs}")
        if len(set(offs)) != len(offs):
            raise ValueError(f"duplicate offsets in stencil {self.name!r}")
        object.__setattr__(self, "offsets", offs)

    @property
    def ndim(self) -> int:
        return len(self.offsets[0])

    @property
    def noffsets(self) -> int:
        return len(self.offsets)

    @property
    def radius(self) -> int:
        """Max |offset| component — the halo width the stencil needs."""
        return max(abs(c) for o in self.offsets for c in o)

    def radius_per_dim(self) -> tuple[int, ...]:
        return tuple(max(abs(o[d]) for o in self.offsets)
                     for d in range(self.ndim))

    def index(self, offset) -> int:
        """Slot of ``offset`` in the gathered neighbour axis."""
        key = tuple(int(c) for c in offset)
        try:
            return self.offsets.index(key)
        except ValueError:
            raise KeyError(
                f"offset {key} not in stencil {self.name!r}") from None

    def compose(self, other: "Stencil", name: str | None = None) -> "Stencil":
        """Minkowski sum: every ``a + b`` offset, deduplicated.

        Composition is how fused multi-stage stencils are built: a pull
        stream (offsets ``-c_q``) composed with a gradient star gives the
        neighbourhood of gradient-of-streamed-field in one launch.
        """
        seen, offs = set(), []
        for a in self.offsets:
            for b in other.offsets:
                o = tuple(x + y for x, y in zip(a, b))
                if o not in seen:
                    seen.add(o)
                    offs.append(o)
        return Stencil(name or f"{self.name}*{other.name}", tuple(offs))


def _d3q19_velocities() -> tuple[tuple[int, int, int], ...]:
    """The D3Q19 velocity set (rest, 6 axis vectors, 12 face diagonals) —
    canonical integer form; ``repro.kernels.lb_collision.CV`` is its float
    counterpart (asserted equal there)."""
    axis = [(1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1),
            (0, 0, -1)]
    diag = [(1, 1, 0), (1, -1, 0), (-1, 1, 0), (-1, -1, 0),
            (1, 0, 1), (1, 0, -1), (-1, 0, 1), (-1, 0, -1),
            (0, 1, 1), (0, 1, -1), (0, -1, 1), (0, -1, -1)]
    return tuple([(0, 0, 0)] + axis + diag)


D3Q19_VELOCITIES: tuple[tuple[int, int, int], ...] = _d3q19_velocities()

#: Pull-scheme streaming: slot q holds the neighbour at ``-c_q``, i.e. the
#: upstream site whose population arrives here (f_q(x) ← f_q(x - c_q)).
STENCIL_D3Q19_PULL = Stencil(
    "d3q19_pull", tuple(tuple(-c for c in o) for o in D3Q19_VELOCITIES))

#: 6-point nearest-neighbour gradient star (+ centre): slot 0 is the site
#: itself, slots 1.. are (+x, -x, +y, -y, +z, -z).
STENCIL_GRAD_6PT = Stencil("grad_6pt", tuple(D3Q19_VELOCITIES[:7]))

#: 19-point isotropic gradient neighbourhood (centre + 18 D3Q19 neighbours).
STENCIL_GRAD_19PT = Stencil("grad_19pt", D3Q19_VELOCITIES)
