"""``tdp.faults`` — deterministic fault injection for chaos testing.

A resilience claim is only as good as the faults it was proven against.
This module is the *test harness* side of ``tdp.resilience``: small,
deterministic injectors covering the failure modes a long-running fleet
service actually sees, each scheduled explicitly (raise on the k-th
call, poison at member step s, damage checkpoint step n) so a chaos
test is a **seeded schedule**, not a dice roll:

* :func:`register_failing_executor` — an executor that delegates to a
  real one but raises :class:`InjectedFault` on scheduled invocations.
  Executors run at *trace* time inside the jitted launch closure, so
  the fault fires when a bucket (re)compiles — the "device backend
  fell over" failure.
* :func:`nan_at_step` / :func:`raise_in_pump` — chaos hooks for
  :meth:`FleetDriver.inject`: poison one ticket's live state with a
  non-finite value once it reaches a step (the silent-divergence
  failure), or blow up the pump loop itself (the pump-thread-crash
  failure the driver must surface, not swallow).
* :func:`kill_pump_thread` — abrupt shutdown: stops the background
  thread without the graceful final checkpoint flush, simulating
  process death for kill-and-restore tests.
* :func:`corrupt_checkpoint` — byte-flip / truncate / manifest-damage
  a written snapshot, for restore-fallback tests.

Everything here reaches into driver internals on purpose; it ships in
the library (not the test tree) so operators can rehearse failure
drills against their own programs — but nothing in the serving path
imports it.
"""
from __future__ import annotations

import os

import numpy as np

from .registry import get_executor_entry, register_executor, \
    unregister_executor


__all__ = [
    "InjectedFault",
    "register_failing_executor",
    "nan_at_step",
    "raise_in_pump",
    "kill_pump_thread",
    "corrupt_checkpoint",
]


class InjectedFault(RuntimeError):
    """The marker exception every injector raises — chaos tests assert
    on this type to be sure they caught *their* fault, not a real bug."""


class _FailingExecutor:
    """Callable executor delegating to ``base`` except on scheduled
    host-level invocations (see :func:`register_failing_executor`)."""

    def __init__(self, name: str, base_fn, fail_on: int, times: float):
        self.name = name
        self._base = base_fn
        self.fail_on = int(fail_on)
        self.times = times          # float("inf") = persistent
        self.calls = 0

    def __call__(self, plan, arrays):
        self.calls += 1
        if self.fail_on <= self.calls < self.fail_on + self.times:
            raise InjectedFault(
                f"injected executor fault: call {self.calls} of "
                f"executor {self.name!r} (schedule: fail_on="
                f"{self.fail_on}, times={self.times})")
        return self._base(plan, arrays)


def register_failing_executor(name: str, *, base: str = "xla",
                              fail_on: int = 1,
                              times: float = 1) -> _FailingExecutor:
    """Register executor ``name``: behaves exactly like ``base`` but
    raises :class:`InjectedFault` on host invocations ``fail_on ..
    fail_on+times-1`` (1-based; ``times=float("inf")`` never recovers).

    Executors are invoked when a launch *traces* (jit caching means a
    repeated identical launch does not re-invoke them), so ``fail_on=1``
    faults the first compile of whatever Target routes here.  Returns
    the handle (``.calls`` counts invocations); call
    :func:`unregister_failing_executor` (or
    ``tdp.unregister_executor(name)``) to clean up.
    """
    if fail_on < 1:
        raise ValueError(f"fail_on is a 1-based call index, got {fail_on}")
    if not times >= 1:
        raise ValueError(f"times must be >= 1 (or inf), got {times}")
    entry = get_executor_entry(base)
    handle = _FailingExecutor(name, entry.fn, fail_on, times)
    register_executor(name, handle, overwrite=True, wants=entry.wants,
                      tunables=entry.tunables)
    return handle


def unregister_failing_executor(name: str) -> None:
    unregister_executor(name)


# ---------------------------------------------------------------------------
# driver chaos hooks (FleetDriver.inject)
# ---------------------------------------------------------------------------
# A hook is ``fn(driver) -> bool`` run under the driver lock at the top
# of every pump round; returning True retires the hook.

def nan_at_step(ticket_id: str, field: str, at_step: int, *,
                value: float = np.nan):
    """Chaos hook: once ticket ``ticket_id`` reaches member step
    ``at_step``, poison one element of ``field`` in its *live* state
    (the bucket slot row, or the solo state) with ``value`` — the next
    pump chunk propagates it, and a :class:`~repro.core.health.
    HealthPolicy` guard should quarantine exactly that member."""
    import jax.numpy as jnp

    def hook(driver) -> bool:
        t = driver._tickets.get(ticket_id)
        if t is None or t.status in ("done", "failed"):
            return True                       # too late — retire
        if t.step < at_step:
            return False
        if t._bucket is not None and t._slot is not None:
            b, f = t._bucket, field
            a = b.state[f]
            idx = (t._slot,) + (0,) * (a.ndim - 1)
            b.state = {**b.state, f: a.at[idx].set(value)}
        else:
            a = jnp.asarray(t._state[field])
            t._state = {**t._state,
                        field: a.at[(0,) * a.ndim].set(value)}
        return True

    return hook


def raise_in_pump(at_pump: int = 1):
    """Chaos hook: raise :class:`InjectedFault` from inside
    :meth:`FleetDriver.pump` itself, *outside* the per-bucket fault
    protocol — the pump-thread-crash failure.  One-shot: fires on the
    first pump round where ``driver._pumps + 1 >= at_pump``."""
    armed = {"live": True}

    def hook(driver) -> bool:
        if not armed["live"]:
            return True
        if driver._pumps + 1 >= at_pump:
            armed["live"] = False
            raise InjectedFault(
                f"injected pump-thread fault at pump round "
                f"{driver._pumps + 1}")
        return False

    return hook


def kill_pump_thread(driver) -> None:
    """Abruptly stop a driver's background pump thread: no graceful
    shutdown, no final checkpoint flush — what a SIGKILL mid-service
    leaves behind.  Restore-path tests pair this with
    :meth:`FleetDriver.restore`."""
    driver._stop.set()
    with driver._lock:
        driver._cond.notify_all()
    if driver._thread is not None:
        driver._thread.join()
        driver._thread = None


# ---------------------------------------------------------------------------
# checkpoint damage
# ---------------------------------------------------------------------------

def corrupt_checkpoint(root: str, *, step: int | None = None,
                       mode: str = "flip") -> str:
    """Deterministically damage the checkpoint at ``step`` (default:
    the newest) under ``root``.  Modes:

    * ``"flip"`` — XOR one byte in the first array shard (sha256
      mismatch; the file still loads).
    * ``"truncate"`` — cut the first array shard in half (torn write).
    * ``"manifest"`` — truncate ``manifest.json`` (unreadable step).

    Returns the damaged directory path.
    """
    from repro.checkpoint.store import _MANIFEST, _step_dir, latest_step

    step = latest_step(root) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {root}")
    path = _step_dir(root, int(step))
    if mode == "manifest":
        mpath = os.path.join(path, _MANIFEST)
        size = os.path.getsize(mpath)
        with open(mpath, "r+b") as fh:
            fh.truncate(max(1, size // 2))
        return path
    arrs = sorted(f for f in os.listdir(path) if f.endswith(".npy"))
    if not arrs:
        raise FileNotFoundError(f"checkpoint {path} has no array shards")
    fp = os.path.join(path, arrs[0])
    size = os.path.getsize(fp)
    if mode == "flip":
        with open(fp, "r+b") as fh:
            off = min(128, size - 1)           # land inside the payload
            fh.seek(off)
            b = fh.read(1)
            fh.seek(off)
            fh.write(bytes([b[0] ^ 0xFF]))
    elif mode == "truncate":
        with open(fp, "r+b") as fh:
            fh.truncate(max(1, size // 2))
    else:
        raise ValueError(f"unknown corruption mode {mode!r}; expected "
                         f"'flip', 'truncate' or 'manifest'")
    return path
