"""The unified targetDP launch: ``tdp.launch(spec, target, *arrays)``.

One entry point replaces the old ``launch``/``launch_stencil`` fork: the
:class:`~repro.core.spec.KernelSpec` declares *what* (kernel body, field
roles, stencils, outputs), the :class:`~repro.core.target.Target`
declares *where/how* (executor, VVL, tuning), and this module owns the
single shared path every launch takes:

1. **validation** — field roles vs array ranks/extents, stencil geometry
   vs lattice + halo, const names;
2. **const unwrapping** — ``TargetConst`` → raw values, content-hashed
   into the cache key;
3. **plan caching** — compiled closures keyed on
   ``(spec, target, resolved VVL, lattice, halo, out, consts, registry
   version)``, so a mutated default VVL or a re-registered executor
   (even one re-registered with a different capability) can never hit a
   stale closure;
4. **the neighbour prologue** — *capability-aware*: executors declaring
   ``wants="gathered"`` (the default) get the periodic-roll /
   ghost-window gather into ``(noffsets, ncomp, nsites)`` stacks;
   executors declaring ``wants="halo_extended"`` get each stencil field
   **once**, as a halo-extended ``(ncomp, *ext_shape)`` grid
   (:func:`halo_extend`) — no ``noffsets×`` re-materialisation in HBM;
5. **dispatch** — through the executor registry
   (:mod:`repro.core.registry`).

Built-in executors registered here: ``"xla"`` (vmap over VVL chunks — the
paper's C build), ``"pallas"`` and ``"pallas_interpret"`` (explicit VMEM
tiling — the CUDA build), and ``"pallas_windowed"`` (gather-free x-plane
windowed VMEM loads — ROADMAP stencil-memory stage (b); Pallas modules
imported lazily so the core stays importable without Pallas).
"""
from __future__ import annotations

import functools
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from .lattice import Lattice, Stencil
from .layout import aosoa_to_soa, soa_to_aosoa
from .memory import BatchedConst, TargetConst
from .registry import (
    get_executor_entry,
    register_executor,
    registry_version,
)
from .spec import FieldSpec, KernelSpec
from .target import Target, as_target


# ---------------------------------------------------------------------------
# shared helpers (padding, gathering, const handling)
# ---------------------------------------------------------------------------

def pad_sites(x: jax.Array, vvl: int) -> jax.Array:
    """Zero-pad the trailing site axis up to a VVL multiple (paper §III-C:
    the TLP loop strides in whole chunks).  Shared by every executor —
    padded lanes are sliced away after the launch, so kernels may produce
    garbage (even NaN) there."""
    n = x.shape[-1]
    n_pad = -(-n // vvl) * vvl
    if n_pad == n:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, n_pad - n)]
    return jnp.pad(x, widths)


def _prod_shape(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def gather_neighbors(x: jax.Array, shape: tuple[int, ...],
                     halo: tuple[int, ...], stencil: Stencil) -> jax.Array:
    """``(ncomp, nsites_ext)`` → ``(noffsets, ncomp, nsites)`` neighbour
    stack over the interior sites.

    Dimensions with ``halo[d] == 0`` wrap periodically (``roll``); those
    with ``halo[d] > 0`` read the caller-supplied ghost planes (offset
    window into the extended extent).
    """
    ext = tuple(s + 2 * h for s, h in zip(shape, halo))
    grid = x.reshape(x.shape[0], *ext)
    n = _prod_shape(shape)
    planes = []
    for off in stencil.offsets:
        g = grid
        for d, o in enumerate(off):
            ax = d + 1
            if halo[d]:
                g = jax.lax.slice_in_dim(g, halo[d] + o,
                                         halo[d] + o + shape[d], axis=ax)
            elif o:
                g = jnp.roll(g, -o, axis=ax)
        planes.append(g.reshape(x.shape[0], n))
    return jnp.stack(planes)


def halo_extend(x: jax.Array, shape: tuple[int, ...],
                halo: tuple[int, ...], stencil: Stencil) -> jax.Array:
    """``(ncomp, nsites_ext)`` → halo-extended grid ``(ncomp, *ext)`` with
    exactly ``stencil.radius_per_dim()`` ghost layers per dimension.

    The gather-free prologue for ``wants="halo_extended"`` executors
    (:mod:`repro.core.registry`): instead of rolling out one copy of the
    field per stencil offset, the field is padded **once** so every
    neighbour of every interior site is addressable by a static in-kernel
    shift.  Dimensions with ``halo[d] == 0`` wrap periodically
    (``jnp.pad(mode="wrap")``); dimensions with ``halo[d] > 0`` reuse the
    caller-supplied ghost planes, trimmed down to the stencil radius.
    """
    r = stencil.radius_per_dim()
    ext_in = tuple(s + 2 * h for s, h in zip(shape, halo))
    g = x.reshape(x.shape[0], *ext_in)
    widths = [(0, 0)]
    for d, (h, rd, s) in enumerate(zip(halo, r, shape)):
        if h:
            if h > rd:       # caller ghost wider than needed: trim
                g = jax.lax.slice_in_dim(g, h - rd, h + rd + s, axis=d + 1)
            widths.append((0, 0))
        else:
            if rd > s:
                raise ValueError(
                    f"stencil {stencil.name!r} radius {rd} in dim {d} "
                    f"exceeds the periodic extent {s}; refusing to "
                    f"wrap-pad more than one full period — supply "
                    f">= {rd} exchanged ghost planes in dim {d} "
                    f"(halo > 0) or enlarge the dimension")
            widths.append((rd, rd))
    if any(w != (0, 0) for w in widths):
        g = jnp.pad(g, widths, mode="wrap")
    return g


def _unwrap_consts(consts: Mapping[str, object]) -> dict:
    out = {}
    for k, v in consts.items():
        out[k] = v.value if isinstance(v, TargetConst) else v
    return out


def _consts_cache_key(consts: Mapping[str, object]):
    items = []
    for k in sorted(consts):
        v = consts[k]
        if isinstance(v, TargetConst):
            items.append((k, v))
        elif isinstance(v, (int, float, bool, str)):
            items.append((k, v))
        else:
            # Fall back to content hashing through TargetConst semantics.
            items.append((k, TargetConst(v)))
    return tuple(items)


def _split_consts(consts: Mapping[str, object]):
    """Partition launch consts into *static* values (hashable — closed
    over at jit time, in the plan cache key by content) and *dynamic*
    ones (jax arrays / tracers — per-call operands threaded into the
    jitted launch as trailing arguments; the cache key carries only
    their ``(name, shape, dtype)`` signature).  Dynamic consts are how
    per-member fleet parameters (``BatchedConst`` sweeps vmapped over an
    ensemble axis) flow through the shared plan cache without ever
    leaking a tracer into it."""
    static, dyn = {}, {}
    for k, v in consts.items():
        if isinstance(v, BatchedConst):
            raise ValueError(
                f"const {k!r} is a BatchedConst (per-member ensemble "
                f"sweep); a bare launch has no ensemble axis — bind it "
                f"through a Program stage and compile a fleet with "
                f"CompiledProgram.vmap(batch) (tdp.fleet)")
        if isinstance(v, jax.Array):
            dyn[k] = v
        else:
            static[k] = v
    return static, dyn


def _normalize_halo(halo, ndim) -> tuple[int, ...]:
    if halo is None:
        return (0,) * ndim
    if isinstance(halo, int):
        return (int(halo),) * ndim
    h = tuple(int(x) for x in halo)
    if len(h) != ndim:
        raise ValueError(f"halo {h} does not match lattice ndim {ndim}")
    return h


# ---------------------------------------------------------------------------
# launch plan — what an executor receives
# ---------------------------------------------------------------------------

class LaunchPlan:
    """Everything an executor needs to map one kernel over site chunks.

    Built (and cached) by :func:`launch`; executors are called as
    ``executor(plan, prepared)`` where ``prepared`` holds one array per
    field — shape depends on the executor's declared capability
    (``plan.wants``): ``"gathered"`` stencil fields are
    ``(noffsets, ncomp, n)`` neighbour stacks, ``"halo_extended"`` ones
    are ``(ncomp, *ext_shape)`` grids; pointwise fields are ``(ncomp, n)``
    either way.

    ``shape``/``halo``/``stencils`` carry the launch geometry (``None`` /
    all-``None`` for pure pointwise launches), so capability-declaring
    executors can resolve neighbour offsets themselves and so the
    :meth:`vmem_bytes_estimate` / :meth:`hbm_bytes_estimate` memory
    models are derivable from the plan alone (see docs/stencil.md).
    """

    __slots__ = ("kernel", "name", "vvl", "out_ncomp", "consts",
                 "with_site_index", "interpret", "target", "shape", "halo",
                 "stencils", "field_ncomp", "wants")

    def __init__(self, *, kernel, name, vvl, out_ncomp, consts,
                 with_site_index, interpret, target, shape=None, halo=None,
                 stencils=None, field_ncomp=None, wants="gathered"):
        self.kernel = kernel
        self.name = name
        self.vvl = vvl
        self.out_ncomp = out_ncomp
        self.consts = consts
        self.with_site_index = with_site_index
        self.interpret = interpret
        self.target = target
        self.shape = shape
        self.halo = halo
        self.stencils = tuple(stencils) if stencils is not None else None
        self.field_ncomp = (tuple(field_ncomp)
                            if field_ncomp is not None else None)
        self.wants = wants

    @property
    def layout(self) -> str:
        """Executor-internal memory layout (``Target.layout``): ``"soa"``
        or ``"aosoa"`` — the transforms live at field boundaries inside
        the executors (:mod:`repro.core.layout`), so the plan's operand
        and output *byte counts* are layout-invariant; only the AoSoA
        boundary transforms add traffic (see :meth:`hbm_bytes_estimate`).
        """
        return self.target.layout

    def with_consts(self, consts: Mapping[str, object]) -> "LaunchPlan":
        """Shallow copy with ``consts`` replaced — the per-call plan the
        dynamic-const path hands to the executor (same kernel, geometry
        and tuning; traced const values merged in)."""
        p = LaunchPlan.__new__(LaunchPlan)
        for s in LaunchPlan.__slots__:
            setattr(p, s, getattr(self, s))
        p.consts = dict(consts)
        return p

    # -- memory models ----------------------------------------------------
    #
    # Per-field rows: a gathered stencil field contributes noffsets·ncomp
    # rows (the HBM-materialised neighbour stack), a halo-extended one
    # ncomp rows over the (slightly larger) extended extent — the
    # ``noffsets×`` factor is exactly what ``wants="halo_extended"``
    # eliminates.  Fields with undeclared ncomp count as 1.

    def _fields(self):
        if self.field_ncomp is None:
            raise ValueError(
                f"plan {self.name!r} carries no field metadata; build it "
                f"through tdp.launch / tdp.launch_plan")
        stencils = self.stencils or (None,) * len(self.field_ncomp)
        return tuple(zip(self.field_ncomp, stencils))

    def _ext_shape(self, stencil):
        r = stencil.radius_per_dim()
        return tuple(s + 2 * rd for s, rd in zip(self.shape, r))

    def vmem_bytes_estimate(self, itemsize: int = 4) -> int:
        """Fast-memory footprint of one grid step (inputs + outputs).

        ``"gathered"`` executors hold ``noffsets_i · ncomp_i · VVL`` input
        rows per stencil field; ``"halo_extended"`` ones hold a
        ``(plane_block + 2·radius)``-plane window of the extended array —
        no ``noffsets`` factor (docs/stencil.md, "VMEM footprint rule").
        """
        out_rows = sum(self.out_ncomp)
        if self.wants != "halo_extended":
            in_rows = sum((s.noffsets if s is not None else 1) * c
                          for c, s in self._fields())
            return (in_rows + out_rows) * self.vvl * itemsize
        if self.shape is None:
            raise ValueError("halo_extended estimates need a lattice shape")
        p = int(self.target.tune("plane_block", 1))
        rest = _prod_shape(self.shape[1:]) if len(self.shape) > 1 else 1
        total = out_rows * p * rest
        for c, s in self._fields():
            if s is None:
                total += c * p * rest
            else:
                ext = self._ext_shape(s)
                window = p + 2 * s.radius_per_dim()[0]
                total += c * window * _prod_shape(ext[1:])
        return total * itemsize

    def hbm_bytes_estimate(self, itemsize: int = 4) -> int:
        """Main-memory footprint of the executor's prepared operands plus
        outputs (excluding the caller's own input arrays).

        The gathered path materialises ``noffsets_i`` copies of every
        stencil field (the ~noffsets× amplification this framework's
        windowed executor exists to remove); the halo-extended path pays
        only the ghost-layer overhead ``prod(shape + 2·radius) /
        prod(shape)`` — independent of ``noffsets``.

        ``layout="aosoa"`` doubles the estimate: the SoA↔AoSoA boundary
        transforms re-materialise every prepared operand and output once
        (one extra HBM round-trip each) — the cost the autotuner's
        roofline model weighs the layout axis against.
        """
        if self.shape is None:
            raise ValueError("hbm_bytes_estimate needs a lattice shape")
        n = _prod_shape(self.shape)
        total = sum(self.out_ncomp) * n
        for c, s in self._fields():
            if s is None:
                total += c * n
            elif self.wants == "halo_extended":
                total += c * _prod_shape(self._ext_shape(s))
            else:
                total += c * s.noffsets * n
        if self.layout == "aosoa":
            total *= 2
        return total * itemsize

    def __repr__(self):
        return (f"LaunchPlan({self.name!r}, executor={self.target.executor!r}"
                f", vvl={self.vvl}, out={self.out_ncomp}, "
                f"wants={self.wants!r})")


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def _validate_arrays(spec: KernelSpec, arrays, lattice, halo):
    if len(arrays) != len(spec.fields):
        raise ValueError(
            f"kernel {spec.name!r} declares {len(spec.fields)} field(s) "
            f"but got {len(arrays)} array(s)")
    for i, (x, fs) in enumerate(zip(arrays, spec.fields)):
        if getattr(x, "ndim", None) != 2:
            raise ValueError(
                f"{fs.label(i)} of kernel {spec.name!r} has role "
                f"{fs.role!r} and must be an SoA array of shape "
                f"(ncomp, nsites); got rank "
                f"{getattr(x, 'ndim', '?')} array")
        if fs.ncomp is not None and int(x.shape[0]) != fs.ncomp:
            raise ValueError(
                f"{fs.label(i)} of kernel {spec.name!r} declares "
                f"ncomp={fs.ncomp} but the array has {x.shape[0]} "
                f"component(s)")

    if spec.has_stencil:
        if lattice is None:
            raise ValueError(
                f"kernel {spec.name!r} has stencil input(s) but the launch "
                f"is missing a lattice (neighbour geometry needs the shape)")
        h = _normalize_halo(halo, lattice.ndim)
        n_ext = _prod_shape(tuple(s + 2 * hh
                                  for s, hh in zip(lattice.shape, h)))
        for i, (x, fs) in enumerate(zip(arrays, spec.fields)):
            s = fs.stencil
            want = n_ext if s is not None else lattice.nsites
            if int(x.shape[-1]) != want:
                raise ValueError(
                    f"{fs.label(i)} extent {x.shape[-1]} != expected {want} "
                    f"({'extended' if s is not None else 'interior'}; "
                    f"shape={lattice.shape}, halo={h})")
            if s is None:
                continue
            if s.ndim != lattice.ndim:
                raise ValueError(
                    f"stencil {s.name!r} is {s.ndim}-D on a "
                    f"{lattice.ndim}-D lattice")
            for d, r in enumerate(s.radius_per_dim()):
                if h[d] and h[d] < r:
                    raise ValueError(
                        f"halo {h[d]} in dim {d} < stencil {s.name!r} "
                        f"radius {r}")
            if fs.halo == "periodic" and any(h):
                raise ValueError(
                    f"{fs.label(i)} declares halo policy 'periodic' but "
                    f"the launch supplies ghost planes (halo={h})")
            if fs.halo == "ghost" and not all(
                    h[d] >= r for d, r in enumerate(s.radius_per_dim())
                    if r):
                raise ValueError(
                    f"{fs.label(i)} declares halo policy 'ghost' but the "
                    f"launch halo {h} does not cover stencil "
                    f"{s.name!r} radius {s.radius_per_dim()}")
        return h

    # pure pointwise launch
    if halo is not None:
        hseq = (halo,) if isinstance(halo, int) else tuple(halo)
        if any(int(x) for x in hseq):
            raise ValueError("halo is only meaningful for stencil launches")
    nsite_set = {int(x.shape[-1]) for x in arrays}
    if len(nsite_set) != 1:
        raise ValueError(f"inputs disagree on site extent: "
                         f"{sorted(nsite_set)}")
    if lattice is not None:
        n = nsite_set.pop()
        if n not in (lattice.nsites, lattice.nsites_with_halo):
            raise ValueError(
                f"site extent {n} matches neither interior "
                f"({lattice.nsites}) nor halo-padded "
                f"({lattice.nsites_with_halo}) lattice")
    return None


def _validate_wrap_extents(spec: KernelSpec, lattice, halo):
    """Plan-build guard for :func:`halo_extend`'s periodic path: a
    ``wants="halo_extended"`` launch wrap-pads every dimension whose halo
    is 0 by the stencil radius, which this framework refuses when the
    radius exceeds the extent (e.g. a radius-2 stencil meeting a 1-plane
    pencil).  Raising here names the dim/radius/extent *before* tracing,
    instead of surfacing deep inside the jitted launch."""
    if lattice is None or not spec.has_stencil:
        return
    h = halo if halo is not None else (0,) * lattice.ndim
    for i, fs in enumerate(spec.fields):
        s = fs.stencil
        if s is None:
            continue
        for d, r in enumerate(s.radius_per_dim()):
            if r and h[d] == 0 and r > lattice.shape[d]:
                raise ValueError(
                    f"{fs.label(i)} of kernel {spec.name!r}: stencil "
                    f"{s.name!r} radius {r} in dim {d} exceeds the "
                    f"periodic extent {lattice.shape[d]} (halo_extend "
                    f"cannot wrap-pad a dimension thinner than the "
                    f"stencil radius); supply >= {r} ghost planes in "
                    f"dim {d} or enlarge it")


class WindowVmemError(ValueError):
    """A ``pallas_windowed`` launch whose VMEM window cannot fit.

    Raised at plan-build time (before any tracing) when
    :meth:`LaunchPlan.vmem_bytes_estimate` exceeds the fast-memory cap:
    the ``plane_block + 2·radius`` slab of some field is too large for
    one grid step.  The message names the worst field, its window bytes,
    and the cap.  ``tdp.autotune`` *prunes* candidates that raise this
    (the base target excepted — an unrunnable base is a caller error);
    shrinking ``plane_block`` or the y/z extents is the fix (y/z window
    blocking is a carried follow-up, see ROADMAP).
    """


def _vmem_cap() -> int:
    # lazy: repro.core.costmodel is stdlib-at-import but keep the single
    # authoritative constant there without risking an import cycle here
    from .costmodel import DEFAULT_VMEM_LIMIT
    return DEFAULT_VMEM_LIMIT


def _check_window_vmem(plan: "LaunchPlan", spec: KernelSpec) -> None:
    """Satellite guard: refuse to build a windowed launch whose VMEM
    window exceeds the cap instead of letting Pallas lowering fail (or
    silently thrash) deep inside the jitted launch."""
    cap = _vmem_cap()
    total = plan.vmem_bytes_estimate()
    if total <= cap:
        return
    p = int(plan.target.tune("plane_block", 1))
    worst_label, worst_bytes = "<output>", 0
    for i, (fs, (c, s)) in enumerate(zip(spec.fields, plan._fields())):
        if s is None:
            b = c * p * _prod_shape(plan.shape[1:]) * 4
        else:
            ext = plan._ext_shape(s)
            b = c * (p + 2 * s.radius_per_dim()[0]) * \
                _prod_shape(ext[1:]) * 4
        if b > worst_bytes:
            worst_label, worst_bytes = fs.label(i), b
    raise WindowVmemError(
        f"kernel {plan.name!r} under executor "
        f"{plan.target.executor!r}: the plane_block={p} window needs an "
        f"estimated {total} bytes of VMEM (> cap {cap}); largest window "
        f"is {worst_label} at {worst_bytes} bytes "
        f"({p} + 2·radius x-planes of the extended grid) — shrink "
        f"plane_block or the y/z extents")


def _validate_layout(spec: KernelSpec, target: Target,
                     lattice: Lattice | None, wants: str) -> None:
    """Plan-build validation of the AoSoA layout axis (satellite fix:
    an indivisible vvl used to surface deep inside the executor as a
    reshape error).  Gathered executors pad remainder sites, so any vvl
    is valid there; the *windowed* AoSoA path regroups each x-plane into
    vvl blocks and its *output* windows have no remainder story — vvl
    must divide the interior plane site count.  (Halo-extended stencil
    operand planes are zero-padded to a vvl multiple inside the
    executor, so only the interior extent constrains vvl.)"""
    if target.layout != "aosoa" or wants != "halo_extended":
        return
    vvl = target.resolve_vvl()
    if lattice is None:
        return
    shape = lattice.shape
    rest_n = _prod_shape(shape[1:]) if len(shape) > 1 else 1
    if rest_n % vvl:
        raise ValueError(
            f"kernel {spec.name!r} with layout='aosoa' under executor "
            f"{target.executor!r}: vvl={vvl} does not divide the "
            f"interior plane extent {rest_n} (= prod{tuple(shape[1:])}) "
            f"— the windowed AoSoA path regroups whole x-planes into "
            f"vvl-site blocks; pick a vvl dividing the plane site count")


# ---------------------------------------------------------------------------
# the launch itself
# ---------------------------------------------------------------------------

def _make_plan(spec: KernelSpec, target: Target, vvl: int,
               out_ncomp: tuple[int, ...], lattice: Lattice | None,
               halo: tuple[int, ...] | None, consts: dict,
               wants: str) -> LaunchPlan:
    return LaunchPlan(
        kernel=spec.fn, name=spec.name, vvl=vvl, out_ncomp=out_ncomp,
        consts=consts, with_site_index=spec.site_index,
        interpret=target.interpret, target=target,
        shape=lattice.shape if lattice is not None else None, halo=halo,
        stencils=spec.stencils,
        field_ncomp=tuple(fs.ncomp if fs.ncomp is not None else 1
                          for fs in spec.fields),
        wants=wants)


@functools.lru_cache(maxsize=4096)
def _build_plan(spec: KernelSpec, target: Target, vvl: int,
                out_ncomp: tuple[int, ...], lattice: Lattice | None,
                halo: tuple[int, ...] | None, const_key, dyn_sig,
                _registry_version):
    consts = _unwrap_consts(dict(const_key))
    dyn_names = tuple(k for k, _, _ in dyn_sig)
    entry = get_executor_entry(target.executor)
    executor = entry.fn
    plan = _make_plan(spec, target, vvl, out_ncomp, lattice, halo, consts,
                      entry.wants)
    if entry.wants == "halo_extended":
        _check_window_vmem(plan, spec)
    stencils = spec.stencils
    shape = lattice.shape if lattice is not None else None
    n_out = len(out_ncomp)
    nf = len(spec.fields)

    if entry.wants == "halo_extended":
        # Capability-aware prologue: pad each stencil field once instead
        # of rolling out one HBM copy per offset.
        def prepare(x, s):
            return x if s is None else halo_extend(x, shape, halo, s)
    else:
        def prepare(x, s):
            return x if s is None else gather_neighbors(x, shape, halo, s)

    def run(*args):
        # trailing args past the declared fields are dynamic const values
        arrays, dvals = args[:nf], args[nf:]
        p = plan
        if dyn_names:
            p = plan.with_consts({**plan.consts,
                                  **dict(zip(dyn_names, dvals))})
        prepared = tuple(prepare(x, s) for x, s in zip(arrays, stencils))
        outs = executor(p, prepared)
        outs = (outs,) if not isinstance(outs, (tuple, list)) else tuple(outs)
        if len(outs) != n_out:
            raise ValueError(
                f"executor {target.executor!r} returned {len(outs)} "
                f"output(s) for kernel {spec.name!r}; plan declares "
                f"{n_out}")
        return outs[0] if n_out == 1 else outs

    return jax.jit(run)


def launch(spec: KernelSpec, target: Target | str | None = None, /,
           *arrays, lattice: Lattice | None = None,
           halo: int | Sequence[int] | None = None,
           consts: Mapping[str, object] | None = None, **kw_consts):
    """Launch a declared kernel over the lattice (``TARGET_LAUNCH``).

    Args:
      spec: the :class:`KernelSpec` (build with ``@tdp.kernel`` or the
        constructor).
      target: a :class:`Target`, a backend-name string (coerced through
        :func:`~repro.core.target.as_target`), or ``None`` for the xla
        default.
      *arrays: one SoA target array per declared field — ``(ncomp,
        nsites)``; stencil fields span the halo-extended extent when
        ``halo`` is non-zero.
      lattice: grid descriptor.  Required when any field carries a
        stencil; optional (validation only) for pointwise launches.
      halo: per-dimension ghost width already present in stencil inputs
        (``0`` → periodic wrap).
      consts / **kw_consts: ``TARGET_CONST`` parameters (``TargetConst``
        or scalars), closed over at jit time.  ``lattice``, ``halo`` and
        ``consts`` are reserved keyword names — pass consts with those
        names through the ``consts=`` mapping.

    Returns one ``(ncomp_o, nsites)`` array per declared output (a bare
    array for single-output kernels).
    """
    if not isinstance(spec, KernelSpec):
        raise TypeError(
            f"tdp.launch expects a KernelSpec as first argument, got "
            f"{type(spec).__name__}; build one with @tdp.kernel / "
            f"tdp.KernelSpec (the legacy launch(kernel, lattice, inputs) "
            f"signature lives in repro.core.launch)")
    tgt = as_target(target)
    # fail fast on unknown executor names / capability mismatches
    entry = get_executor_entry(tgt.executor)
    if entry.wants == "halo_extended" and not spec.has_stencil:
        raise ValueError(
            f"executor {tgt.executor!r} declares wants='halo_extended' "
            f"(gather-free stencil windows) but kernel {spec.name!r} has "
            f"no stencil-carrying fields; use a 'gathered' executor such "
            f"as 'xla' or 'pallas' for pointwise kernels")
    arrays = tuple(arrays)
    if not arrays:
        raise ValueError("launch requires at least one input field")
    all_consts = dict(consts or {})
    all_consts.update(kw_consts)
    if spec.consts is not None:
        unknown = sorted(set(all_consts) - set(spec.consts))
        if unknown:
            raise ValueError(
                f"kernel {spec.name!r} does not declare const(s) "
                f"{unknown}; declared: {sorted(spec.consts)}")
    h = _validate_arrays(spec, arrays, lattice, halo)
    if entry.wants == "halo_extended":
        _validate_wrap_extents(spec, lattice, h)
    _validate_layout(spec, tgt, lattice, entry.wants)
    vvl = tgt.resolve_vvl()
    out_ncomp = spec.out if spec.out is not None else (int(arrays[0].shape[0]),)
    static_consts, dyn_consts = _split_consts(all_consts)
    key = _consts_cache_key(static_consts)
    dyn_names = tuple(sorted(dyn_consts))
    dyn_sig = tuple((k, tuple(int(s) for s in dyn_consts[k].shape),
                     str(dyn_consts[k].dtype)) for k in dyn_names)
    fn = _build_plan(spec, tgt, vvl, out_ncomp, lattice, h, key, dyn_sig,
                     registry_version())
    return fn(*arrays, *(dyn_consts[k] for k in dyn_names))


def launch_plan(spec: KernelSpec, target: Target | str | None = None, *,
                lattice: Lattice | None = None,
                halo: int | Sequence[int] | None = None,
                consts: Mapping[str, object] | None = None) -> LaunchPlan:
    """Build (without compiling or launching) the :class:`LaunchPlan` a
    launch of ``spec`` under ``target`` would dispatch with — the
    introspection surface for the :meth:`LaunchPlan.vmem_bytes_estimate`
    and :meth:`LaunchPlan.hbm_bytes_estimate` memory models.

    Mirrors :func:`launch`'s resolution (executor capability, VVL,
    normalised halo) but takes no arrays; geometry checks that need them
    are skipped.
    """
    if not isinstance(spec, KernelSpec):
        raise TypeError(f"launch_plan expects a KernelSpec, got "
                        f"{type(spec).__name__}")
    tgt = as_target(target)
    entry = get_executor_entry(tgt.executor)
    if entry.wants == "halo_extended" and not spec.has_stencil:
        raise ValueError(
            f"executor {tgt.executor!r} declares wants='halo_extended' but "
            f"kernel {spec.name!r} has no stencil-carrying fields")
    if spec.has_stencil and lattice is None:
        raise ValueError(f"kernel {spec.name!r} has stencil input(s); "
                         f"launch_plan needs the lattice")
    h = (_normalize_halo(halo, lattice.ndim)
         if lattice is not None and spec.has_stencil else None)
    if entry.wants == "halo_extended":
        _validate_wrap_extents(spec, lattice, h)
    _validate_layout(spec, tgt, lattice, entry.wants)
    if spec.out is not None:
        out_ncomp = spec.out
    elif spec.fields[0].ncomp is not None:
        # matches launch: out defaults to input 0's component count, and
        # validation pins the array to the declared ncomp
        out_ncomp = (spec.fields[0].ncomp,)
    else:
        raise ValueError(
            f"kernel {spec.name!r} declares neither out= nor an ncomp for "
            f"field 0 — its output count is only known at launch time, so "
            f"launch_plan cannot build a faithful plan")
    return _make_plan(spec, tgt, tgt.resolve_vvl(), tuple(out_ncomp),
                      lattice, h, _unwrap_consts(dict(consts or {})),
                      entry.wants)


# ---------------------------------------------------------------------------
# built-in executors
# ---------------------------------------------------------------------------

def xla_executor(plan: LaunchPlan, gathered):
    """The "C implementation": vmap the kernel body over VVL-sized chunks
    (TLP = the chunk loop, fused and threaded by XLA; ILP = jnp ops
    vectorised over the trailing VVL axis).  Handles pointwise chunks,
    stencil neighbour stacks, and the site-index role uniformly.

    ``plan.layout == "aosoa"``: operands are reordered through
    :func:`repro.core.layout.soa_to_aosoa` — site blocks outermost,
    ``(ncomp, vvl)`` tiles contiguous per block — and the chunk loop
    vmaps over the leading block axis.  Each chunk holds exactly the
    sites the SoA path's chunk *i* holds (same zero padding, same
    grouping), so results are bit-identical across layouts; only the
    physical operand ordering differs.
    """
    vvl = plan.vvl
    n = gathered[0].shape[-1]
    n_pad = -(-n // vvl) * vvl
    nchunks = n_pad // vvl
    aosoa = plan.layout == "aosoa"

    if aosoa:
        chunks = [soa_to_aosoa(x, vvl) for x in gathered]
        in_axes = [0] * len(chunks)
    else:
        chunks = [pad_sites(x, vvl).reshape(*x.shape[:-1], nchunks, vvl)
                  for x in gathered]
        in_axes = [x.ndim - 2 for x in chunks]
    body = (functools.partial(plan.kernel, **plan.consts)
            if plan.consts else plan.kernel)
    if plan.with_site_index:
        chunks.append(jnp.arange(n_pad, dtype=jnp.int32).reshape(nchunks,
                                                                 vvl))
        in_axes.append(0)
    n_out = len(plan.out_ncomp)
    out_ax = 0 if aosoa else 1
    outs = jax.vmap(body, in_axes=tuple(in_axes),
                    out_axes=out_ax if n_out == 1 else (out_ax,) * n_out
                    )(*chunks)
    outs = (outs,) if n_out == 1 else tuple(outs)
    if aosoa:
        return tuple(aosoa_to_soa(o, n) for o in outs)
    return tuple(o.reshape(o.shape[0], n_pad)[:, :n] for o in outs)


def _pallas_executor(plan: LaunchPlan, gathered):
    # Lazy import: the core stays importable without Pallas.
    from repro.kernels.tdp_pointwise import pallas_execute
    return pallas_execute(plan, gathered)


def _pallas_windowed_executor(plan: LaunchPlan, extended):
    from repro.kernels.tdp_windowed import windowed_execute
    return windowed_execute(plan, extended)


# ``tunables`` declares the Target.tuning keys consulted when
# dispatching under each name — the sweep/autotune contract.  The
# pointwise block knobs on "pallas" are consumed by the ops layer
# (repro.kernels.ops reads them off the same Target), not by
# pallas_execute itself; declaring them here keeps one authoritative
# table for `benchmarks/run.py --sweep` validation and `tdp.autotune`
# space construction.
_PALLAS_TUNABLES = ("block_f", "block_q", "block_k", "block_d", "block_t")

register_executor("xla", xla_executor)
register_executor("pallas", _pallas_executor, tunables=_PALLAS_TUNABLES)
register_executor("pallas_interpret", _pallas_executor,
                  tunables=_PALLAS_TUNABLES)
register_executor("pallas_windowed", _pallas_windowed_executor,
                  wants="halo_extended", tunables=("plane_block",))
