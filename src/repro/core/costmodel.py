"""``tdp.costmodel`` — the analytical performance model behind tuning.

The paper's portability claim rests on the abstraction exposing *enough
structure to reason about performance*: grid geometry, halo widths,
vector length, and the per-stage memory models are all part of the
:class:`~repro.core.api.LaunchPlan` / :class:`~repro.core.program.ProgramPlan`
surface.  This module turns that structure into numbers:

* :class:`MachineProfile` — per-device peak-FLOP / HBM-bandwidth /
  VMEM-size / link-bandwidth rates.  Calibrated once by a
  micro-benchmark (:func:`calibrate`) and cached on disk under
  ``results/tuning/machine-<device>[-interpret].json``
  (:func:`machine_profile`).  Interpreter rates are *honest*: an
  ``interpret=True`` profile is calibrated through actual Pallas
  interpret-mode launches and can never answer for a compiled run —
  :func:`predict` raises on the mismatch, mirroring the autotune
  cache-key rule that keeps interpreter medians out of compiled entries.

* :func:`predict` — a roofline predictor: per stage,
  ``t = max(flops / peak, hbm_bytes / bw · spill)`` with
  ``spill = max(1, vmem_bytes / profile.vmem_bytes)``, summed over the
  step, plus a communication term ``exchanged_bytes_per_step /
  link_bw`` driven by :meth:`CompiledProgram.comm_stats`.  FLOPs come
  from abstractly tracing the kernel body (:func:`kernel_flops`);
  bytes from the plan memory models.  The estimate reports seconds,
  the three time terms, and the binding bottleneck
  (``compute`` / ``hbm`` / ``vmem-spill`` / ``comm``).

* a second, XLA-derived backend (``source="hlo"``): the trip-count-
  exact HLO walker (:func:`analyze`, absorbed from the retired
  ``repro.launch.hlo_analysis``) runs over the compiled step's
  post-optimisation HLO text — exact dot FLOPs and fusion-aware HBM
  traffic, at the price of a compile.

:func:`repro.core.autotune.autotune` uses :func:`predict` to rank the
candidate space and measure only the top-K (``top_k=``); see the
"Cost model & predictor-guided tuning" section of docs/targetdp_api.md.

Pure-stdlib at import time: jax is imported lazily inside the functions
that trace or calibrate, so the HLO walker stays usable standalone
(``python -m repro.core.costmodel hlo.txt``).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import re
import tempfile
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Mapping

#: default per-launch VMEM feasibility budget — one TPU core's vector
#: memory (the windowed executor's window must fit).  ``tdp.autotune``
#: aliases this as its ``vmem_limit`` default.
DEFAULT_VMEM_LIMIT = 16 * 2 ** 20

__all__ = [
    "MachineProfile", "CostEstimate", "predict", "roofline_seconds",
    "kernel_flops", "calibrate", "machine_profile", "load_profile",
    "store_profile", "profile_path", "analyze", "parse_module",
    "collective_bytes", "dryrun_record_terms", "DEFAULT_VMEM_LIMIT",
]


# ---------------------------------------------------------------------------
# machine profiles
# ---------------------------------------------------------------------------

#: default rates per platform family (the key is matched against the
#: platform prefix of the device string).  The TPU row is the v5e
#: roofline from ``benchmarks/roofline.py``'s original constants; the
#: cpu row is a deliberately conservative laptop-class estimate; the
#: interpret row derates everything to Pallas-interpreter throughput
#: (the emulator runs the kernel body per site chunk in Python).
_DEFAULT_RATES: dict[str, dict[str, float]] = {
    "tpu": dict(peak_flops=197e12, hbm_bw=819e9, link_bw=50e9,
                dcn_bw=25e9, hbm_bytes=16 * 2 ** 30),
    "gpu": dict(peak_flops=60e12, hbm_bw=1500e9, link_bw=25e9,
                dcn_bw=12.5e9, hbm_bytes=40 * 2 ** 30),
    "cpu": dict(peak_flops=1e11, hbm_bw=2e10, link_bw=1e10,
                dcn_bw=1e10, hbm_bytes=8 * 2 ** 30),
    "interpret": dict(peak_flops=5e7, hbm_bw=5e8, link_bw=5e8,
                      dcn_bw=5e8, hbm_bytes=8 * 2 ** 30),
}


@dataclass(frozen=True)
class MachineProfile:
    """Per-device roofline rates.

    ``device`` is the autotune spelling ``"<platform>:<device_kind>"``;
    ``interpret`` marks a profile calibrated through the Pallas
    interpreter (orders of magnitude slower — never comparable to
    compiled rates, and :func:`predict` enforces that).  ``source``
    records provenance: ``"default"`` (table), ``"calibrated"``
    (micro-benchmark this process), ``"cached"`` (read back from disk).
    """

    device: str
    interpret: bool = False
    peak_flops: float = 1e11     # FLOP/s
    hbm_bw: float = 2e10         # bytes/s main-memory bandwidth
    vmem_bytes: int = DEFAULT_VMEM_LIMIT   # fast-memory capacity
    hbm_bytes: int = 8 * 2 ** 30           # main-memory capacity
    link_bw: float = 1e10        # bytes/s inter-device (ICI) link
    dcn_bw: float = 1e10         # bytes/s cross-pod link
    source: str = "default"

    @classmethod
    def default(cls, device: str | None = None,
                interpret: bool = False) -> "MachineProfile":
        """The table profile for ``device`` (current device if None)."""
        dev = device if device is not None else _device_kind()
        key = "interpret" if interpret else dev.split(":", 1)[0]
        rates = _DEFAULT_RATES.get(key, _DEFAULT_RATES["cpu"])
        return cls(device=dev, interpret=bool(interpret), source="default",
                   vmem_bytes=DEFAULT_VMEM_LIMIT, **rates)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "MachineProfile":
        return cls(device=str(d["device"]),
                   interpret=bool(d.get("interpret", False)),
                   peak_flops=float(d["peak_flops"]),
                   hbm_bw=float(d["hbm_bw"]),
                   vmem_bytes=int(d["vmem_bytes"]),
                   hbm_bytes=int(d.get("hbm_bytes", 8 * 2 ** 30)),
                   link_bw=float(d.get("link_bw", 1e10)),
                   dcn_bw=float(d.get("dcn_bw", 1e10)),
                   source=str(d.get("source", "cached")))


def _device_kind() -> str:
    try:
        import jax
        d = jax.devices()[0]
        return f"{d.platform}:{getattr(d, 'device_kind', '?')}"
    except Exception:
        return "unknown:?"


def _best_seconds(fn, reps: int = 5) -> float:
    """Best-of-``reps`` wall seconds of ``fn()`` (blocks on outputs)."""
    import time

    import jax
    jax.block_until_ready(fn())            # compile / warm
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _calibrate_compiled(reps: int) -> dict[str, float]:
    """Measured peak-FLOP and HBM rates through jitted XLA kernels."""
    import jax
    import jax.numpy as jnp

    n = 512                                 # 0.27 GFLOP matmul
    a = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda x, y: x @ y)
    t_mm = _best_seconds(lambda: mm(a, a), reps)
    peak = 2.0 * n ** 3 / max(t_mm, 1e-9)

    m = 4 * 2 ** 20                         # 16 MiB per operand stream
    x = jnp.ones((m,), jnp.float32)
    add = jax.jit(lambda u, v: u + v)
    t_add = _best_seconds(lambda: add(x, x), reps)
    bw = 3.0 * 4 * m / max(t_add, 1e-9)     # 2 reads + 1 write
    return {"peak_flops": peak, "hbm_bw": bw}


def _calibrate_interpret(reps: int) -> dict[str, float]:
    """Measured rates through actual Pallas interpret-mode launches —
    the honest interpreter numbers (the emulator is the bottleneck, not
    the hardware)."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    n = 1 << 14                             # tiny: the interpreter is slow

    def add_kernel(x_ref, y_ref, o_ref):
        o_ref[...] = x_ref[...] + y_ref[...]

    add = pl.pallas_call(
        add_kernel, out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True)
    x = jnp.ones((n,), jnp.float32)
    t_add = _best_seconds(lambda: add(x, x), reps)
    bw = 3.0 * 4 * n / max(t_add, 1e-9)

    k = 8

    def fma_kernel(x_ref, o_ref):
        v = x_ref[...]
        acc = v
        for _ in range(k):
            acc = acc * v + v               # 2 FLOPs per element per rung
        o_ref[...] = acc

    fma = pl.pallas_call(
        fma_kernel, out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True)
    t_fma = _best_seconds(lambda: fma(x), reps)
    peak = 2.0 * k * n / max(t_fma, 1e-9)
    return {"peak_flops": peak, "hbm_bw": bw}


def calibrate(device: str | None = None, interpret: bool = False, *,
              reps: int = 5) -> MachineProfile:
    """Micro-benchmark the current device into a :class:`MachineProfile`.

    Compiled profiles time a jitted matmul (peak FLOP/s) and a jitted
    streaming add (HBM bytes/s); ``interpret=True`` profiles time the
    same shapes through Pallas interpret-mode launches instead, so the
    recorded rates are the interpreter's, never the hardware's.  VMEM
    and link numbers are not measurable from a single host and keep
    their table defaults.  Falls back to :meth:`MachineProfile.default`
    if the micro-benchmark cannot run (e.g. no Pallas)."""
    base = MachineProfile.default(device, interpret)
    try:
        rates = (_calibrate_interpret(reps) if interpret
                 else _calibrate_compiled(reps))
    except Exception:
        return base
    return dataclasses.replace(base, source="calibrated", **rates)


# -- profile cache (results/tuning/machine-<device>[-interpret].json) -------

def profile_path(cache_dir: str, device: str, interpret: bool) -> str:
    dev = device.replace(" ", "_").replace("/", "_")
    tag = "-interpret" if interpret else ""
    return os.path.join(cache_dir, f"machine-{dev}{tag}.json")


def load_profile(cache_dir: str, device: str,
                 interpret: bool) -> MachineProfile | None:
    """The cached profile, or ``None`` on miss.  A corrupt file, a
    device mismatch, or an interpret-flag mismatch is a miss, never an
    error — the same contract as the autotune cache."""
    path = profile_path(cache_dir, device, interpret)
    try:
        with open(path) as fh:
            d = json.load(fh)
        if (str(d.get("device")) != device
                or bool(d.get("interpret", False)) != bool(interpret)):
            return None
        prof = MachineProfile.from_dict(d)
    except (OSError, ValueError, KeyError, TypeError):
        return None
    return dataclasses.replace(prof, source="cached")


def store_profile(cache_dir: str, profile: MachineProfile) -> str:
    """Atomically persist ``profile`` (tempfile + ``os.replace``, like
    the tuning cache — an interrupted write never truncates)."""
    os.makedirs(cache_dir, exist_ok=True)
    path = profile_path(cache_dir, profile.device, profile.interpret)
    fd, tmp = tempfile.mkstemp(dir=cache_dir, prefix=".machine-",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(profile.as_dict(), fh, indent=1)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


_PROFILE_MEMO: dict[tuple, MachineProfile] = {}


def machine_profile(device: str | None = None, interpret: bool = False, *,
                    cache_dir: str = "results/tuning",
                    calibrate_if_missing: bool = True,
                    store: bool = False,
                    force: bool = False) -> MachineProfile:
    """The one-stop profile lookup: in-process memo → on-disk cache →
    :func:`calibrate` → table default.

    ``store=True`` persists a freshly calibrated profile to
    ``cache_dir`` (the bench path does; :func:`predict`'s implicit
    lookup never writes).  ``force=True`` recalibrates, bypassing both
    caches."""
    dev = device if device is not None else _device_kind()
    memo_key = (dev, bool(interpret), cache_dir)
    if not force:
        hit = _PROFILE_MEMO.get(memo_key)
        if hit is not None:
            return hit
        cached = load_profile(cache_dir, dev, interpret)
        if cached is not None:
            _PROFILE_MEMO[memo_key] = cached
            return cached
    prof = (calibrate(dev, interpret) if calibrate_if_missing
            else MachineProfile.default(dev, interpret))
    if store and prof.source == "calibrated":
        store_profile(cache_dir, prof)
    _PROFILE_MEMO[memo_key] = prof
    return prof


# ---------------------------------------------------------------------------
# the estimate
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class CostEstimate:
    """One prediction: seconds, the three roofline terms, the inputs
    they came from, and the binding bottleneck.

    ``bottleneck`` ∈ {``"compute"``, ``"hbm"``, ``"vmem-spill"``,
    ``"comm"``}; ``source`` ∈ {``"analytic"``, ``"hlo"``};
    ``per_stage`` holds one row per Program stage on aggregated
    estimates (empty for single launches)."""

    seconds: float
    t_compute: float
    t_hbm: float
    t_comm: float
    flops: float
    hbm_bytes: float
    vmem_bytes: float
    comm_bytes: float
    bottleneck: str
    source: str
    device: str
    per_stage: tuple = ()

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["per_stage"] = [dict(r) for r in self.per_stage]
        return d

    def __repr__(self):
        return (f"CostEstimate({self.seconds:.3g}s, "
                f"bottleneck={self.bottleneck!r}, source={self.source!r}, "
                f"flops={self.flops:.3g}, hbm={self.hbm_bytes:.3g}B, "
                f"comm={self.comm_bytes:.3g}B)")


def roofline_seconds(flops: float, hbm_bytes: float, *,
                     vmem_bytes: float = 0.0, comm_bytes: float = 0.0,
                     profile: MachineProfile,
                     source: str = "analytic") -> CostEstimate:
    """The pure roofline: ``max(flops/peak, hbm/bw · spill) + comm/link``.

    ``spill = max(1, vmem_bytes / profile.vmem_bytes)`` derates the HBM
    term when the working set exceeds fast memory (every spilled window
    makes an extra round trip).  Monotone non-decreasing in every one of
    ``flops``, ``hbm_bytes``, ``vmem_bytes``, ``comm_bytes`` by
    construction — the property the model tests pin."""
    t_c = float(flops) / profile.peak_flops
    spill = (max(1.0, float(vmem_bytes) / profile.vmem_bytes)
             if profile.vmem_bytes else 1.0)
    t_h = (float(hbm_bytes) / profile.hbm_bw) * spill
    t_x = float(comm_bytes) / profile.link_bw
    seconds = max(t_c, t_h) + t_x
    if t_x > max(t_c, t_h):
        bottleneck = "comm"
    elif t_c >= t_h:
        bottleneck = "compute"
    else:
        bottleneck = "vmem-spill" if spill > 1.0 else "hbm"
    return CostEstimate(
        seconds=seconds, t_compute=t_c, t_hbm=t_h, t_comm=t_x,
        flops=float(flops), hbm_bytes=float(hbm_bytes),
        vmem_bytes=float(vmem_bytes), comm_bytes=float(comm_bytes),
        bottleneck=bottleneck, source=source, device=profile.device)


# ---------------------------------------------------------------------------
# analytic FLOP counting (trace the kernel body abstractly)
# ---------------------------------------------------------------------------

#: FLOPs per output element for elementwise primitives.  Transcendentals
#: are charged a conventional 8 (polynomial approximation); pure data
#: movement (broadcast/transpose/slice/convert/...) is free.
_ELEMWISE_FLOPS = {
    "add": 1, "sub": 1, "mul": 1, "div": 2, "neg": 1, "max": 1, "min": 1,
    "abs": 1, "sign": 1, "floor": 1, "ceil": 1, "round": 1, "rem": 2,
    "integer_pow": 1, "square": 1, "clamp": 2, "select_n": 1,
    "eq": 1, "ne": 1, "lt": 1, "le": 1, "gt": 1, "ge": 1,
    "and": 1, "or": 1, "not": 1, "xor": 1,
    "exp": 8, "log": 8, "log1p": 8, "expm1": 8, "tanh": 8, "logistic": 8,
    "sin": 8, "cos": 8, "tan": 8, "atan2": 8, "pow": 8,
    "sqrt": 4, "rsqrt": 4, "cbrt": 8, "erf": 8, "erfc": 8, "erf_inv": 8,
}


def _aval_size(v) -> int:
    shape = getattr(getattr(v, "aval", None), "shape", ())
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _sub_jaxprs(val) -> list:
    out = []

    def visit(v):
        inner = getattr(v, "jaxpr", None)       # ClosedJaxpr
        if inner is not None and hasattr(inner, "eqns"):
            out.append(inner)
        elif hasattr(v, "eqns"):                # raw Jaxpr
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for x in v:
                visit(x)

    visit(val)
    return out


def _jaxpr_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        sub = 0.0
        for pval in eqn.params.values():
            for j in _sub_jaxprs(pval):
                sub += _jaxpr_flops(j)
        if sub:
            mult = (int(eqn.params.get("length", 1))
                    if prim == "scan" else 1)
            total += sub * mult
            continue
        if prim == "dot_general":
            out = _aval_size(eqn.outvars[0])
            contracting = eqn.params["dimension_numbers"][0][0]
            lhs_shape = eqn.invars[0].aval.shape
            k = 1
            for d in contracting:
                k *= int(lhs_shape[d])
            total += 2.0 * out * k
        elif prim in _ELEMWISE_FLOPS:
            total += (_ELEMWISE_FLOPS[prim]
                      * max(_aval_size(v) for v in eqn.outvars))
        elif prim.startswith(("reduce_", "cum", "arg")):
            total += max((_aval_size(v) for v in eqn.invars), default=0)
    return total


def kernel_flops(plan) -> float:
    """Arithmetic FLOPs of one launch of ``plan``, from an abstract
    trace of the kernel body.

    The body is traced once over one VVL chunk — stencil fields as
    ``(noffsets, ncomp, VVL)``, pointwise fields as ``(ncomp, VVL)``,
    the site index as ``(VVL,)`` int32, consts closed over — exactly the
    executor calling convention, then scaled by ``nsites / VVL``.
    Returns 0.0 when the trace is impossible (no shape metadata, kernel
    refuses abstract values): the prediction degrades to memory-bound,
    which is the right prior for lattice kernels."""
    if plan.shape is None or plan.field_ncomp is None:
        return 0.0
    try:
        import jax
        import jax.numpy as jnp
        vvl = int(plan.vvl)
        stencils = plan.stencils or (None,) * len(plan.field_ncomp)
        args = []
        for c, s in zip(plan.field_ncomp, stencils):
            c = int(c or 1)
            shape = (c, vvl) if s is None else (int(s.noffsets), c, vvl)
            args.append(jax.ShapeDtypeStruct(shape, jnp.float32))
        if plan.with_site_index:
            args.append(jax.ShapeDtypeStruct((vvl,), jnp.int32))
        body = (functools.partial(plan.kernel, **plan.consts)
                if plan.consts else plan.kernel)
        closed = jax.make_jaxpr(lambda *a: body(*a))(*args)
        per_chunk = _jaxpr_flops(closed.jaxpr)
    except Exception:
        return 0.0
    nsites = 1
    for s in plan.shape:
        nsites *= int(s)
    return per_chunk * (nsites / max(1, vvl))


# ---------------------------------------------------------------------------
# predict
# ---------------------------------------------------------------------------

def _resolve_profile(profile: MachineProfile | None,
                     interpret: bool) -> MachineProfile:
    if profile is not None:
        if bool(profile.interpret) != bool(interpret):
            raise ValueError(
                f"MachineProfile(interpret={profile.interpret}) cannot "
                f"answer for a plan with interpret={interpret} — "
                f"interpreter rates and compiled rates are never "
                f"comparable (calibrate both; see machine_profile())")
        return profile
    return machine_profile(interpret=interpret)


def _predict_stages(name, stages, profile, comm, itemsize,
                    source="analytic") -> CostEstimate:
    comm_bytes = float((comm or {}).get("exchanged_bytes_per_step", 0))
    rows = []
    t_c = t_h = flops = hbm = 0.0
    vmem = 0.0
    spilled = False
    for sname, p in stages:
        est = roofline_seconds(
            kernel_flops(p), p.hbm_bytes_estimate(itemsize),
            vmem_bytes=p.vmem_bytes_estimate(itemsize), profile=profile,
            source=source)
        rows.append({
            "stage": sname, "executor": p.target.executor,
            "wants": p.wants, "seconds": est.seconds,
            "t_compute": est.t_compute, "t_hbm": est.t_hbm,
            "flops": est.flops, "hbm_bytes": est.hbm_bytes,
            "vmem_bytes": est.vmem_bytes, "bottleneck": est.bottleneck})
        t_c += est.t_compute
        t_h += est.t_hbm
        flops += est.flops
        hbm += est.hbm_bytes
        vmem = max(vmem, est.vmem_bytes)
        spilled = spilled or est.bottleneck == "vmem-spill"
    t_x = comm_bytes / profile.link_bw
    seconds = sum(r["seconds"] for r in rows) + t_x
    if t_x > max(t_c, t_h):
        bottleneck = "comm"
    elif t_c >= t_h:
        bottleneck = "compute"
    else:
        bottleneck = "vmem-spill" if spilled else "hbm"
    return CostEstimate(
        seconds=seconds, t_compute=t_c, t_hbm=t_h, t_comm=t_x,
        flops=flops, hbm_bytes=hbm, vmem_bytes=vmem,
        comm_bytes=comm_bytes, bottleneck=bottleneck, source=source,
        device=profile.device, per_stage=tuple(rows))


def _predict_hlo(exe, profile, comm, itemsize) -> CostEstimate:
    """The XLA-derived backend: compile the step, walk the HLO."""
    import jax
    import jax.numpy as jnp
    if exe.dyn_names:
        raise ValueError("source='hlo' does not support programs with "
                         "BatchedConst parameters")
    args = [jax.ShapeDtypeStruct(
        (int(exe.program.ncomp[f] or 1), *exe.grid_shape), jnp.float32)
        for f in exe.program.fields]
    text = exe._jit_step.lower(*args).compile().as_text()
    ha = analyze(text)
    comm_bytes = float((comm or {}).get("exchanged_bytes_per_step", 0))
    comm_bytes = max(comm_bytes,
                     ha["wire_bytes_ici"] + ha["wire_bytes_dcn"])
    interp = any(p.interpret for _, p in exe.plan().stages)
    est = roofline_seconds(
        ha["flops"], ha["traffic_bytes"], comm_bytes=comm_bytes,
        profile=profile, source="hlo")
    row = {"stage": "<step>", "executor": exe.target.executor,
           "wants": "-", "seconds": est.seconds,
           "t_compute": est.t_compute, "t_hbm": est.t_hbm,
           "flops": est.flops, "hbm_bytes": est.hbm_bytes,
           "vmem_bytes": 0.0, "bottleneck": est.bottleneck,
           "interpret": interp}
    return dataclasses.replace(est, per_stage=(row,))


def predict(subject, target=None, profile: MachineProfile | None = None, *,
            grid_shape=None, source: str = "analytic", comm=None,
            itemsize: int = 4) -> CostEstimate:
    """Predict the per-step cost of ``subject``.

    Args:
      subject: a :class:`~repro.core.api.LaunchPlan`,
        :class:`~repro.core.program.ProgramPlan`,
        :class:`~repro.core.program.Program` (needs ``grid_shape``; the
        plan is built with ``target``), or
        :class:`~repro.core.program.CompiledProgram` (its own plan,
        target and :meth:`comm_stats` are used).
      target: the target to plan a bare ``Program`` under.
      profile: the :class:`MachineProfile`; defaults to
        :func:`machine_profile` for the subject's interpret mode.
        Passing a profile whose ``interpret`` flag mismatches the
        subject raises — interpreter numbers never answer for compiled
        runs, and vice versa.
      grid_shape: required for a bare ``Program``.
      source: ``"analytic"`` (plan memory models + traced-kernel FLOPs;
        no compilation) or ``"hlo"`` (compile and walk the
        post-optimisation HLO — trip-count-exact dots and fusion-aware
        traffic; ``CompiledProgram`` only).
      comm: override the communication stats dict (any mapping with
        ``exchanged_bytes_per_step``); defaults to the subject's
        :meth:`comm_stats` when it has one, else no comm term.
      itemsize: bytes per field element (float32 default).
    """
    from .api import LaunchPlan
    from .program import CompiledProgram, Program, ProgramPlan

    if source not in ("analytic", "hlo"):
        raise ValueError(f"source must be 'analytic' or 'hlo', "
                         f"got {source!r}")

    if isinstance(subject, CompiledProgram):
        if comm is None:
            comm = subject.comm_stats(itemsize)
        pplan = subject.plan()
        interp = any(p.interpret for _, p in pplan.stages)
        prof = _resolve_profile(profile, interp)
        if source == "hlo":
            return _predict_hlo(subject, prof, comm, itemsize)
        return _predict_stages(pplan.name, pplan.stages, prof, comm,
                               itemsize)
    if source == "hlo":
        raise ValueError("source='hlo' needs a CompiledProgram (the HLO "
                         "walker runs over a compiled step)")
    if isinstance(subject, Program):
        if grid_shape is None:
            raise ValueError("predict over a Program needs grid_shape")
        pplan = subject.plan(target, grid_shape=grid_shape)
        subject = pplan
    if isinstance(subject, ProgramPlan):
        interp = any(p.interpret for _, p in subject.stages)
        prof = _resolve_profile(profile, interp)
        return _predict_stages(subject.name, subject.stages, prof, comm,
                               itemsize)
    if isinstance(subject, LaunchPlan):
        prof = _resolve_profile(profile, subject.interpret)
        return _predict_stages(subject.name,
                               ((subject.name, subject),), prof, comm,
                               itemsize)
    raise TypeError(f"predict expects a LaunchPlan, ProgramPlan, Program "
                    f"or CompiledProgram; got {type(subject).__name__}")


# ---------------------------------------------------------------------------
# the XLA-derived backend: trip-count-exact HLO analysis
# (absorbed from the retired repro.launch.hlo_analysis)
# ---------------------------------------------------------------------------
#
# Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
# ``while`` body **once**, so anything under ``lax.scan`` (layer stacks,
# grad-accumulation, chunked attention) is undercounted by its trip count.
# The compiled HLO text, however, carries
# ``backend_config={"known_trip_count":{"n":...}}`` on every scan-derived
# while loop, so an exact account is a parse away:
#
#   1. split the module into computations; index every instruction's
#      output shape(s) by name;
#   2. build the call graph (while body/condition, fusion ``calls``,
#      ``to_apply``, conditional branches) and propagate a *multiplier* =
#      Σ over call sites of (caller multiplier × trip count);
#   3. FLOPs: every ``dot`` = 2 · prod(output) · K (K = lhs contracting
#      extents) × multiplier;
#   4. HBM traffic: Σ (operand bytes + output bytes) over instructions in
#      non-fusion computations × multiplier (a fusion is one kernel: its
#      internals live in registers/VMEM; its call site counts);
#   5. collectives: operand bytes × multiplier, plus a per-chip
#      *wire-byte* estimate from ring algorithms (see ``_WIRE``); groups
#      are classified ICI vs DCN by their device stride (``pod_stride``).
#
# All shapes in a post-partitioning module are per-chip shard shapes, so
# every number is per-chip.

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota"}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(\([^=]*?\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)"
    r"\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                        r"(?:T\(([0-9,]+)\))?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_list(text: str) -> list[tuple[str, tuple[int, ...]]]:
    """All dtype[dims] shapes in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instr:
    name: str
    out_shapes: list
    opcode: str
    operands: list
    line: str


@dataclass
class Computation:
    name: str
    instrs: dict = field(default_factory=dict)
    order: list = field(default_factory=list)


def parse_module(text: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if cur is None:
            # computation headers sit at column 0:
            #   %name (args...) -> type {     /  ENTRY %name (...) -> ... {
            if (line.startswith("%") or line.startswith("ENTRY")) and \
                    line.rstrip().endswith("{") and "->" in line:
                is_entry = line.startswith("ENTRY")
                tok = line.split()[1] if is_entry else line.split()[0]
                cur = Computation(tok.lstrip("%"))
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    return comps, entry


def _parse_instr(line: str):
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].lstrip("%")
    rest = s[eq + 3:]
    # type: either a balanced-paren tuple (may contain /*index=N*/ comments)
    # or dtype[dims]{layout}
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        typ, rest2 = rest[:i + 1], rest[i + 1:]
    else:
        m = re.match(r"\w+\[[0-9,]*\](?:\{[^}]*\})?", rest)
        if not m:
            return None
        typ, rest2 = m.group(0), rest[m.end():]
    rest2 = rest2.lstrip()
    mo = re.match(r"([\w\-]+)\(", rest2)
    if not mo:
        return None
    opcode = mo.group(1)
    paren = rest2.find("(", mo.start())
    depth = 0
    for i in range(paren, len(rest2)):
        if rest2[i] == "(":
            depth += 1
        elif rest2[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operands = _OPERAND_RE.findall(rest2[paren:i + 1])
    return Instr(name, _shape_list(typ), opcode, operands, line)


def _call_edges(comp: Computation):
    """[(callee_name, factor, kind)] for one computation."""
    edges = []
    for iname in comp.order:
        ins = comp.instrs[iname]
        line = ins.line
        if ins.opcode == "while":
            trip = 1
            mt = _TRIP_RE.search(line)
            if mt:
                trip = int(mt.group(1))
            for key in ("body=", "condition="):
                k = line.find(key)
                if k >= 0:
                    nm = re.match(r"%?([\w.\-]+)", line[k + len(key):].lstrip("%"))
                    if nm:
                        edges.append((nm.group(1), trip,
                                      "while_" + key[:-1]))
        else:
            for key, kind in (("calls=", "fusion"), ("to_apply=", "apply"),
                              ("branch_computations={", "cond"),
                              ("body=", "body"), ("condition=", "condition")):
                k = line.find(key)
                if k < 0:
                    continue
                tail = line[k + len(key):]
                if key.endswith("{"):
                    names = re.findall(r"%([\w.\-]+)", tail[:tail.find("}")])
                    for nm in names:
                        edges.append((nm, 1, kind))
                else:
                    nm = re.match(r"%?([\w.\-]+)", tail.lstrip("%"))
                    if nm:
                        edges.append((nm.group(1), 1, kind))
    return edges


def _multipliers(comps, entry):
    mult = defaultdict(float)
    mult[entry] = 1.0
    # topological: repeatedly relax (call graph is a DAG in HLO)
    edges = {c: _call_edges(comp) for c, comp in comps.items()}
    order = []
    seen = set()

    def dfs(c):
        if c in seen:
            return
        seen.add(c)
        for callee, _, _ in edges.get(c, ()):  # post-order
            dfs(callee)
        order.append(c)

    dfs(entry)
    for c in reversed(order):                  # callers before callees
        for callee, factor, _ in edges.get(c, ()):
            mult[callee] += mult[c] * factor
    fusion_like = {callee for c in comps for callee, _, kind in edges[c]
                   if kind in ("fusion", "apply")}
    return mult, fusion_like


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for _, dims in ins.out_shapes:
        for d in dims:
            out_elems *= d
    k = 1
    mc = _CONTRACT_RE.search(ins.line)
    if mc and ins.operands:
        lhs = comp.instrs.get(ins.operands[0])
        if lhs is not None and lhs.out_shapes:
            shape = lhs.out_shapes[0][1]
            for idx in (int(i) for i in mc.group(1).split(",") if i):
                if idx < len(shape):
                    k *= shape[idx]
    return 2.0 * out_elems * k


def _group_size_and_kind(line: str, pod_stride: int = 256):
    """(group_size, dcn_fraction).

    A group *spans* pods when its member span (stride·(size−1)) reaches
    the pod stride; a ring over such a group crosses the DCN boundary
    ``span // pod_stride`` times out of ``size−1`` hops — that fraction
    of the wire bytes rides DCN, the rest ICI.  Pure-pod groups (stride
    = pod_stride) give fraction 1."""
    def frac(stride, gsize):
        if gsize <= 1:
            return 0.0
        span = stride * (gsize - 1)
        crossings = span // pod_stride
        return min(1.0, crossings / (gsize - 1))

    m = _GROUPS_RE.search(line)
    if m:
        iota = [int(x) for x in m.group(3).split(",")]
        gsize = int(m.group(2))
        # transposed iota ⇒ group members stride by the trailing iota dims
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            strides = 1
            for d in perm[1:]:
                strides *= iota[d]
            stride = strides
        else:
            stride = 1
        return gsize, frac(stride, gsize)
    m2 = _GROUPS_LIST_RE.search(line)
    if m2:
        members = [int(x) for x in m2.group(1).split(",")]
        gsize = len(members)
        stride = abs(members[1] - members[0]) if gsize > 1 else 1
        return gsize, frac(stride, gsize)
    return 1, 0.0


def _operand_nbytes(ins: Instr, comp: Computation, idx: int) -> int:
    if idx >= len(ins.operands):
        return 0
    o = comp.instrs.get(ins.operands[idx])
    return _nbytes(o.out_shapes) if o is not None else 0


def _fusion_param_read(callee: Computation, pidx: int, full: int) -> int:
    """Bytes a fusion actually reads of parameter ``pidx``.

    If every consumer of the parameter inside the fusion is a windowed
    read (dynamic-slice / slice / gather), charge the windows, not the
    whole tensor — scan bodies dynamic-slice one layer out of the stacked
    parameters *inside* a fusion, and charging the stack per iteration is
    a ~10× traffic overcount.
    """
    pname = None
    consumers = []
    for iname in callee.order:
        ins = callee.instrs[iname]
        if ins.opcode == "parameter" and ins.line.strip().split(" = ")[0] \
                .lstrip("%").startswith(f"param_{pidx}"):
            pname = ins.name
            break
    if pname is None:
        # fall back: parameters are in order
        params = [i for i in callee.order
                  if callee.instrs[i].opcode == "parameter"]
        if pidx < len(params):
            pname = params[pidx]
    if pname is None:
        return full
    windowed = 0
    for iname in callee.order:
        ins = callee.instrs[iname]
        if pname in ins.operands:
            consumers.append(ins)
    if not consumers:
        return 0
    for ins in consumers:
        if ins.opcode in ("dynamic-slice", "slice", "gather"):
            windowed += _nbytes(ins.out_shapes)
        elif ins.opcode == "dynamic-update-slice" and \
                ins.operands and ins.operands[0] == pname:
            windowed += _operand_nbytes(ins, callee, 1)  # aliased update
        else:
            return full
    return windowed


def _read_bytes(ins: Instr, comp: Computation, out_bytes: int,
                comps=None) -> int:
    """Bytes actually *read* by an instruction.

    Sliced/gathered reads touch only the addressed window, not the whole
    operand.  In-place updates (dynamic-update-slice / scatter) read+write
    only the update window; XLA aliases the rest.  Fusion call sites defer
    to :func:`_fusion_param_read` per operand.
    """
    op = ins.opcode
    if op in ("dynamic-slice", "slice", "gather"):
        return out_bytes
    if op == "dynamic-update-slice":
        return _operand_nbytes(ins, comp, 1)         # the update window
    if op == "scatter":
        return (_operand_nbytes(ins, comp, 1) +      # indices
                2 * _operand_nbytes(ins, comp, 2))   # updates read+write
    if op == "fusion" and comps is not None:
        mcall = re.search(r"calls=%?([\w.\-]+)", ins.line)
        callee = comps.get(mcall.group(1)) if mcall else None
        if callee is not None:
            total = 0
            for i in range(len(ins.operands)):
                total += _fusion_param_read(callee, i,
                                            _operand_nbytes(ins, comp, i))
            return total
    total = 0
    for i in range(len(ins.operands)):
        total += _operand_nbytes(ins, comp, i)
    return total


#: per-chip ring-algorithm wire bytes per collective (b = operand bytes,
#: s = replica-group size)
_WIRE = {
    "all-gather": lambda b, s: b * (s - 1),
    "reduce-scatter": lambda b, s: b * (s - 1) / s,
    "all-reduce": lambda b, s: 2 * b * (s - 1) / s,
    "all-to-all": lambda b, s: b * (s - 1) / s,
    "collective-permute": lambda b, s: b,
}


def analyze(text: str, *, pod_stride: int = 256) -> dict:
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult, fusion_like = _multipliers(comps, entry)

    flops = 0.0
    traffic = 0.0
    coll = {op: {"operand_bytes": 0.0, "wire_bytes_ici": 0.0,
                 "wire_bytes_dcn": 0.0, "count": 0} for op in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_like
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op == "dot":
                flops += m * _dot_flops(ins, comp)
            if in_fusion:
                continue                      # fused internals: no traffic
            if op.endswith("-done") or op in _FREE_OPS or op == "while":
                continue
            out_bytes = _nbytes(ins.out_shapes)
            if op == "dynamic-update-slice":       # in-place: writes window
                out_bytes = _operand_nbytes(ins, comp, 1)
            elif op == "scatter":
                out_bytes = 0                      # counted in _read_bytes
            operand_bytes = _read_bytes(ins, comp, out_bytes, comps)
            traffic += m * (operand_bytes + out_bytes)
            if base in _COLLECTIVES:
                gsize, dcn_frac = _group_size_and_kind(ins.line, pod_stride)
                c = coll[base]
                c["operand_bytes"] += m * operand_bytes
                wire = m * _WIRE[base](operand_bytes, max(gsize, 1))
                c["wire_bytes_dcn"] += wire * dcn_frac
                c["wire_bytes_ici"] += wire * (1.0 - dcn_frac)
                c["count"] += m
    total_ici = sum(c["wire_bytes_ici"] for c in coll.values())
    total_dcn = sum(c["wire_bytes_dcn"] for c in coll.values())
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collectives": coll,
        "wire_bytes_ici": total_ici,
        "wire_bytes_dcn": total_dcn,
        "n_computations": len(comps),
    }


def collective_bytes(hlo_text: str) -> dict:
    """Per-opcode summed *operand* bytes (post-partitioning = per chip).

    Start ops (``all-reduce-start``) are counted; their matching
    ``-done`` ops carry no payload.  ``collective-permute`` pairs count
    once.  (The quick line-scan companion to :func:`analyze` — no call
    graph, no multipliers; absorbed from the retired dryrun module.)
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            marker = f" {op}("
            start_marker = f" {op}-start("
            pos = line.find(marker)
            if pos < 0:
                pos = line.find(start_marker)
            if pos < 0:
                continue
            paren = line.find("(", pos)
            operands = line[paren:line.find(")", paren) + 1]
            b = sum(_nbytes([(m.group(1), tuple(
                int(d) for d in m.group(2).split(",") if d))])
                for m in _SHAPE_RE.finditer(operands))
            out[op] += b
            counts[op] += 1
            break
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def dryrun_record_terms(rec: Mapping, profile: MachineProfile | None = None
                        ) -> dict:
    """Roofline terms for one ``results/dryrun`` record (the
    ``benchmarks/roofline.py`` table row, computed here so the CLI is a
    thin view over the cost model).  ``profile`` defaults to the TPU
    table profile the dry-run targets."""
    p = profile if profile is not None else MachineProfile.default("tpu:v5e")
    ha = rec["hlo_analysis"]
    t_c = ha["flops"] / p.peak_flops
    t_m = ha["traffic_bytes"] / p.hbm_bw
    t_x = (ha["wire_bytes_ici"] / p.link_bw
           + ha["wire_bytes_dcn"] / p.dcn_bw)
    chips = rec["n_devices"]
    hlo_total = ha["flops"] * chips
    useful = rec["model_flops"] / hlo_total if hlo_total else 0.0
    mem = rec["memory_analysis"]
    per_dev = (mem.get("argument_size_in_bytes", 0) +
               mem.get("temp_size_in_bytes", 0))
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    total = t_c + t_m + t_x
    return {
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom[0], "t_dominant": dom[1],
        "frac": dom[1] / total if total else 0.0,
        "useful_ratio": useful,
        "bytes_per_dev": per_dev,
        "fits": per_dev <= p.hbm_bytes,
    }


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=1))
