"""``ProgramState`` — the named-field pytree container for Program state.

A :class:`~repro.core.program.Program` steps a set of named fields
(``{"f": (19, X, Y, Z), "g": (19, X, Y, Z)}``).  Until now that state was
a plain dict; fleets (:mod:`repro.core.fleet`) need a container that
additionally *annotates* what the leading axis means — the annotated-
pytree idiom: the pytree leaves are the field arrays, the aux data
carries the field names **and** whether an ensemble axis is present.

* ``ProgramState({"f": f, "g": g})`` — single-member state; every field
  is ``(ncomp, *grid_shape)``.
* ``ProgramState({...}, ensemble=B)`` — fleet state; every field is
  ``(B, ncomp, *grid_shape)`` (ensemble axis **leading**, so ``vmap``
  over axis 0 lifts a compiled step to the whole ensemble).
* ``ProgramState.stack([s0, s1, ...])`` ↔ ``state.unstack()`` /
  ``state.member(i)`` move between the two.

``CompiledProgram.step``/``run`` accept either a plain mapping or a
``ProgramState`` and return the same kind; ``FleetProgram`` requires the
ensemble form (or a mapping of pre-batched arrays).  Validation
(:meth:`ProgramState.validate` / :func:`validate_field`) names the
offending field and dimension instead of dumping bare shape tuples.
"""
from __future__ import annotations

from collections.abc import Iterator, Mapping

import jax
import jax.numpy as jnp


def _dim_name(i: int, ensemble: bool) -> str:
    if ensemble and i == 0:
        return "dim 0 (ensemble)"
    j = i - (1 if ensemble else 0)
    return ("dim %d (ncomp)" % i) if j == 0 else (
        "dim %d (grid dim %d)" % (i, j - 1))


def validate_field(name: str, arr, *, ncomp: int | None,
                   grid_shape: tuple[int, ...],
                   ensemble: int | None = None,
                   program: str | None = None) -> None:
    """Shape/ncomp check for one field, raising errors that name the
    offending field and dimension.

    Expected shape: ``(ncomp, *grid_shape)``, with a leading ``ensemble``
    extent prepended when given.  ``ncomp=None`` skips the component
    check (the Program could not infer it).
    """
    where = f" of program {program!r}" if program else ""
    exp = ((ensemble,) if ensemble is not None else ()) \
        + (ncomp if ncomp is not None else -1,) + tuple(grid_shape)
    rank = len(exp)
    got = getattr(arr, "shape", None)
    if got is None or getattr(arr, "ndim", None) != rank:
        raise ValueError(
            f"field {name!r}{where} must be rank {rank} "
            f"({'ensemble, ' if ensemble is not None else ''}ncomp, "
            f"{', '.join(map(str, grid_shape))}); got "
            f"{'rank ' + str(arr.ndim) if hasattr(arr, 'ndim') else 'a non-array'}"
            f" with shape {got}")
    off = 1 if ensemble is not None else 0
    if ensemble is not None and int(got[0]) != ensemble:
        raise ValueError(
            f"field {name!r}{where}: {_dim_name(0, True)} is {got[0]}, "
            f"expected ensemble extent {ensemble}")
    if ncomp is not None and int(got[off]) != ncomp:
        raise ValueError(
            f"field {name!r}{where}: {_dim_name(off, ensemble is not None)} "
            f"is {got[off]}, expected ncomp {ncomp}")
    for d, want in enumerate(grid_shape):
        i = off + 1 + d
        if int(got[i]) != int(want):
            raise ValueError(
                f"field {name!r}{where}: "
                f"{_dim_name(i, ensemble is not None)} is {got[i]}, "
                f"expected grid extent {want} "
                f"(grid_shape {tuple(grid_shape)})")


@jax.tree_util.register_pytree_node_class
class ProgramState(Mapping):
    """Registered-pytree mapping of field name → array, annotated with an
    optional leading ensemble axis.

    Behaves as a read-only mapping (``state["f"]``, ``dict(state)``,
    ``**state``); the pytree leaves are the arrays in field order, the
    aux data is ``(names, ensemble)`` — so ``jax.vmap``/``lax.scan``/
    checkpointing treat it structurally and the annotation survives
    tracing.
    """

    __slots__ = ("_names", "_arrays", "ensemble")

    def __init__(self, arrays: Mapping[str, jax.Array], *,
                 ensemble: int | None = None):
        if not isinstance(arrays, Mapping):
            raise TypeError(f"ProgramState expects a mapping of field "
                            f"name -> array, got {type(arrays).__name__}")
        if ensemble is not None and int(ensemble) <= 0:
            raise ValueError(f"ensemble extent must be positive, "
                             f"got {ensemble}")
        self._names = tuple(arrays)
        self._arrays = {str(k): arrays[k] for k in self._names}
        self.ensemble = int(ensemble) if ensemble is not None else None

    # -- mapping protocol --------------------------------------------------

    def __getitem__(self, key: str):
        try:
            return self._arrays[key]
        except KeyError:
            raise KeyError(
                f"ProgramState has no field {key!r}; fields: "
                f"{list(self._names)}") from None

    def __iter__(self) -> Iterator[str]:
        return iter(self._names)

    def __len__(self) -> int:
        return len(self._names)

    @property
    def fields(self) -> tuple[str, ...]:
        return self._names

    def replace(self, **arrays) -> "ProgramState":
        """Copy with the named field arrays swapped."""
        unknown = sorted(set(arrays) - set(self._names))
        if unknown:
            raise ValueError(f"ProgramState.replace: unknown field(s) "
                             f"{unknown}; fields: {list(self._names)}")
        return ProgramState({n: arrays.get(n, self._arrays[n])
                             for n in self._names}, ensemble=self.ensemble)

    # -- ensemble axis -----------------------------------------------------

    @classmethod
    def stack(cls, states) -> "ProgramState":
        """Stack single-member states (mappings or ``ProgramState``\\ s)
        into one ensemble state along a new leading axis."""
        states = list(states)
        if not states:
            raise ValueError("ProgramState.stack needs at least one state")
        names = tuple(states[0])
        for i, s in enumerate(states):
            if tuple(s) != names:
                raise ValueError(
                    f"ProgramState.stack: member {i} has fields "
                    f"{list(s)}, expected {list(names)}")
            if isinstance(s, ProgramState) and s.ensemble is not None:
                raise ValueError(
                    f"ProgramState.stack: member {i} already carries an "
                    f"ensemble axis (ensemble={s.ensemble})")
        return cls({n: jnp.stack([s[n] for s in states]) for n in names},
                   ensemble=len(states))

    def member(self, i: int) -> "ProgramState":
        """Member *i* of an ensemble state (drops the ensemble axis)."""
        if self.ensemble is None:
            raise ValueError("ProgramState.member: state has no ensemble "
                             "axis")
        if not (-self.ensemble <= int(i) < self.ensemble):
            raise IndexError(f"member {i} out of range for ensemble "
                             f"extent {self.ensemble}")
        return ProgramState({n: self._arrays[n][i] for n in self._names})

    def unstack(self) -> list["ProgramState"]:
        """Split an ensemble state into its members."""
        if self.ensemble is None:
            raise ValueError("ProgramState.unstack: state has no ensemble "
                             "axis")
        return [self.member(i) for i in range(self.ensemble)]

    # -- validation --------------------------------------------------------

    def validate(self, ncomp: Mapping[str, int | None],
                 grid_shape, *, fields=None,
                 program: str | None = None) -> None:
        """Check every field's shape against ``(ncomp, *grid_shape)``
        (plus this state's ensemble extent, if any), raising errors that
        name the offending field and dim.  ``fields`` defaults to this
        state's own field set."""
        grid_shape = tuple(int(s) for s in grid_shape)
        for f in (fields if fields is not None else self._names):
            if f not in self._arrays:
                raise ValueError(
                    f"state{' for program ' + repr(program) if program else ''}"
                    f" is missing field {f!r}; present: "
                    f"{list(self._names)}")
            validate_field(f, self._arrays[f], ncomp=ncomp.get(f),
                           grid_shape=grid_shape, ensemble=self.ensemble,
                           program=program)

    # -- pytree protocol ---------------------------------------------------

    def tree_flatten(self):
        return (tuple(self._arrays[n] for n in self._names),
                (self._names, self.ensemble))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        names, ensemble = aux
        obj = cls.__new__(cls)
        obj._names = names
        obj._arrays = dict(zip(names, leaves))
        obj.ensemble = ensemble
        return obj

    def __repr__(self):
        shapes = {n: tuple(getattr(a, "shape", ()))
                  for n, a in self._arrays.items()}
        ens = f", ensemble={self.ensemble}" if self.ensemble is not None \
            else ""
        return f"ProgramState({shapes}{ens})"
