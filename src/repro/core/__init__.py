"""targetDP core — the paper's contribution as a composable JAX module.

Public surface (paper → here):

* lattice/fields: :class:`Lattice`, :class:`Field` (SoA mandated, AoS kept
  as the measurable baseline layout).
* memory model: :func:`target_malloc`, :func:`copy_to_target`,
  :func:`copy_from_target`, masked variants, :class:`TargetConst`,
  :func:`sync_target`.
* execution model: :func:`site_kernel` (``TARGET_ENTRY``), :func:`launch`
  (``TARGET_LAUNCH`` + ``TARGET_TLP``/``TARGET_ILP`` with tunable VVL),
  :func:`reduce` (the paper's §V planned extension).
"""
from .lattice import (
    D3Q19_VELOCITIES,
    Lattice,
    Stencil,
    STENCIL_D3Q19_PULL,
    STENCIL_GRAD_6PT,
    STENCIL_GRAD_19PT,
    token_lattice,
)
from .field import Field, field_like
from .memory import (
    TargetConst,
    copy_constant_to_target,
    copy_from_target,
    copy_from_target_masked,
    copy_to_target,
    copy_to_target_masked,
    sync_target,
    target_free,
    target_malloc,
    target_malloc_like,
)
from .execute import (
    default_vvl,
    launch,
    launch_stencil,
    reduce,
    set_default_vvl,
    site_kernel,
)

__all__ = [
    "Lattice", "token_lattice", "Field", "field_like",
    "Stencil", "STENCIL_D3Q19_PULL", "STENCIL_GRAD_6PT", "STENCIL_GRAD_19PT",
    "D3Q19_VELOCITIES", "launch_stencil",
    "TargetConst", "copy_constant_to_target",
    "copy_to_target", "copy_from_target",
    "copy_to_target_masked", "copy_from_target_masked",
    "sync_target", "target_free", "target_malloc", "target_malloc_like",
    "site_kernel", "launch", "reduce", "default_vvl", "set_default_vvl",
]
