"""targetDP core — the paper's contribution as a composable JAX module.

Public surface (paper → here):

* lattice/fields: :class:`Lattice`, :class:`Field` (SoA mandated, AoS kept
  as the measurable baseline layout), :class:`Stencil` neighbourhoods.
* memory model: :func:`target_malloc`, :func:`copy_to_target`,
  :func:`copy_from_target`, masked variants, :class:`TargetConst`,
  :func:`sync_target`.
* execution model (declarative): :class:`KernelSpec` + :func:`kernel`
  (``TARGET_ENTRY`` with declared field roles), :class:`Target` (the
  build switch as an exchangeable descriptor), :func:`tdp_launch`
  (``TARGET_LAUNCH`` + ``TARGET_TLP``/``TARGET_ILP`` with tunable VVL)
  dispatching through :func:`register_executor`'s table, and
  :func:`reduce` (the paper's §V planned extension).
* legacy surface: :func:`site_kernel`, :func:`launch`,
  :func:`launch_stencil` (deprecation shims over ``tdp_launch``).

The ergonomic import is ``from repro import tdp`` — see
:mod:`repro.tdp` and docs/targetdp_api.md.
"""
from .lattice import (
    D3Q19_VELOCITIES,
    Lattice,
    Stencil,
    STENCIL_D3Q19_PULL,
    STENCIL_GRAD_6PT,
    STENCIL_GRAD_19PT,
    token_lattice,
)
from .field import Field, field_like
from .memory import (
    BatchedConst,
    TargetConst,
    copy_constant_to_target,
    copy_from_target,
    copy_from_target_masked,
    copy_to_target,
    copy_to_target_masked,
    sync_target,
    target_free,
    target_malloc,
    target_malloc_like,
)
from .target import Target, as_target, default_vvl, set_default_vvl
from .spec import FieldSpec, KernelSpec, kernel
from .registry import (
    compatible_executors,
    executor_tunables,
    executor_wants,
    get_executor,
    get_executor_entry,
    list_executors,
    register_executor,
    registry_version,
    unregister_executor,
)
from .api import (
    LaunchPlan,
    WindowVmemError,
    gather_neighbors,
    halo_extend,
    launch_plan,
    pad_sites,
)
from .api import launch as tdp_launch
from .layout import (
    LAYOUTS,
    aosoa_nblocks,
    aosoa_to_soa,
    soa_to_aosoa,
)
from .program import (
    CompiledProgram,
    Program,
    ProgramPlan,
    exchange_ghosts,
    exchange_stats,
    Stage,
    program,
    stage,
)
from .state import ProgramState, validate_field
from .fleet import FleetDriver, FleetProgram, Ticket
from .autotune import (
    Candidate,
    TuneReport,
    TuneResult,
    autotune,
    default_space,
    plane_block_candidates,
    wall_clock_timer,
)
from .costmodel import (
    CostEstimate,
    MachineProfile,
    machine_profile,
    predict,
    roofline_seconds,
)
from .execute import (
    launch,
    launch_stencil,
    reduce,
    site_kernel,
)

__all__ = [
    "Lattice", "token_lattice", "Field", "field_like",
    "Stencil", "STENCIL_D3Q19_PULL", "STENCIL_GRAD_6PT", "STENCIL_GRAD_19PT",
    "D3Q19_VELOCITIES", "launch_stencil",
    "TargetConst", "copy_constant_to_target",
    "copy_to_target", "copy_from_target",
    "copy_to_target_masked", "copy_from_target_masked",
    "sync_target", "target_free", "target_malloc", "target_malloc_like",
    "site_kernel", "launch", "reduce", "default_vvl", "set_default_vvl",
    # declarative API
    "Target", "as_target", "FieldSpec", "KernelSpec", "kernel",
    "tdp_launch", "launch_plan", "LaunchPlan", "gather_neighbors",
    "halo_extend", "pad_sites", "WindowVmemError",
    # memory layout axis (SoA ↔ AoSoA)
    "LAYOUTS", "aosoa_nblocks", "aosoa_to_soa", "soa_to_aosoa",
    "register_executor", "unregister_executor", "get_executor",
    "get_executor_entry", "executor_wants", "executor_tunables",
    "compatible_executors", "list_executors", "registry_version",
    # step graphs
    "Program", "CompiledProgram", "ProgramPlan", "Stage", "program",
    "exchange_ghosts", "exchange_stats",
    "stage",
    # fleets (ensemble execution + async service)
    "BatchedConst", "ProgramState", "validate_field",
    "FleetProgram", "FleetDriver", "Ticket",
    # autotuning
    "autotune", "default_space", "plane_block_candidates",
    "Candidate", "TuneReport", "TuneResult", "wall_clock_timer",
    # cost model
    "CostEstimate", "MachineProfile", "machine_profile", "predict",
    "roofline_seconds",
]
