"""Kernel specifications — *what* a launch computes, declared up front.

A :class:`KernelSpec` is the declarative form of the paper's
``TARGET_ENTRY`` + launch-site annotations: the site-kernel body plus, per
input field, its *role* — pointwise (``(ncomp, VVL)`` chunks), or
stencil-carrying (``(noffsets, ncomp, VVL)`` neighbour chunks, with the
:class:`~repro.core.lattice.Stencil` and halo policy) — plus the output
component counts and whether the kernel wants the global site index
(``site_index=True``, the position-dependent-kernel role).

Build one with the :func:`kernel` decorator::

    @tdp.kernel(fields=[tdp.field(3)], out=3)
    def scale(x, a=1.0):
        return a * x

or the explicit constructor (when one body backs several specs)::

    STREAM_SPEC = KernelSpec(stream_site_kernel,
                             fields=(FieldSpec(stencil=STENCIL_D3Q19_PULL),),
                             out=NVEL)

Specs are frozen and hashable: together with the :class:`Target` they key
the launch-plan cache in :mod:`repro.core.api`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

from .lattice import Stencil

#: FieldSpec.halo policies: "auto" accepts both regimes, "periodic"
#: requires a wrap-only gather (halo 0), "ghost" requires caller-filled
#: ghost planes (halo > 0) in every dimension the stencil reaches.
_HALO_POLICIES = ("auto", "periodic", "ghost")


@dataclass(frozen=True)
class FieldSpec:
    """Role declaration for one launch input.

    Args:
      ncomp: expected component count (leading SoA axis); ``None`` skips
        the check.
      stencil: neighbourhood this input is gathered over — ``None`` means
        a pointwise input.
      halo: halo policy for stencil inputs (see ``_HALO_POLICIES``).
      name: optional label used in error messages.
    """

    ncomp: int | None = None
    stencil: Stencil | None = None
    halo: str = "auto"
    name: str | None = None

    def __post_init__(self):
        if self.ncomp is not None and int(self.ncomp) <= 0:
            raise ValueError(f"ncomp must be positive, got {self.ncomp}")
        if self.stencil is not None and not isinstance(self.stencil, Stencil):
            raise TypeError(f"stencil must be a Stencil, got "
                            f"{type(self.stencil).__name__}")
        if self.halo not in _HALO_POLICIES:
            raise ValueError(f"halo policy must be one of {_HALO_POLICIES}, "
                             f"got {self.halo!r}")
        if self.halo == "ghost" and self.stencil is None:
            raise ValueError("halo='ghost' only applies to stencil fields")

    @property
    def role(self) -> str:
        return "pointwise" if self.stencil is None else "stencil"

    def label(self, i: int) -> str:
        return self.name or f"field {i}"


def field(ncomp: int | None = None, *, stencil: Stencil | None = None,
          halo: str = "auto", name: str | None = None) -> FieldSpec:
    """Ergonomic :class:`FieldSpec` constructor for ``@kernel(fields=[...])``."""
    return FieldSpec(ncomp=ncomp, stencil=stencil, halo=halo, name=name)


def _as_field_spec(x) -> FieldSpec:
    if isinstance(x, FieldSpec):
        return x
    if isinstance(x, Stencil):
        return FieldSpec(stencil=x)
    if x is None:
        return FieldSpec()
    if isinstance(x, int):
        return FieldSpec(ncomp=x)
    raise TypeError(f"cannot interpret {x!r} as a FieldSpec "
                    "(expected FieldSpec, Stencil, int ncomp, or None)")


def _normalize_out(out) -> tuple[int, ...] | None:
    if out is None:
        return None
    if isinstance(out, int):
        return (int(out),)
    return tuple(int(c) for c in out)


@dataclass(frozen=True)
class KernelSpec:
    """Declarative description of one targetDP kernel launch.

    Args:
      fn: the site-kernel body (pure jnp — single-source across executors).
      fields: per-input role declarations (coercible: a ``Stencil`` means a
        stencil field, an int means a pointwise field of that ncomp,
        ``None`` means unconstrained pointwise).
      out: output component count(s); ``None`` → infer from input 0.
      site_index: pass the global site indices ``(VVL,)`` as the last
        positional kernel argument (``TARGET_ILP`` offset + baseIndex).
      consts: optionally, the accepted ``TARGET_CONST`` names — launches
        passing an undeclared const name fail fast.
      name: display name (defaults to ``fn.__name__``).
    """

    fn: Callable
    fields: tuple[FieldSpec, ...]
    out: tuple[int, ...] | None = None
    site_index: bool = False
    consts: tuple[str, ...] | None = None
    name: str = ""

    def __post_init__(self):
        if not callable(self.fn):
            raise TypeError(f"kernel fn must be callable, got {self.fn!r}")
        fields = tuple(_as_field_spec(f) for f in self.fields)
        if not fields:
            raise ValueError("a KernelSpec needs at least one input field")
        object.__setattr__(self, "fields", fields)
        object.__setattr__(self, "out", _normalize_out(self.out))
        if self.consts is not None:
            object.__setattr__(self, "consts",
                               tuple(str(c) for c in self.consts))
        if not self.name:
            object.__setattr__(
                self, "name", getattr(self.fn, "__name__", "site_kernel"))

    @property
    def has_stencil(self) -> bool:
        return any(f.stencil is not None for f in self.fields)

    @property
    def stencils(self) -> tuple[Stencil | None, ...]:
        return tuple(f.stencil for f in self.fields)

    def max_radius_per_dim(self) -> tuple[int, ...]:
        """Per-dimension maximum stencil radius over all fields — the
        ghost-layer requirement of a launch of this spec (what a
        ``wants="halo_extended"`` executor's window depth and a sharded
        caller's halo exchange width must cover).  Raises on pointwise
        specs (no stencil geometry to report)."""
        radii = [f.stencil.radius_per_dim() for f in self.fields
                 if f.stencil is not None]
        if not radii:
            raise ValueError(
                f"kernel {self.name!r} has no stencil-carrying fields")
        return tuple(max(r[d] for r in radii) for d in range(len(radii[0])))

    def __call__(self, *args, **kwargs):
        """A spec is callable as its body — handy for composing kernels."""
        return self.fn(*args, **kwargs)


def kernel(fields: Sequence, out=None, *, site_index: bool = False,
           consts: Sequence[str] | None = None,
           name: str | None = None) -> Callable[[Callable], KernelSpec]:
    """Decorator form of :class:`KernelSpec` (``TARGET_ENTRY`` declared
    together with its launch-site roles)::

        @tdp.kernel(fields=[tdp.field(1, stencil=STENCIL_GRAD_6PT)],
                    out=(3, 1))
        def grad6(phi_nb): ...

    The decorated name *is* the spec; its body stays reachable as
    ``spec.fn`` and the spec itself remains callable.
    """
    def deco(fn: Callable) -> KernelSpec:
        fn.__tdp_site_kernel__ = True
        return KernelSpec(fn, tuple(fields), out=out, site_index=site_index,
                          consts=tuple(consts) if consts is not None else None,
                          name=name or getattr(fn, "__name__", ""))
    return deco
