"""targetDP memory model: host vs target copies, masked transfers, constants.

Paper §III-A/B: *"We maintain both host and target copies of our lattice
data, where the target copy is located in a memory space suitable for access
on the target, and is treated as the master copy within those lattice-based
computations."*  Crucially the distinction is kept **even when the target is
the host CPU** — which is exactly this container's situation (the target is
a CpuDevice; on a real deployment it is a TPU chip's HBM, possibly sharded
over a mesh).

Mapping of the paper's library surface:

=========================  ====================================================
paper                      this module
=========================  ====================================================
``targetMalloc``           :func:`target_malloc`  (``jax.device_put`` of zeros,
                           optionally with a ``NamedSharding``)
``targetFree``             :func:`target_free`    (``.delete()``)
``copyToTarget``           :func:`copy_to_target`
``copyFromTarget``         :func:`copy_from_target`
``copyToTargetMasked``     :func:`copy_to_target_masked`   (pack → transfer →
``copyFromTargetMasked``   :func:`copy_from_target_masked`  device scatter, the
                           same compress/unpack scheme as the paper's CUDA impl)
``TARGET_CONST`` +         :class:`TargetConst` — small read-only parameters
``copyConstant<X>ToTarget``  closed over at ``jit`` time (XLA constant-folds
                           them into fast memory; the TPU analogue of
                           ``__constant__``), or fed to Pallas kernels via
                           scalar prefetch (SMEM).
``syncTarget``             :func:`sync_target` (``block_until_ready``)
=========================  ====================================================
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .field import Field, field_like


def _maybe_put(x, sharding):
    if sharding is None:
        return jax.device_put(x)
    return jax.device_put(x, sharding)


# ---------------------------------------------------------------------------
# allocation
# ---------------------------------------------------------------------------

def target_malloc(shape: tuple[int, ...], dtype=jnp.float32, sharding=None) -> jax.Array:
    """Allocate a zeroed target array (``targetMalloc`` + error checking).

    With a ``NamedSharding`` the allocation lands distributed over the mesh —
    the multi-chip generalisation of "a memory space suitable for access on
    the target".
    """
    if any(int(s) <= 0 for s in shape):
        raise ValueError(f"non-positive extent in {shape}")
    return _maybe_put(jnp.zeros(shape, dtype=dtype), sharding)


def target_malloc_like(f: Field, sharding=None, dtype=None) -> jax.Array:
    return target_malloc(f.array_shape, dtype or f.dtype, sharding)


def target_free(arr: jax.Array) -> None:
    """Release target memory eagerly (``targetFree``)."""
    arr.delete()


# ---------------------------------------------------------------------------
# full-lattice transfers
# ---------------------------------------------------------------------------

def copy_to_target(host: Field | np.ndarray, sharding=None, dtype=None) -> jax.Array:
    """Host → target transfer of a full field (``copyToTarget``)."""
    data = host.data if isinstance(host, Field) else np.asarray(host)
    if dtype is not None:
        data = data.astype(dtype)
    return _maybe_put(data, sharding)


def copy_from_target(target: jax.Array, host: Field | None = None) -> Field | np.ndarray:
    """Target → host transfer (``copyFromTarget``).

    If ``host`` is given, its buffer is overwritten in place (matching the
    paper's signature); otherwise a bare ndarray is returned.
    """
    out = np.asarray(jax.device_get(target))
    if host is None:
        return out
    if out.shape != host.data.shape:
        raise ValueError(f"shape mismatch {out.shape} vs {host.data.shape}")
    host.data[...] = out.astype(host.dtype)
    return host


# ---------------------------------------------------------------------------
# masked (compressed) transfers — paper §III-B
# ---------------------------------------------------------------------------
#
# "It is often the case that only a subset of the lattice data is required in
#  such transfers. ... a CUDA kernel ... pack[s] the included sites into a
#  scratch structure on the GPU, transferring the packed structure with
#  cudaMemcpy, and unpacking on the host using a loop."
#
# We realise pack/unpack with gather/scatter.  The mask is boolean over sites;
# the packed buffer has static shape (ncomp, nsel) so the pack step is
# jit-able (nsel is derived on the host from the mask, which the paper also
# requires to be host-known).

def _site_indices(mask: np.ndarray) -> np.ndarray:
    mask = np.asarray(mask)
    if mask.dtype != np.bool_:
        mask = mask.astype(bool)
    return np.flatnonzero(mask.reshape(-1))


@jax.jit
def _pack_soa(target: jax.Array, idx: jax.Array) -> jax.Array:
    return jnp.take(target, idx, axis=-1)


@jax.jit
def _scatter_soa(target: jax.Array, idx: jax.Array, packed: jax.Array) -> jax.Array:
    return target.at[..., idx].set(packed)


def copy_from_target_masked(target: jax.Array, mask: np.ndarray,
                            host: Field | None = None) -> np.ndarray | Field:
    """Compressed target → host copy of the masked site subset.

    Pack on device (gather over the site axis), transfer only the packed
    buffer, unpack into the host field.  SoA layout (site axis last).
    """
    idx = _site_indices(mask)
    if idx.size == 0:
        if host is not None:
            return host
        return np.zeros(target.shape[:-1] + (0,), dtype=target.dtype)
    packed = np.asarray(jax.device_get(_pack_soa(target, jnp.asarray(idx))))
    if host is None:
        return packed
    host.data[..., idx] = packed.astype(host.dtype)
    return host


def copy_to_target_masked(target: jax.Array, host: Field | np.ndarray,
                          mask: np.ndarray) -> jax.Array:
    """Compressed host → target copy of the masked site subset.

    Pack on the host (cheap), transfer the packed buffer, scatter on device.
    Returns the updated target array (functional update — JAX arrays are
    immutable, the paper's in-place semantics become a rebind).
    """
    data = host.data if isinstance(host, Field) else np.asarray(host)
    idx = _site_indices(mask)
    if idx.size == 0:
        return target
    packed = data[..., idx]
    return _scatter_soa(target, jnp.asarray(idx), jax.device_put(packed.astype(target.dtype)))


# ---------------------------------------------------------------------------
# constants
# ---------------------------------------------------------------------------

class TargetConst:
    """A small read-only parameter living "close to the registers".

    The paper's CUDA implementation copies these to ``__constant__`` memory
    via ``cudaMemcpyToSymbol``; the C implementation memcpys.  Under XLA the
    equivalent is to let the value be **closed over** by the jitted launch:
    XLA embeds it in the executable and stages it into the fastest available
    memory.  For Pallas kernels, scalars are additionally eligible for SMEM
    scalar-prefetch.

    ``TargetConst`` values hash by content so they participate in jit cache
    keys correctly — re-copying a constant (``copyConstant<X>ToTarget``)
    triggers exactly one recompile, mirroring the paper's explicit update.
    """

    __slots__ = ("value", "_key")

    def __init__(self, value: Any):
        arr = np.asarray(value)
        # Keep the host (numpy) array: constructing a device array here
        # would, under an active jit trace, capture a tracer that outlives
        # the trace (launch closures are cached across traces).  jnp ops
        # consume numpy constants transparently at trace time.
        self.value = arr
        self._key = (arr.shape, str(arr.dtype), arr.tobytes())

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, TargetConst) and self._key == other._key

    def __repr__(self):
        return f"TargetConst(shape={self.value.shape}, dtype={self.value.dtype})"


class BatchedConst(TargetConst):
    """A :class:`TargetConst` with a leading **ensemble axis**: row *i*
    is member *i*'s value of the constant (a parameter sweep — per-member
    mobility, viscosity, ...).

    A Program stage binding a ``BatchedConst`` can only execute inside a
    fleet (:meth:`repro.core.program.CompiledProgram.vmap`): the compiled
    core receives the per-member slice as a *dynamic* const (a traced
    value threaded through the launch as an operand instead of being
    closed over), so one jitted fleet step serves every member of the
    sweep.  Content-hashing is inherited — two sweeps with equal values
    share plan-cache entries.
    """

    __slots__ = ()

    def __init__(self, value: Any):
        super().__init__(value)
        if self.value.ndim < 1:
            raise ValueError(
                f"BatchedConst needs a leading ensemble axis; got a "
                f"0-d value (shape {self.value.shape}) — wrap a plain "
                f"scalar in TargetConst instead")

    @property
    def batch(self) -> int:
        """The ensemble extent (leading-axis length)."""
        return int(self.value.shape[0])

    def member_shape(self) -> tuple:
        return tuple(self.value.shape[1:])

    def __repr__(self):
        return (f"BatchedConst(batch={self.batch}, "
                f"member_shape={self.member_shape()}, "
                f"dtype={self.value.dtype})")


def copy_constant_to_target(value: Any) -> TargetConst:
    """Family stand-in for ``copyConstant<Double|Int|...>ToTarget``."""
    return TargetConst(value)


# ---------------------------------------------------------------------------
# synchronisation
# ---------------------------------------------------------------------------

def sync_target(*arrays: jax.Array) -> None:
    """``syncTarget``: wait for outstanding target work (no-op semantics on
    the C/host build, a real barrier for asynchronous device execution)."""
    for a in arrays:
        a.block_until_ready()
    if not arrays:
        (jnp.zeros(()) + 0).block_until_ready()
