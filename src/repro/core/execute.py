"""targetDP execution model: single-source site kernels, TLP × ILP, VVL.

Paper §III-C, restated for TPU/JAX:

* A **site kernel** is written once, against *chunk* arrays of shape
  ``(ncomp, VVL)`` — ``VVL`` (virtual vector length) is the tunable innermost
  extent the paper strip-mines out of the site loop (``TARGET_ILP``).
* **TLP**: the loop over chunks (``TARGET_TLP``).  On the jnp executor it is
  a ``vmap`` over the chunk axis (XLA fuses and threads it); on the Pallas
  executor it is the ``pallas_call`` grid; one level up, the site axis is
  sharded over the device mesh by the caller (``shard_map``/``jit``) — the
  analogue of the paper's MPI level.
* **ILP**: inside a chunk, every op is vectorised over the trailing ``VVL``
  axis — VPU lanes on TPU (the analogue of AVX lanes / per-thread ILP).
* **Single source**: the same kernel body runs under both executors; the
  ``backend=`` switch is the paper's C-vs-CUDA build switch.

The Pallas executor lives in :mod:`repro.kernels.tdp_pointwise` (explicit
``BlockSpec`` VMEM tiling, block extent = VVL); it is imported lazily so the
core stays importable without Pallas.
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from .lattice import Lattice
from .memory import TargetConst

# Default VVL: one full TPU vector register row of lanes.  The paper tunes
# VVL per architecture (8 on AVX, 2 on K40); benchmarks/run.py sweeps it here.
_DEFAULT_VVL = 128

Backend = str  # "xla" | "pallas" | "pallas_interpret"
_VALID_BACKENDS = ("xla", "pallas", "pallas_interpret")


def default_vvl() -> int:
    return _DEFAULT_VVL


def set_default_vvl(vvl: int) -> None:
    global _DEFAULT_VVL
    if vvl <= 0:
        raise ValueError("vvl must be positive")
    _DEFAULT_VVL = int(vvl)


def site_kernel(fn: Callable) -> Callable:
    """Mark ``fn`` as a targetDP site kernel (``TARGET_ENTRY``).

    ``fn(*chunks, **consts)`` receives one ``(ncomp_i, VVL)`` array per input
    field (plus ``site_idx`` of shape ``(VVL,)`` if requested at launch) and
    returns one ``(ncomp_o, VVL)`` array or a tuple of them.  The body must
    be pure jnp — that is what makes it single-source across executors.
    """
    fn.__tdp_site_kernel__ = True
    return fn


def _unwrap_consts(consts: Mapping[str, object]) -> dict:
    out = {}
    for k, v in consts.items():
        out[k] = v.value if isinstance(v, TargetConst) else v
    return out


def _consts_cache_key(consts: Mapping[str, object]):
    items = []
    for k in sorted(consts):
        v = consts[k]
        if isinstance(v, TargetConst):
            items.append((k, v))
        elif isinstance(v, (int, float, bool, str)):
            items.append((k, v))
        else:
            # Fall back to content hashing through TargetConst semantics.
            items.append((k, TargetConst(v)))
    return tuple(items)


def _normalize_out_ncomp(out_ncomp, inputs) -> tuple[int, ...]:
    if out_ncomp is None:
        return (inputs[0].shape[0],)
    if isinstance(out_ncomp, int):
        return (out_ncomp,)
    return tuple(int(c) for c in out_ncomp)


# ---------------------------------------------------------------------------
# jnp executor ("C implementation")
# ---------------------------------------------------------------------------

def _xla_launch(kernel, vvl: int, with_site_index: bool, n_out: int,
                consts: dict, inputs: Sequence[jax.Array]):
    n = inputs[0].shape[-1]
    n_pad = -(-n // vvl) * vvl
    nchunks = n_pad // vvl

    def pad(x):
        if n_pad == n:
            return x
        return jnp.pad(x, ((0, 0), (0, n_pad - n)))

    chunked = [pad(x).reshape(x.shape[0], nchunks, vvl) for x in inputs]

    body = functools.partial(kernel, **consts) if consts else kernel
    if with_site_index:
        site_idx = jnp.arange(n_pad, dtype=jnp.int32).reshape(nchunks, vvl)
        outs = jax.vmap(body, in_axes=(1,) * len(chunked) + (0,),
                        out_axes=1 if n_out == 1 else (1,) * n_out)(*chunked, site_idx)
    else:
        outs = jax.vmap(body, in_axes=1,
                        out_axes=1 if n_out == 1 else (1,) * n_out)(*chunked)
    outs = (outs,) if n_out == 1 else tuple(outs)
    flat = tuple(o.reshape(o.shape[0], n_pad)[:, :n] for o in outs)
    return flat[0] if n_out == 1 else flat


# ---------------------------------------------------------------------------
# launch ("TARGET_LAUNCH") — dispatches on backend, jit-cached
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _build_launch(kernel, vvl: int, backend: Backend, with_site_index: bool,
                  out_ncomp: tuple[int, ...], const_key) -> Callable:
    consts = _unwrap_consts(dict(const_key))
    n_out = len(out_ncomp)

    if backend == "xla":
        fn = functools.partial(_xla_launch, kernel, vvl, with_site_index, n_out, consts)
    else:
        from repro.kernels import tdp_pointwise  # lazy: Pallas import
        fn = functools.partial(
            tdp_pointwise.pallas_launch, kernel, vvl, with_site_index,
            out_ncomp, consts, backend == "pallas_interpret")
    return jax.jit(lambda *xs: fn(xs))


def launch(kernel: Callable, lattice: Lattice | None, inputs: Sequence[jax.Array], *,
           out_ncomp: int | Sequence[int] | None = None,
           consts: Mapping[str, object] | None = None,
           vvl: int | None = None,
           backend: Backend = "xla",
           with_site_index: bool = False):
    """Launch a site kernel over the lattice (``kernel TARGET_LAUNCH(N) (...)``).

    Args:
      kernel: a :func:`site_kernel` function.
      lattice: optional lattice descriptor (used for validation only; the
        site extent is taken from the input arrays, which may include halo).
      inputs: SoA target arrays, each ``(ncomp_i, nsites)``.  targetDP
        *requires* SoA (paper §III-B); pass ``Field.to_layout("soa")`` data.
      out_ncomp: component count(s) of the output(s); defaults to input 0's.
      consts: ``TARGET_CONST`` parameters (``TargetConst`` or scalars) —
        closed over at jit time.
      vvl: virtual vector length (ILP extent).  Default 128 (TPU lane row).
      backend: ``"xla"`` (jnp executor), ``"pallas"`` (TPU VMEM tiling) or
        ``"pallas_interpret"`` (Pallas semantics on CPU, for validation).
      with_site_index: pass global site indices ``(vvl,)`` as the last
        positional argument (e.g. position-dependent kernels like RoPE).
    """
    if backend not in _VALID_BACKENDS:
        raise ValueError(f"backend must be one of {_VALID_BACKENDS}, got {backend!r}")
    inputs = tuple(inputs)
    if not inputs:
        raise ValueError("launch requires at least one input field")
    nsite_set = {int(x.shape[-1]) for x in inputs}
    if len(nsite_set) != 1:
        raise ValueError(f"inputs disagree on site extent: {sorted(nsite_set)}")
    if any(x.ndim != 2 for x in inputs):
        raise ValueError("inputs must be SoA arrays of shape (ncomp, nsites)")
    if lattice is not None:
        n = nsite_set.pop()
        if n not in (lattice.nsites, lattice.nsites_with_halo):
            raise ValueError(
                f"site extent {n} matches neither interior ({lattice.nsites}) "
                f"nor halo-padded ({lattice.nsites_with_halo}) lattice")
    vvl = vvl or _DEFAULT_VVL
    out_spec = _normalize_out_ncomp(out_ncomp, inputs)
    key = _consts_cache_key(consts or {})
    return _build_launch(kernel, vvl, backend, with_site_index, out_spec, key)(*inputs)


# ---------------------------------------------------------------------------
# reductions — the paper's §V "planned extension", implemented
# ---------------------------------------------------------------------------

_REDUCERS = {
    "sum": (jnp.sum, 0.0),
    "max": (jnp.max, -jnp.inf),
    "min": (jnp.min, jnp.inf),
}


@functools.lru_cache(maxsize=None)
def _masked_kernel(kernel: Callable, op: str) -> Callable:
    """Wrap ``kernel`` so padding sites map to the reduction identity.

    Cached per (kernel, op) so repeated ``reduce`` calls reuse one jitted
    launch instead of recompiling (the wrapper's identity is the cache key
    inside :func:`_build_launch`).
    """
    _, ident = _REDUCERS[op]

    def masked(*chunks_and_idx, _tdp_nsites: int = 0, **kw):
        *chunks, site_idx = chunks_and_idx
        vals = kernel(*chunks, **kw)
        single = not isinstance(vals, tuple)
        vals = (vals,) if single else vals
        keep = (site_idx < _tdp_nsites)[None, :]
        out = tuple(jnp.where(keep, v, ident) for v in vals)
        return out[0] if single else out

    masked.__name__ = f"reduce_{op}_{getattr(kernel, '__name__', 'kernel')}"
    return masked


def reduce(kernel: Callable, lattice: Lattice | None, inputs: Sequence[jax.Array], *,
           op: str = "sum",
           out_ncomp: int | Sequence[int] | None = None,
           consts: Mapping[str, object] | None = None,
           vvl: int | None = None,
           backend: Backend = "xla") -> jax.Array:
    """Map a site kernel over the lattice and reduce over sites.

    Returns ``(ncomp_out,)``.  Padding sites are masked with the reduction
    identity *after* mapping, so kernels need not behave on padded zeros.
    """
    if op not in _REDUCERS:
        raise ValueError(f"op must be one of {sorted(_REDUCERS)}")
    reducer, _ = _REDUCERS[op]
    n = int(inputs[0].shape[-1])
    all_consts = dict(consts or {})
    all_consts["_tdp_nsites"] = n
    out_spec = _normalize_out_ncomp(out_ncomp, inputs)
    mapped = launch(_masked_kernel(kernel, op), lattice, inputs, out_ncomp=out_spec,
                    consts=all_consts, vvl=vvl, backend=backend, with_site_index=True)
    mapped = (mapped,) if not isinstance(mapped, tuple) else mapped
    red = tuple(reducer(m, axis=-1) for m in mapped)
    return red[0] if len(red) == 1 else red
