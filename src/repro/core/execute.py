"""targetDP execution model: single-source site kernels, TLP × ILP, VVL.

Paper §III-C, restated for TPU/JAX:

* A **site kernel** is written once, against *chunk* arrays of shape
  ``(ncomp, VVL)`` — ``VVL`` (virtual vector length) is the tunable innermost
  extent the paper strip-mines out of the site loop (``TARGET_ILP``).
* **TLP**: the loop over chunks (``TARGET_TLP``).  On the jnp executor it is
  a ``vmap`` over the chunk axis (XLA fuses and threads it); on the Pallas
  executor it is the ``pallas_call`` grid; one level up, the site axis is
  sharded over the device mesh by the caller (``shard_map``/``jit``) — the
  analogue of the paper's MPI level.
* **ILP**: inside a chunk, every op is vectorised over the trailing ``VVL``
  axis — VPU lanes on TPU (the analogue of AVX lanes / per-thread ILP).
* **Single source**: the same kernel body runs under both executors; the
  ``backend=`` switch is the paper's C-vs-CUDA build switch.

The Pallas executor lives in :mod:`repro.kernels.tdp_pointwise` (explicit
``BlockSpec`` VMEM tiling, block extent = VVL); it is imported lazily so the
core stays importable without Pallas.
"""
from __future__ import annotations

import functools
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from .lattice import Lattice, Stencil
from .memory import TargetConst

# Default VVL: one full TPU vector register row of lanes.  The paper tunes
# VVL per architecture (8 on AVX, 2 on K40); benchmarks/run.py sweeps it here.
_DEFAULT_VVL = 128

Backend = str  # "xla" | "pallas" | "pallas_interpret"
_VALID_BACKENDS = ("xla", "pallas", "pallas_interpret")


def default_vvl() -> int:
    return _DEFAULT_VVL


def set_default_vvl(vvl: int) -> None:
    global _DEFAULT_VVL
    if vvl <= 0:
        raise ValueError("vvl must be positive")
    _DEFAULT_VVL = int(vvl)


def site_kernel(fn: Callable) -> Callable:
    """Mark ``fn`` as a targetDP site kernel (``TARGET_ENTRY``).

    ``fn(*chunks, **consts)`` receives one ``(ncomp_i, VVL)`` array per input
    field (plus ``site_idx`` of shape ``(VVL,)`` if requested at launch) and
    returns one ``(ncomp_o, VVL)`` array or a tuple of them.  The body must
    be pure jnp — that is what makes it single-source across executors.
    """
    fn.__tdp_site_kernel__ = True
    return fn


def _unwrap_consts(consts: Mapping[str, object]) -> dict:
    out = {}
    for k, v in consts.items():
        out[k] = v.value if isinstance(v, TargetConst) else v
    return out


def _consts_cache_key(consts: Mapping[str, object]):
    items = []
    for k in sorted(consts):
        v = consts[k]
        if isinstance(v, TargetConst):
            items.append((k, v))
        elif isinstance(v, (int, float, bool, str)):
            items.append((k, v))
        else:
            # Fall back to content hashing through TargetConst semantics.
            items.append((k, TargetConst(v)))
    return tuple(items)


def _normalize_out_ncomp(out_ncomp, inputs) -> tuple[int, ...]:
    if out_ncomp is None:
        return (inputs[0].shape[0],)
    if isinstance(out_ncomp, int):
        return (out_ncomp,)
    return tuple(int(c) for c in out_ncomp)


# ---------------------------------------------------------------------------
# jnp executor ("C implementation")
# ---------------------------------------------------------------------------

def pad_sites(x: jax.Array, vvl: int) -> jax.Array:
    """Zero-pad the trailing site axis up to a VVL multiple (paper §III-C:
    the TLP loop strides in whole chunks).  Shared by every executor —
    padded lanes are sliced away after the launch, so kernels may produce
    garbage (even NaN) there."""
    n = x.shape[-1]
    n_pad = -(-n // vvl) * vvl
    if n_pad == n:
        return x
    widths = [(0, 0)] * (x.ndim - 1) + [(0, n_pad - n)]
    return jnp.pad(x, widths)


def _xla_launch(kernel, vvl: int, with_site_index: bool, n_out: int,
                consts: dict, inputs: Sequence[jax.Array]):
    n = inputs[0].shape[-1]
    n_pad = -(-n // vvl) * vvl
    nchunks = n_pad // vvl

    chunked = [pad_sites(x, vvl).reshape(x.shape[0], nchunks, vvl)
               for x in inputs]

    body = functools.partial(kernel, **consts) if consts else kernel
    if with_site_index:
        site_idx = jnp.arange(n_pad, dtype=jnp.int32).reshape(nchunks, vvl)
        outs = jax.vmap(body, in_axes=(1,) * len(chunked) + (0,),
                        out_axes=1 if n_out == 1 else (1,) * n_out)(*chunked, site_idx)
    else:
        outs = jax.vmap(body, in_axes=1,
                        out_axes=1 if n_out == 1 else (1,) * n_out)(*chunked)
    outs = (outs,) if n_out == 1 else tuple(outs)
    flat = tuple(o.reshape(o.shape[0], n_pad)[:, :n] for o in outs)
    return flat[0] if n_out == 1 else flat


# ---------------------------------------------------------------------------
# launch ("TARGET_LAUNCH") — dispatches on backend, jit-cached
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _build_launch(kernel, vvl: int, backend: Backend, with_site_index: bool,
                  out_ncomp: tuple[int, ...], const_key) -> Callable:
    consts = _unwrap_consts(dict(const_key))
    n_out = len(out_ncomp)

    if backend == "xla":
        fn = functools.partial(_xla_launch, kernel, vvl, with_site_index, n_out, consts)
    else:
        from repro.kernels import tdp_pointwise  # lazy: Pallas import
        fn = functools.partial(
            tdp_pointwise.pallas_launch, kernel, vvl, with_site_index,
            out_ncomp, consts, backend == "pallas_interpret")
    return jax.jit(lambda *xs: fn(xs))


def launch(kernel: Callable, lattice: Lattice | None, inputs: Sequence[jax.Array], *,
           out_ncomp: int | Sequence[int] | None = None,
           consts: Mapping[str, object] | None = None,
           vvl: int | None = None,
           backend: Backend = "xla",
           with_site_index: bool = False):
    """Launch a site kernel over the lattice (``kernel TARGET_LAUNCH(N) (...)``).

    Args:
      kernel: a :func:`site_kernel` function.
      lattice: optional lattice descriptor (used for validation only; the
        site extent is taken from the input arrays, which may include halo).
      inputs: SoA target arrays, each ``(ncomp_i, nsites)``.  targetDP
        *requires* SoA (paper §III-B); pass ``Field.to_layout("soa")`` data.
      out_ncomp: component count(s) of the output(s); defaults to input 0's.
      consts: ``TARGET_CONST`` parameters (``TargetConst`` or scalars) —
        closed over at jit time.
      vvl: virtual vector length (ILP extent).  Default 128 (TPU lane row).
      backend: ``"xla"`` (jnp executor), ``"pallas"`` (TPU VMEM tiling) or
        ``"pallas_interpret"`` (Pallas semantics on CPU, for validation).
      with_site_index: pass global site indices ``(vvl,)`` as the last
        positional argument (e.g. position-dependent kernels like RoPE).
    """
    if backend not in _VALID_BACKENDS:
        raise ValueError(f"backend must be one of {_VALID_BACKENDS}, got {backend!r}")
    inputs = tuple(inputs)
    if not inputs:
        raise ValueError("launch requires at least one input field")
    nsite_set = {int(x.shape[-1]) for x in inputs}
    if len(nsite_set) != 1:
        raise ValueError(f"inputs disagree on site extent: {sorted(nsite_set)}")
    if any(x.ndim != 2 for x in inputs):
        raise ValueError("inputs must be SoA arrays of shape (ncomp, nsites)")
    if lattice is not None:
        n = nsite_set.pop()
        if n not in (lattice.nsites, lattice.nsites_with_halo):
            raise ValueError(
                f"site extent {n} matches neither interior ({lattice.nsites}) "
                f"nor halo-padded ({lattice.nsites_with_halo}) lattice")
    vvl = vvl or _DEFAULT_VVL
    out_spec = _normalize_out_ncomp(out_ncomp, inputs)
    key = _consts_cache_key(consts or {})
    return _build_launch(kernel, vvl, backend, with_site_index, out_spec, key)(*inputs)


# ---------------------------------------------------------------------------
# stencil launch — halo-aware site kernels (paper §III-B meets §III-C)
# ---------------------------------------------------------------------------
#
# A *stencil* site kernel receives, for each input field that carries a
# Stencil descriptor, a ``(noffsets, ncomp, VVL)`` chunk: slot i holds the
# field at ``site + stencil.offsets[i]`` for every site lane of the chunk.
# Inputs without a stencil stay pointwise ``(ncomp, VVL)``.  The gather is
# periodic (roll) along dimensions with no halo and window-sliced along
# dimensions where the caller supplies ghost planes (the mesh-sharded path:
# ``ppermute`` halo exchange fills the ghost planes, this launch consumes
# them) — the JAX restatement of targetDP's masked-copy halo machinery.


def _normalize_stencils(stencil, n_inputs) -> tuple:
    if isinstance(stencil, Stencil):
        return (stencil,) * n_inputs
    stencils = tuple(stencil)
    if len(stencils) != n_inputs:
        raise ValueError(
            f"got {len(stencils)} stencils for {n_inputs} inputs")
    if not any(s is not None for s in stencils):
        raise ValueError("launch_stencil needs at least one Stencil; "
                         "use launch() for pointwise kernels")
    return stencils


def _normalize_halo(halo, ndim) -> tuple[int, ...]:
    if halo is None:
        return (0,) * ndim
    if isinstance(halo, int):
        return (int(halo),) * ndim
    h = tuple(int(x) for x in halo)
    if len(h) != ndim:
        raise ValueError(f"halo {h} does not match lattice ndim {ndim}")
    return h


def gather_neighbors(x: jax.Array, shape: tuple[int, ...],
                     halo: tuple[int, ...], stencil: Stencil) -> jax.Array:
    """``(ncomp, nsites_ext)`` → ``(noffsets, ncomp, nsites)`` neighbour
    stack over the interior sites.

    Dimensions with ``halo[d] == 0`` wrap periodically (``roll``); those
    with ``halo[d] > 0`` read the caller-supplied ghost planes (offset
    window into the extended extent).
    """
    ext = tuple(s + 2 * h for s, h in zip(shape, halo))
    grid = x.reshape(x.shape[0], *ext)
    n = _prod_shape(shape)
    planes = []
    for off in stencil.offsets:
        g = grid
        for d, o in enumerate(off):
            ax = d + 1
            if halo[d]:
                g = jax.lax.slice_in_dim(g, halo[d] + o,
                                         halo[d] + o + shape[d], axis=ax)
            elif o:
                g = jnp.roll(g, -o, axis=ax)
        planes.append(g.reshape(x.shape[0], n))
    return jnp.stack(planes)


def _prod_shape(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def _stencil_xla_launch(kernel, vvl: int, n_out: int, consts: dict,
                        gathered: Sequence[jax.Array]):
    """vmap the kernel over VVL chunks of pre-gathered neighbour stacks.

    ``gathered``: per input either ``(noffsets, ncomp, n)`` (stencil) or
    ``(ncomp, n)`` (pointwise).
    """
    n = gathered[0].shape[-1]
    n_pad = -(-n // vvl) * vvl
    nchunks = n_pad // vvl

    chunks = [pad_sites(x, vvl).reshape(*x.shape[:-1], nchunks, vvl)
              for x in gathered]
    body = functools.partial(kernel, **consts) if consts else kernel
    in_axes = tuple(x.ndim - 2 for x in chunks)
    outs = jax.vmap(body, in_axes=in_axes,
                    out_axes=1 if n_out == 1 else (1,) * n_out)(*chunks)
    outs = (outs,) if n_out == 1 else tuple(outs)
    flat = tuple(o.reshape(o.shape[0], n_pad)[:, :n] for o in outs)
    return flat[0] if n_out == 1 else flat


@functools.lru_cache(maxsize=4096)
def _build_stencil_launch(kernel, vvl: int, backend: Backend,
                          out_ncomp: tuple[int, ...], const_key,
                          lattice: Lattice, halo: tuple[int, ...],
                          stencils: tuple) -> Callable:
    consts = _unwrap_consts(dict(const_key))
    n_out = len(out_ncomp)
    shape = lattice.shape

    def run(*inputs):
        gathered = [
            x if s is None else gather_neighbors(x, shape, halo, s)
            for x, s in zip(inputs, stencils)
        ]
        if backend == "xla":
            return _stencil_xla_launch(kernel, vvl, n_out, consts, gathered)
        from repro.kernels import tdp_stencil  # lazy: Pallas import
        return tdp_stencil.pallas_stencil_launch(
            kernel, vvl, out_ncomp, consts,
            backend == "pallas_interpret", gathered)

    return jax.jit(run)


def launch_stencil(kernel: Callable, lattice: Lattice,
                   inputs: Sequence[jax.Array], *,
                   stencil: Stencil | Sequence[Stencil | None],
                   out_ncomp: int | Sequence[int] | None = None,
                   consts: Mapping[str, object] | None = None,
                   vvl: int | None = None,
                   backend: Backend = "xla",
                   halo: int | Sequence[int] | None = None):
    """Launch a stencil site kernel over the lattice interior.

    Args:
      kernel: site kernel.  For each input with a stencil it receives a
        ``(noffsets, ncomp_i, VVL)`` neighbour chunk (slot order =
        ``stencil.offsets``); pointwise inputs stay ``(ncomp_i, VVL)``.
      lattice: the grid (required — neighbour geometry needs the shape).
      inputs: SoA arrays.  Stencil-carrying inputs span the *extended*
        extent ``prod(shape[d] + 2·halo[d])`` (ghost planes filled by the
        caller when ``halo[d] > 0``); pointwise inputs span the interior.
      stencil: one :class:`Stencil` for all inputs, or a per-input sequence
        (``None`` → pointwise input).
      out_ncomp / consts / vvl / backend: as :func:`launch`.
      halo: per-dimension ghost width already present in the stencil
        inputs.  ``0`` (default) → that dimension wraps periodically.
        Must cover the stencil radius wherever non-zero.

    Returns interior-extent outputs ``(ncomp_out, lattice.nsites)``.
    """
    if backend not in _VALID_BACKENDS:
        raise ValueError(f"backend must be one of {_VALID_BACKENDS}, got {backend!r}")
    if lattice is None:
        raise ValueError("launch_stencil requires a lattice")
    inputs = tuple(inputs)
    if not inputs:
        raise ValueError("launch_stencil requires at least one input field")
    if any(x.ndim != 2 for x in inputs):
        raise ValueError("inputs must be SoA arrays of shape (ncomp, nsites)")
    stencils = _normalize_stencils(stencil, len(inputs))
    h = _normalize_halo(halo, lattice.ndim)
    n_ext = _prod_shape(tuple(s + 2 * hh for s, hh in zip(lattice.shape, h)))
    for x, s in zip(inputs, stencils):
        want = n_ext if s is not None else lattice.nsites
        if int(x.shape[-1]) != want:
            raise ValueError(
                f"input extent {x.shape[-1]} != expected {want} "
                f"({'extended' if s is not None else 'interior'}; "
                f"shape={lattice.shape}, halo={h})")
        if s is not None:
            if s.ndim != lattice.ndim:
                raise ValueError(
                    f"stencil {s.name!r} is {s.ndim}-D on a "
                    f"{lattice.ndim}-D lattice")
            for d, r in enumerate(s.radius_per_dim()):
                if h[d] and h[d] < r:
                    raise ValueError(
                        f"halo {h[d]} in dim {d} < stencil {s.name!r} "
                        f"radius {r}")
    vvl = vvl or _DEFAULT_VVL
    out_spec = _normalize_out_ncomp(out_ncomp, inputs)
    key = _consts_cache_key(consts or {})
    fn = _build_stencil_launch(kernel, vvl, backend, out_spec, key,
                               lattice, h, stencils)
    return fn(*inputs)


# ---------------------------------------------------------------------------
# reductions — the paper's §V "planned extension", implemented
# ---------------------------------------------------------------------------

_REDUCERS = {
    "sum": (jnp.sum, 0.0),
    "max": (jnp.max, -jnp.inf),
    "min": (jnp.min, jnp.inf),
}


@functools.lru_cache(maxsize=None)
def _masked_kernel(kernel: Callable, op: str) -> Callable:
    """Wrap ``kernel`` so padding sites map to the reduction identity.

    Cached per (kernel, op) so repeated ``reduce`` calls reuse one jitted
    launch instead of recompiling (the wrapper's identity is the cache key
    inside :func:`_build_launch`).
    """
    _, ident = _REDUCERS[op]

    def masked(*chunks_and_idx, _tdp_nsites: int = 0, **kw):
        *chunks, site_idx = chunks_and_idx
        vals = kernel(*chunks, **kw)
        single = not isinstance(vals, tuple)
        vals = (vals,) if single else vals
        keep = (site_idx < _tdp_nsites)[None, :]
        out = tuple(jnp.where(keep, v, ident) for v in vals)
        return out[0] if single else out

    masked.__name__ = f"reduce_{op}_{getattr(kernel, '__name__', 'kernel')}"
    return masked


def reduce(kernel: Callable, lattice: Lattice | None, inputs: Sequence[jax.Array], *,
           op: str = "sum",
           out_ncomp: int | Sequence[int] | None = None,
           consts: Mapping[str, object] | None = None,
           vvl: int | None = None,
           backend: Backend = "xla") -> jax.Array:
    """Map a site kernel over the lattice and reduce over sites.

    Returns ``(ncomp_out,)``.  Padding sites are masked with the reduction
    identity *after* mapping, so kernels need not behave on padded zeros.
    """
    if op not in _REDUCERS:
        raise ValueError(f"op must be one of {sorted(_REDUCERS)}")
    reducer, _ = _REDUCERS[op]
    n = int(inputs[0].shape[-1])
    all_consts = dict(consts or {})
    all_consts["_tdp_nsites"] = n
    out_spec = _normalize_out_ncomp(out_ncomp, inputs)
    mapped = launch(_masked_kernel(kernel, op), lattice, inputs, out_ncomp=out_spec,
                    consts=all_consts, vvl=vvl, backend=backend, with_site_index=True)
    mapped = (mapped,) if not isinstance(mapped, tuple) else mapped
    red = tuple(reducer(m, axis=-1) for m in mapped)
    return red[0] if len(red) == 1 else red
