"""Legacy targetDP launch surface + reductions.

The execution model itself (single-source site kernels, TLP × ILP, VVL,
executor dispatch) now lives in the declarative API:

* :mod:`repro.core.spec`     — ``KernelSpec`` / ``FieldSpec`` (*what*)
* :mod:`repro.core.target`   — ``Target`` descriptor (*where/how*)
* :mod:`repro.core.registry` — pluggable executor table
* :mod:`repro.core.api`      — the single ``tdp.launch(spec, target,
  *arrays)`` entry point with the shared validation / padding / const /
  gather / plan-cache path

This module keeps the original ``launch(kernel, lattice, inputs)`` and
``launch_stencil(...)`` signatures as thin deprecation shims over that
entry point (so pre-redesign callers keep working), plus :func:`reduce`
(the paper's §V planned extension) and :func:`site_kernel`.
"""
from __future__ import annotations

import functools
import warnings
from typing import Callable, Mapping, Sequence

import jax.numpy as jnp

from . import api as _api
from .api import (  # noqa: F401 — re-exported for executor modules
    gather_neighbors,
    pad_sites,
)
from .lattice import Lattice, Stencil
from .spec import FieldSpec, KernelSpec
from .target import as_target, default_vvl, set_default_vvl  # noqa: F401


def site_kernel(fn: Callable) -> Callable:
    """Mark ``fn`` as a targetDP site kernel (``TARGET_ENTRY``).

    ``fn(*chunks, **consts)`` receives one ``(ncomp_i, VVL)`` array per
    input field (plus ``site_idx`` of shape ``(VVL,)`` if requested at
    launch) and returns one ``(ncomp_o, VVL)`` array or a tuple of them.
    The body must be pure jnp — that is what makes it single-source across
    executors.  For the declarative form (roles declared up front) use
    :func:`repro.core.spec.kernel` instead.
    """
    fn.__tdp_site_kernel__ = True
    return fn


def _normalize_out_ncomp(out_ncomp, inputs) -> tuple[int, ...]:
    if out_ncomp is None:
        return (inputs[0].shape[0],)
    if isinstance(out_ncomp, int):
        return (out_ncomp,)
    return tuple(int(c) for c in out_ncomp)


def _as_fn(kernel):
    return kernel.fn if isinstance(kernel, KernelSpec) else kernel


# ---------------------------------------------------------------------------
# deprecation shims — delegate to tdp.launch (repro.core.api.launch)
# ---------------------------------------------------------------------------

def launch(kernel: Callable, lattice: Lattice | None,
           inputs: Sequence, *,
           out_ncomp: int | Sequence[int] | None = None,
           consts: Mapping[str, object] | None = None,
           vvl: int | None = None,
           backend: str = "xla",
           with_site_index: bool = False):
    """Deprecated: use ``tdp.launch(KernelSpec, Target, *arrays)``."""
    warnings.warn(
        "launch(kernel, lattice, inputs, backend=...) is deprecated; "
        "declare a KernelSpec and call tdp.launch(spec, Target(...), "
        "*arrays) — see docs/targetdp_api.md",
        DeprecationWarning, stacklevel=2)
    inputs = tuple(inputs)
    if not inputs:
        raise ValueError("launch requires at least one input field")
    spec = KernelSpec(_as_fn(kernel), fields=(FieldSpec(),) * len(inputs),
                      out=out_ncomp, site_index=with_site_index)
    return _api.launch(spec, as_target(backend, vvl=vvl), *inputs,
                       lattice=lattice, consts=consts)


def _normalize_stencils(stencil, n_inputs) -> tuple:
    if isinstance(stencil, Stencil):
        return (stencil,) * n_inputs
    stencils = tuple(stencil)
    if len(stencils) != n_inputs:
        raise ValueError(
            f"got {len(stencils)} stencils for {n_inputs} inputs")
    if not any(s is not None for s in stencils):
        raise ValueError("launch_stencil needs at least one Stencil; "
                         "use launch() for pointwise kernels")
    return stencils


def launch_stencil(kernel: Callable, lattice: Lattice,
                   inputs: Sequence, *,
                   stencil: Stencil | Sequence[Stencil | None],
                   out_ncomp: int | Sequence[int] | None = None,
                   consts: Mapping[str, object] | None = None,
                   vvl: int | None = None,
                   backend: str = "xla",
                   halo: int | Sequence[int] | None = None):
    """Deprecated: use ``tdp.launch`` with stencil-carrying ``FieldSpec``s."""
    warnings.warn(
        "launch_stencil(...) is deprecated; declare stencil fields on a "
        "KernelSpec and call tdp.launch(spec, Target(...), *arrays) — "
        "see docs/targetdp_api.md",
        DeprecationWarning, stacklevel=2)
    inputs = tuple(inputs)
    if not inputs:
        raise ValueError("launch_stencil requires at least one input field")
    if lattice is None:
        raise ValueError("launch_stencil requires a lattice")
    stencils = _normalize_stencils(stencil, len(inputs))
    spec = KernelSpec(_as_fn(kernel),
                      fields=tuple(FieldSpec(stencil=s) for s in stencils),
                      out=out_ncomp)
    return _api.launch(spec, as_target(backend, vvl=vvl), *inputs,
                       lattice=lattice, halo=halo, consts=consts)


# ---------------------------------------------------------------------------
# reductions — the paper's §V "planned extension", implemented
# ---------------------------------------------------------------------------

_REDUCERS = {
    "sum": (jnp.sum, 0.0),
    "max": (jnp.max, -jnp.inf),
    "min": (jnp.min, jnp.inf),
}


@functools.lru_cache(maxsize=None)
def _masked_kernel(kernel: Callable, op: str) -> Callable:
    """Wrap ``kernel`` so padding sites map to the reduction identity.

    Cached per (kernel, op) so repeated ``reduce`` calls reuse one jitted
    launch instead of recompiling (the wrapper's identity is the cache key
    inside the launch-plan cache).
    """
    _, ident = _REDUCERS[op]

    def masked(*chunks_and_idx, _tdp_nsites: int = 0, **kw):
        *chunks, site_idx = chunks_and_idx
        vals = kernel(*chunks, **kw)
        single = not isinstance(vals, tuple)
        vals = (vals,) if single else vals
        keep = (site_idx < _tdp_nsites)[None, :]
        out = tuple(jnp.where(keep, v, ident) for v in vals)
        return out[0] if single else out

    masked.__name__ = f"reduce_{op}_{getattr(kernel, '__name__', 'kernel')}"
    return masked


def reduce(kernel: Callable, lattice: Lattice | None,
           inputs: Sequence, *,
           op: str = "sum",
           out_ncomp: int | Sequence[int] | None = None,
           consts: Mapping[str, object] | None = None,
           vvl: int | None = None,
           backend: str | None = None,
           target=None):
    """Map a site kernel over the lattice and reduce over sites.

    Returns ``(ncomp_out,)``.  Padding sites are masked with the reduction
    identity *after* mapping, so kernels need not behave on padded zeros.
    Accepts a plain site kernel or a :class:`KernelSpec` (its body and
    declared outputs are used); the target may be a ``Target`` or the
    legacy ``backend=`` string.
    """
    if op not in _REDUCERS:
        raise ValueError(f"op must be one of {sorted(_REDUCERS)}")
    reducer, _ = _REDUCERS[op]
    inputs = tuple(inputs)
    if isinstance(kernel, KernelSpec):
        if out_ncomp is None:
            out_ncomp = kernel.out
        kernel = kernel.fn
    n = int(inputs[0].shape[-1])
    all_consts = dict(consts or {})
    all_consts["_tdp_nsites"] = n
    out_spec = _normalize_out_ncomp(out_ncomp, inputs)
    spec = KernelSpec(_masked_kernel(kernel, op),
                      fields=(FieldSpec(),) * len(inputs),
                      out=out_spec, site_index=True)
    tgt = as_target(target if target is not None else (backend or "xla"),
                    vvl=vvl)
    mapped = _api.launch(spec, tgt, *inputs, lattice=lattice,
                         consts=all_consts)
    mapped = (mapped,) if not isinstance(mapped, tuple) else mapped
    red = tuple(reducer(m, axis=-1) for m in mapped)
    return red[0] if len(red) == 1 else red
