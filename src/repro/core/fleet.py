"""``tdp.fleet`` — batched ensemble execution of Programs behind an
async simulation service.

The targetDP layers below this one run *one* lattice simulation well;
the ROADMAP north star wants *throughput* — many independent
trajectories (parameter sweeps, per-user simulations) per device.  Every
:class:`~repro.core.program.CompiledProgram` step is a pure pytree
function, so ``vmap`` lifts it over a leading **ensemble axis** for
free, and members never interact — a fleet trajectory is bit-identical
to running its members one by one.  Three layers:

1. **Ensemble execution** — :class:`FleetProgram` (built by
   ``compiled.vmap(batch)``): the compiled core vmapped over axis 0 of
   every field.  State is a :class:`~repro.core.state.ProgramState` with
   ``ensemble=batch`` (plain mappings of pre-stacked arrays work too).
   Per-member parameters (mobility/viscosity sweeps) are
   :class:`~repro.core.memory.BatchedConst` stage bindings — their
   values thread through the launch machinery as *dynamic* consts, so
   one jitted fleet step serves the whole sweep.  Sharded compiles
   compose the vmap **outside** ``shard_map``: a decomposed fleet still
   runs one halo-exchange round per step.
2. **The async service driver** — :class:`FleetDriver`:
   ``submit(program, params, nsteps) -> ticket`` / ``poll`` /
   ``stream(ticket, every=k)`` / ``drain()``.  Pending requests batch
   into grid-shape **buckets**; each bucket owns one ``FleetProgram``
   (one jit for all its members) and a launch loop fills slots, steps
   the fleet, and scatters results back per ticket — the
   ``examples/serve_lm.py`` prefill/decode request loop, for lattices.
   A submitted grid outside the configured buckets warns **once** and
   falls back to per-member execution instead of silently compiling a
   fresh jit per request.
3. **Durability** — in-flight trajectories checkpoint through
   :mod:`repro.checkpoint.store` (atomic, checksummed, async): member
   states plus ticket metadata (step counter, RNG key, bucket id).  A
   killed driver :meth:`FleetDriver.restore`\\ s every ticket at its
   last saved step; deterministic stepping makes the resumed trajectory
   match an uninterrupted run bit-for-bit.
4. **Resilience** — tickets carry a failure lifecycle (``status ∈
   {queued, running, failed, done}`` with the captured exception and
   traceback).  A fault while pumping a bucket is *attributed*: each
   active ticket replays the chunk through a cached batch-1 fleet
   (traced consts — the bit-identical replay path), so only the
   ticket(s) that actually raise are quarantined while the rest advance
   exactly as a fault-free pump would have.  An optional
   :class:`~repro.core.health.HealthPolicy` adds NaN/Inf/norm guards
   between chunks, quarantining diverged members with a field +
   step-range diagnosis.  Failed tickets retry up to ``max_retries``
   (with backoff), rolling back to their last snapshot; background
   pump-thread exceptions are recorded and re-raised from
   ``drain``/``stream``/``stop`` instead of dying silently; and
   :meth:`FleetDriver.restore` falls back to the newest
   checksum-*valid* snapshot when the latest one is torn.
"""
from __future__ import annotations

import collections
import threading
import time
import traceback as traceback_mod
import warnings
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .health import HealthError, HealthPolicy, diagnose
from .memory import BatchedConst, TargetConst
from .program import CompiledProgram, Program, Stage
from .state import ProgramState, validate_field
from .target import Target, as_target


__all__ = ["FleetProgram", "FleetDriver", "Ticket"]


# ---------------------------------------------------------------------------
# layer 1 — the vmapped ensemble step
# ---------------------------------------------------------------------------

class FleetProgram:
    """``batch`` independent trajectories of one compiled Program,
    stepped by a single jitted launch (``jax.vmap`` of the compiled
    core over a leading ensemble axis).

    Build with :meth:`CompiledProgram.vmap`::

        fleet = prog.compile(target, grid_shape=(16,) * 3).vmap(8)
        state = ProgramState.stack([member0, member1, ...])   # ensemble=8
        state = fleet.run(state, 100)

    Per-member consts: stages binding a :class:`BatchedConst` receive
    member *i*'s row in member *i*'s trajectory.  The baked sweep is the
    default; ``step``/``run`` accept a ``consts=`` mapping overriding
    any batched const with a fresh ``(batch, ...)`` array (the driver's
    slot values) without recompiling.
    """

    def __init__(self, compiled: CompiledProgram, batch: int):
        if not isinstance(compiled, CompiledProgram):
            raise TypeError(f"FleetProgram wraps a CompiledProgram, got "
                            f"{type(compiled).__name__}")
        self.compiled = compiled
        self.batch = int(batch)
        if self.batch < 1:
            raise ValueError(f"fleet batch must be >= 1, got {batch}")
        self.program = compiled.program
        self.grid_shape = compiled.grid_shape
        for name, bc in compiled.batched_consts.items():
            if bc.batch != self.batch:
                raise ValueError(
                    f"program {self.program.name!r}: batched const "
                    f"{name!r} sweeps {bc.batch} member value(s) but the "
                    f"fleet batch is {self.batch}; the ensemble extents "
                    f"must agree")
        self._defaults = {k: jnp.asarray(bc.value)
                          for k, bc in compiled.batched_consts.items()}
        # vmap over axis 0 of every field array and every dynamic const
        self._vcore = jax.vmap(compiled._core)
        self._jit_step = jax.jit(self._vcore)
        self._run_cache: dict = {}

    # -- state plumbing ----------------------------------------------------

    def _as_tuple(self, state: Mapping[str, jax.Array]):
        if isinstance(state, ProgramState):
            if state.ensemble is None:
                raise ValueError(
                    f"fleet state for program {self.program.name!r} "
                    f"must carry an ensemble axis; got a single-member "
                    f"ProgramState — build one with ProgramState.stack "
                    f"or ProgramState(arrays, ensemble={self.batch})")
            if state.ensemble != self.batch:
                raise ValueError(
                    f"fleet state ensemble extent {state.ensemble} != "
                    f"fleet batch {self.batch} "
                    f"(program {self.program.name!r})")
        arrays = []
        for f in self.program.fields:
            if f not in state:
                raise ValueError(
                    f"fleet state for program {self.program.name!r} is "
                    f"missing field {f!r}; present: {sorted(state)}")
            a = state[f]
            validate_field(f, a, ncomp=self.program.ncomp.get(f),
                           grid_shape=self.grid_shape,
                           ensemble=self.batch,
                           program=self.program.name)
            arrays.append(a)
        return tuple(arrays)

    def _wrap(self, state, outs):
        out = dict(zip(self.program.fields, outs))
        if isinstance(state, ProgramState):
            return ProgramState(out, ensemble=self.batch)
        return out

    def _dyn_values(self, consts: Mapping[str, Any] | None):
        names = self.compiled.dyn_names
        over = dict(consts or {})
        unknown = sorted(set(over) - set(names))
        if unknown:
            raise ValueError(
                f"program {self.program.name!r} binds no batched "
                f"const(s) {unknown}; batched: {list(names)}")
        vals = []
        for k in names:
            v = jnp.asarray(over[k]) if k in over else self._defaults[k]
            if v.ndim < 1 or int(v.shape[0]) != self.batch:
                raise ValueError(
                    f"batched const {k!r}: leading (ensemble) extent is "
                    f"{v.shape[0] if v.ndim else '(scalar)'}, expected "
                    f"the fleet batch {self.batch}")
            vals.append(v)
        return tuple(vals)

    # -- stepping ----------------------------------------------------------

    def stack(self, states: Sequence[Mapping[str, jax.Array]]
              ) -> ProgramState:
        """Stack ``batch`` single-member states into fleet state."""
        states = list(states)
        if len(states) != self.batch:
            raise ValueError(f"need exactly {self.batch} member state(s) "
                             f"to fill the fleet, got {len(states)}")
        return ProgramState.stack(states)

    def step(self, state, *, consts: Mapping[str, Any] | None = None):
        """One fleet step: every member advances one program step."""
        outs = self._jit_step(*self._as_tuple(state),
                              *self._dyn_values(consts))
        return self._wrap(state, outs)

    def run(self, state, nsteps: int, *,
            consts: Mapping[str, Any] | None = None,
            donate: bool = False, health: "HealthPolicy | None" = None):
        """``nsteps`` fleet steps under one jitted ``lax.scan``
        (``donate=True`` ping-pongs the ensemble field buffers).
        Compiled once per ``(nsteps, donate)``; const overrides are
        traced operands, so fresh sweep values never recompile.

        ``health``: optional :class:`~repro.core.health.HealthPolicy` —
        chunk the scan at ``health.every`` member steps and check
        between chunks (the same jitted core iterated, so the
        trajectory stays bit-identical); a violation raises
        :class:`~repro.core.health.HealthError` attributing the
        diverged **member** and the step range."""
        if health is not None:
            from .health import check
            health.select_fields(self.program.fields)
            done, n = 0, int(nsteps)
            while done < n:
                chunk = min(health.every, n - done)
                state = self.run(state, chunk, consts=consts,
                                 donate=donate and done > 0)
                check(health, state, ensemble=self.batch,
                      step_range=(done, done + chunk),
                      where=f"fleet {self.program.name!r}")
                done += chunk
            return state
        if nsteps <= 0:
            return self._wrap(state, tuple(state[f]
                                           for f in self.program.fields))
        key = (int(nsteps), bool(donate))
        fn = self._run_cache.get(key)
        if fn is None:
            vcore, n = self._vcore, int(nsteps)

            def many(arrays, dvals):
                def body(carry, _):
                    return vcore(*carry, *dvals), None
                out, _ = jax.lax.scan(body, arrays, None, length=n)
                return out

            fn = jax.jit(many, donate_argnums=(0,) if donate else ())
            self._run_cache[key] = fn
        outs = fn(self._as_tuple(state), self._dyn_values(consts))
        return self._wrap(state, outs)

    # -- introspection -----------------------------------------------------

    def plan(self):
        """The per-member :class:`ProgramPlan` (multiply HBM by
        ``batch`` for the fleet footprint)."""
        return self.compiled.plan()

    def comm_stats(self, itemsize: int = 4) -> dict:
        """Per-member exchange budget; the vmap sits outside
        ``shard_map``, so per-device bytes scale by ``batch`` while the
        ppermute *count* per fleet step stays the single-member count."""
        return self.compiled.comm_stats(itemsize)

    def __repr__(self):
        return (f"FleetProgram({self.program.name!r}, "
                f"batch={self.batch}, grid={self.grid_shape}, "
                f"sharded={self.compiled.mesh is not None})")


# ---------------------------------------------------------------------------
# layer 2 — the service driver
# ---------------------------------------------------------------------------

#: the ticket state machine: queued → running → {failed, done}, with a
#: retry edge failed-candidate → queued (rollback) while retries remain.
TICKET_STATUSES = ("queued", "running", "failed", "done")


class Ticket:
    """Handle for one submitted trajectory (see
    :meth:`FleetDriver.submit`).

    ``status`` walks queued → running → done, or → failed: a failed
    ticket carries its cause on ``error`` (the exception instance, or
    its string form after a checkpoint restore) and ``traceback``, and
    ``retries`` counts rollback-retries already consumed.
    """

    __slots__ = ("id", "program_name", "nsteps", "step", "grid_shape",
                 "consts", "rng", "bucket_id", "status", "error",
                 "traceback", "retries", "_state", "_slot", "_bucket",
                 "_solo", "_stream_every", "_snapshots", "_not_before",
                 "_retry_ckpt")

    def __init__(self, tid: str, program_name: str, nsteps: int,
                 grid_shape: tuple[int, ...], state: dict, consts: dict,
                 rng, step: int = 0):
        self.id = tid
        self.program_name = program_name
        self.nsteps = int(nsteps)
        self.step = int(step)
        self.grid_shape = grid_shape
        self.consts = dict(consts)
        self.rng = rng
        self.bucket_id = ""          # assigned on placement ("" = solo)
        self.status = "queued"
        self.error: BaseException | str | None = None
        self.traceback: str | None = None
        self.retries = 0
        self._state = state          # latest member state (dict f -> arr)
        self._slot: int | None = None
        self._bucket = None
        self._solo: CompiledProgram | None = None
        self._stream_every: int | None = None
        self._snapshots: collections.deque = collections.deque()
        self._not_before = 0.0       # retry-backoff gate (monotonic s)
        # rollback point for retries: (step, state) — the submit state
        # until the driver's checkpoint cadence refreshes it
        self._retry_ckpt: tuple[int, dict] = (int(step), dict(state))

    @property
    def done(self) -> bool:
        return self.status == "done"

    @property
    def failed(self) -> bool:
        return self.status == "failed"

    @property
    def finished(self) -> bool:
        """Terminal — the driver will never step this ticket again."""
        return self.status in ("done", "failed")

    def __repr__(self):
        return (f"Ticket({self.id!r}, step={self.step}/{self.nsteps}, "
                f"status={self.status!r}"
                f"{', error=' + repr(str(self.error)) if self.failed else ''}"
                f")")


class _Bucket:
    """One (program, grid, const-signature) equivalence class: a shared
    :class:`FleetProgram` plus slot bookkeeping."""

    __slots__ = ("key", "label", "fleet", "slots", "pending", "state",
                 "const_rows", "dyn_names", "replay")

    def __init__(self, key, label: str, fleet: FleetProgram,
                 const_shapes: dict):
        self.key = key
        self.label = label
        self.fleet = fleet
        self.slots: list[Ticket | None] = [None] * fleet.batch
        self.pending: collections.deque = collections.deque()
        self.state: dict | None = None     # f -> (B, ncomp, *grid)
        self.dyn_names = fleet.compiled.dyn_names
        # host-side per-slot const rows, mutated on placement
        self.const_rows = {
            k: np.zeros((fleet.batch,) + shape, dtype)
            for k, (shape, dtype) in const_shapes.items()}
        # lazily-built batch-1 fleet for fault-attribution replays
        self.replay: FleetProgram | None = None

    def free_slot(self) -> int | None:
        for i, t in enumerate(self.slots):
            if t is None:
                return i
        return None

    def active(self):
        return [(i, t) for i, t in enumerate(self.slots)
                if t is not None and not t.finished]


def _override_consts(program: Program, overrides: Mapping[str, Any]
                     ) -> Program:
    """Rebuild ``program`` with const ``name`` rebound to ``value`` in
    every stage that binds it (the driver's sweep-substitution: a
    ``BatchedConst`` placeholder for bucket compiles, a ``TargetConst``
    for solo fallbacks)."""
    if not overrides:
        return program
    bound: set[str] = set()
    stages = []
    for st in program.stages:
        cd = st.consts_dict()
        hit = False
        for k, v in overrides.items():
            if k in cd:
                cd[k] = v
                bound.add(k)
                hit = True
        stages.append(Stage(st.spec, st.reads, st.writes, consts=tuple(
            sorted(cd.items())), name=st.name) if hit else st)
    missing = sorted(set(overrides) - bound)
    if missing:
        raise ValueError(
            f"program {program.name!r}: no stage binds const(s) "
            f"{missing} — submitted params['consts'] must name consts "
            f"the program's stages already bind")
    return Program(program.name, stages, fields=program.fields,
                   intermediates=program.intermediates)


def _program_digest(program: Program) -> str:
    from .autotune import _subject_digest
    return _subject_digest(program)[1]


class FleetDriver:
    """The async simulation service: submit trajectories, poll/stream
    progress, drain results — requests batched into fleet steps.

    Args:
      target: the :class:`Target` every bucket compiles under.
      batch: slots per bucket (the fleet/ensemble extent).
      grid_shapes: optional whitelist of bucketable grid shapes.  When
        given, a submitted grid outside it warns **once** (per driver
        and grid) and runs solo (per-member ``CompiledProgram``) instead
        of minting a fresh fleet jit; when ``None`` (default) every new
        grid opens a bucket.
      steps_per_launch: member steps per fleet launch (request-batching
        granularity; streams and completions stay exact — a launch never
        overshoots a ticket's ``nsteps`` or stream mark).
      checkpoint_dir / checkpoint_every / checkpoint_keep: durability —
        every ``checkpoint_every`` pump rounds the driver snapshots all
        in-flight tickets through :class:`repro.checkpoint.store.
        CheckpointManager` (atomic + checksummed, written off-thread),
        retaining the newest ``checkpoint_keep`` snapshots so restore
        can fall back past a torn directory.
      health: optional :class:`~repro.core.health.HealthPolicy` —
        NaN/Inf/norm guards between pump chunks; a diagnosed member is
        quarantined (its ticket fails, or retries) while healthy
        members keep the exact results of the shared vmapped launch.
      max_retries / retry_backoff: failed tickets retry up to
        ``max_retries`` times, rolling back to their last snapshot
        (the submit state until the checkpoint cadence refreshes it);
        ``retry_backoff`` seconds (doubling per retry) gate each
        attempt.
      mesh / shard_axis / overlap: forwarded to ``Program.compile`` —
        buckets of decomposed fleets (vmap outside ``shard_map``).

    Lifecycle: ``submit`` places tickets; stepping happens inside
    :meth:`pump` — called inline by :meth:`drain`/:meth:`stream`, or
    continuously from the background thread :meth:`start`\\ s.  A fault
    while pumping fails only the offending ticket(s) — see the module
    docstring's resilience layer; background-thread exceptions are
    re-raised from ``drain``/``stream``/``stop`` (and reported by
    ``poll``), never swallowed.
    """

    def __init__(self, target: Target | str | None = None, *,
                 batch: int = 8,
                 grid_shapes: Sequence[Sequence[int]] | None = None,
                 steps_per_launch: int = 1,
                 checkpoint_dir: str | None = None,
                 checkpoint_every: int | None = None,
                 checkpoint_keep: int = 3,
                 health: HealthPolicy | None = None,
                 max_retries: int = 0,
                 retry_backoff: float = 0.0,
                 mesh=None, shard_axis=None, overlap=None):
        self.target = as_target(target)
        self.batch = int(batch)
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        self.grid_shapes = (None if grid_shapes is None else
                            {tuple(int(s) for s in g) for g in grid_shapes})
        self.steps_per_launch = max(1, int(steps_per_launch))
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        if health is not None and not isinstance(health, HealthPolicy):
            raise TypeError(f"health expects a HealthPolicy, got "
                            f"{type(health).__name__}")
        self.health = health
        self.max_retries = int(max_retries)
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        self.retry_backoff = float(retry_backoff)
        if self.retry_backoff < 0:
            raise ValueError(f"retry_backoff must be >= 0, "
                             f"got {retry_backoff}")
        self._mesh, self._shard_axis, self._overlap = mesh, shard_axis, \
            overlap
        self._buckets: dict = {}
        self._solo_cache: dict = {}
        self._solo_active: list[Ticket] = []
        self._tickets: dict[str, Ticket] = {}
        self._programs: dict[str, Program] = {}
        self._counter = 0
        self._pumps = 0
        self._warned_grids: set = set()
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._loop_error: BaseException | None = None
        self._chaos: list[Callable] = []    # fault-injection hooks
        self._ckpt = None
        if checkpoint_dir is not None:
            from repro.checkpoint.store import CheckpointManager
            self._ckpt = CheckpointManager(checkpoint_dir,
                                           keep=int(checkpoint_keep))

    # -- submission --------------------------------------------------------

    def submit(self, program: Program, params: Mapping[str, Any],
               nsteps: int) -> Ticket:
        """Queue one trajectory: ``params = {"state": <field mapping or
        ProgramState (single member)>, "consts": {name: value, ...}
        (optional per-member sweep values), "rng": <PRNGKey> (optional,
        carried through checkpoints)}``.  Returns a :class:`Ticket`."""
        if not isinstance(program, Program):
            raise TypeError(f"submit expects a Program, got "
                            f"{type(program).__name__}")
        if int(nsteps) < 1:
            raise ValueError(f"nsteps must be >= 1, got {nsteps}")
        state = params["state"]
        if isinstance(state, ProgramState) and state.ensemble is not None:
            raise ValueError(
                "submit takes one member per ticket (no ensemble axis); "
                "submit each member separately — the driver does the "
                "batching")
        if self.health is not None and self.health.fields is not None:
            unknown = sorted(set(self.health.fields)
                             - set(program.fields))
            if unknown:
                raise ValueError(
                    f"driver HealthPolicy guards field(s) {unknown} that "
                    f"program {program.name!r} does not step; fields: "
                    f"{list(program.fields)}")
        member = {f: jnp.asarray(state[f]) for f in program.fields}
        first = member[program.fields[0]]
        grid = tuple(int(s) for s in first.shape[1:])
        consts = {k: np.asarray(v)
                  for k, v in dict(params.get("consts") or {}).items()}
        with self._lock:
            self._counter += 1
            t = Ticket(f"t{self._counter:04d}", program.name, nsteps,
                       grid, member, consts, params.get("rng"))
            self._tickets[t.id] = t
            self._programs.setdefault(program.name, program)
            self._place(t, program)
            self._cond.notify_all()
        return t

    def _place(self, t: Ticket, program: Program):
        if self.grid_shapes is not None and t.grid_shape not in \
                self.grid_shapes:
            if t.grid_shape not in self._warned_grids:
                self._warned_grids.add(t.grid_shape)
                warnings.warn(
                    f"fleet driver: grid {t.grid_shape} fits no "
                    f"configured bucket {sorted(self.grid_shapes)}; "
                    f"falling back to per-member execution for this "
                    f"grid (one CompiledProgram, stepped solo)",
                    stacklevel=3)
            t._solo = self._solo_program(program, t)
            t.status = "running"
            self._solo_active.append(t)
            return
        bucket = self._bucket_for(t, program)
        t._bucket = bucket
        t.bucket_id = bucket.label
        slot = bucket.free_slot()
        if slot is None:
            bucket.pending.append(t)
        else:
            self._occupy(bucket, slot, t)

    def _const_sig(self, consts: Mapping[str, np.ndarray]):
        return tuple((k, tuple(int(s) for s in consts[k].shape),
                      str(consts[k].dtype)) for k in sorted(consts))

    def _bucket_for(self, t: Ticket, program: Program) -> _Bucket:
        sig = self._const_sig(t.consts)
        static = tuple(
            (st.name, tuple((k, v) for k, v in st.consts
                            if k not in t.consts))
            for st in program.stages)
        key = (program.name, _program_digest(program), t.grid_shape,
               sig, static)
        bucket = self._buckets.get(key)
        if bucket is not None:
            return bucket
        sweeps = {
            k: BatchedConst(np.zeros((self.batch,) + shape,
                                     np.dtype(dtype)))
            for k, shape, dtype in sig}
        fleet = _override_consts(program, sweeps).compile(
            self.target, grid_shape=t.grid_shape, mesh=self._mesh,
            shard_axis=self._shard_axis,
            overlap=self._overlap).vmap(self.batch)
        label = (f"{program.name}@{'x'.join(map(str, t.grid_shape))}"
                 f"#{len(self._buckets)}")
        bucket = _Bucket(key, label,
                         fleet, {k: (shape, np.dtype(dtype))
                                 for k, shape, dtype in sig})
        self._buckets[key] = bucket
        return bucket

    def _solo_program(self, program: Program, t: Ticket
                      ) -> CompiledProgram:
        overrides = {k: TargetConst(v) for k, v in t.consts.items()}
        key = (program.name, _program_digest(program), t.grid_shape,
               tuple((k, TargetConst(v)) for k, v in
                     sorted(t.consts.items())))
        cp = self._solo_cache.get(key)
        if cp is None:
            cp = _override_consts(program, overrides).compile(
                self.target, grid_shape=t.grid_shape, mesh=self._mesh,
                shard_axis=self._shard_axis, overlap=self._overlap)
            self._solo_cache[key] = cp
        return cp

    def _occupy(self, bucket: _Bucket, slot: int, t: Ticket):
        t._slot = slot
        t.status = "running"
        bucket.slots[slot] = t
        if bucket.state is None:
            # first member defines the bucket arrays; idle slots carry a
            # copy of it (valid fields — no NaN poisoning, results of
            # idle slots are never read back)
            bucket.state = {
                f: jnp.stack([t._state[f]] * bucket.fleet.batch)
                for f in bucket.fleet.program.fields}
        else:
            bucket.state = {
                f: bucket.state[f].at[slot].set(t._state[f])
                for f in bucket.fleet.program.fields}
        for k in bucket.dyn_names:
            if k in t.consts:
                bucket.const_rows[k][slot] = t.consts[k]

    # -- the step loop -----------------------------------------------------

    def _chunk_for(self, tickets) -> int:
        chunk = self.steps_per_launch
        for t in tickets:
            chunk = min(chunk, t.nsteps - t.step)
            if t._stream_every:
                to_mark = -t.step % t._stream_every
                if to_mark:
                    chunk = min(chunk, to_mark)
            if self.health is not None:
                # land chunk boundaries on the guard cadence so every
                # check happens at a multiple of health.every
                to_check = -t.step % self.health.every
                chunk = min(chunk, to_check or self.health.every)
        return max(1, chunk)

    def _advance_ticket(self, t: Ticket, chunk: int, state: dict):
        t.step += chunk
        t._state = state
        hit_mark = t._stream_every and t.step % t._stream_every == 0
        if t.step >= t.nsteps:
            t.status = "done"
        if t._stream_every and (hit_mark or t.done):
            t._snapshots.append((t.step, dict(state)))

    def _ready(self, t: Ticket) -> bool:
        return t._not_before <= time.monotonic()

    def _health_due(self, t: Ticket, chunk: int) -> bool:
        # on the guard cadence, and always on the ticket's final chunk
        # (a trailing partial chunk must not finish unchecked)
        return self.health is not None and (
            (t.step + chunk) % self.health.every == 0
            or t.step + chunk >= t.nsteps)

    def _retire(self, bucket: _Bucket, slot: int, t: Ticket):
        """Free a bucket slot (its ticket finished or was quarantined)
        and pull the next pending ticket in."""
        bucket.slots[slot] = None
        t._slot = None
        if bucket.pending:
            self._occupy(bucket, slot, bucket.pending.popleft())

    def _fail_ticket(self, t: Ticket, err: BaseException):
        """Quarantine or retry one ticket.  With retries remaining, the
        ticket rolls back to its last snapshot (step + state), re-queues
        (backoff-gated) and keeps the error for observability; otherwise
        it goes terminal ``failed`` with the captured traceback."""
        t.error = err
        t.traceback = "".join(traceback_mod.format_exception(
            type(err), err, err.__traceback__))
        if t.retries < self.max_retries:
            t.retries += 1
            step0, state0 = t._retry_ckpt
            t.step = int(step0)
            t._state = {f: jnp.asarray(a) for f, a in state0.items()}
            t.status = "queued"
            if self.retry_backoff > 0:
                t._not_before = time.monotonic() + \
                    self.retry_backoff * (2 ** (t.retries - 1))
            if t._solo is None:
                self._place(t, self._programs[t.program_name])
            # solo tickets stay in _solo_active and re-pump in place
        else:
            t.status = "failed"

    def _replay_fleet(self, bucket: _Bucket) -> FleetProgram:
        """The bucket's batch-1 attribution fleet: same program, same
        traced-const story (a fresh ``BatchedConst`` placeholder per
        sweep), so replays are *bit-identical* to the bucket's vmapped
        path — a static-const solo compile would drift ~1 ulp through
        XLA constant folding and break the healthy-members-exact
        contract."""
        if bucket.replay is None:
            program = self._programs[bucket.fleet.program.name]
            sweeps = {
                k: BatchedConst(np.zeros((1,) + row.shape[1:], row.dtype))
                for k, row in bucket.const_rows.items()}
            bucket.replay = _override_consts(program, sweeps).compile(
                self.target, grid_shape=bucket.fleet.grid_shape,
                mesh=self._mesh, shard_axis=self._shard_axis,
                overlap=self._overlap).vmap(1)
        return bucket.replay

    def _attribute_bucket_fault(self, bucket: _Bucket, active, chunk: int,
                                err: BaseException):
        """A fault while stepping the whole bucket: attribute blame by
        replaying each active ticket through the batch-1 fleet.  Tickets
        whose replay raises are failed/retried with *their* exception;
        tickets whose replay succeeds advance exactly as a fault-free
        pump would have (one-shot faults therefore recover every
        ticket)."""
        fields = bucket.fleet.program.fields
        try:
            replay = self._replay_fleet(bucket)
        except Exception:
            # cannot even build the replay fleet (e.g. a persistent
            # compile-time fault): blame every active ticket with the
            # original bucket error
            for slot, t in active:
                self._retire(bucket, slot, t)
                self._fail_ticket(t, err)
            return
        for slot, t in active:
            st1 = {f: t._state[f][None] for f in fields}
            c1 = {k: jnp.asarray(bucket.const_rows[k][slot:slot + 1])
                  for k in bucket.dyn_names}
            try:
                out = replay.run(st1, chunk, consts=c1)
            except Exception as e2:
                self._retire(bucket, slot, t)
                self._fail_ticket(t, e2)
                continue
            member = {f: out[f][0] for f in fields}
            bucket.state = {f: bucket.state[f].at[slot].set(member[f])
                            for f in fields}
            if self._health_due(t, chunk):
                diag = diagnose(self.health, member)
                if diag:
                    e3 = HealthError.of(
                        diag[0], member=slot,
                        step_range=(t.step, t.step + chunk), ticket=t.id)
                    self._retire(bucket, slot, t)
                    self._fail_ticket(t, e3)
                    continue
            self._advance_ticket(t, chunk, member)
            if t.done:
                self._retire(bucket, slot, t)

    def _pump_bucket(self, bucket: _Bucket) -> bool:
        active = [(i, t) for i, t in bucket.active() if self._ready(t)]
        if not active:
            return False
        chunk = self._chunk_for([t for _, t in active])
        consts = {k: jnp.asarray(v)
                  for k, v in bucket.const_rows.items()}
        for _, t in active:
            t.status = "running"
        try:
            new_state = bucket.fleet.run(bucket.state, chunk,
                                         consts=consts)
        except Exception as err:
            self._attribute_bucket_fault(bucket, active, chunk, err)
            return True
        bucket.state = new_state
        sick: dict[int, Any] = {}
        if self.health is not None:
            due = {i for i, t in active if self._health_due(t, chunk)}
            if due:
                diag = diagnose(self.health, bucket.state,
                                ensemble=bucket.fleet.batch)
                sick = {i: d for i, d in diag.items() if i in due}
        for slot, t in active:
            if slot in sick:
                err = HealthError.of(
                    sick[slot], member=slot,
                    step_range=(t.step, t.step + chunk), ticket=t.id)
                self._retire(bucket, slot, t)
                self._fail_ticket(t, err)
                continue
            self._advance_ticket(
                t, chunk,
                {f: bucket.state[f][slot]
                 for f in bucket.fleet.program.fields})
            if t.done:
                self._retire(bucket, slot, t)
        return True

    def _pump_solo(self, t: Ticket) -> bool:
        if t.finished or not self._ready(t):
            return False
        chunk = self._chunk_for([t])
        t.status = "running"
        try:
            state = t._solo.run(dict(t._state), chunk)
        except Exception as err:
            self._fail_ticket(t, err)
            return True
        if self._health_due(t, chunk):
            diag = diagnose(self.health, state)
            if diag:
                err = HealthError.of(
                    diag[0], step_range=(t.step, t.step + chunk),
                    ticket=t.id)
                self._fail_ticket(t, err)
                return True
        self._advance_ticket(t, chunk, dict(state))
        return True

    def _run_chaos(self):
        """Run installed fault-injection hooks (see :meth:`inject`);
        hooks returning True retire."""
        if not self._chaos:
            return
        self._chaos = [fn for fn in self._chaos if not fn(self)]

    def inject(self, hook: Callable[["FleetDriver"], bool]) -> None:
        """Install a chaos hook: ``hook(driver) -> retired?`` runs under
        the driver lock at the top of every pump round.  The
        deterministic fault-injection surface — see
        :mod:`repro.core.faults` for ready-made hooks (NaN poisoning,
        pump-thread crashes).  Test/drill harness only: hooks may mutate
        driver internals and may raise."""
        with self._lock:
            self._chaos.append(hook)

    def pump(self, rounds: int = 1) -> bool:
        """Advance every bucket (and solo ticket) by up to ``rounds``
        launch chunks.  Returns whether any ticket progressed — the
        inline spelling of the background loop, and the unit the
        checkpoint cadence counts.  A fault while stepping fails (or
        retries) only the offending ticket(s); pump itself only raises
        on driver-level errors (which the background loop records and
        re-raises from ``drain``/``stream``/``stop``)."""
        progressed_any = False
        with self._lock:
            for _ in range(max(1, int(rounds))):
                self._run_chaos()
                progressed = False
                for bucket in self._buckets.values():
                    progressed |= self._pump_bucket(bucket)
                for t in list(self._solo_active):
                    progressed |= self._pump_solo(t)
                    if t.finished:
                        self._solo_active.remove(t)
                if progressed:
                    self._pumps += 1
                    if (self._ckpt is not None and self.checkpoint_every
                            and self._pumps % self.checkpoint_every == 0):
                        self._checkpoint_locked()
                progressed_any |= progressed
                self._cond.notify_all()
                if not progressed:
                    break
        return progressed_any

    def _unfinished(self):
        return [t for t in self._tickets.values() if not t.finished]

    def _backoff_wait(self) -> float | None:
        """Seconds until the earliest backoff-gated ticket is ready, or
        ``None`` when nothing is waiting on backoff."""
        now = time.monotonic()
        waits = [t._not_before - now for t in self._tickets.values()
                 if not t.finished and t._not_before > now]
        return max(0.0, min(waits)) if waits else None

    # -- service surface ---------------------------------------------------

    def _raise_loop_error(self):
        """Re-raise (once) an exception the background pump thread died
        with — the first ``drain``/``stream``/``stop`` caller gets it."""
        if self._loop_error is not None:
            err, self._loop_error = self._loop_error, None
            raise err

    def poll(self, ticket: Ticket) -> dict:
        """Non-blocking progress: ``{"id", "step", "nsteps", "done",
        "status", "retries", "error", "traceback", "state"}`` (``state``
        = the member's latest stepped fields — a diagnosed ticket keeps
        its state from before the chunk that failed it, so with
        ``health.every=1`` a failed ticket's state is always its last
        healthy one; ``error``/``traceback`` the captured cause of a
        failed or retried ticket).  When the background pump thread
        itself died, ``driver_error`` carries its exception (poll never
        raises)."""
        with self._lock:
            out = {"id": ticket.id, "step": ticket.step,
                   "nsteps": ticket.nsteps, "done": ticket.done,
                   "status": ticket.status, "retries": ticket.retries,
                   "error": ticket.error, "traceback": ticket.traceback,
                   "state": dict(ticket._state)}
            if self._loop_error is not None:
                out["driver_error"] = self._loop_error
            return out

    def stream(self, ticket: Ticket, every: int = 1):
        """Iterate ``(step, state)`` snapshots every ``every`` member
        steps (plus the final step).  Call before the ticket advances
        past its first mark.  Without a background thread the generator
        pumps the driver inline; with one it blocks on progress.
        Raises the ticket's captured error when it fails terminally,
        and re-raises a background-thread crash."""
        if int(every) < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        with self._lock:
            ticket._stream_every = int(every)
        while True:
            with self._lock:
                self._raise_loop_error()
                if ticket._snapshots:
                    yield ticket._snapshots.popleft()
                    continue
                if ticket.done:
                    return
                if ticket.failed:
                    raise ticket.error if isinstance(
                        ticket.error, BaseException) else RuntimeError(
                        f"ticket {ticket.id} failed: {ticket.error}")
                if self._thread is not None:
                    self._cond.wait(timeout=1.0)
                    continue
            if not self.pump():
                with self._lock:
                    wait = self._backoff_wait()
                if wait is not None:
                    time.sleep(min(wait, 0.5) + 1e-3)
                    continue
                raise RuntimeError(
                    f"fleet driver made no progress streaming "
                    f"{ticket.id} (step {ticket.step}/{ticket.nsteps})")

    def drain(self) -> dict[str, dict]:
        """Run until every submitted ticket reaches a terminal state
        (``done`` or ``failed``); returns ``{ticket_id: final_state}``
        — a failed ticket's entry is its state from before the chunk
        that failed it (its cause is on ``poll(t)["error"]``).  Pumps
        inline unless the
        background loop is running (then it waits on it, re-raising
        any exception that thread died with)."""
        while True:
            with self._lock:
                self._raise_loop_error()
                if not self._unfinished():
                    break
                if self._thread is not None:
                    self._cond.wait(timeout=1.0)
                    continue
            if not self.pump():
                with self._lock:
                    wait = self._backoff_wait()
                if wait is not None:
                    # everything left is gated on retry backoff
                    time.sleep(min(wait, 0.5) + 1e-3)
                    continue
                stuck = [t.id for t in self._unfinished()]
                raise RuntimeError(
                    f"fleet driver made no progress with unfinished "
                    f"ticket(s) {stuck}")
        if self._ckpt is not None:
            self._ckpt.wait()
        return {t.id: dict(t._state) for t in self._tickets.values()}

    # -- background loop ---------------------------------------------------

    def start(self):
        """Run the step loop on a daemon thread until :meth:`stop`.
        An exception escaping :meth:`pump` is recorded on the driver,
        every waiter is woken, and the error re-raises from
        ``drain``/``stream``/``stop`` (``poll`` reports it) — it is
        never swallowed with the thread."""
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                try:
                    progressed = self.pump()
                except BaseException as err:
                    with self._lock:
                        self._loop_error = err
                        self._cond.notify_all()
                    return
                if not progressed:
                    with self._lock:
                        self._cond.wait(timeout=0.05)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="fleet-driver")
        self._thread.start()

    def stop(self):
        """Stop the background loop (tickets keep their progress).
        Re-raises an exception the loop died with, after cleanup."""
        if self._thread is None:
            return
        self._stop.set()
        with self._lock:
            self._cond.notify_all()
        self._thread.join()
        self._thread = None
        if self._ckpt is not None:
            self._ckpt.wait()
        self._raise_loop_error()

    # -- durability --------------------------------------------------------

    def _snapshot_tree(self):
        tickets, meta = {}, {}
        for t in self._tickets.values():
            entry = {"state": dict(t._state), "step": int(t.step),
                     "bucket": t.bucket_id}
            if t.rng is not None:
                entry["rng"] = t.rng
            tickets[t.id] = entry
            meta[t.id] = {
                "program": t.program_name, "nsteps": int(t.nsteps),
                "step": int(t.step),
                "grid_shape": list(t.grid_shape),
                "fields": list(t._state),
                "has_rng": t.rng is not None,
                "status": t.status,
                "retries": int(t.retries),
                "error": None if t.error is None else str(t.error),
                "consts": {k: {"value": np.asarray(v).tolist(),
                               "dtype": str(np.asarray(v).dtype)}
                           for k, v in t.consts.items()},
            }
        return {"tickets": tickets}, {"tickets": meta,
                                      "batch": self.batch}

    def _checkpoint_locked(self, blocking: bool = False):
        tree, extra = self._snapshot_tree()
        self._ckpt.save(self._pumps, tree, extra=extra,
                        blocking=blocking)
        # everything just snapshotted is durable — retries of a future
        # fault roll back here, not to the submit-time state
        for t in self._tickets.values():
            if not t.finished:
                t._retry_ckpt = (int(t.step), dict(t._state))

    def checkpoint(self, blocking: bool = True):
        """Snapshot every ticket now (atomic, checksummed)."""
        if self._ckpt is None:
            raise ValueError("driver has no checkpoint_dir configured")
        with self._lock:
            self._checkpoint_locked(blocking=blocking)

    @classmethod
    def restore(cls, checkpoint_dir: str,
                programs: Mapping[str, Program] | Program,
                **driver_kw) -> "FleetDriver":
        """Rebuild a driver from the latest checkpoint under
        ``checkpoint_dir``: every in-flight ticket resumes at its saved
        step (ids, step counters, RNG keys and const sweeps restored;
        completed tickets come back completed, failed ones failed).
        ``programs`` maps program name → :class:`Program` (or a single
        Program when only one was served) — graphs are code, not data,
        so the caller re-supplies them.  Deterministic stepping makes
        resumed trajectories bit-identical to uninterrupted ones.

        Every candidate snapshot is sha256-verified against its
        manifest; a torn or corrupted newest directory is *skipped*
        (with a warning) in favour of the newest valid one under the
        keep-last-K retention — only when no snapshot verifies does
        restore raise ``IOError``."""
        import warnings

        from repro.checkpoint.store import (_load_manifest, _step_dir,
                                            checkpoint_steps,
                                            restore_checkpoint,
                                            verify_checkpoint)
        steps = checkpoint_steps(checkpoint_dir)
        if not steps:
            raise FileNotFoundError(
                f"no fleet checkpoints under {checkpoint_dir}")
        step, skipped = None, []
        for cand in reversed(steps):
            if verify_checkpoint(_step_dir(checkpoint_dir, cand)):
                step = cand
                break
            skipped.append(cand)
        if step is None:
            raise IOError(
                f"no valid fleet checkpoint under {checkpoint_dir}: all "
                f"of step(s) {skipped} failed integrity verification")
        if skipped:
            warnings.warn(
                f"fleet restore: checkpoint step(s) {skipped} under "
                f"{checkpoint_dir} failed integrity verification; "
                f"falling back to step {step}", RuntimeWarning,
                stacklevel=2)
        extra = _load_manifest(_step_dir(checkpoint_dir,
                                         step)).get("extra", {})
        meta = extra.get("tickets", {})
        if isinstance(programs, Program):
            programs = {programs.name: programs}
        missing = sorted({m["program"] for m in meta.values()}
                         - set(programs))
        if missing:
            raise ValueError(
                f"checkpoint references program(s) {missing} not in the "
                f"supplied mapping {sorted(programs)}")
        driver_kw.setdefault("batch", int(extra.get("batch", 8)))
        driver_kw.setdefault("checkpoint_dir", checkpoint_dir)
        drv = cls(**driver_kw)

        tree_like = {"tickets": {}}
        for tid, m in meta.items():
            entry = {"state": {f: 0.0 for f in m["fields"]},
                     "step": 0, "bucket": ""}
            if m.get("has_rng"):
                entry["rng"] = 0
            tree_like["tickets"][tid] = entry
        tree, _, _ = restore_checkpoint(checkpoint_dir, tree_like,
                                        step=step, verify=False)

        with drv._lock:
            for tid in sorted(meta, key=lambda s: int(s[1:])):
                m, saved = meta[tid], tree["tickets"][tid]
                program = programs[m["program"]]
                consts = {k: np.asarray(c["value"],
                                        np.dtype(c["dtype"]))
                          for k, c in m["consts"].items()}
                t = Ticket(tid, m["program"], m["nsteps"],
                           tuple(m["grid_shape"]),
                           {f: jnp.asarray(saved["state"][f])
                            for f in m["fields"]},
                           consts, saved.get("rng"),
                           step=int(saved["step"]))
                t.retries = int(m.get("retries", 0))
                drv._tickets[tid] = t
                drv._programs.setdefault(program.name, program)
                drv._counter = max(drv._counter, int(tid[1:]))
                if m.get("status") == "failed":
                    # terminal at snapshot time — comes back failed (the
                    # live exception object is gone; keep the message)
                    t.status = "failed"
                    t.error = RuntimeError(m.get("error") or
                                           f"ticket {tid} failed before "
                                           f"the checkpoint")
                    t.bucket_id = str(saved["bucket"])
                elif t.step >= t.nsteps:
                    t.status = "done"
                    t.bucket_id = str(saved["bucket"])
                else:
                    drv._place(t, program)
        return drv

    def __repr__(self):
        with self._lock:
            n_done = sum(t.done for t in self._tickets.values())
            return (f"FleetDriver(batch={self.batch}, "
                    f"buckets={len(self._buckets)}, "
                    f"tickets={len(self._tickets)} ({n_done} done), "
                    f"running={self._thread is not None})")
