"""SoA ↔ AoSoA(vvl) layout transforms — the paper's VVL site-ordering.

targetDP's ``VVL`` macro does more than strip-mine the ILP loop: in the
AoSoA build it *reorders memory* so that each group of VVL sites stores
its components contiguously — ``[site-block][component][site-in-block]``
— which is what lets one source kernel vectorise on AVX lanes and CUDA
threads alike (arXiv:1405.6162 §III; arXiv:1609.01479 extends the same
axis to Xeon Phi; Alpaka, arXiv:1602.08477, makes the identical
layout-as-abstraction argument).  This module is that reordering as a
pair of exact inverse transforms applied at *field boundaries* — callers
and kernels only ever see SoA ``(ncomp, nsites)`` arrays / ``(ncomp,
VVL)`` chunks; the executor-internal operand layout is what changes.

Remainder-site contract: when ``vvl`` does not divide ``nsites`` the
trailing partial block is **zero-padded** (``soa_to_aosoa``) and the pad
lanes are sliced away on the way back (``aosoa_to_soa``) — round-trip
exact for every extent, including ``nsites < vvl``.  Kernels may write
garbage (even NaN) into pad lanes, exactly the :func:`repro.core.api.
pad_sites` contract the chunked executors already rely on.

Layout axis values (``Target.layout``):

==========  ============================================================
``"soa"``   structure-of-arrays, sites contiguous per component (default)
``"aosoa"`` array-of-structures-of-arrays: vvl-site blocks outermost,
            components per block, sites-in-block innermost
==========  ============================================================
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LAYOUTS = ("soa", "aosoa")


def aosoa_nblocks(nsites: int, vvl: int) -> int:
    """Number of AoSoA site blocks covering ``nsites`` (last one padded)."""
    if vvl <= 0:
        raise ValueError(f"vvl must be positive, got {vvl}")
    return -(-int(nsites) // int(vvl))


def soa_to_aosoa(x: jax.Array, vvl: int) -> jax.Array:
    """``(..., ncomp, nsites)`` SoA → ``(nblocks, ..., ncomp, vvl)`` AoSoA.

    The trailing site axis is zero-padded to a ``vvl`` multiple and split
    into blocks; blocks move to the *front* so the per-block tile
    ``(..., ncomp, vvl)`` is contiguous — components interleave per
    block, sites stay innermost (lane axis).  Leading axes (e.g. the
    ``noffsets`` axis of a gathered stencil stack) ride along inside
    each block.
    """
    n = int(x.shape[-1])
    nblk = aosoa_nblocks(n, vvl)
    n_pad = nblk * vvl
    if n_pad != n:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, n_pad - n)]
        x = jnp.pad(x, widths)
    y = x.reshape(*x.shape[:-1], nblk, vvl)          # (..., ncomp, nblk, vvl)
    return jnp.moveaxis(y, -2, 0)                    # (nblk, ..., ncomp, vvl)


def aosoa_to_soa(y: jax.Array, nsites: int) -> jax.Array:
    """Exact inverse of :func:`soa_to_aosoa`: ``(nblocks, ..., ncomp,
    vvl)`` → ``(..., ncomp, nsites)``, pad lanes sliced away."""
    x = jnp.moveaxis(y, 0, -2)                       # (..., ncomp, nblk, vvl)
    x = x.reshape(*x.shape[:-2], x.shape[-2] * x.shape[-1])
    return x[..., :int(nsites)]


def plane_to_aosoa(x: jax.Array, vvl: int) -> jax.Array:
    """Per-plane AoSoA for the windowed executor: ``(ncomp, nplanes,
    *rest)`` → ``(nplanes, nblk, ncomp, vvl)`` with ``nblk =
    prod(rest) / vvl``.

    Each x-plane's rest-sites are regrouped into vvl blocks so a window
    BlockSpec can DMA ``(plane_block + 2r, nblk, ncomp, vvl)`` tiles.
    Unlike :func:`soa_to_aosoa` this transform has **no remainder
    path**: ``vvl`` must divide the plane's site count exactly (a
    partial block would straddle two x-planes and break the window
    aliasing) — :func:`repro.core.api.launch` validates this at
    plan-build time.
    """
    ncomp, npl = int(x.shape[0]), int(x.shape[1])
    rest_n = 1
    for s in x.shape[2:]:
        rest_n *= int(s)
    if rest_n % int(vvl):
        raise ValueError(
            f"plane site count {rest_n} is not divisible by vvl {vvl}; "
            f"the windowed AoSoA path has no remainder blocks")
    nblk = rest_n // int(vvl)
    y = x.reshape(ncomp, npl, nblk, vvl)
    return jnp.transpose(y, (1, 2, 0, 3))            # (npl, nblk, ncomp, vvl)


def plane_from_aosoa(y: jax.Array, rest_shape: tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`plane_to_aosoa`: ``(nplanes, nblk, ncomp, vvl)``
    → ``(ncomp, nplanes, *rest_shape)``."""
    npl, nblk, ncomp, vvl = (int(s) for s in y.shape)
    x = jnp.transpose(y, (2, 0, 1, 3)).reshape(ncomp, npl, nblk * vvl)
    return x.reshape(ncomp, npl, *rest_shape)
