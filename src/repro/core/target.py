"""Target descriptors — *where and how* a kernel launch executes.

The paper selects its implementation (C vs CUDA) with a build switch; the
successor paper (1609.01479) and Alpaka (1602.08477) make the target an
exchangeable *descriptor* instead.  :class:`Target` is that descriptor: a
small frozen value object naming the executor, carrying the tunable VVL
(ILP extent), the interpret flag (Pallas semantics on CPU), optional
mesh/sharding hints, and an executor-specific ``tuning`` mapping for
per-op knobs (block sizes etc.) that used to be threaded by hand.

Being frozen and hashable, a Target participates directly in the launch
plan cache key — two launches under different targets can never alias one
compiled closure (the ``set_default_vvl`` staleness class of bug).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping

# Default VVL: one full TPU vector register row of lanes.  The paper tunes
# VVL per architecture (8 on AVX, 2 on K40); benchmarks/run.py sweeps it.
_DEFAULT_VVL = 128


def default_vvl() -> int:
    return _DEFAULT_VVL


def set_default_vvl(vvl: int) -> None:
    """Change the process-wide default VVL.

    Targets with ``vvl=None`` resolve this value *at launch time*, and the
    resolved VVL is part of the plan cache key — so flipping the default
    between two launches always rebuilds the closure (regression-pinned by
    ``tests/test_tdp_api.py``).
    """
    global _DEFAULT_VVL
    if vvl <= 0:
        raise ValueError("vvl must be positive")
    _DEFAULT_VVL = int(vvl)


def _freeze_tuning(tuning) -> tuple[tuple[str, Any], ...]:
    if isinstance(tuning, Mapping):
        items = sorted(tuning.items())
    else:
        items = sorted(tuple(kv) for kv in tuning)
    for k, v in items:
        if not isinstance(k, str):
            raise TypeError(f"tuning keys must be strings, got {k!r}")
        hash(v)  # tuning participates in the plan cache key
    return tuple((k, v) for k, v in items)


@dataclass(frozen=True)
class Target:
    """Execution target descriptor (replaces the stringly ``backend=`` +
    ``vvl=`` kwarg plumbing).

    Args:
      backend: executor name in the registry (``"xla"``, ``"pallas"``,
        ``"pallas_windowed"``, or any
        :func:`repro.core.register_executor`-registered name).  The
        spellings ``"pallas_interpret"`` / ``"pallas_windowed_interpret"``
        canonicalise to the base backend + ``interpret=True``.
      vvl: virtual vector length (ILP extent).  ``None`` → resolve the
        process default at launch time.  (The windowed executor chunks by
        x-planes, not VVL — see ``plane_block`` below.)
      interpret: run Pallas semantics on CPU (validation mode).
      layout: executor-internal memory layout — ``"soa"`` (default;
        sites contiguous per component) or ``"aosoa"`` (vvl-site blocks
        outermost, the paper's AoSoA ``VVL`` ordering; ``vvl`` is the
        inner block width).  Callers always pass and receive SoA
        ``(ncomp, nsites)`` arrays; the transforms live at field
        boundaries (:mod:`repro.core.layout`), so kernels stay
        single-source and results are bit-identical across layouts.
      mesh / shard_axis: optional sharding hints for mesh-aware callers
        (e.g. :class:`repro.lb.sim.BinaryFluidSim`); the core launch does
        not act on them, it only carries them.  ``shard_axis`` is one
        mesh-axis name (slab decomposition) or a tuple of names
        (pencil/block: axis *k* shards grid dim *k*).
      tuning: executor/op-specific knobs, stored as a sorted tuple of
        pairs so the Target stays hashable.  Established keys:
        ``block_f`` / ``block_q`` / ... (pointwise Pallas block sizes,
        see :mod:`repro.kernels.ops`) and ``plane_block`` (the
        ``pallas_windowed`` executor's TLP chunk: how many output
        x-planes each grid step computes; its VMEM window depth is
        ``plane_block + 2·radius`` planes).
    """

    backend: str = "xla"
    vvl: int | None = None
    interpret: bool = False
    layout: str = "soa"
    mesh: Any = None
    shard_axis: str | tuple[str, ...] | None = None
    tuning: tuple[tuple[str, Any], ...] = field(default=())

    def __post_init__(self):
        if not isinstance(self.backend, str) or not self.backend:
            raise ValueError(f"backend must be a non-empty string, got "
                             f"{self.backend!r}")
        if self.backend in ("pallas_interpret", "pallas_windowed_interpret"):
            object.__setattr__(self, "backend",
                               self.backend[:-len("_interpret")])
            object.__setattr__(self, "interpret", True)
        if self.vvl is not None:
            if int(self.vvl) <= 0:
                raise ValueError(f"vvl must be positive, got {self.vvl}")
            object.__setattr__(self, "vvl", int(self.vvl))
        if self.layout not in ("soa", "aosoa"):
            raise ValueError(
                f"layout must be 'soa' or 'aosoa', got {self.layout!r} "
                f"(the AoSoA inner width is the separate vvl field)")
        # multi-axis decompositions name one mesh axis per sharded grid
        # dim; freeze to a tuple so the Target stays hashable
        if isinstance(self.shard_axis, (list, tuple)):
            object.__setattr__(self, "shard_axis",
                               tuple(str(a) for a in self.shard_axis))
        object.__setattr__(self, "tuning", _freeze_tuning(self.tuning))

    @property
    def executor(self) -> str:
        """Registry name this target dispatches to."""
        if self.backend == "pallas" and self.interpret:
            return "pallas_interpret"
        return self.backend

    def resolve_vvl(self) -> int:
        """The VVL this target launches with *right now* (explicit value,
        else the current process default)."""
        return self.vvl if self.vvl is not None else _DEFAULT_VVL

    def tuning_dict(self) -> dict[str, Any]:
        return dict(self.tuning)

    def tune(self, key: str, default: Any = None) -> Any:
        for k, v in self.tuning:
            if k == key:
                return v
        return default

    def with_(self, **updates) -> "Target":
        """Functional update (``dataclasses.replace`` with dict-friendly
        ``tuning``)."""
        if "tuning" in updates:
            updates["tuning"] = _freeze_tuning(updates["tuning"])
        return dataclasses.replace(self, **updates)

    # ``with_`` under its conventional name, for callers that expect the
    # dataclasses spelling (tdp.autotune's report records use it).
    replace = with_

    def with_tuning(self, updates: "Mapping[str, Any] | None" = None,
                    **kw) -> "Target":
        """Merge tuning knobs into the existing ``tuning`` mapping.

        Unlike ``with_(tuning=...)`` — which *replaces* the whole mapping
        — this keeps unrelated knobs: ``t.with_tuning(plane_block=2)`` on
        a target already carrying ``block_f`` preserves ``block_f``.  The
        result re-freezes (sorted, hashable), so equal merged tunings
        always compare and hash equal regardless of update order — the
        plan-cache-key contract ``tdp.autotune`` candidates rely on.
        """
        merged = self.tuning_dict()
        if updates:
            merged.update(updates)
        merged.update(kw)
        return self.with_(tuning=merged)


def as_target(target: "Target | str | None" = None, *,
              vvl: int | None = None,
              layout: str | None = None) -> Target:
    """Coerce the accepted spellings to a :class:`Target`.

    This is the *single* place a backend string becomes a Target — ops and
    launches accept strings only through here.

    ``None`` → default xla target; a string → ``Target(backend=string)``;
    a Target passes through.  ``vvl`` / ``layout`` (if given) override
    the target's.
    """
    if target is None:
        target = Target()
    elif isinstance(target, str):
        target = Target(backend=target)
    elif not isinstance(target, Target):
        raise TypeError(
            f"expected a Target, backend-name string, or None; got "
            f"{type(target).__name__}: {target!r}")
    if vvl is not None:
        target = target.with_(vvl=vvl)
    if layout is not None:
        target = target.with_(layout=layout)
    return target
