"""JAX version compatibility shims.

The framework targets the modern JAX surface (``jax.shard_map`` with
``axis_names``/``check_vma``, ``jax.make_mesh`` with ``axis_types``,
``jax.lax.ragged_dot_general``); older runtimes (0.4.x, as baked into this
container) expose the same functionality under different names:

* ``jax.shard_map``            → ``jax.experimental.shard_map.shard_map``
  (``check_vma`` was ``check_rep``; ``axis_names={a}`` — manual over the
  named axes only — was the complement set ``auto=all_axes - {a}``).
* ``jax.make_mesh(axis_types=...)`` → same call without ``axis_types``
  (all axes were implicitly Auto under GSPMD).

Every in-repo call site goes through this module, so the rest of the code
reads as if it were written against one JAX.  Keep the shims *thin*: each
wrapper maps arguments, it never reimplements semantics.
"""
from __future__ import annotations

from typing import Any

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = True):
    """``jax.shard_map`` on new JAX; the experimental equivalent on 0.4.x.

    ``axis_names``: the mesh axes the body is *manual* over (``None`` →
    all of them).  ``check_vma`` maps onto old-JAX ``check_rep`` and
    keeps the modern default (True) — call sites opt out explicitly.
    """
    if _HAS_NEW_SHARD_MAP:
        kw: dict[str, Any] = dict(mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_vma=check_vma)
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kw)

    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)


def supports_partial_manual() -> bool:
    """Whether shard_map may be manual over a *subset* of mesh axes.

    Old XLA builds (paired with 0.4.x jax) hit a partitioner CHECK
    (``sharding.IsManualSubgroup()``) when auto-sharded ops appear inside a
    partially-manual region; callers fall back to fully-manual bodies.
    """
    return _HAS_NEW_SHARD_MAP


def axis_size(axis_name) -> int:
    """Static size of a named mesh axis inside a manual region
    (``jax.lax.axis_size`` on new JAX; the axis-env frame on 0.4.x)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    import jax.core as _core
    return int(_core.axis_frame(axis_name))


def make_mesh(axis_shapes, axis_names, *, explicit: bool = False):
    """``jax.make_mesh`` with all-Auto (or all-Explicit) axis types where the
    runtime supports typed mesh axes; the untyped GSPMD mesh otherwise."""
    if _HAS_AXIS_TYPE:
        t = (jax.sharding.AxisType.Explicit if explicit
             else jax.sharding.AxisType.Auto)
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(t,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
