"""Executor registry — the pluggable backend table behind ``tdp.launch``.

An *executor* realises the paper's ``TARGET_TLP``/``TARGET_ILP`` loops for
one architecture.  The core launch path (validation, padding, const
unwrapping, the neighbour prologue, plan caching) is executor-independent;
an executor only maps a prepared plan over prepared site arrays:

    def my_executor(plan, prepared):
        # plan:     repro.core.api.LaunchPlan (kernel, vvl, out_ncomp,
        #           consts, with_site_index, interpret, target, shape,
        #           halo, stencils, wants, memory estimates)
        # prepared: one array per input field.  What a stencil field looks
        #           like depends on the executor's declared capability:
        #             wants="gathered"       (default) — the shared gather
        #               prologue ran: (noffsets, ncomp, nsites) neighbour
        #               stack per stencil field, (ncomp, nsites) pointwise.
        #             wants="halo_extended"  — no gather: each stencil
        #               field arrives ONCE as a halo-extended grid
        #               (ncomp, *ext_shape) with exactly
        #               stencil.radius_per_dim() ghost layers per
        #               dimension (periodic dims wrap-padded, sharded
        #               dims trimmed from the caller's ghost planes);
        #               the executor resolves offsets itself, in-kernel.
        # returns:  tuple of (ncomp_o, nsites) outputs, one per
        #           plan.out_ncomp entry (a bare array is accepted for
        #           single-output kernels)
        ...

    register_executor("my_backend", my_executor)                 # gathered
    register_executor("my_windowed", my_win, wants="halo_extended")
    tdp.launch(spec, Target("my_backend"), *arrays)

Registering a new architecture is *one* ``register_executor`` call — the
windowed-block stencil executor (``"pallas_windowed"``) lands this way,
not as a fork of launch logic.  Registration bumps an internal version
that is part of the plan cache key, so re-registering a name (even with a
different capability) can never serve a stale compiled closure.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

#: Executor input capabilities: what the launch prologue prepares for each
#: stencil-carrying field before dispatch.
EXECUTOR_WANTS = ("gathered", "halo_extended")


class ExecutorEntry(NamedTuple):
    """One registry row: the executor callable plus its declared input
    capability (see ``EXECUTOR_WANTS``) and the ``Target.tuning`` keys it
    consults (``tunables`` — the sweep/autotune surface)."""

    fn: Callable
    wants: str
    tunables: tuple[str, ...] = ()


_EXECUTORS: dict[str, ExecutorEntry] = {}
_VERSION = 0


def register_executor(name: str, fn: Callable, *, overwrite: bool = False,
                      wants: str = "gathered",
                      tunables: tuple[str, ...] = ()) -> None:
    """Register ``fn`` as the executor behind ``Target(backend=name)``.

    ``wants`` declares the input capability: ``"gathered"`` (default)
    receives pre-gathered ``(noffsets, ncomp, nsites)`` neighbour stacks;
    ``"halo_extended"`` suppresses the gather and receives each stencil
    field once, as a halo-extended ``(ncomp, *ext_shape)`` grid.

    ``tunables`` declares the ``Target.tuning`` keys the executor actually
    consults (e.g. ``("plane_block",)`` for the windowed executor) — the
    contract ``benchmarks/run.py --sweep`` and ``tdp.autotune`` build
    candidate spaces from; sweeping a key outside this set is rejected up
    front instead of silently measuring a no-op.

    Raises ``ValueError`` on duplicate names unless ``overwrite=True``.
    """
    global _VERSION
    if not isinstance(name, str) or not name:
        raise ValueError(f"executor name must be a non-empty string, "
                         f"got {name!r}")
    if not callable(fn):
        raise TypeError(f"executor must be callable, got {fn!r}")
    if wants not in EXECUTOR_WANTS:
        raise ValueError(f"executor capability must be one of "
                         f"{EXECUTOR_WANTS}, got {wants!r}")
    tunables = tuple(str(t) for t in tunables)
    if name in _EXECUTORS and not overwrite:
        raise ValueError(
            f"executor {name!r} is already registered; pass overwrite=True "
            f"to replace it")
    _EXECUTORS[name] = ExecutorEntry(fn, wants, tunables)
    _VERSION += 1


def unregister_executor(name: str) -> None:
    global _VERSION
    if name not in _EXECUTORS:
        raise ValueError(f"executor {name!r} is not registered "
                         f"(have: {sorted(_EXECUTORS)})")
    del _EXECUTORS[name]
    _VERSION += 1


def get_executor(name: str) -> Callable:
    return get_executor_entry(name).fn


def get_executor_entry(name: str) -> ExecutorEntry:
    """The full registry row — callable plus declared capability."""
    try:
        return _EXECUTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown executor {name!r}; registered executors: "
            f"{sorted(_EXECUTORS)}") from None


def executor_wants(name: str) -> str:
    """The declared input capability of a registered executor."""
    return get_executor_entry(name).wants


def executor_tunables(name: str) -> tuple[str, ...]:
    """The ``Target.tuning`` keys a registered executor consults."""
    return get_executor_entry(name).tunables


def compatible_executors(*, stencil: bool) -> tuple[str, ...]:
    """Registered executor names able to run a launch of the given shape.

    A stencil-carrying spec can run on every capability (the prologue
    adapts: gather vs halo-extend); a pure pointwise spec has nothing to
    window, so ``wants="halo_extended"`` executors are excluded — the
    same rule :func:`repro.core.api.launch` enforces at dispatch.  This
    is the executor axis of ``tdp.autotune``'s candidate space.
    """
    return tuple(sorted(
        name for name, entry in _EXECUTORS.items()
        if stencil or entry.wants != "halo_extended"))


def list_executors() -> tuple[str, ...]:
    return tuple(sorted(_EXECUTORS))


def registry_version() -> int:
    """Monotonic counter bumped on every (un)registration — part of the
    launch-plan cache key."""
    return _VERSION
