"""Executor registry — the pluggable backend table behind ``tdp.launch``.

An *executor* realises the paper's ``TARGET_TLP``/``TARGET_ILP`` loops for
one architecture.  The core launch path (validation, padding, const
unwrapping, neighbour gathering, plan caching) is executor-independent;
an executor only maps a prepared plan over pre-gathered site arrays:

    def my_executor(plan, gathered):
        # plan:     repro.core.api.LaunchPlan (kernel, vvl, out_ncomp,
        #           consts, with_site_index, interpret, target)
        # gathered: one array per input field —
        #           (ncomp, nsites_padded?) for pointwise fields,
        #           (noffsets, ncomp, nsites) for stencil fields
        # returns:  tuple of (ncomp_o, nsites) outputs, one per
        #           plan.out_ncomp entry (a bare array is accepted for
        #           single-output kernels)
        ...

    register_executor("my_backend", my_executor)
    tdp.launch(spec, Target("my_backend"), *arrays)

Registering a new architecture is *one* ``register_executor`` call — the
ROADMAP's windowed-block stencil executor lands this way, not as a third
fork of launch logic.  Registration bumps an internal version that is part
of the plan cache key, so re-registering a name can never serve a stale
compiled closure.
"""
from __future__ import annotations

from typing import Callable

_EXECUTORS: dict[str, Callable] = {}
_VERSION = 0


def register_executor(name: str, fn: Callable, *,
                      overwrite: bool = False) -> None:
    """Register ``fn`` as the executor behind ``Target(backend=name)``.

    Raises ``ValueError`` on duplicate names unless ``overwrite=True``.
    """
    global _VERSION
    if not isinstance(name, str) or not name:
        raise ValueError(f"executor name must be a non-empty string, "
                         f"got {name!r}")
    if not callable(fn):
        raise TypeError(f"executor must be callable, got {fn!r}")
    if name in _EXECUTORS and not overwrite:
        raise ValueError(
            f"executor {name!r} is already registered; pass overwrite=True "
            f"to replace it")
    _EXECUTORS[name] = fn
    _VERSION += 1


def unregister_executor(name: str) -> None:
    global _VERSION
    if name not in _EXECUTORS:
        raise ValueError(f"executor {name!r} is not registered "
                         f"(have: {sorted(_EXECUTORS)})")
    del _EXECUTORS[name]
    _VERSION += 1


def get_executor(name: str) -> Callable:
    try:
        return _EXECUTORS[name]
    except KeyError:
        raise ValueError(
            f"unknown executor {name!r}; registered executors: "
            f"{sorted(_EXECUTORS)}") from None


def list_executors() -> tuple[str, ...]:
    return tuple(sorted(_EXECUTORS))


def registry_version() -> int:
    """Monotonic counter bumped on every (un)registration — part of the
    launch-plan cache key."""
    return _VERSION
