"""``tdp.Program`` — declarative multi-launch step graphs.

The paper's targetDP layer abstracts *single* kernel launches; a real
lattice application step (the Ludwig binary fluid, our
:class:`repro.lb.sim.BinaryFluidSim`) is a short *pipeline* of launches
plus host-side glue: halo exchange, executor fallbacks, intermediate
buffers, ``lax.scan`` stepping.  The successor paper ("A Lightweight
Approach to Performance Portability with targetDP", 1609.01479) names
that glue as the remaining portability gap; task-graph layers (HPX,
2206.06302) close it with dependency graphs.  A :class:`Program` is that
graph, declaratively:

* a **Stage** binds one :class:`~repro.core.spec.KernelSpec` to named
  values — ``reads`` (one name per declared field, in order) and
  ``writes`` (one name per declared output) — plus its ``TARGET_CONST``
  bindings;
* a **Program** is an ordered tuple of stages over two kinds of names:
  **fields** (persistent, double-buffered step state — what
  ``step``/``run`` carry from one step to the next) and
  **intermediates** (step-local values, written before read, never
  materialised across steps).

Compiling a Program (:meth:`Program.compile`) lowers it through the
existing launch machinery (:func:`repro.core.api.launch` — plan cache,
executor registry, capability-aware prologue) into a single jitted step
function, adding exactly the glue applications used to hand-write:

a. **per-stage target routing** — each stage dispatches to the requested
   target, except pointwise stages under a stencil-only
   (``wants="halo_extended"``) executor, which route to ``"xla"``
   (generalising the ad-hoc fallback formerly buried in
   ``BinaryFluidSim``);
b. **one halo-exchange schedule per step** — ghost requirements are
   back-propagated through the stage graph (:meth:`Program.schedule`),
   so under ``shard_map`` every field is exchanged **once** per step, at
   the width the whole step needs; stages that read step-local
   intermediates through stencils *recompute* them on a ghost ring
   instead of triggering extra communication;
c. **buffer donation + ping-pong aliasing** —
   :meth:`CompiledProgram.run` executes ``nsteps`` under one
   ``lax.scan``; with ``donate=True`` the field buffers are donated so
   XLA aliases input and output state (no per-step reallocation);
d. **aggregated memory models** — :meth:`Program.plan` /
   :meth:`CompiledProgram.plan` build one
   :class:`~repro.core.api.LaunchPlan` per stage and aggregate the PR 3
   ``vmem_bytes_estimate`` / ``hbm_bytes_estimate`` models across the
   step;
e. **pencil/block decomposition with comm/compute overlap** — the mesh
   may shard up to ``ndim`` grid dimensions (mesh axis *k* ↔ grid dim
   *k*; one axis = slab, two = pencil, three = block).  Ghost exchanges
   run as **ordered per-dimension sweeps** (dim 0 first): the dim-1
   exchange transfers the already-dim-0-extended planes, so corner and
   edge ghosts arrive via the orthogonal neighbour without any explicit
   diagonal ``ppermute``.  With ``overlap=True``, each step's launches
   are split into an **interior** region that reads only local data —
   XLA's latency-hiding scheduler runs it while the ``ppermute``\\ s are
   in flight — plus two **boundary** slabs per sharded dim launched on
   the exchanged arrays (:func:`_overlap_regions`); the split is
   data-exact but region-shaped codegen may reassociate at ≤1 ULP, so
   it is opt-in.  :meth:`CompiledProgram.comm_stats` reports the
   analytic exchanged-bytes/ppermute budget per step.

:meth:`Program.execute` is the uncompiled single-step entry for callers
that manage their own ghost planes (``repro.kernels.ops.lb_fused_step``);
it runs the same stage pipeline eagerly, each launch hitting the shared
plan cache.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from . import compat
from .api import launch as _launch
from .api import launch_plan as _launch_plan
from .api import _normalize_halo
from .lattice import Lattice
from .memory import BatchedConst
from .registry import executor_wants
from .spec import KernelSpec
from .state import ProgramState, validate_field
from .target import Target, as_target


# ---------------------------------------------------------------------------
# Stage — one KernelSpec bound to named values
# ---------------------------------------------------------------------------

def _as_names(x, what: str) -> tuple[str, ...]:
    if isinstance(x, str):
        x = (x,)
    names = tuple(str(n) for n in x)
    if not names:
        raise ValueError(f"a stage needs at least one {what} name")
    return names


def _freeze_consts(consts) -> tuple[tuple[str, Any], ...]:
    if not consts:
        return ()
    items = (sorted(consts.items()) if isinstance(consts, Mapping)
             else sorted(tuple(kv) for kv in consts))
    for k, _ in items:
        if not isinstance(k, str):
            raise TypeError(f"const names must be strings, got {k!r}")
    return tuple((k, v) for k, v in items)


@dataclass(frozen=True)
class Stage:
    """One launch of the step graph: a :class:`KernelSpec` bound to named
    program values.

    Args:
      spec: the kernel.  Its output counts must be declared (``out=``) —
        a Program wires outputs to names, so their arity/ncomp cannot be
        launch-inferred.
      reads: one name per declared field, in declaration order.
      writes: one name per declared output.  Writing a *field* name
        defines that field's next-step value; writing an *intermediate*
        name binds a step-local value for later stages.
      consts: ``TARGET_CONST`` bindings for this stage (mapping or item
        tuple; ``TargetConst`` values participate in the plan cache by
        content hash).
      name: display name (defaults to the spec's).
    """

    spec: KernelSpec
    reads: tuple[str, ...]
    writes: tuple[str, ...]
    consts: tuple[tuple[str, Any], ...] = dc_field(default=())
    name: str = ""

    def __post_init__(self):
        if not isinstance(self.spec, KernelSpec):
            raise TypeError(f"stage spec must be a KernelSpec, got "
                            f"{type(self.spec).__name__}")
        object.__setattr__(self, "reads", _as_names(self.reads, "read"))
        object.__setattr__(self, "writes", _as_names(self.writes, "write"))
        object.__setattr__(self, "consts", _freeze_consts(self.consts))
        if not self.name:
            object.__setattr__(self, "name", self.spec.name)
        if len(self.reads) != len(self.spec.fields):
            raise ValueError(
                f"stage {self.name!r} binds {len(self.reads)} read(s) but "
                f"kernel {self.spec.name!r} declares "
                f"{len(self.spec.fields)} field(s)")
        if self.spec.out is None:
            raise ValueError(
                f"stage {self.name!r}: kernel {self.spec.name!r} must "
                f"declare out= to participate in a Program (outputs are "
                f"wired to names)")
        if len(self.writes) != len(self.spec.out):
            raise ValueError(
                f"stage {self.name!r} binds {len(self.writes)} write(s) "
                f"but kernel {self.spec.name!r} declares "
                f"{len(self.spec.out)} output(s)")

    def consts_dict(self) -> dict:
        return dict(self.consts)


def stage(spec: KernelSpec, reads, writes, *, consts=None,
          name: str | None = None) -> Stage:
    """Ergonomic :class:`Stage` constructor (accepts bare-string names and
    dict consts)."""
    return Stage(spec, reads, writes, consts=_freeze_consts(consts),
                 name=name or "")


# ---------------------------------------------------------------------------
# Program — the ordered stage graph
# ---------------------------------------------------------------------------

def _grid_trim(arr: jax.Array, shape: tuple[int, ...],
               ext: tuple[int, ...], want: tuple[int, ...]) -> jax.Array:
    """Trim a ghost-extended grid ``(ncomp, *(shape + 2·ext))`` down to
    ``want`` ghost layers per dimension (``want <= ext`` everywhere)."""
    if ext == want:
        return arr
    for d, (e, w) in enumerate(zip(ext, want)):
        if e < w:
            raise ValueError(
                f"cannot widen ghost extent in dim {d}: have {e}, "
                f"need {w}")
        if e > w:
            arr = jax.lax.slice_in_dim(arr, e - w, e + w + shape[d],
                                       axis=d + 1)
    return arr


def resolve_stage_target(target: Target | str | None,
                         spec: KernelSpec,
                         stage_name: str | None = None) -> Target:
    """Per-stage target routing (the PR 3 capability surface, applied per
    stage): stencil stages keep the requested target; pointwise stages
    under a stencil-only (``wants="halo_extended"``) executor route to
    the ``"xla"`` executor at the same VVL.

    Per-stage tuning: ``Target.tuning`` keys of the reserved form
    ``"stage:<name>"`` hold a nested ``((knob, value), ...)`` assignment
    for that stage only (``tdp.autotune(..., per_stage=True)`` emits
    them).  All ``stage:*`` keys are stripped from the flat tuning, then
    the entry matching ``stage_name`` is merged over it — so a stage
    never sees another stage's knobs, and a per-stage value overrides
    the program-wide one."""
    tgt = as_target(target)
    if any(k.startswith("stage:") for k, _ in tgt.tuning):
        flat = {k: v for k, v in tgt.tuning
                if not k.startswith("stage:")}
        if stage_name is not None:
            mine = dict(tgt.tuning).get(f"stage:{stage_name}")
            if mine:
                flat.update(dict(mine))
        tgt = tgt.with_(tuning=flat)
    if spec.has_stencil:
        return tgt
    try:
        wants = executor_wants(tgt.executor)
    except ValueError:
        wants = "gathered"      # custom executor registered later
    if wants == "halo_extended":
        return tgt.with_(backend="xla", interpret=False)
    return tgt


class Program:
    """An ordered graph of :class:`Stage`\\ s over named fields and
    intermediates — one application *step* as a declarative object.

    Args:
      name: display name.
      stages: the launches, in execution order.
      fields: persistent state names (ordered — this is the order
        ``step``/``run`` tuples use).  A field's pre-step value is read
        until a stage writes it; the last write is the next-step value;
        unwritten fields pass through unchanged.
      intermediates: step-local names.  ``None`` infers them (every
        written name that is not a field); passing them explicitly
        validates the set exactly.
    """

    def __init__(self, name: str, stages: Sequence[Stage], *,
                 fields: Sequence[str],
                 intermediates: Sequence[str] | None = None):
        self.name = str(name)
        self.stages = tuple(stages)
        if not self.stages:
            raise ValueError(f"program {name!r} needs at least one stage")
        for st in self.stages:
            if not isinstance(st, Stage):
                raise TypeError(f"program {name!r}: stages must be Stage "
                                f"objects, got {type(st).__name__}")
        self.fields = _as_names(fields, "field")
        if len(set(self.fields)) != len(self.fields):
            raise ValueError(f"duplicate field names: {self.fields}")

        written = [w for st in self.stages for w in st.writes]
        inferred = tuple(dict.fromkeys(w for w in written
                                       if w not in self.fields))
        if intermediates is None:
            self.intermediates = inferred
        else:
            self.intermediates = tuple(str(n) for n in intermediates)
            if set(self.intermediates) != set(inferred):
                raise ValueError(
                    f"program {name!r}: declared intermediates "
                    f"{sorted(self.intermediates)} != written non-field "
                    f"names {sorted(inferred)}")
        overlap = set(self.fields) & set(self.intermediates)
        if overlap:
            raise ValueError(f"names {sorted(overlap)} are both fields "
                             f"and intermediates")

        # dataflow validation: reads resolve to fields or already-written
        # intermediates; every intermediate is consumed.
        known = set(self.fields) | set(self.intermediates)
        bound = set(self.fields)
        read_ever: set[str] = set()
        for st in self.stages:
            for r in st.reads:
                if r not in known:
                    raise ValueError(
                        f"stage {st.name!r} reads unknown name {r!r} "
                        f"(fields: {sorted(self.fields)}, intermediates: "
                        f"{sorted(self.intermediates)})")
                if r not in bound:
                    raise ValueError(
                        f"stage {st.name!r} reads intermediate {r!r} "
                        f"before any stage writes it")
                read_ever.add(r)
            bound.update(st.writes)
        dead = sorted(set(self.intermediates) - read_ever)
        if dead:
            raise ValueError(
                f"program {name!r}: intermediate(s) {dead} are written "
                f"but never read — drop them or make them fields")

        # per-name component counts (consistency across all bindings)
        self.ncomp: dict[str, int | None] = {n: None for n in known}

        def _record(n, c, where):
            if c is None:
                return
            c = int(c)
            if self.ncomp[n] is None:
                self.ncomp[n] = c
            elif self.ncomp[n] != c:
                raise ValueError(
                    f"name {n!r} has inconsistent ncomp: {self.ncomp[n]} "
                    f"vs {c} at {where}")

        for st in self.stages:
            for r, fs in zip(st.reads, st.spec.fields):
                _record(r, fs.ncomp, f"stage {st.name!r} read")
            for w, oc in zip(st.writes, st.spec.out):
                _record(w, oc, f"stage {st.name!r} write")

    def batched_consts(self) -> dict:
        """The program's per-member ensemble sweeps: ordered mapping of
        const name → :class:`~repro.core.memory.BatchedConst` over every
        stage binding one.  A name bound by several stages must bind the
        *same* sweep (content equality) — the fleet threads one value
        per name through the whole step."""
        out: dict[str, BatchedConst] = {}
        for st in self.stages:
            for k, v in st.consts:
                if not isinstance(v, BatchedConst):
                    continue
                prev = out.get(k)
                if prev is not None and prev != v:
                    raise ValueError(
                        f"program {self.name!r}: const {k!r} is bound to "
                        f"two different BatchedConst sweeps (stage "
                        f"{st.name!r} disagrees with an earlier stage); "
                        f"every stage must share one sweep per name")
                out[k] = v
        return out

    def __repr__(self):
        return (f"Program({self.name!r}, stages="
                f"{[st.name for st in self.stages]}, "
                f"fields={list(self.fields)}, "
                f"intermediates={list(self.intermediates)})")

    # -- the halo schedule -------------------------------------------------

    def schedule(self, ndim: int, open_dims: Sequence[bool]):
        """Back-propagate per-dimension ghost requirements through the
        stage graph — **the one halo-exchange schedule per step**.

        ``open_dims[d]`` marks dimensions whose ghosts are caller-managed
        (sharded slabs / pre-filled ghost planes); closed dimensions wrap
        periodically inside each launch and need nothing.

        Returns ``(field_widths, stage_geo)``:

        * ``field_widths[name]`` — ghost layers each *field* must carry at
          the start of the step (the exchange width: the max requirement
          over every stage that consumes its pre-step value);
        * ``stage_geo[i] = (ext_out, halo)`` — stage *i* computes its
          outputs on the interior extended by ``ext_out`` ghost layers
          (recompute-in-ghost for step-local intermediates read through
          stencils downstream) and launches with ``halo`` ghost width
          (the max stencil radius over its stencil-carrying reads, in
          open dimensions).
        """
        open_mask = tuple(bool(b) for b in open_dims)
        if len(open_mask) != ndim:
            raise ValueError(f"open_dims {open_mask} does not match "
                             f"ndim {ndim}")
        zeros = (0,) * ndim
        need: dict[str, tuple[int, ...]] = {f: zeros for f in self.fields}
        geo: list[tuple[tuple[int, ...], tuple[int, ...]]] = []
        for st in reversed(self.stages):
            outs = [need.pop(w, zeros) for w in st.writes]
            e_out = tuple(max(o[d] for o in outs) if open_mask[d] else 0
                          for d in range(ndim))
            radii = [s.radius_per_dim() for s in st.spec.stencils
                     if s is not None]
            h = tuple(max(r[d] for r in radii)
                      if radii and open_mask[d] else 0
                      for d in range(ndim))
            geo.append((e_out, h))
            for rname, s in zip(st.reads, st.spec.stencils):
                req = (e_out if s is None
                       else tuple(e + hh for e, hh in zip(e_out, h)))
                prev = need.get(rname, zeros)
                need[rname] = tuple(max(p, q) for p, q in zip(prev, req))
        geo.reverse()
        widths = {f: need.get(f, zeros) for f in self.fields}
        return widths, geo

    # -- stage execution core (shared by execute / compile) ----------------

    def _run_stages(self, stage_targets, shape: tuple[int, ...],
                    geo, env: dict, dyn: Mapping[str, Any] | None = None
                    ) -> dict:
        """Run all stages over ``env`` (name → ``(grid_array, ext)``),
        mutating and returning it.  ``geo`` is :meth:`schedule`'s
        per-stage ``(ext_out, halo)`` list.  ``dyn`` maps batched const
        names to this call's (possibly traced) per-member values —
        stages binding a :class:`BatchedConst` launch with the dynamic
        value instead of the baked sweep."""
        for st, tgt, (e_out, h) in zip(self.stages, stage_targets, geo):
            lat_shape = tuple(s + 2 * e for s, e in zip(shape, e_out))
            lat = Lattice(lat_shape)
            arrays = []
            for rname, s in zip(st.reads, st.spec.stencils):
                arr, ext = env[rname]
                want = (e_out if s is None
                        else tuple(e + hh for e, hh in zip(e_out, h)))
                arr = _grid_trim(arr, shape, ext, want)
                arrays.append(arr.reshape(arr.shape[0], -1))
            consts = st.consts_dict()
            if dyn:
                for k, v in consts.items():
                    if isinstance(v, BatchedConst) and k in dyn:
                        consts[k] = dyn[k]
            outs = _launch(st.spec, tgt, *arrays, lattice=lat,
                           halo=h if any(h) else None,
                           consts=consts)
            outs = (outs,) if not isinstance(outs, tuple) else outs
            for w, o in zip(st.writes, outs):
                env[w] = (o.reshape(o.shape[0], *lat_shape), e_out)
        return env

    # -- eager execution with caller-managed ghosts ------------------------

    def execute(self, target: Target | str | None,
                state: Mapping[str, jax.Array], *,
                grid_shape: Sequence[int],
                halo: int | Sequence[int] | None = 0) -> dict:
        """Run one step eagerly over grid arrays, ghosts managed by the
        caller.

        ``state[name]`` is ``(ncomp, *(grid_shape + 2·halo))`` for every
        field; dimensions with ``halo[d] > 0`` carry caller-filled ghost
        planes (the sharded contract), dimensions with ``halo[d] == 0``
        wrap periodically.  Returns the next-step field grids over the
        interior.  Each launch dispatches through the shared plan cache,
        so repeated calls never re-trace.
        """
        shape = tuple(int(s) for s in grid_shape)
        ndim = len(shape)
        h0 = _normalize_halo(halo, ndim)
        open_mask = tuple(hh > 0 for hh in h0)
        widths, geo = self.schedule(ndim, open_mask)
        stage_targets = tuple(resolve_stage_target(target, st.spec, st.name)
                              for st in self.stages)
        env = {}
        for f in self.fields:
            if f not in state:
                raise ValueError(f"program {self.name!r}: state is "
                                 f"missing field {f!r}")
            short = [d for d in range(ndim) if h0[d] < widths[f][d]]
            if short:
                raise ValueError(
                    f"program {self.name!r}: field {f!r} needs "
                    f"{widths[f]} ghost layer(s) but the caller supplied "
                    f"halo={h0} (short in dim(s) {short})")
            env[f] = (state[f], h0)
        env = self._run_stages(stage_targets, shape, geo, env)
        zeros = (0,) * ndim
        return {f: _grid_trim(env[f][0], shape, env[f][1], zeros)
                for f in self.fields}

    # -- lowering ----------------------------------------------------------

    def compile(self, target: Target | str | None = None, *,
                grid_shape: Sequence[int], mesh=None,
                shard_axis: str | Sequence[str] | None = None,
                overlap: bool | None = None) -> "CompiledProgram":
        """Lower to one jitted step function (see
        :class:`CompiledProgram`).  ``mesh``/``shard_axis`` default to the
        target's hints; with a mesh, the step runs under ``shard_map``
        with mesh axis *k* sharding grid dim *k* (one name = slab, two =
        pencil, three = block) and one ghost-exchange round per field per
        sharded dim per step.  ``overlap=True`` opts into the
        comm/compute overlap schedule (interior launched while the
        exchanges are in flight); it is numerically equivalent but not
        bit-reproducible against the default unsplit schedule — XLA
        codegen for the region shapes reassociates at the ≤1-ULP level —
        so the default (``None``/``False``) keeps the bit-identical
        trajectory."""
        return CompiledProgram(self, target, grid_shape, mesh=mesh,
                               shard_axis=shard_axis, overlap=overlap)

    def autotune(self, target: Target | str | None,
                 example_state: Mapping[str, jax.Array], **kw):
        """Tune ``Target.tuning`` (and the executor) for this program —
        convenience front-end for :func:`repro.core.autotune.autotune`
        (which see for the keyword surface: ``space``, ``budget``,
        ``measure_steps``, ``timer``, ``cache_dir``, ...).  Returns a
        ``TuneResult`` ``(tuned_target, report)``."""
        from .autotune import autotune as _autotune
        return _autotune(self, target, example_state, **kw)

    def plan(self, target: Target | str | None = None, *,
             grid_shape: Sequence[int]) -> "ProgramPlan":
        """Aggregate the per-launch memory models across the step without
        compiling (single-device periodic geometry; for the sharded
        local geometry use :meth:`CompiledProgram.plan`)."""
        shape = tuple(int(s) for s in grid_shape)
        ndim = len(shape)
        _, geo = self.schedule(ndim, (False,) * ndim)
        stage_targets = tuple(resolve_stage_target(target, st.spec, st.name)
                              for st in self.stages)
        return _build_program_plan(self, stage_targets, shape, geo, {})


# ---------------------------------------------------------------------------
# the compiled step
# ---------------------------------------------------------------------------

def _shard_axes(shard_axis) -> tuple[str, ...]:
    """Normalise a ``shard_axis`` argument (name or sequence of names) to
    the ordered tuple of mesh axis names; axis *k* shards grid dim *k*."""
    if shard_axis is None:
        return ()
    if isinstance(shard_axis, str):
        return (shard_axis,)
    return tuple(str(a) for a in shard_axis)


def _exchange_hops(width: int, local_extent: int) -> list[tuple[int, int]]:
    """Hop plan for a ``width``-plane ghost exchange across shards of
    ``local_extent`` planes: ``[(hop, take), ...]`` — hop *j* transfers
    the ``take`` boundary planes of the rank ``±j`` neighbour.  One hop
    when the neighbour covers the width; one extra hop per additional
    shard when ``width > local_extent`` (maximal decompositions: a
    1-plane pencil feeding a radius-2 schedule reads from ranks ±2)."""
    hops = -(-width // local_extent)         # ceil: shards per side
    return [(j, min(local_extent, width - (j - 1) * local_extent))
            for j in range(1, hops + 1)]


def exchange_ghosts(arr: jax.Array, dim: int, width: int, nranks: int,
                    permute) -> jax.Array:
    """Extend a local shard ``(ncomp, *local)`` by ``width`` exchanged
    ghost planes on each side of grid dimension ``dim``.

    The transfer set is exactly the boundary planes (the paper's
    masked-copy idea), concatenated in global-coordinate order: the hop-j
    left ghosts sit left of the hop-(j-1) ones, mirroring on the right.
    ``permute(x, pairs)`` is the rank-permutation primitive —
    ``jax.lax.ppermute`` under ``shard_map`` (:func:`_exchange_dim`);
    tests inject a stacked-shard fake to cross-check the hop plan against
    a single-device roll reference.
    """
    ax = dim + 1                             # grid dim d is array axis d+1
    xl = arr.shape[ax]
    left, right = [], []
    for j, t in _exchange_hops(width, xl):
        fwd = [(i, (i + j) % nranks) for i in range(nranks)]  # recv from -j
        bwd = [(i, (i - j) % nranks) for i in range(nranks)]  # recv from +j
        last = jax.lax.slice_in_dim(arr, xl - t, xl, axis=ax)
        first = jax.lax.slice_in_dim(arr, 0, t, axis=ax)
        left.insert(0, permute(last, fwd))
        right.append(permute(first, bwd))
    return jnp.concatenate(left + [arr] + right, axis=ax)


def _exchange_dim(arr: jax.Array, axis_name: str, width: int,
                  dim: int) -> jax.Array:
    """:func:`exchange_ghosts` under ``shard_map``: mesh axis
    ``axis_name`` shards grid dim ``dim``."""
    n = compat.axis_size(axis_name)
    return exchange_ghosts(
        arr, dim, width, n,
        lambda x, pairs: jax.lax.ppermute(x, axis_name, pairs))


def exchange_stats(widths: Mapping[str, Sequence[int]],
                   ncomp: Mapping[str, int | None],
                   local: Sequence[int], shard_dims: Sequence[int],
                   itemsize: int = 4) -> dict:
    """Analytic per-device cost of one step's exchange round.

    Mirrors the compiled sweep exactly: fields exchange dim by dim in
    ``shard_dims`` order, and a later dim's planes span the earlier
    dims' already-extended extents (that is how corner/edge ghosts
    travel), so its per-plane byte count grows accordingly.  Returns
    ``per_field`` rows plus the step totals ``exchanged_bytes_per_step``
    and ``ppermutes_per_step`` (the latter is checkable against
    ``collective-permute`` ops in the lowered HLO).
    """
    per_field = {}
    total_bytes = total_pp = 0
    for f, w in widths.items():
        c = int(ncomp.get(f) or 1)
        ext = list(int(s) for s in local)
        fbytes = fpp = 0
        sched = {}
        for d in shard_dims:
            wd = int(w[d])
            if not wd:
                continue
            plane = 1
            for dd, e in enumerate(ext):
                if dd != d:
                    plane *= e
            fbytes += 2 * wd * plane * c * itemsize
            fpp += 2 * len(_exchange_hops(wd, int(local[d])))
            sched[d] = wd
            ext[d] += 2 * wd
        per_field[f] = {"widths": sched, "bytes": fbytes,
                        "ppermutes": fpp}
        total_bytes += fbytes
        total_pp += fpp
    return {"per_field": per_field,
            "exchanged_bytes_per_step": total_bytes,
            "ppermutes_per_step": total_pp}


def _overlap_regions(local: Sequence[int], W: Sequence[int],
                     shard_dims: Sequence[int]):
    """The comm/compute-overlap partition of the local domain.

    ``W[d]`` is the step's max exchange width in dim ``d``.  Returns
    ``(interior, boundaries)`` where every region is ``(start, shape)``
    in local interior coordinates:

    * ``interior`` — the block at distance ≥ ``W[d]`` from every
      exchanged face: computable from local data alone, so it launches
      while the ``ppermute``\\ s are in flight;
    * ``boundaries`` — ``[(dim, lo_region, hi_region), ...]``, two
      ``W[d]``-thick slabs per exchanged dim, launched on the exchanged
      arrays.  The dim-*d* slabs span the *interior* extent in exchanged
      dims < *d* and the full local extent in dims > *d*, so the regions
      tile the local domain exactly once (corners belong to the lowest
      exchanged dim's slabs).
    """
    ndim = len(local)
    active = [d for d in shard_dims if W[d] > 0]
    i_start = tuple(W[d] if d in active else 0 for d in range(ndim))
    i_shape = tuple(local[d] - 2 * W[d] if d in active else local[d]
                    for d in range(ndim))
    bounds = []
    for d in active:
        start = tuple(W[dd] if (dd in active and dd < d) else 0
                      for dd in range(ndim))
        shape = tuple(W[d] if dd == d
                      else (local[dd] - 2 * W[dd]
                            if (dd in active and dd < d) else local[dd])
                      for dd in range(ndim))
        hi_start = tuple(local[d] - W[d] if dd == d else start[dd]
                         for dd in range(ndim))
        bounds.append((d, (start, shape), (hi_start, shape)))
    return (i_start, i_shape), bounds


def _run_region(program: Program, stage_targets, geo, widths, fields,
                sources: Mapping[str, tuple[jax.Array, tuple[int, ...]]],
                start: tuple[int, ...], shape: tuple[int, ...],
                zeros: tuple[int, ...], dyn=None) -> dict:
    """Run the whole stage pipeline over one region of the local domain.

    ``sources[f] = (array, src_ext)`` covers interior coordinates
    ``[-src_ext[d], local[d] + src_ext[d])`` — raw local arrays
    (``src_ext = 0``, the interior region) or exchanged arrays
    (``src_ext = widths[f]``, boundary regions).  Each field is sliced to
    the region plus its own schedule width, so the region's launches see
    exactly the ghost geometry the full-domain pipeline would.
    """
    env = {}
    for f in fields:
        a, src_ext = sources[f]
        w = widths[f]
        for d in range(len(shape)):
            lo = start[d] - w[d] + src_ext[d]
            ln = shape[d] + 2 * w[d]
            if lo == 0 and ln == a.shape[d + 1]:
                continue
            a = jax.lax.slice_in_dim(a, lo, lo + ln, axis=d + 1)
        env[f] = (a, w)
    env = program._run_stages(stage_targets, shape, geo, env, dyn=dyn)
    return {f: _grid_trim(env[f][0], shape, env[f][1], zeros)
            for f in fields}


def _validate_decomposition(program: Program, grid_shape, open_mask):
    """Compile-time guard: every stencil-read dimension left *unsharded*
    wraps periodically inside each launch, which is only meaningful while
    the extent covers the stencil radius — a pencil misconfiguration
    (e.g. a radius-2 stencil on an unsharded extent-1 dim) must fail
    here, not deep inside ``lax.scan``."""
    for st in program.stages:
        for s in st.spec.stencils:
            if s is None:
                continue
            for d, r in enumerate(s.radius_per_dim()):
                if r and not open_mask[d] and r > grid_shape[d]:
                    sharded = [i for i, o in enumerate(open_mask) if o]
                    raise ValueError(
                        f"program {program.name!r} stage {st.name!r}: "
                        f"stencil {s.name!r} radius {r} in dim {d} "
                        f"exceeds the unsharded (periodic) extent "
                        f"{grid_shape[d]} — this decomposition (sharded "
                        f"dims {sharded}) leaves dim {d} too thin to "
                        f"wrap; shard dim {d} with a mesh axis or "
                        f"enlarge the grid")


class CompiledProgram:
    """A :class:`Program` lowered for one target + geometry.

    * :meth:`step` — one jitted step over the field dict;
    * :meth:`run` — ``nsteps`` under one jitted ``lax.scan``
      (``donate=True`` donates the field buffers: XLA aliases state in
      and out, the ping-pong);
    * :meth:`plan` — the aggregated :class:`ProgramPlan`;
    * :meth:`comm_stats` — the analytic exchange budget per step;
    * ``halo_schedule`` — field → dim-0 exchange width (sharded compiles
      only; the legacy slab view of ``exchange_schedule``);
    * ``exchange_schedule`` — field → ``{dim: width}`` over the sharded
      dims with a non-zero width (one exchange round each per step);
    * ``overlap`` — whether the compiled step uses the interior/boundary
      overlap split;
    * ``stage_targets`` — the per-stage routed targets (capability
      fallback applied).
    """

    def __init__(self, program: Program, target: Target | str | None,
                 grid_shape: Sequence[int], *, mesh=None,
                 shard_axis: str | Sequence[str] | None = None,
                 overlap: bool | None = None):
        self.program = program
        tgt = as_target(target)
        self.target = tgt
        self.grid_shape = tuple(int(s) for s in grid_shape)
        ndim = len(self.grid_shape)
        self.mesh = mesh if mesh is not None else tgt.mesh
        self.shard_axis = (shard_axis if shard_axis is not None
                           else (tgt.shard_axis or "data"))
        self.shard_axes = (_shard_axes(self.shard_axis)
                           if self.mesh is not None else ())
        self.stage_targets = tuple(resolve_stage_target(tgt, st.spec,
                                                        st.name)
                                   for st in program.stages)
        fields = program.fields
        zeros = (0,) * ndim
        # Per-member ensemble sweeps: their (traced) values enter the
        # core as trailing arguments after the field arrays, so one
        # compiled step serves every member under vmap (tdp.fleet).
        self.batched_consts = program.batched_consts()
        self.dyn_names = tuple(self.batched_consts)
        dyn_names = self.dyn_names
        nfields = len(fields)

        def _split(args):
            return args[:nfields], dict(zip(dyn_names, args[nfields:]))

        if self.mesh is None:
            self.local_shape = self.grid_shape
            open_mask = (False,) * ndim
            widths, geo = program.schedule(ndim, open_mask)
            _validate_decomposition(program, self.grid_shape, open_mask)
            self.halo_schedule: dict[str, int] = {}
            self.exchange_schedule: dict[str, dict[int, int]] = {}
            self._geo = geo
            self._widths = widths
            self._shard_dims: tuple[int, ...] = ()
            self._interior_shape = self.grid_shape
            self.overlap = False

            def core(*args):
                arrays, dyn = _split(args)
                env = {f: (a, zeros) for f, a in zip(fields, arrays)}
                env = program._run_stages(self.stage_targets,
                                          self.grid_shape, geo, env,
                                          dyn=dyn)
                return tuple(env[f][0] for f in fields)

        else:
            axes = self.shard_axes
            if not axes:
                raise ValueError(
                    f"program {program.name!r}: a mesh was given but "
                    f"shard_axis is empty — name the mesh axis(es) that "
                    f"shard grid dims 0..k")
            if len(axes) != len(set(axes)):
                raise ValueError(f"duplicate shard axes {axes}")
            if len(axes) > ndim:
                raise ValueError(
                    f"{len(axes)} shard axes {axes} for a {ndim}-D grid; "
                    f"mesh axis k shards grid dim k, so at most {ndim} "
                    f"axes apply")
            local = list(self.grid_shape)
            for d, ax in enumerate(axes):
                if ax not in self.mesh.shape:
                    raise ValueError(
                        f"shard axis {ax!r} is not a mesh axis "
                        f"(mesh has {tuple(self.mesh.shape)})")
                nsh = int(self.mesh.shape[ax])
                if self.grid_shape[d] % nsh != 0:
                    raise ValueError(
                        f"{'XYZ'[d] if d < 3 else f'dim-{d}'} extent "
                        f"{self.grid_shape[d]} not divisible by mesh "
                        f"axis {ax}={nsh}")
                local[d] = self.grid_shape[d] // nsh
            local = tuple(local)
            self.local_shape = local
            shard_dims = tuple(range(len(axes)))
            self._shard_dims = shard_dims
            open_mask = tuple(d < len(axes) for d in range(ndim))
            widths, geo = program.schedule(ndim, open_mask)
            self._geo = geo
            self._widths = widths
            self.halo_schedule = {f: widths[f][0] for f in fields}
            self.exchange_schedule = {
                f: {d: widths[f][d] for d in shard_dims if widths[f][d]}
                for f in fields}
            for d in shard_dims:
                w_max = max((widths[f][d] for f in fields), default=0)
                if w_max >= self.grid_shape[d]:
                    raise ValueError(
                        f"program {program.name!r} needs a {w_max}-plane "
                        f"ghost exchange in dim {d} but the global "
                        f"extent is only {self.grid_shape[d]} plane(s)")
            _validate_decomposition(program, self.grid_shape, open_mask)

            # Overlap is opt-in: splitting a launch into region-shaped
            # launches is *data*-exact (the eager split is bitwise equal
            # to the full launch) but XLA codegen for the region shapes
            # may reassociate float ops at the ≤1-ULP level, so the
            # default keeps the unsplit schedule and its bit-identical-
            # to-single-device guarantee.  Feasibility: the interior must
            # be non-empty in every exchanged dim (thin pencils where the
            # exchange width swallows the whole shard stay unsplit).
            W = tuple(max((widths[f][d] for f in fields), default=0)
                      if open_mask[d] else 0 for d in range(ndim))
            (i_start, i_shape), bounds = _overlap_regions(local, W,
                                                          shard_dims)
            can_overlap = any(W) and all(s > 0 for s in i_shape)
            self.overlap = bool(overlap) and can_overlap
            self._interior_shape = i_shape if self.overlap else local

            def _exchange_all(arrays):
                """Ordered per-dim sweep: dim 1 transfers the already-
                dim-0-extended planes, so corner ghosts arrive via the
                orthogonal neighbour (no diagonal ppermute)."""
                out = {}
                for f, a in zip(fields, arrays):
                    w = widths[f]
                    for d, ax in enumerate(axes):
                        if w[d]:
                            a = _exchange_dim(a, ax, w[d], d)
                    out[f] = a
                return out

            if not self.overlap:
                def core_local(*args):
                    arrays, dyn = _split(args)
                    ex = _exchange_all(arrays)
                    env = {f: (ex[f], widths[f]) for f in fields}
                    env = program._run_stages(self.stage_targets, local,
                                              geo, env, dyn=dyn)
                    return tuple(_grid_trim(env[f][0], local, env[f][1],
                                            zeros) for f in fields)
            else:
                def core_local(*args):
                    arrays, dyn = _split(args)
                    # Interior first, fed the *raw* local arrays — no
                    # data dependency on any ppermute, so XLA is free to
                    # run it while the exchanges are in flight.
                    raw = {f: (a, zeros) for f, a in zip(fields, arrays)}
                    out = _run_region(program, self.stage_targets, geo,
                                      widths, fields, raw, i_start,
                                      i_shape, zeros, dyn=dyn)
                    ex = _exchange_all(arrays)
                    exd = {f: (ex[f], widths[f]) for f in fields}
                    for d, lo, hi in reversed(bounds):
                        o_lo = _run_region(program, self.stage_targets,
                                           geo, widths, fields, exd,
                                           *lo, zeros, dyn=dyn)
                        o_hi = _run_region(program, self.stage_targets,
                                           geo, widths, fields, exd,
                                           *hi, zeros, dyn=dyn)
                        out = {f: jnp.concatenate(
                                   [o_lo[f], out[f], o_hi[f]], axis=d + 1)
                               for f in fields}
                    return tuple(out[f] for f in fields)

            pspec = PartitionSpec(*((None,) + axes
                                    + (None,) * (ndim - len(axes))))
            # pallas_call has no shard_map replication rule on jax 0.4.x:
            # drop the check whenever any stage dispatches off-xla.
            check = all(t.executor == "xla" for t in self.stage_targets)
            core = compat.shard_map(
                core_local, mesh=self.mesh,
                in_specs=(pspec,) * len(fields)
                + (PartitionSpec(),) * len(dyn_names),
                out_specs=(pspec,) * len(fields), check_vma=check)

        self._core = core
        self._jit_step = jax.jit(core)
        self._run_cache: dict = {}

    # -- running -----------------------------------------------------------

    def _as_tuple(self, state: Mapping[str, jax.Array]):
        if isinstance(state, ProgramState) and state.ensemble is not None:
            raise ValueError(
                f"program {self.program.name!r}: state carries an "
                f"ensemble axis (ensemble={state.ensemble}) but this is "
                f"a single-member compile — run it through a fleet "
                f"(.vmap({state.ensemble})) or pass state.member(i)")
        arrays = []
        for f in self.program.fields:
            if f not in state:
                raise ValueError(
                    f"state for program {self.program.name!r} is missing "
                    f"field {f!r}; present: {sorted(state)}")
            a = state[f]
            validate_field(f, a, ncomp=self.program.ncomp.get(f),
                           grid_shape=self.grid_shape,
                           program=self.program.name)
            arrays.append(a)
        return tuple(arrays)

    def _wrap(self, state, outs) -> Mapping[str, jax.Array]:
        out = dict(zip(self.program.fields, outs))
        if isinstance(state, ProgramState):
            return ProgramState(out)
        return out

    def _require_unbatched(self, what: str):
        if self.dyn_names:
            raise ValueError(
                f"program {self.program.name!r} binds batched const(s) "
                f"{list(self.dyn_names)} (per-member ensemble sweeps); "
                f"{what} has no ensemble axis — compile a fleet with "
                f".vmap(batch) (tdp.fleet) instead")

    def step(self, state: Mapping[str, jax.Array]):
        """One step: field mapping in (dict or
        :class:`~repro.core.state.ProgramState`), same kind out."""
        self._require_unbatched("CompiledProgram.step")
        outs = self._jit_step(*self._as_tuple(state))
        return self._wrap(state, outs)

    def run(self, state: Mapping[str, jax.Array], nsteps: int, *,
            donate: bool = False, health=None):
        """``nsteps`` steps under one jitted ``lax.scan``.

        ``donate=True`` donates the input field buffers so XLA aliases
        them with the outputs (no per-step reallocation; the caller's
        arrays are consumed — feed each call the previous call's output,
        the ping-pong).  Compiled once per ``(nsteps, donate)``.
        Accepts a plain dict or a :class:`ProgramState`; returns the
        same kind.

        ``health``: an optional :class:`~repro.core.health.HealthPolicy`
        — the run splits into ``health.every``-step chunks (the same
        jitted scan iterated, so the trajectory is bit-identical to an
        unguarded run) with a host-side NaN/Inf/norm check between
        chunks; a violation raises
        :class:`~repro.core.health.HealthError` diagnosing the field
        and the ``every``-wide step range it appeared in.
        """
        self._require_unbatched("CompiledProgram.run")
        if health is not None:
            return self._run_guarded(state, int(nsteps), health,
                                     donate=donate)
        if nsteps <= 0:
            return self._wrap(state, tuple(state[f]
                                           for f in self.program.fields))
        key = (int(nsteps), bool(donate))
        fn = self._run_cache.get(key)
        if fn is None:
            core, n = self._core, int(nsteps)

            def many(arrays):
                def body(carry, _):
                    return core(*carry), None
                out, _ = jax.lax.scan(body, arrays, None, length=n)
                return out

            fn = jax.jit(many, donate_argnums=(0,) if donate else ())
            self._run_cache[key] = fn
        outs = fn(self._as_tuple(state))
        return self._wrap(state, outs)

    def _run_guarded(self, state, nsteps: int, health, *,
                     donate: bool = False):
        """Chunked run with health checks between chunks (see ``run``)."""
        from .health import check
        health.select_fields(self.program.fields)   # fail fast on typos
        done = 0
        while done < nsteps:
            chunk = min(health.every, nsteps - done)
            # donate only from the second chunk on: the first chunk's
            # inputs are the caller's arrays, which donate= promises to
            # consume only across the whole call, not per chunk — but an
            # intermediate chunk's inputs are ours to alias away.
            state = self.run(state, chunk, donate=donate and done > 0)
            check(health, state,
                  step_range=(done, done + chunk),
                  where=f"program {self.program.name!r}")
            done += chunk
        return state

    def vmap(self, batch: int) -> "repro.core.fleet.FleetProgram":  # noqa: F821
        """Lift this compiled step over a leading ensemble axis: a
        :class:`~repro.core.fleet.FleetProgram` stepping ``batch``
        independent trajectories (one per ensemble member) in one jitted
        launch — members never interact, so the fleet trajectory is
        bit-identical to ``batch`` single runs.  Sharded compiles
        compose the vmap *outside* ``shard_map``, so a decomposed fleet
        still runs one halo-exchange round per step."""
        from .fleet import FleetProgram
        return FleetProgram(self, batch)

    def plan(self) -> "ProgramPlan":
        """Aggregated memory models for this compile's local geometry."""
        return _build_program_plan(self.program, self.stage_targets,
                                   self.local_shape, self._geo,
                                   self.halo_schedule,
                                   self.exchange_schedule)

    def comm_stats(self, itemsize: int = 4) -> dict:
        """The analytic communication budget of one compiled step.

        Per-device, per-step: exchanged ghost bytes and ``ppermute``
        count (:func:`exchange_stats` — checkable against
        ``collective-permute`` ops in the lowered HLO), plus the
        decomposition shape and the overlap split's interior fraction
        (the share of local sites whose compute does not wait on any
        exchange).  ``itemsize`` defaults to float32 fields.
        """
        if self.mesh is None:
            return {"decomposition": "single", "shard_axes": (),
                    "mesh_axis_sizes": (), "local_shape": self.local_shape,
                    "exchange_schedule": {},
                    "exchanged_bytes_per_step": 0,
                    "ppermutes_per_step": 0, "per_field": {},
                    "overlap": False, "interior_fraction": 1.0}
        stats = exchange_stats(self._widths, self.program.ncomp,
                               self.local_shape, self._shard_dims,
                               itemsize)
        kinds = {1: "slab", 2: "pencil", 3: "block"}
        n_loc = 1
        for s in self.local_shape:
            n_loc *= s
        n_int = 1
        for s in self._interior_shape:
            n_int *= s
        stats.update(
            decomposition=kinds.get(len(self.shard_axes), "block"),
            shard_axes=self.shard_axes,
            mesh_axis_sizes=tuple(int(self.mesh.shape[a])
                                  for a in self.shard_axes),
            local_shape=self.local_shape,
            exchange_schedule=self.exchange_schedule,
            overlap=self.overlap,
            interior_fraction=(n_int / n_loc if self.overlap else 0.0))
        return stats

    def __repr__(self):
        return (f"CompiledProgram({self.program.name!r}, "
                f"target={self.target.executor!r}, "
                f"grid={self.grid_shape}, "
                f"sharded={self.mesh is not None})")


# ---------------------------------------------------------------------------
# aggregated memory models
# ---------------------------------------------------------------------------

class ProgramPlan:
    """Per-stage :class:`~repro.core.api.LaunchPlan`\\ s plus step-level
    aggregates.

    ``hbm_bytes_estimate`` **sums** the stage models — every executor
    operand and output materialised over one step (the per-step HBM
    footprint; stage transients are live at least until the next stage
    consumes them).  ``vmem_bytes_estimate`` takes the **max** — stages
    run sequentially, fast memory is reused.
    """

    __slots__ = ("name", "stages", "halo_schedule", "exchange_schedule")

    def __init__(self, name: str, stages, halo_schedule,
                 exchange_schedule=None):
        self.name = name
        self.stages = tuple(stages)          # (stage_name, LaunchPlan)
        self.halo_schedule = dict(halo_schedule)
        self.exchange_schedule = dict(exchange_schedule or {})

    def hbm_bytes_estimate(self, itemsize: int = 4) -> int:
        return sum(p.hbm_bytes_estimate(itemsize) for _, p in self.stages)

    def vmem_bytes_estimate(self, itemsize: int = 4) -> int:
        return max(p.vmem_bytes_estimate(itemsize) for _, p in self.stages)

    def per_stage(self, itemsize: int = 4) -> list[dict]:
        """One row per stage — the stage table (executor, capability,
        memory models)."""
        return [{"stage": name, "executor": p.target.executor,
                 "wants": p.wants,
                 "hbm_bytes_estimate": p.hbm_bytes_estimate(itemsize),
                 "vmem_bytes_estimate": p.vmem_bytes_estimate(itemsize)}
                for name, p in self.stages]

    def __repr__(self):
        return (f"ProgramPlan({self.name!r}, "
                f"stages={[n for n, _ in self.stages]}, "
                f"hbm={self.hbm_bytes_estimate()}, "
                f"vmem={self.vmem_bytes_estimate()})")


def _build_program_plan(program: Program, stage_targets,
                        shape: tuple[int, ...], geo, halo_schedule,
                        exchange_schedule=None) -> ProgramPlan:
    plans = []
    for st, tgt, (e_out, h) in zip(program.stages, stage_targets, geo):
        lat = Lattice(tuple(s + 2 * e for s, e in zip(shape, e_out)))
        lp = _launch_plan(st.spec, tgt, lattice=lat,
                          halo=h if any(h) else None,
                          consts=st.consts_dict())
        plans.append((st.name, lp))
    return ProgramPlan(program.name, plans, halo_schedule,
                       exchange_schedule)


# ---------------------------------------------------------------------------
# facade constructor
# ---------------------------------------------------------------------------

def program(name: str, stages: Sequence[Stage], *, fields: Sequence[str],
            intermediates: Sequence[str] | None = None) -> Program:
    """Build a :class:`Program` (``tdp.program(...)``)::

        prog = tdp.program(
            "lb_fused",
            [tdp.stage(FUSED_SPEC, reads=("f", "g"), writes=("f", "g"),
                       consts=collision_consts)],
            fields=("f", "g"))
        exe = prog.compile(tdp.Target("pallas_windowed"),
                           grid_shape=(64, 64, 64))
        state = exe.run(state, 100, donate=True)
    """
    return Program(name, stages, fields=fields, intermediates=intermediates)
