"""Lattice fields — per-site value sets with an explicit memory layout.

The paper (§III-B) mandates a Structure-of-Arrays (SoA) layout, "where the
consecutive lattice site indices correspond to consecutive memory locations,
to allow chunks of lattice site data to be loaded as vectors for ILP
operations".  We make the layout an explicit, testable property:

* ``soa``: array shape ``(ncomp, nsites)`` — sites contiguous (lane axis on
  TPU).  This is the layout every targetDP launch requires.
* ``aos``: array shape ``(nsites, ncomp)`` — the "original code" layout whose
  innermost extent is dictated by the model (19 momenta, 3 dimensions) and
  under-utilises vector hardware.  Kept so the benchmark can measure exactly
  the pathology Fig. 1 of the paper measures.

A :class:`Field` is the *host* copy (NumPy, host RAM).  The *target* copy is
a ``jax.Array`` produced by :mod:`repro.core.memory`.  Host fields of
stencil lattices are halo-padded.
"""
from __future__ import annotations

from dataclasses import dataclass, field as dc_field
from typing import Literal

import numpy as np

from .lattice import Lattice

Layout = Literal["soa", "aos"]


@dataclass
class Field:
    """Host-side lattice field: ``ncomp`` double/float values per site.

    Data is stored flat over the (halo-padded) site index so that the same
    container serves both the 3-D fluid lattice and the token lattice.
    """

    lattice: Lattice
    ncomp: int
    dtype: np.dtype = np.dtype(np.float64)
    layout: Layout = "soa"
    data: np.ndarray = dc_field(default=None, repr=False)  # type: ignore[assignment]

    def __post_init__(self):
        if self.ncomp <= 0:
            raise ValueError("ncomp must be positive")
        if self.layout not in ("soa", "aos"):
            raise ValueError(f"unknown layout {self.layout!r}")
        self.dtype = np.dtype(self.dtype)
        if self.data is None:
            self.data = np.zeros(self.array_shape, dtype=self.dtype)
        else:
            self.data = np.asarray(self.data, dtype=self.dtype)
            if self.data.shape != self.array_shape:
                raise ValueError(
                    f"field data shape {self.data.shape} != expected {self.array_shape}"
                )

    # -- shapes ------------------------------------------------------------

    @property
    def nsites(self) -> int:
        return self.lattice.nsites_with_halo

    @property
    def array_shape(self) -> tuple[int, int]:
        if self.layout == "soa":
            return (self.ncomp, self.nsites)
        return (self.nsites, self.ncomp)

    # -- views -------------------------------------------------------------

    def grid_view(self) -> np.ndarray:
        """View shaped ``(ncomp, *halo_shape)`` (soa) / ``(*halo_shape, ncomp)``."""
        hs = self.lattice.halo_shape
        if self.layout == "soa":
            return self.data.reshape((self.ncomp, *hs))
        return self.data.reshape((*hs, self.ncomp))

    def interior(self) -> np.ndarray:
        """Interior (halo-stripped) grid view."""
        sl = self.lattice.interior_slices()
        g = self.grid_view()
        if self.layout == "soa":
            return g[(slice(None), *sl)]
        return g[(*sl, slice(None))]

    def site(self, *idx: int) -> np.ndarray:
        """All components at one (interior) grid index — convenience for tests."""
        off = tuple(i + self.lattice.halo for i in idx)
        g = self.grid_view()
        if self.layout == "soa":
            return g[(slice(None), *off)]
        return g[(*off, slice(None))]

    # -- layout conversion ---------------------------------------------------

    def to_layout(self, layout: Layout) -> "Field":
        if layout == self.layout:
            return self
        return Field(self.lattice, self.ncomp, self.dtype, layout, self.data.T.copy())

    def copy(self) -> "Field":
        return Field(self.lattice, self.ncomp, self.dtype, self.layout, self.data.copy())


def field_like(f: Field, data: np.ndarray | None = None) -> Field:
    return Field(f.lattice, f.ncomp, f.dtype, f.layout, data)
