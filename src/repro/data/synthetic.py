"""Deterministic synthetic LM data.

Design requirements at 1000-node scale (DESIGN.md §6):

* **Stateless addressing** — ``batch_for_step(step)`` is a pure function of
  ``(seed, step, host)``; a restarted / re-meshed job replays the exact
  stream from any step with no data-loader state in the checkpoint beyond
  the step counter.  This is also the straggler/elastic story: batches are
  *owned by position*, not by host identity, so when the mesh shrinks the
  surviving hosts re-partition the same global batch.
* **Learnable structure** — tokens follow a fixed random unigram→bigram
  table (order-1 Markov), so the ~100M example run has a real, falling loss
  (a pure-uniform stream would pin CE at log V).
* **Host sharding** — each host materialises only its slice of the global
  batch; ``make_batch_loader`` device_puts with the batch NamedSharding.

NumPy only (no jax) in the hot path: the generator must not touch device
state (dry-run safety).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8       # bigram successors per token (entropy ≈ log2 b)


def _successor_table(cfg: SyntheticConfig) -> np.ndarray:
    """(vocab, branching) int32 successor table, derived from the seed."""
    rng = np.random.default_rng(cfg.seed ^ 0x5EED)
    return rng.integers(0, cfg.vocab_size,
                        size=(cfg.vocab_size, cfg.branching), dtype=np.int64)


_TABLE_CACHE: dict = {}


def batch_for_step(cfg: SyntheticConfig, step: int, *,
                   lo: int = 0, hi: Optional[int] = None) -> dict:
    """Global batch rows [lo, hi) for ``step`` (hi=None → full batch).

    Returns {"tokens": (rows, S) int32, "labels": (rows, S) int32}.
    Labels are next-token targets: labels[t] = tokens[t+1] continuation.
    """
    hi = cfg.global_batch if hi is None else hi
    key = (cfg.vocab_size, cfg.branching, cfg.seed)
    table = _TABLE_CACHE.get(key)
    if table is None:
        table = _successor_table(cfg)
        _TABLE_CACHE[key] = table

    rows = hi - lo
    # per-(step,row) independent streams — a row's content depends only on
    # its global position, so any host slicing reproduces the same batch
    seq = np.empty((rows, cfg.seq_len + 1), dtype=np.int64)
    choices = np.empty((rows, cfg.seq_len), dtype=np.int64)
    for i, row in enumerate(range(lo, hi)):
        rng = np.random.default_rng(np.random.SeedSequence(
            [cfg.seed, step, row]))
        seq[i, 0] = rng.integers(0, cfg.vocab_size)
        choices[i] = rng.integers(0, cfg.branching, size=cfg.seq_len)
    for t in range(cfg.seq_len):
        seq[:, t + 1] = table[seq[:, t], choices[:, t]]
    return {"tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32)}


def make_batch_loader(cfg: SyntheticConfig, *, sharding=None,
                      process_index: int = 0, process_count: int = 1):
    """Returns ``load(step) -> device batch``.

    Each process materialises rows [pi·B/P, (pi+1)·B/P); with one process
    (this container) that is the whole batch, placed with ``sharding``.
    """
    import jax

    per = cfg.global_batch // process_count
    lo = process_index * per
    hi = lo + per

    def load(step: int):
        host = batch_for_step(cfg, step, lo=lo, hi=hi)
        if sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, sharding.get(k) if isinstance(sharding, dict)
                                  else sharding)
                for k, v in host.items()}

    return load
