"""Data pipeline: deterministic synthetic token streams, host-sharded."""
from .synthetic import SyntheticConfig, batch_for_step, make_batch_loader

__all__ = ["SyntheticConfig", "batch_for_step", "make_batch_loader"]
