"""Logical-axis → mesh-axis rules engine with divisibility fallbacks.

``init_params`` returns twin pytrees ``(params, axes)`` where every leaf of
``axes`` names the logical axes of the matching ``params`` leaf (see
:mod:`repro.models.params`).  A :class:`Plan` is an *ordered* rule table
``logical axis → candidate mesh axes``; :func:`spec_for_axes` walks a
tensor's logical axes left-to-right, assigning to each the first candidate
mesh axis that

  * is not already used by another dim of the same tensor, and
  * divides the *unit count* of that logical axis evenly (a head axis
    shards by whole heads, an expert axis by whole experts, ...).

Anything that fails both candidates falls back to replication — the engine
never errors on an "awkward" config (kv_heads=10 on a 16-way model axis
simply replicates the KV projections, as DESIGN.md §5 documents per arch).

Plans are plain data so the §Perf hillclimb can mutate them (e.g. move
``experts`` from replicated to ``("data",)``) and re-lower.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# logical-axis unit counts
# ---------------------------------------------------------------------------

def logical_axis_sizes(cfg: ModelConfig) -> dict[str, int]:
    """Shardable *unit count* per logical axis.

    For fused axes (``heads_x_dim`` = n_heads·head_dim) the unit count is
    the number of semantic units (heads), not the dim extent: sharding must
    place whole heads on a chip or attention reshapes stop being local.
    """
    sizes: dict[str, int] = {
        "d_model": cfg.d_model,
        "vocab": cfg.padded_vocab,
        "d_ff": max(cfg.d_ff, 1),
    }
    if cfg.attn is not None:
        sizes["heads_x_dim"] = cfg.attn.n_heads
        sizes["kv_x_dim"] = cfg.attn.n_kv_heads
        sizes["head_dim"] = 1          # never sharded (unit 1 → only TP=1)
        sizes["heads"] = cfg.attn.n_heads
    if cfg.mla is not None:
        sizes["lora"] = 1              # LoRA ranks stay replicated
    if cfg.moe is not None:
        sizes["experts"] = cfg.moe.num_experts
        # expert FFN width (the d_ff axis on expert tensors) — the dense
        # d_ff and expert d_ff share the logical name; take the gcd so one
        # rule covers both.
        import math
        sizes["d_ff"] = math.gcd(max(cfg.d_ff, cfg.moe.d_expert),
                                 cfg.moe.d_expert)
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
        import math
        sizes["d_ff"] = math.gcd(sizes["d_ff"], d_inner) if cfg.d_ff else d_inner
        if cfg.ssm.kind == "mamba2":
            sizes["heads"] = d_inner // cfg.ssm.head_dim
    sizes["layers"] = 1                # scan-stack dim: never sharded
    return sizes


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Plan:
    """An ordered rule table.  ``rules[logical] = (mesh axis candidates)``."""
    rules: tuple[tuple[str, tuple[str, ...]], ...]
    axis_sizes: Mapping[str, int]
    name: str = "custom"

    def candidates(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        for k, v in self.rules:
            if k == logical:
                return v
        return ()


def make_plan(cfg: ModelConfig, *, mode: str = "train",
              fsdp: bool = True, moe_impl: str = "ragged",
              extra_rules: Sequence[tuple[str, tuple[str, ...]]] = (),
              data_axes: tuple[str, ...] = ("data",)) -> Plan:
    """Default parallelism plan for an architecture.

    * ``train``: TP over ``model`` (heads/d_ff/vocab), optional ZeRO-3-style
      FSDP of ``d_model`` over ``data`` (GSPMD re-gathers per scan step —
      the all-gather is the explicit FSDP collective).  Expert tensors get
      *both*: ``d_model`` over data + ``d_ff`` over model, which is what
      makes the 671B config fit (DESIGN.md §6).
    * ``serve``: weights must additionally spread over ``data`` (no
      optimizer state to displace them); experts shard over ``data`` as
      whole experts, with d_ff over ``model``.
    * ``moe_impl="a2a"``: experts ride the model axis as whole experts
      (tokens travel instead of a d_model-wide psum).
    """
    d = tuple(data_axes)
    rules: list[tuple[str, tuple[str, ...]]] = list(extra_rules)

    if moe_impl == "a2a":
        rules.append(("experts", ("model",)))
    elif mode == "serve":
        rules.append(("experts", d))
    else:
        rules.append(("experts", d if fsdp else ()))

    rules += [
        ("vocab", ("model",)),
        ("heads_x_dim", ("model",)),
        ("kv_x_dim", ("model",)),
        ("heads", ("model",)),
        ("d_ff", ("model",)),
    ]
    if mode == "serve":
        rules.append(("d_model", d))
    elif fsdp:
        rules.append(("d_model", d))
    return Plan(rules=tuple(rules), axis_sizes=logical_axis_sizes(cfg),
                name=f"{mode}:{'fsdp' if fsdp else 'tp'}:{moe_impl}")


# ---------------------------------------------------------------------------
# spec resolution
# ---------------------------------------------------------------------------

def _mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_axes(axes: Optional[tuple], plan: Plan, mesh: Mesh) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec."""
    if axes is None:
        return P()
    msizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    out = []
    for logical in axes:
        assigned = None
        if logical is not None and logical != "layers":
            units = plan.axis_sizes.get(logical, 1)
            for cand in plan.candidates(logical):
                if cand in used or cand not in msizes:
                    continue
                if units % msizes[cand] == 0 and msizes[cand] > 1:
                    assigned = cand
                    used.add(cand)
                    break
        out.append(assigned)
    # trim trailing Nones (cosmetic; jax treats them identically)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for_tree(axes_tree, plan: Plan, mesh: Mesh):
    """Map the twin ``axes`` pytree to a pytree of NamedShardings."""
    def leaf(ax):
        return NamedSharding(mesh, spec_for_axes(ax, plan, mesh))
    return jax.tree.map(leaf, axes_tree,
                        is_leaf=lambda x: x is None or isinstance(x, tuple))


# ---------------------------------------------------------------------------
# activation / batch specs
# ---------------------------------------------------------------------------

def batch_specs(batch_axes: tuple[str, ...], mesh: Mesh,
                spec_map: Mapping[str, tuple] ) -> dict:
    """NamedShardings for a batch dict.

    ``spec_map`` gives per-key logical dims, e.g. ``{"tokens": ("batch",
    "seq")}``; only ``"batch"`` is sharded (over ``batch_axes``), everything
    else replicates.  Sequence stays unsharded at the boundary — interior
    sequence parallelism is introduced by constraints/shard_map, not input
    layout.
    """
    ba = tuple(a for a in batch_axes if a in mesh.axis_names)

    def to_spec(dims: tuple) -> NamedSharding:
        parts = [ba if d == "batch" and ba else None for d in dims]
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return {k: to_spec(v) for k, v in spec_map.items()}
