"""Sharding: logical-axis → mesh-axis rules engine (mesh-level TLP).

This is the mesh-level half of the targetDP TLP mapping (DESIGN.md §2):
the paper partitions lattice sites between threads; here the token/weight
lattices are partitioned between chips.  Rules are *data*, not code, so a
parallelism plan is a config artifact the §Perf loop can hillclimb.
"""
from .rules import (
    Plan,
    logical_axis_sizes,
    make_plan,
    sharding_for_tree,
    spec_for_axes,
    batch_specs,
)

__all__ = [
    "Plan", "logical_axis_sizes", "make_plan", "sharding_for_tree",
    "spec_for_axes", "batch_specs",
]
