"""The paper-facing targetDP API surface: ``from repro import tdp``.

One kernel body, one launch syntax, retargeted by swapping the
:class:`Target` descriptor — the paper's single-source contract as a
module namespace::

    from repro import tdp

    @tdp.kernel(fields=[tdp.field(3)], out=3)
    def scale(x, a=1.0):
        return a * x

    y = tdp.launch(scale, tdp.Target("pallas", vvl=256), x, a=2.0)

Paper macro → API mapping (full table in docs/targetdp_api.md):

==================  =====================================================
paper               here
==================  =====================================================
``TARGET_ENTRY``    ``@tdp.kernel`` (or :func:`site_kernel` legacy form)
``TARGET_LAUNCH``   :func:`tdp.launch` — ``launch(spec, target, *arrays)``
``TARGET_TLP``      the executor's chunk loop (vmap / pallas grid)
``TARGET_ILP``      the trailing VVL axis, ``Target.vvl`` tunes it
``VVL`` AoSoA site  ``Target.layout="aosoa"`` — executor-internal
ordering            SoA↔AoSoA transforms at field boundaries
                    (:mod:`repro.core.layout`), ``vvl`` as the inner
                    block width; bit-identical across layouts
``TARGET_CONST``    :class:`TargetConst` / launch ``**consts``
C-vs-CUDA switch    :class:`Target` + :func:`register_executor`
host step glue      :func:`tdp.program` — multi-launch step graphs with
                    double-buffered fields and one halo schedule per
                    step (:mod:`repro.core.program`)
per-device tuning   :func:`tdp.autotune` — measured selection over
                    ``Target.tuning`` / the executor axis, cached on
                    disk per (program, grid, device)
ensemble serving    ``compiled.vmap(batch)`` / :class:`FleetDriver` —
                    batched trajectories behind submit/poll/stream,
                    ``BatchedConst`` parameter sweeps, durable tickets
                    (:mod:`repro.core.fleet`)
failure handling    :class:`HealthPolicy` guards (``run(...,
                    health=...)``), ticket status/retry/rollback on the
                    driver, :mod:`tdp.faults <repro.core.faults>` chaos
                    injectors (:mod:`repro.core.health`)
==================  =====================================================
"""
from repro.core.target import (  # noqa: F401
    Target,
    as_target,
    default_vvl,
    set_default_vvl,
)
from repro.core.spec import (  # noqa: F401
    FieldSpec,
    KernelSpec,
    field,
    kernel,
)
from repro.core.registry import (  # noqa: F401
    compatible_executors,
    executor_tunables,
    executor_wants,
    get_executor,
    get_executor_entry,
    list_executors,
    register_executor,
    registry_version,
    unregister_executor,
)
from repro.core.api import (  # noqa: F401
    LaunchPlan,
    WindowVmemError,
    gather_neighbors,
    halo_extend,
    launch,
    launch_plan,
    pad_sites,
    xla_executor,
)
from repro.core.layout import (  # noqa: F401
    LAYOUTS,
    aosoa_nblocks,
    aosoa_to_soa,
    soa_to_aosoa,
)
from repro.core.program import (  # noqa: F401
    CompiledProgram,
    Program,
    ProgramPlan,
    exchange_ghosts,
    exchange_stats,
    Stage,
    program,
    stage,
)
from repro.core.autotune import (  # noqa: F401
    Candidate,
    TuneReport,
    TuneResult,
    autotune,
    default_space,
    plane_block_candidates,
    wall_clock_timer,
)
from repro.core import costmodel  # noqa: F401  (module: tdp.costmodel)
from repro.core.costmodel import (  # noqa: F401
    CostEstimate,
    MachineProfile,
    machine_profile,
    predict,
    roofline_seconds,
)
from repro.core.execute import reduce, site_kernel  # noqa: F401
from repro.core.lattice import (  # noqa: F401
    D3Q19_VELOCITIES,
    Lattice,
    Stencil,
    STENCIL_D3Q19_PULL,
    STENCIL_GRAD_6PT,
    STENCIL_GRAD_19PT,
    token_lattice,
)
from repro.core import fleet  # noqa: F401  (module: tdp.fleet)
from repro.core.fleet import (  # noqa: F401
    FleetDriver,
    FleetProgram,
    Ticket,
)
from repro.core import faults, health  # noqa: F401  (tdp.faults, tdp.health)
from repro.core.faults import InjectedFault  # noqa: F401
from repro.core.health import (  # noqa: F401
    Diagnosis,
    HealthError,
    HealthPolicy,
)
from repro.core.state import ProgramState, validate_field  # noqa: F401
from repro.core.memory import (  # noqa: F401
    BatchedConst,
    TargetConst,
    copy_constant_to_target,
    copy_from_target,
    copy_to_target,
    sync_target,
    target_free,
    target_malloc,
)

__all__ = [
    "Target", "as_target", "default_vvl", "set_default_vvl",
    "FieldSpec", "KernelSpec", "field", "kernel",
    "register_executor", "unregister_executor", "get_executor",
    "get_executor_entry", "executor_wants", "list_executors",
    "registry_version",
    "launch", "launch_plan", "LaunchPlan", "xla_executor",
    "gather_neighbors", "halo_extend", "pad_sites", "WindowVmemError",
    "LAYOUTS", "aosoa_nblocks", "aosoa_to_soa", "soa_to_aosoa",
    "Program", "CompiledProgram", "ProgramPlan", "Stage", "program",
    "exchange_ghosts", "exchange_stats",
    "stage",
    "autotune", "default_space", "plane_block_candidates",
    "Candidate", "TuneReport", "TuneResult", "wall_clock_timer",
    "costmodel", "CostEstimate", "MachineProfile", "machine_profile",
    "predict", "roofline_seconds",
    "compatible_executors", "executor_tunables",
    "reduce", "site_kernel",
    "Lattice", "token_lattice", "Stencil", "D3Q19_VELOCITIES",
    "STENCIL_D3Q19_PULL", "STENCIL_GRAD_6PT", "STENCIL_GRAD_19PT",
    "TargetConst", "copy_constant_to_target", "copy_to_target",
    "copy_from_target", "sync_target", "target_free", "target_malloc",
    "fleet", "FleetProgram", "FleetDriver", "Ticket",
    "ProgramState", "BatchedConst", "validate_field",
    "health", "faults", "HealthPolicy", "HealthError", "Diagnosis",
    "InjectedFault",
]
