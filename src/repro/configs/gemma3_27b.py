"""Gemma 3 27B  [google/gemma-3 technical report; hf:google/gemma-3-27b-pt].

62 layers in a 5:1 local:global pattern (window 1024; local rope θ=10k,
global θ=1M), d_model 5376, 32 heads (GQA kv=16, head_dim 128), FFN 21504
(GeGLU), vocab 262 144, RMSNorm with qk-norm, embeddings scaled √d.
"""
from repro.models.config import AttnConfig, ModelConfig, repeat_program

CONFIG = ModelConfig(
    name="gemma3-27b",
    d_model=5376,
    n_layers=62,
    vocab_size=262_144,
    d_ff=21_504,
    layer_program=repeat_program(
        ("local", "local", "local", "local", "local", "attn"), 62),
    attn=AttnConfig(n_heads=32, n_kv_heads=16, head_dim=128,
                    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
                    window=1024, qk_norm=True),
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke",
    d_model=64,
    n_layers=6,
    vocab_size=512,
    d_ff=128,
    layer_program=repeat_program(
        ("local", "local", "local", "local", "local", "attn"), 6),
    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
                    window=8, qk_norm=True),
    act="geglu",
    embed_scale=True,
    tie_embeddings=True,
)

# 52 of 62 layers are 1024-token sliding window → sub-quadratic decode; the
# 10 global layers' KV budget is the §Perf target (ring-buffer local cache).
LONG_OK = True
