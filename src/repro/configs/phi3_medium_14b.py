"""Phi-3 Medium 14B  [arXiv:2404.14219; hf:microsoft/Phi-3-medium-4k-instruct].

40 layers, d_model 5120, 40 heads (GQA kv=10, head_dim 128), FFN 17920
(SwiGLU), RoPE θ=10k, vocab 100 352, untied head.

kv=10 does not divide the 16-way model axis → the rules engine replicates
the KV projections (DESIGN.md §5); Q/O stay 16-way sharded (40 % 16 ≠ 0
too, so Q also falls back — the attention TP for this arch runs on d_ff /
vocab only, an explicitly recorded fallback).
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    d_model=5120,
    n_layers=40,
    vocab_size=100_352,
    d_ff=17_920,
    layer_program=("attn",) * 40,
    attn=AttnConfig(n_heads=40, n_kv_heads=10, head_dim=128,
                    rope_theta=10_000.0),
    act="swiglu",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="phi3-smoke",
    d_model=64,
    n_layers=3,
    vocab_size=512,
    d_ff=192,
    layer_program=("attn",) * 3,
    attn=AttnConfig(n_heads=8, n_kv_heads=2, head_dim=8),
    act="swiglu",
    tie_embeddings=False,
)

LONG_OK = False
