"""Qwen2-VL 2B  [arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B].

28 layers, d_model 1536, 12 heads (GQA kv=2, head_dim 128), FFN 8960
(SwiGLU), vocab 151 936, **M-RoPE** with (t, h, w) sections (16, 24, 24)
over the 64 rotary frequencies, tied embeddings.

Vision tower is a STUB per the brief: ``input_specs()`` supplies
precomputed patch embeddings ``vision_embed (B, P, D)`` + a slot map
``vision_slot (B, S)`` (-1 = text) + the 3-component position tensor
``positions3 (3, B, S)`` that M-RoPE consumes (dynamic-resolution grids
produce exactly these).

12 heads / kv=2 don't divide the 16-way model axis → attention projections
replicate; TP carries d_ff + vocab (DESIGN.md §5 fallback, recorded).
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    d_model=1536,
    n_layers=28,
    vocab_size=151_936,
    d_ff=8960,
    layer_program=("attn",) * 28,
    attn=AttnConfig(n_heads=12, n_kv_heads=2, head_dim=128,
                    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24)),
    act="swiglu",
    pos_embed="mrope",
    vision_stub=True,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke",
    d_model=64,
    n_layers=3,
    vocab_size=512,
    d_ff=128,
    layer_program=("attn",) * 3,
    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                    rope_theta=1_000_000.0, mrope_sections=(2, 3, 3)),
    act="swiglu",
    pos_embed="mrope",
    vision_stub=True,
    tie_embeddings=True,
)

LONG_OK = False
