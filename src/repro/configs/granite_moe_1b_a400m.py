"""IBM Granite 3.0 1B-A400M base  [hf:ibm-granite/granite-3.0-1b-a400m-base].

24 layers, d_model 1024, 16 heads (GQA kv=8, head_dim 64), 32 experts of
width 512 (top-8, no shared expert), vocab 49 155.  Granite's embedding /
residual / attention multiplier scalars are omitted (constant rescalings;
systems-neutral).
"""
from repro.models.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    d_model=1024,
    n_layers=24,
    vocab_size=49_155,
    d_ff=512,
    layer_program=("attn_moe",) * 24,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=64,
                    rope_theta=10_000.0),
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512, num_shared=0),
    act="swiglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-moe-smoke",
    d_model=64,
    n_layers=3,
    vocab_size=512,
    d_ff=32,
    layer_program=("attn_moe",) * 3,
    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16),
    # capacity_factor = E/K ⇒ dropless (see deepseek smoke note)
    moe=MoEConfig(num_experts=8, top_k=4, d_expert=32, num_shared=0,
                  capacity_factor=2.0),
    act="swiglu",
    tie_embeddings=True,
)

LONG_OK = False
