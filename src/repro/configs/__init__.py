"""Assigned-architecture registry + input-shape cells.

One module per architecture exports:
  ``CONFIG``  — the exact public configuration (sources cited in-module)
  ``SMOKE``   — a reduced same-family config for CPU smoke tests
  ``LONG_OK`` — whether the ``long_500k`` cell applies (sub-quadratic decode)

The shape cells (seq_len × global_batch) come from the assignment brief;
``decode_*``/``long_*`` lower ``serve_step`` (single-token with a KV/state
cache of seq_len), not ``train_step``.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig

ARCHS = (
    "deepseek_v3_671b",
    "granite_moe_1b_a400m",
    "gemma3_27b",
    "nemotron_4_15b",
    "phi3_medium_14b",
    "gemma2_2b",
    "zamba2_2p7b",
    "falcon_mamba_7b",
    "whisper_medium",
    "qwen2_vl_2b",
)

# brief ids ↔ module names
ALIASES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "gemma3-27b": "gemma3_27b",
    "nemotron-4-15b": "nemotron_4_15b",
    "phi3-medium-14b": "phi3_medium_14b",
    "gemma2-2b": "gemma2_2b",
    "zamba2-2.7b": "zamba2_2p7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "whisper-medium": "whisper_medium",
    "qwen2-vl-2b": "qwen2_vl_2b",
}


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def _module(arch: str):
    arch = ALIASES.get(arch, arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; know {list(ARCHS)}"
                       f" (+aliases {list(ALIASES)})")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def long_ok(arch: str) -> bool:
    return bool(getattr(_module(arch), "LONG_OK", False))


def applicable_cells(arch: str) -> list[tuple[str, str]]:
    """[(shape_name, "" | skip-reason)] for all four shape cells."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not long_ok(arch):
            out.append((s.name, "pure full-attention arch: 500k decode "
                        "skipped per brief (DESIGN.md §5)"))
        else:
            out.append((s.name, ""))
    return out


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — never allocate)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _modality_stubs(cfg: ModelConfig, b: int, s: int) -> dict:
    extra = {}
    if cfg.is_encdec:
        extra["audio_embed"] = _sds((b, cfg.encoder.n_frames, cfg.d_model),
                                    jnp.float32)
    if cfg.vision_stub:
        npatch = min(256, s)
        extra["vision_embed"] = _sds((b, npatch, cfg.d_model), jnp.float32)
        extra["vision_slot"] = _sds((b, s), jnp.int32)
    if cfg.pos_embed == "mrope":
        extra["positions3"] = _sds((3, b, s), jnp.int32)
    return extra


def input_specs(arch: str, shape: str, *, cache_dtype=jnp.bfloat16,
                local_ring: bool = False) -> dict:
    """Abstract inputs for one (arch × shape) cell.

    Returns a dict:
      train:   {"batch": {tokens, labels, ...}}
      prefill: {"batch": {tokens, ...}}
      decode:  {"token", "caches", "length" [, "positions3"]}
    """
    cfg = get_config(arch)
    cell = SHAPES[shape]
    b, s = cell.global_batch, cell.seq_len

    if cell.kind == "train":
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        batch.update(_modality_stubs(cfg, b, s))
        return {"batch": batch}

    if cell.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
        batch.update(_modality_stubs(cfg, b, s))
        return {"batch": batch}

    # decode: cache shapes via eval_shape of init_cache — no allocation
    caches = jax.eval_shape(
        lambda: lm.init_cache(None, cfg, b, s, dtype=cache_dtype,
                              local_ring=local_ring))
    out = {"token": _sds((b, 1), jnp.int32),
           "caches": caches,
           "length": _sds((), jnp.int32)}
    if cfg.pos_embed == "mrope":
        out["positions3"] = _sds((3, b, 1), jnp.int32)
    return out
