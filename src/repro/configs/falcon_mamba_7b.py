"""Falcon-Mamba 7B  [arXiv:2410.05355; hf:tiiuae/falcon-mamba-7b].

64 pure Mamba-1 layers (attention-free), d_model 4096, d_state 16,
d_conv 4, expand 2 (d_inner 8192, dt_rank 256), vocab 65 024, untied head.

Arch-applicability note (DESIGN.md §5): attention-specific features
(flash kernel, KV-cache sharding) are unused; the targetDP layer applies
to the selective-scan's pointwise pre/post ops and the scan kernel's
block tiling is the VVL-analogue tunable.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    d_model=4096,
    n_layers=64,
    vocab_size=65_024,
    d_ff=0,
    layer_program=("mamba1",) * 64,
    ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2),
    pos_embed="none",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke",
    d_model=64,
    n_layers=4,
    vocab_size=512,
    d_ff=0,
    layer_program=("mamba1",) * 4,
    ssm=SSMConfig(kind="mamba1", d_state=8, d_conv=4, expand=2),
    pos_embed="none",
    tie_embeddings=False,
)

LONG_OK = True      # SSM: O(1) decode state, linear prefill
