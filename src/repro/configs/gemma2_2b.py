"""Gemma 2 2B  [arXiv:2408.00118; hf:google/gemma-2-2b].

26 layers alternating local (window 4096) / global attention, d_model 2304,
8 heads (GQA kv=4, head_dim 256), FFN 9216 (GeGLU), attention-logit softcap
50, final-logit softcap 30, vocab 256 000, embeddings scaled √d.
"""
from repro.models.config import AttnConfig, ModelConfig, repeat_program

CONFIG = ModelConfig(
    name="gemma2-2b",
    d_model=2304,
    n_layers=26,
    vocab_size=256_000,
    d_ff=9216,
    layer_program=repeat_program(("local", "attn"), 26),
    attn=AttnConfig(n_heads=8, n_kv_heads=4, head_dim=256,
                    rope_theta=10_000.0, window=4096, softcap=50.0),
    act="geglu",
    embed_scale=True,
    logit_softcap=30.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma2-smoke",
    d_model=64,
    n_layers=4,
    vocab_size=512,
    d_ff=128,
    layer_program=repeat_program(("local", "attn"), 4),
    attn=AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16,
                    rope_theta=10_000.0, window=8, softcap=50.0),
    act="geglu",
    embed_scale=True,
    logit_softcap=30.0,
    tie_embeddings=True,
)

# Half the layers are windowed; the 13 global layers hold 500k KV only via
# sequence-sharding + the ring-buffer local cache (§Perf) — included.
LONG_OK = True
