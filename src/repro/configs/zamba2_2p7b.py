"""Zamba2 2.7B  [arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B].

54 Mamba2 (SSD) layers with a *weight-shared* full transformer block
interleaved every 6th position, d_model 2560, ssm_state 64 (head_dim 64,
expand 2 → d_inner 5120, 80 SSD heads), shared attention 32 heads
(kv=32, head_dim 80), FFN 10240, vocab 32 000.

Simplification: Zamba2 concatenates the residual with the original
embedding at the shared block and uses two alternating shared blocks +
LoRA adapters; here one weight-tied shared block is invoked at the same
positions (same memory/traffic shape — the tying is the systems point).
"""
from repro.models.config import (AttnConfig, ModelConfig, SSMConfig,
                                 repeat_program)

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    d_model=2560,
    n_layers=54,
    vocab_size=32_000,
    d_ff=10_240,
    layer_program=repeat_program(
        ("mamba2",) * 5 + ("shared_attn",), 54),
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=80,
                    rope_theta=10_000.0),
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                  head_dim=64, n_groups=1, chunk=128),
    act="geglu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    d_model=64,
    n_layers=6,
    vocab_size=512,
    d_ff=128,
    layer_program=repeat_program(("mamba2",) * 5 + ("shared_attn",), 6),
    attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16,
                    rope_theta=10_000.0),
    ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2,
                  head_dim=16, n_groups=1, chunk=32),
    act="geglu",
    tie_embeddings=True,
)

LONG_OK = True      # hybrid: SSD state is O(1); 9 shared-attn KV caches
