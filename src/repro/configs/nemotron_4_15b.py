"""Nemotron-4 15B  [arXiv:2402.16819].

32 layers, d_model 6144, 48 heads (GQA kv=8, head_dim 128), FFN 24576 with
squared-ReLU (non-gated), RoPE, vocab 256 000, untied output layer.
"""
from repro.models.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    d_model=6144,
    n_layers=32,
    vocab_size=256_000,
    d_ff=24_576,
    layer_program=("attn",) * 32,
    attn=AttnConfig(n_heads=48, n_kv_heads=8, head_dim=128,
                    rope_theta=10_000.0),
    act="relu2",
    norm="layernorm",
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="nemotron-smoke",
    d_model=64,
    n_layers=3,
    vocab_size=512,
    d_ff=256,
    layer_program=("attn",) * 3,
    attn=AttnConfig(n_heads=8, n_kv_heads=2, head_dim=8),
    act="relu2",
    norm="layernorm",
    tie_embeddings=False,
)

LONG_OK = False
