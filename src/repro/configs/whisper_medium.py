"""Whisper medium  [arXiv:2212.04356; hf:openai/whisper-medium].

Encoder–decoder, 24+24 layers, d_model 1024, 16 heads (kv=16, head_dim 64),
FFN 4096 (GELU, non-gated), LayerNorm, learned positions, vocab 51 865.

Modality frontend is a STUB per the brief: ``input_specs()`` supplies
precomputed 1500-frame mel→conv embeddings ``audio_embed (B, 1500, 1024)``.
The decoder runs the brief's LM shape cells (its trained ctx is 448; the
32k cells exercise the systems path, which is shape-generic).
"""
from repro.models.config import AttnConfig, EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    d_model=1024,
    n_layers=24,
    vocab_size=51_865,
    d_ff=4096,
    layer_program=("xattn",) * 24,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=64),
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
    act="gelu",
    norm="layernorm",
    pos_embed="learned",
    max_position=32_768,          # decode cells go past the trained 448
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    d_model=64,
    n_layers=3,
    vocab_size=512,
    d_ff=128,
    layer_program=("xattn",) * 3,
    attn=AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16),
    encoder=EncoderConfig(n_layers=2, n_frames=16),
    act="gelu",
    norm="layernorm",
    pos_embed="learned",
    max_position=128,
    tie_embeddings=True,
)

LONG_OK = False
