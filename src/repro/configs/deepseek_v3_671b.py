"""DeepSeek-V3 671B  [arXiv:2412.19437; hf deepseek-ai/DeepSeek-V3].

61 layers (first 3 dense, 58 MoE), d_model 7168, 128 heads, MLA
(q_lora 1536, kv_lora 512, rope 64, nope 128, v 128), dense FFN 18432,
MoE: 1 shared + 256 routed experts of width 2048, top-8, vocab 129 280,
multi-token prediction depth 1.

Documented simplifications (systems-neutral; DESIGN.md §5):
  * softmax top-8 routing stands in for sigmoid + group-limited top-k;
  * the aux-loss-free bias update is not modelled.
"""
from repro.models.config import (AttnConfig, MLAConfig, ModelConfig,
                                 MoEConfig)

N_DENSE = 3
N_LAYERS = 61

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    d_model=7168,
    n_layers=N_LAYERS,
    vocab_size=129_280,
    d_ff=18_432,                       # the 3 leading dense layers
    layer_program=("attn_dense",) * N_DENSE +
                  ("attn_moe",) * (N_LAYERS - N_DENSE),
    attn=AttnConfig(n_heads=128, n_kv_heads=128, head_dim=128,
                    rope_theta=10_000.0),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1),
    act="swiglu",
    tie_embeddings=False,
    mtp_depth=1,
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    d_model=64,
    n_layers=4,
    vocab_size=512,
    d_ff=160,
    layer_program=("attn_dense",) + ("attn_moe",) * 3,
    attn=AttnConfig(n_heads=8, n_kv_heads=8, head_dim=8),
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                  rope_head_dim=8, nope_head_dim=16, v_head_dim=16),
    # capacity_factor = E/K ⇒ cap ≥ T ⇒ provably dropless (an expert can
    # receive at most T assignments) — smoke tests pin exact equalities.
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=1,
                  capacity_factor=4.0),
    act="swiglu",
    tie_embeddings=False,
    mtp_depth=1,
)

LONG_OK = False    # full attention at every layer
