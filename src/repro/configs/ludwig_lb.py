"""The paper's own application: Ludwig D3Q19 binary-fluid benchmark.

Grid/production sizes follow the Ludwig GPU-scaling papers ([2][3] in the
paper): ~128³ per device.  The benchmark config is what
``benchmarks/run.py`` sweeps (paper Fig. 1); the production config is the
dry-run / multi-pod slab-decomposition cell.
"""
from dataclasses import dataclass

from repro.lb.params import LBParams


@dataclass(frozen=True)
class LudwigConfig:
    grid_shape: tuple
    params: LBParams = LBParams()
    vvl: int = 128
    backend: str = "xla"


# paper Fig. 1 benchmark scale (single device, CPU-measurable)
BENCH = LudwigConfig(grid_shape=(64, 64, 64))

# smoke scale
SMOKE = LudwigConfig(grid_shape=(8, 8, 8), vvl=32)

# production slab per 256-chip pod: X sharded 16-way, Y 16-way
PRODUCTION = LudwigConfig(grid_shape=(512, 512, 256))
