"""Training launcher.

Single entry point for every scale:

  # laptop / CI smoke (1 device, reduced config)
  python -m repro.launch.train --arch gemma2-2b --smoke --steps 50

  # production pod (real TPU runtime provides the devices; the same flags
  # drive the 512-chip multi-pod mesh)
  python -m repro.launch.train --arch deepseek-v3-671b --mesh single \
      --steps 10000 --ckpt-dir /ckpt/ds671b

The restart loop (fault tolerance) is inside ``Trainer.run``: on peer
failure it reloads the newest checkpoint — elastic across mesh sizes —
and the stateless data pipeline resumes from the same global step.
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--mesh", default="none",
                    choices=("none", "single", "multi", "test"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--peak-lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--quant-moments", action="store_true")
    ap.add_argument("--compress-pod", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    # mesh selection must precede any jax device use only for the
    # placeholder-device dry-run; real runtimes provide devices natively.
    from repro import configs as C
    from repro.data import SyntheticConfig
    from repro.optim import AdamWConfig
    from repro.runtime import Trainer, TrainerConfig, TrainHParams

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)

    mesh = None
    if args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    elif args.mesh == "test":
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh()

    data = SyntheticConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                           global_batch=args.global_batch, seed=args.seed)
    hp = TrainHParams(peak_lr=args.peak_lr, warmup_steps=args.warmup,
                      total_steps=args.steps, grad_accum=args.grad_accum,
                      compress_pod=args.compress_pod)
    opt = AdamWConfig(quantize_moments=args.quant_moments)
    tc = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       hb_dir=args.ckpt_dir + "/hb", seed=args.seed)

    trainer = Trainer(cfg, mesh, data, opt, hp, tc)
    hist = trainer.run(args.steps)
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} at step {hist[-1]['step']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
