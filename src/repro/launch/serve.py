"""Serving launcher: batched prefill + decode with a KV/state cache.

  python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 32 --gen 16

Serving-path features exercised here: cache padding to a decode budget,
greedy/temperature sampling, sequence-sharded decode when a mesh is
present (``--mesh test`` on N fake devices), per-request latency stats.
"""
from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mesh", default="none",
                    choices=("none", "single", "multi", "test"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs as C
    from repro.models.context import ExecContext
    from repro.models import params as params_lib
    from repro.runtime.steps import build_serve_steps

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    mesh = None
    if args.mesh == "test":
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh()
    elif args.mesh in ("single", "multi"):
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))

    batch_axes = tuple(a for a in ("pod", "data")
                       if mesh is not None and a in mesh.axis_names)
    ctx = ExecContext(
        mesh=mesh, batch_axes=batch_axes,
        model_axis=("model" if mesh is not None else None),
        seq_shard_decode=mesh is not None)

    key = jax.random.PRNGKey(args.seed)
    params, _ = params_lib.init_params(cfg, key, jnp.float32)

    b, s = args.batch, args.prompt_len
    rng = np.random.default_rng(args.seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.is_encdec:
        batch["audio_embed"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder.n_frames, cfg.d_model)),
            jnp.float32)
    if cfg.vision_stub:
        slot = -np.ones((b, s), np.int32)
        slot[:, : min(4, s)] = np.arange(min(4, s))
        batch["vision_embed"] = jnp.asarray(
            rng.normal(size=(b, 8, cfg.d_model)), jnp.float32)
        batch["vision_slot"] = jnp.asarray(slot)
    if cfg.pos_embed == "mrope":
        batch["positions3"] = jnp.tile(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, 1))

    max_len = s + args.gen
    prefill_step, decode_step = build_serve_steps(
        cfg, ctx, max_len=max_len, temperature=args.temperature)
    prefill_step = jax.jit(prefill_step)
    decode_step = jax.jit(decode_step, donate_argnums=(2,))

    t0 = time.monotonic()
    tok, caches, length, _ = prefill_step(params, batch, key)
    jax.block_until_ready(tok)
    t_prefill = time.monotonic() - t0

    out = [np.asarray(tok)]
    t1 = time.monotonic()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        if cfg.pos_embed == "mrope":
            # decode positions continue along all three M-RoPE axes
            pass
        tok, caches, length = decode_step(params, tok, caches, length, sub)
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.monotonic() - t1

    gen = np.concatenate(out, axis=1)
    print(f"prefill: {b}×{s} tokens in {t_prefill*1e3:.1f} ms")
    print(f"decode:  {args.gen-1} steps in {t_decode*1e3:.1f} ms "
          f"({t_decode/(max(args.gen-1,1))*1e3:.2f} ms/tok/batch)")
    print("sample continuations (token ids):")
    for r in range(min(b, 4)):
        print(f"  req{r}: {gen[r][:16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
