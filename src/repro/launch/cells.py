"""Cell builder: (arch × shape × mesh × variant) → AOT-lowerable closure.

One function, :func:`build_cell`, assembles everything a dry-run /
roofline pass needs:

  * the step function (train_step / prefill / decode_step),
  * abstract arguments (ShapeDtypeStructs — nothing allocates),
  * in/out shardings from the rules engine,
  * bookkeeping (param counts, MODEL_FLOPS estimate for §Roofline).

``Variant`` carries every §Perf tuning knob so hillclimb candidates are
*data* (recorded in EXPERIMENTS.md) rather than code edits.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as configs_lib
from repro.models import lm, params as params_lib
from repro.models.config import ModelConfig
from repro.models.context import ExecContext
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.steps import TrainHParams, build_train_step
from repro.sharding import make_plan, sharding_for_tree, spec_for_axes


@dataclass(frozen=True)
class Variant:
    """Tuning knobs for one lowering (the §Perf search space)."""
    name: str = "baseline"
    # train
    grad_accum: int = 16
    remat: str = "block"
    fsdp: bool = True
    quantize_moments: bool = False
    compress_pod: bool = False
    param_dtype: str = "bfloat16"
    # attention / kernels
    attn_impl: str = "chunked"
    attn_block_q: int = 512
    seq_parallel_attn: bool = True
    seq_sharded_residual: bool = False
    # moe
    moe_impl: str = "capacity"
    # decode
    seq_shard_decode: bool = True
    seq_over_data: bool = False         # batch-1 decode: KV seq over
                                        # (data×model) under pure GSPMD
    cache_dtype: str = "bfloat16"
    local_ring_cache: bool = False      # window-sized cache for local layers

    def with_(self, **kw) -> "Variant":
        return replace(self, **kw)


BASELINE = Variant()


@dataclass
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    kind: str
    fn: Any                      # the function to jit
    args: tuple                  # abstract args
    in_shardings: Any
    out_shardings: Any
    donate: tuple
    model_flops: float           # 6·N(,active)·D per step
    note: str = ""


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

def _batch_shardings(batch_specs: dict, mesh: Mesh, batch_axes) -> dict:
    """tokens/labels/stub tensors: dim0 = batch → batch_axes (positions3 has
    batch on dim1)."""
    out = {}
    for k, v in batch_specs.items():
        if k == "positions3":
            spec = P(None, batch_axes)
        else:
            spec = P(batch_axes)
        out[k] = NamedSharding(mesh, spec)
    return out


def _cache_shardings(caches, cfg: ModelConfig, mesh: Mesh, batch_axes,
                     model_axis: Optional[str], seq_shard: bool,
                     seq_over_data: bool = False):
    """Walk the cache pytree (group-list structure with named leaves).

    ``seq_over_data``: when the request batch can't shard the data axis
    (long_500k decodes batch=1), the otherwise-idle data axis joins the
    model axis on the *sequence* dim — a 500k KV cache then spreads over
    all 256 chips instead of 16.  GSPMD partitions the score/value
    contractions over S and inserts the exact psums (the shard_map
    flash-decode path serves the model-axis-only layout)."""

    def mk(spec_dims):
        # drop axes that don't divide (replicate instead)
        return NamedSharding(mesh, P(*spec_dims))

    def seq_axes_for(extent, baxes):
        if not seq_shard or model_axis is None:
            return None
        if seq_over_data and baxes is None and batch_axes:
            combined = tuple(batch_axes) + (model_axis,)
            if extent % _axes_size(mesh, combined) == 0:
                return combined
        if extent % mesh.shape[model_axis] == 0:
            return model_axis
        return None

    def leaf(name, t):
        shp = t.shape
        bdim = 1                       # (k, B, ...)
        baxes = batch_axes if batch_axes and \
            shp[bdim] % _axes_size(mesh, batch_axes) == 0 else None
        if name in ("k", "v", "xk", "xv"):      # (k,B,Hkv,S,dh)
            return mk((None, baxes, None, seq_axes_for(shp[3], baxes), None))
        if name in ("c_kv", "k_rope"):          # (k,B,S,R)
            return mk((None, baxes, seq_axes_for(shp[2], baxes), None))
        # conv/ssm states: (k,B,...) — batch only
        return mk((None, baxes) + (None,) * (len(shp) - 2))

    def walk(c):
        if isinstance(c, dict):
            return {k: (walk(v) if isinstance(v, dict) else leaf(k, v))
                    for k, v in c.items()}
        if isinstance(c, list):
            return [walk(v) for v in c]
        return c

    return walk(caches)


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def _replicated(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the roofline's useful-compute numerator)
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, kind: str, batch: int, seq: int) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) per step; decode D = batch·1."""
    n = cfg.active_params() if cfg.moe is not None else cfg.num_params()
    if kind == "train":
        return 6.0 * n * batch * seq
    if kind == "prefill":
        return 2.0 * n * batch * seq
    return 2.0 * n * batch          # decode: one token per sequence


# ---------------------------------------------------------------------------
# the builder
# ---------------------------------------------------------------------------

def build_cell(arch: str, shape: str, mesh: Mesh,
               variant: Variant = BASELINE) -> Cell:
    cfg = configs_lib.get_config(arch)
    cell_def = configs_lib.SHAPES[shape]
    kind = cell_def.kind
    specs = configs_lib.input_specs(
        arch, shape, cache_dtype=jnp.dtype(variant.cache_dtype),
        local_ring=variant.local_ring_cache)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model_axis = "model" if "model" in mesh.axis_names else None
    dtype = jnp.dtype(variant.param_dtype)

    ctx = ExecContext(
        backend="xla", mesh=mesh, batch_axes=batch_axes,
        model_axis=model_axis,
        remat=variant.remat if kind == "train" else "none",
        attn_impl=variant.attn_impl, attn_block_q=variant.attn_block_q,
        seq_parallel_attn=variant.seq_parallel_attn,
        seq_sharded_residual=variant.seq_sharded_residual,
        moe_impl=variant.moe_impl,
        # seq_over_data uses plain GSPMD partitioning of the decode
        # contraction instead of the model-axis shard_map flash path
        seq_shard_decode=(variant.seq_shard_decode and kind == "decode"
                          and not variant.seq_over_data),
    )

    key = jax.random.PRNGKey(0)
    # eval_shape the params; the axes twin (string tuples) rides out via
    # closure — it is deterministic metadata, not traced values.
    axes_box = {}

    def _init_p(k):
        p, ax = params_lib.init_params(cfg, k, dtype)
        axes_box["ax"] = ax
        return p

    pshape = jax.eval_shape(_init_p, key)
    axes = axes_box["ax"]
    plan = make_plan(cfg, mode="train" if kind == "train" else "serve",
                     fsdp=variant.fsdp, moe_impl=variant.moe_impl)
    pshard = sharding_for_tree(axes, plan, mesh)

    mf = model_flops(cfg, kind, cell_def.global_batch, cell_def.seq_len)

    if kind == "train":
        opt_cfg = AdamWConfig(quantize_moments=variant.quantize_moments)
        hp = TrainHParams(grad_accum=variant.grad_accum,
                          compress_pod=variant.compress_pod)
        step = build_train_step(cfg, ctx, opt_cfg, hp)
        oshape = jax.eval_shape(functools.partial(adamw_init, cfg=opt_cfg),
                                pshape)
        if variant.quantize_moments:
            oshard = _qtensor_shardings(oshape, pshard, mesh)
        else:
            oshard = {"m": pshard, "v": pshard,
                      "step": NamedSharding(mesh, P())}
        bshard = _batch_shardings(specs["batch"], mesh, batch_axes)
        args = (pshape, oshape, specs["batch"])
        in_sh = (pshard, oshard, bshard)
        if variant.compress_pod:
            efshape = jax.eval_shape(
                lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.dtype(hp.ef_dtype)), p),
                pshape)
            args = args + (efshape,)
            in_sh = in_sh + (pshard,)
            out_sh = (pshard, oshard,
                      _replicated(jax.eval_shape(step, *args)[2], mesh),
                      pshard)
        else:
            out_sh = (pshard, oshard, _replicated(
                jax.eval_shape(step, *args)[2], mesh))
        return Cell(arch, shape, cfg, kind, step, args, in_sh, out_sh,
                    donate=(0, 1), model_flops=mf)

    if kind == "prefill":
        def prefill_fn(params, batch):
            logits, caches, _ = lm.prefill(params, batch, cfg, ctx)
            return logits, caches
        bshard = _batch_shardings(specs["batch"], mesh, batch_axes)
        out_shape = jax.eval_shape(prefill_fn, pshape, specs["batch"])
        cache_sh = _cache_shardings(out_shape[1], cfg, mesh, batch_axes,
                                    model_axis, variant.seq_shard_decode)
        out_sh = (NamedSharding(mesh, P(batch_axes, None, model_axis)),
                  cache_sh)
        return Cell(arch, shape, cfg, kind, prefill_fn,
                    (pshape, specs["batch"]), (pshard, bshard), out_sh,
                    donate=(), model_flops=mf)

    # decode
    pos3 = specs.get("positions3")

    def decode_fn(params, token, caches, length, positions3=None):
        logits, new_caches = lm.decode_step(params, token, caches, length,
                                            cfg, ctx, positions3=positions3)
        return logits, new_caches

    cache_sh = _cache_shardings(specs["caches"], cfg, mesh, batch_axes,
                                model_axis,
                                variant.seq_shard_decode or
                                variant.seq_over_data,
                                seq_over_data=variant.seq_over_data)
    tok_sh = NamedSharding(
        mesh, P(batch_axes if cell_def.global_batch %
                _axes_size(mesh, batch_axes) == 0 else None))
    args = [pshape, specs["token"], specs["caches"], specs["length"]]
    in_sh = [pshard, tok_sh, cache_sh, NamedSharding(mesh, P())]
    if pos3 is not None:
        args.append(pos3)
        in_sh.append(NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(None, None, model_axis)), cache_sh)
    return Cell(arch, shape, cfg, kind, decode_fn, tuple(args),
                tuple(in_sh), out_sh, donate=(2,), model_flops=mf)


def _qtensor_shardings(oshape, pshard, mesh: Mesh):
    """8-bit moments inherit the parameter's sharding: codes are shape-
    identical to the param; scales drop the (blocked) last-axis entry."""
    from repro.optim.quant import QTensor

    def one(q, psh):
        if not isinstance(q, QTensor):
            return NamedSharding(mesh, P())
        spec = psh.spec
        dims = list(spec) + [None] * (q.codes.ndim - len(spec))
        scale_dims = dims[:-1] if q.codes.ndim else []
        # scale's last (block) axis replicates unless divisible
        if q.scale.ndim == len(scale_dims) + 1:
            scale_dims = scale_dims + [None]
        return QTensor(NamedSharding(mesh, P(*dims)),
                       NamedSharding(mesh, P(*scale_dims)))

    is_q = lambda x: isinstance(x, QTensor)
    return {"m": jax.tree.map(one, oshape["m"], pshard, is_leaf=is_q),
            "v": jax.tree.map(one, oshape["v"], pshard, is_leaf=is_q),
            "step": NamedSharding(mesh, P())}
