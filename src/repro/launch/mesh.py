"""Production mesh factories.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax
call; smoke tests must keep seeing 1 device).

Topology (TPU v5e pods):
  single-pod  (16, 16)    → ("data", "model")      256 chips, all-ICI
  multi-pod   (2, 16, 16) → ("pod", "data", "model")  512 chips; the
              leading ``pod`` axis is the DCN hop (pure DP + optionally
              compressed gradient reduction — DESIGN.md §6).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.core import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over however many (fake) devices a test process has."""
    return compat.make_mesh(shape, axes)
