"""Static analysis of post-SPMD HLO: trip-count-exact FLOPs, HBM traffic,
and collective bytes.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
``while`` body **once**, so anything under ``lax.scan`` (layer stacks,
grad-accumulation, chunked attention) is undercounted by its trip count —
for a 61-layer × 16-microbatch step that is a ~1000× error.  The compiled
HLO text, however, carries ``backend_config={"known_trip_count":{"n":...}}``
on every scan-derived while loop, so an exact account is a parse away:

  1. split the module into computations; index every instruction's output
     shape(s) by name;
  2. build the call graph (while body/condition, fusion ``calls``,
     ``to_apply``, conditional branches) and propagate a *multiplier* =
     Σ over call sites of (caller multiplier × trip count);
  3. FLOPs: every ``dot`` = 2 · prod(output) · K (K = lhs contracting
     extents) × multiplier.  (Elementwise FLOPs are ignored — matmuls
     dominate every cell here; noted in EXPERIMENTS.md.)
  4. HBM traffic: Σ (operand bytes + output bytes) over instructions in
     non-fusion computations × multiplier (a fusion is one kernel: its
     internals live in registers/VMEM; its call site counts).  Aliasing
     ops (bitcast/tuple/get-tuple-element/parameter/constant) are free.
  5. collectives: operand bytes × multiplier, plus a per-chip *wire-byte*
     estimate from ring algorithms using the replica-group size S:
        all-gather   operand·(S-1)        (operand = one shard)
        reduce-scatter operand·(S-1)/S
        all-reduce   2·operand·(S-1)/S
        all-to-all   operand·(S-1)/S
        collective-permute operand
     Groups are classified ICI vs DCN ("pod" axis) by their device stride:
     on the (pod, data, model) mesh, pod-axis groups have stride 256.

All shapes in the post-partitioning module are per-chip shard shapes, so
every number this module emits is per-chip.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "iota"}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(\([^=]*?\)|\w+\[[0-9,]*\](?:\{[^}]*\})?)"
    r"\s+([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\]"
                        r"(?:T\(([0-9,]+)\))?")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_list(text: str) -> list[tuple[str, tuple[int, ...]]]:
    """All dtype[dims] shapes in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Instr:
    name: str
    out_shapes: list
    opcode: str
    operands: list
    line: str


@dataclass
class Computation:
    name: str
    instrs: dict = field(default_factory=dict)
    order: list = field(default_factory=list)


def parse_module(text: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if cur is None:
            # computation headers sit at column 0:
            #   %name (args...) -> type {     /  ENTRY %name (...) -> ... {
            if (line.startswith("%") or line.startswith("ENTRY")) and \
                    line.rstrip().endswith("{") and "->" in line:
                is_entry = line.startswith("ENTRY")
                tok = line.split()[1] if is_entry else line.split()[0]
                cur = Computation(tok.lstrip("%"))
                comps[cur.name] = cur
                if is_entry:
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs[ins.name] = ins
            cur.order.append(ins.name)
    return comps, entry


def _parse_instr(line: str):
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") and not s[:1].isalpha():
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[:eq].lstrip("%")
    rest = s[eq + 3:]
    # type: either a balanced-paren tuple (may contain /*index=N*/ comments)
    # or dtype[dims]{layout}
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        typ, rest2 = rest[:i + 1], rest[i + 1:]
    else:
        m = re.match(r"\w+\[[0-9,]*\](?:\{[^}]*\})?", rest)
        if not m:
            return None
        typ, rest2 = m.group(0), rest[m.end():]
    rest2 = rest2.lstrip()
    mo = re.match(r"([\w\-]+)\(", rest2)
    if not mo:
        return None
    opcode = mo.group(1)
    paren = rest2.find("(", mo.start())
    depth = 0
    for i in range(paren, len(rest2)):
        if rest2[i] == "(":
            depth += 1
        elif rest2[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operands = _OPERAND_RE.findall(rest2[paren:i + 1])
    return Instr(name, _shape_list(typ), opcode, operands, line)


def _call_edges(comp: Computation):
    """[(callee_name, factor, kind)] for one computation."""
    edges = []
    for iname in comp.order:
        ins = comp.instrs[iname]
        line = ins.line
        if ins.opcode == "while":
            trip = 1
            mt = _TRIP_RE.search(line)
            if mt:
                trip = int(mt.group(1))
            for key in ("body=", "condition="):
                k = line.find(key)
                if k >= 0:
                    nm = re.match(r"%?([\w.\-]+)", line[k + len(key):].lstrip("%"))
                    if nm:
                        edges.append((nm.group(1), trip,
                                      "while_" + key[:-1]))
        else:
            for key, kind in (("calls=", "fusion"), ("to_apply=", "apply"),
                              ("branch_computations={", "cond"),
                              ("body=", "body"), ("condition=", "condition")):
                k = line.find(key)
                if k < 0:
                    continue
                tail = line[k + len(key):]
                if key.endswith("{"):
                    names = re.findall(r"%([\w.\-]+)", tail[:tail.find("}")])
                    for nm in names:
                        edges.append((nm, 1, kind))
                else:
                    nm = re.match(r"%?([\w.\-]+)", tail.lstrip("%"))
                    if nm:
                        edges.append((nm.group(1), 1, kind))
    return edges


def _multipliers(comps, entry):
    mult = defaultdict(float)
    mult[entry] = 1.0
    # topological: repeatedly relax (call graph is a DAG in HLO)
    edges = {c: _call_edges(comp) for c, comp in comps.items()}
    order = []
    seen = set()

    def dfs(c):
        if c in seen:
            return
        seen.add(c)
        for callee, _, _ in edges.get(c, ()):  # post-order
            dfs(callee)
        order.append(c)

    dfs(entry)
    for c in reversed(order):                  # callers before callees
        for callee, factor, _ in edges.get(c, ()):
            mult[callee] += mult[c] * factor
    fusion_like = {callee for c in comps for callee, _, kind in edges[c]
                   if kind in ("fusion", "apply")}
    return mult, fusion_like


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for _, dims in ins.out_shapes:
        for d in dims:
            out_elems *= d
    k = 1
    mc = _CONTRACT_RE.search(ins.line)
    if mc and ins.operands:
        lhs = comp.instrs.get(ins.operands[0])
        if lhs is not None and lhs.out_shapes:
            shape = lhs.out_shapes[0][1]
            for idx in (int(i) for i in mc.group(1).split(",") if i):
                if idx < len(shape):
                    k *= shape[idx]
    return 2.0 * out_elems * k


def _group_size_and_kind(line: str, pod_stride: int = 256):
    """(group_size, dcn_fraction).

    A group *spans* pods when its member span (stride·(size−1)) reaches
    the pod stride; a ring over such a group crosses the DCN boundary
    ``span // pod_stride`` times out of ``size−1`` hops — that fraction
    of the wire bytes rides DCN, the rest ICI.  Pure-pod groups (stride
    = pod_stride) give fraction 1."""
    def frac(stride, gsize):
        if gsize <= 1:
            return 0.0
        span = stride * (gsize - 1)
        crossings = span // pod_stride
        return min(1.0, crossings / (gsize - 1))

    m = _GROUPS_RE.search(line)
    if m:
        iota = [int(x) for x in m.group(3).split(",")]
        gsize = int(m.group(2))
        # transposed iota ⇒ group members stride by the trailing iota dims
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            strides = 1
            for d in perm[1:]:
                strides *= iota[d]
            stride = strides
        else:
            stride = 1
        return gsize, frac(stride, gsize)
    m2 = _GROUPS_LIST_RE.search(line)
    if m2:
        members = [int(x) for x in m2.group(1).split(",")]
        gsize = len(members)
        stride = abs(members[1] - members[0]) if gsize > 1 else 1
        return gsize, frac(stride, gsize)
    return 1, 0.0


def _operand_nbytes(ins: Instr, comp: Computation, idx: int) -> int:
    if idx >= len(ins.operands):
        return 0
    o = comp.instrs.get(ins.operands[idx])
    return _nbytes(o.out_shapes) if o is not None else 0


def _fusion_param_read(callee: Computation, pidx: int, full: int) -> int:
    """Bytes a fusion actually reads of parameter ``pidx``.

    If every consumer of the parameter inside the fusion is a windowed
    read (dynamic-slice / slice / gather), charge the windows, not the
    whole tensor — scan bodies dynamic-slice one layer out of the stacked
    parameters *inside* a fusion, and charging the stack per iteration is
    a ~10× traffic overcount (measured on the granite cell).
    """
    pname = None
    consumers = []
    for iname in callee.order:
        ins = callee.instrs[iname]
        if ins.opcode == "parameter" and ins.line.strip().split(" = ")[0] \
                .lstrip("%").startswith(f"param_{pidx}"):
            pname = ins.name
            break
    if pname is None:
        # fall back: parameters are in order
        params = [i for i in callee.order
                  if callee.instrs[i].opcode == "parameter"]
        if pidx < len(params):
            pname = params[pidx]
    if pname is None:
        return full
    windowed = 0
    for iname in callee.order:
        ins = callee.instrs[iname]
        if pname in ins.operands:
            consumers.append(ins)
    if not consumers:
        return 0
    for ins in consumers:
        if ins.opcode in ("dynamic-slice", "slice", "gather"):
            windowed += _nbytes(ins.out_shapes)
        elif ins.opcode == "dynamic-update-slice" and \
                ins.operands and ins.operands[0] == pname:
            windowed += _operand_nbytes(ins, callee, 1)  # aliased update
        else:
            return full
    return windowed


def _read_bytes(ins: Instr, comp: Computation, out_bytes: int,
                comps=None) -> int:
    """Bytes actually *read* by an instruction.

    Sliced/gathered reads touch only the addressed window, not the whole
    operand.  In-place updates (dynamic-update-slice / scatter) read+write
    only the update window; XLA aliases the rest.  Fusion call sites defer
    to :func:`_fusion_param_read` per operand.
    """
    op = ins.opcode
    if op in ("dynamic-slice", "slice", "gather"):
        return out_bytes
    if op == "dynamic-update-slice":
        return _operand_nbytes(ins, comp, 1)         # the update window
    if op == "scatter":
        return (_operand_nbytes(ins, comp, 1) +      # indices
                2 * _operand_nbytes(ins, comp, 2))   # updates read+write
    if op == "fusion" and comps is not None:
        mcall = re.search(r"calls=%?([\w.\-]+)", ins.line)
        callee = comps.get(mcall.group(1)) if mcall else None
        if callee is not None:
            total = 0
            for i in range(len(ins.operands)):
                total += _fusion_param_read(callee, i,
                                            _operand_nbytes(ins, comp, i))
            return total
    total = 0
    for i in range(len(ins.operands)):
        total += _operand_nbytes(ins, comp, i)
    return total


_WIRE = {
    "all-gather": lambda b, s: b * (s - 1),
    "reduce-scatter": lambda b, s: b * (s - 1) / s,
    "all-reduce": lambda b, s: 2 * b * (s - 1) / s,
    "all-to-all": lambda b, s: b * (s - 1) / s,
    "collective-permute": lambda b, s: b,
}


def analyze(text: str, *, pod_stride: int = 256) -> dict:
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found")
    mult, fusion_like = _multipliers(comps, entry)

    flops = 0.0
    traffic = 0.0
    coll = {op: {"operand_bytes": 0.0, "wire_bytes_ici": 0.0,
                 "wire_bytes_dcn": 0.0, "count": 0} for op in _COLLECTIVES}

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_like
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op == "dot":
                flops += m * _dot_flops(ins, comp)
            if in_fusion:
                continue                      # fused internals: no traffic
            if op.endswith("-done") or op in _FREE_OPS or op == "while":
                continue
            out_bytes = _nbytes(ins.out_shapes)
            if op == "dynamic-update-slice":       # in-place: writes window
                out_bytes = _operand_nbytes(ins, comp, 1)
            elif op == "scatter":
                out_bytes = 0                      # counted in _read_bytes
            operand_bytes = _read_bytes(ins, comp, out_bytes, comps)
            traffic += m * (operand_bytes + out_bytes)
            if base in _COLLECTIVES:
                gsize, dcn_frac = _group_size_and_kind(ins.line, pod_stride)
                c = coll[base]
                c["operand_bytes"] += m * operand_bytes
                wire = m * _WIRE[base](operand_bytes, max(gsize, 1))
                c["wire_bytes_dcn"] += wire * dcn_frac
                c["wire_bytes_ici"] += wire * (1.0 - dcn_frac)
                c["count"] += m
    total_ici = sum(c["wire_bytes_ici"] for c in coll.values())
    total_dcn = sum(c["wire_bytes_dcn"] for c in coll.values())
    return {
        "flops": flops,
        "traffic_bytes": traffic,
        "collectives": coll,
        "wire_bytes_ici": total_ici,
        "wire_bytes_dcn": total_dcn,
        "n_computations": len(comps),
    }


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=1))
