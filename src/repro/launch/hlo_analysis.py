"""Retired into :mod:`repro.core.costmodel` — import shim.

The trip-count-exact HLO walker now lives in the cost-model subsystem
(it is the ``source="hlo"`` predictor backend of ``tdp.costmodel``).
This module re-exports the public surface so existing imports and the
``python -m repro.launch.hlo_analysis`` CLI keep working.
"""
from repro.core.costmodel import (  # noqa: F401
    _DTYPE_BYTES,
    _COLLECTIVES,
    _FREE_OPS,
    _WIRE,
    Computation,
    Instr,
    _call_edges,
    _dot_flops,
    _fusion_param_read,
    _group_size_and_kind,
    _multipliers,
    _nbytes,
    _operand_nbytes,
    _parse_instr,
    _read_bytes,
    _shape_list,
    analyze,
    parse_module,
)

if __name__ == "__main__":
    import json
    import sys
    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=1))
