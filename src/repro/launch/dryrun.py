import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# The two lines above MUST run before any other import (jax locks the
# device count at first initialisation).  Do not reorder.
"""Multi-pod AOT dry-run.

For every (architecture × input-shape × mesh) cell:
``jax.jit(step, in_shardings, out_shardings).lower(*abstract).compile()``
on 512 placeholder CPU devices, then record

  * ``memory_analysis()``   — per-chip argument/output/temp bytes (fits?),
  * ``cost_analysis()``     — HLO FLOPs + bytes for §Roofline,
  * collective bytes parsed from the post-SPMD HLO (per opcode),
  * wall compile time,

into one JSON per cell under ``results/dryrun/`` (resumable cache — rerun
skips completed cells unless --force).

Usage:
  python -m repro.launch.dryrun --mesh both                  # all cells
  python -m repro.launch.dryrun --arch gemma3-27b --shape decode_32k \
         --mesh single --variant baseline
"""
import argparse
import dataclasses
import json
import time
import traceback

# the static HLO analysis (collective byte scan + trip-count-exact
# walker) lives in the cost-model subsystem now; stdlib-only import, so
# it is safe before jax initialises
from repro.core.costmodel import analyze, collective_bytes  # noqa: F401


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    d = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            d[k] = int(v)
    if not d:
        d["repr"] = str(mem)
    return d


def run_cell(arch: str, shape: str, mesh_name: str, variant, out_dir: str,
             force: bool = False) -> dict:
    """Build, lower, compile, analyse one cell.  Returns the record."""
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch.cells import build_cell

    vtag = variant.name
    fname = f"{arch}__{shape}__{mesh_name}__{vtag}.json".replace("/", "_")
    path = os.path.join(out_dir, fname)
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            return rec

    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "variant": dataclasses.asdict(variant),
           "n_devices": mesh.devices.size}
    t0 = time.monotonic()
    try:
        cell = build_cell(arch, shape, mesh, variant)
        rec["model_flops"] = cell.model_flops
        rec["kind"] = cell.kind
        jfn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                      out_shardings=cell.out_shardings,
                      donate_argnums=cell.donate)
        lowered = jfn.lower(*cell.args)
        rec["lower_s"] = time.monotonic() - t0
        t1 = time.monotonic()
        compiled = lowered.compile()
        rec["compile_s"] = time.monotonic() - t1
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per program
            ca = ca[0] if ca else {}
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float)) and
                                not k.startswith(("utilization",
                                                  "bytes accessed"))}
        rec["memory_analysis"] = _mem_dict(compiled.memory_analysis())
        hlo_text = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo_text)
        # trip-count-exact static analysis (XLA's cost_analysis counts scan
        # bodies once — see the repro.core.costmodel walker docstring)
        rec["hlo_analysis"] = analyze(hlo_text)
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — recorded, reported, non-zero exit
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.monotonic() - t0
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def iter_cells(archs, shapes, meshes):
    from repro import configs as C
    for arch in archs:
        for shape, skip in C.applicable_cells(arch):
            if shapes and shape not in shapes:
                continue
            if skip:
                yield arch, shape, None, skip
                continue
            for mesh_name in meshes:
                yield arch, shape, mesh_name, ""


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=("single", "multi",
                                                       "both"))
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", nargs="*", default=[],
                    help="variant overrides, e.g. --set grad_accum=8 "
                         "fsdp=false attn_impl=ref")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    from repro import configs as C
    from repro.launch.cells import Variant

    archs = list(C.ARCHS) if args.arch == "all" else \
        [C.ALIASES.get(args.arch, args.arch)]
    shapes = None if args.shape == "all" else {args.shape}
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        fld = {f.name: f for f in dataclasses.fields(Variant)}[k]
        if fld.type in ("int",):
            v = int(v)
        elif fld.type in ("bool",):
            v = v.lower() in ("1", "true", "yes")
        overrides[k] = v
    variant = Variant(name=args.variant, **overrides) \
        if overrides else Variant(name=args.variant)

    plan = list(iter_cells(archs, shapes, meshes))
    if args.list:
        for arch, shape, mesh_name, skip in plan:
            print(f"{arch:24s} {shape:12s} "
                  f"{mesh_name or '-':7s} {'SKIP: ' + skip if skip else ''}")
        return 0

    failures = 0
    for arch, shape, mesh_name, skip in plan:
        if skip:
            print(f"[dryrun] {arch} × {shape}: SKIP ({skip.split('(')[0]})",
                  flush=True)
            continue
        print(f"[dryrun] {arch} × {shape} × {mesh_name} "
              f"[{variant.name}] ...", flush=True)
        rec = run_cell(arch, shape, mesh_name, variant, args.out,
                       force=args.force)
        if rec["status"] == "ok":
            ha = rec["hlo_analysis"]
            mem = rec["memory_analysis"]
            per_dev = (mem.get("argument_size_in_bytes", 0) +
                       mem.get("temp_size_in_bytes", 0))
            print(f"  ok in {rec['total_s']:.1f}s  "
                  f"TF/dev={ha['flops']/1e12:.2f}  "
                  f"mem/dev={per_dev/2**30:.2f}GiB  "
                  f"traffic={ha['traffic_bytes']/2**30:.1f}GiB  "
                  f"ici={ha['wire_bytes_ici']/2**30:.2f}GiB "
                  f"dcn={ha['wire_bytes_dcn']/2**30:.2f}GiB",
                  flush=True)
        else:
            failures += 1
            print(f"  ERROR: {rec['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
