"""Parameter initialisation + logical-axis metadata.

``init_params(cfg, key, dtype)`` returns ``(params, axes)``: twin pytrees
where every array leaf in ``params`` has a tuple of logical axis names in
``axes`` (e.g. ``("layers", "d_model", "heads")``).  The sharding rules
engine (:mod:`repro.sharding.rules`) maps logical names → mesh axes; the
``"layers"`` axis is the scan-stack dimension and is never sharded.

Layer-group stacking: params for a scan group with unit ``(t0, t1, ...)``
and ``k`` repeats are stored as ``groups[i] = [per-position params]`` with
every leaf stacked to leading extent ``k`` (``k=1`` groups are still
stacked, keeping one code path).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, MoEConfig, SSMConfig, plan_layer_groups

Axes = tuple[Optional[str], ...]


def _dense(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


class _KeyGen:
    def __init__(self, key):
        self.key = key

    def __call__(self):
        self.key, sub = jax.random.split(self.key)
        return sub


# ---------------------------------------------------------------------------
# per-block parameter builders (params, axes) — structure must match blocks.py
# ---------------------------------------------------------------------------

def _norm_param(d, dtype):
    return jnp.zeros((d,), dtype), ("d_model",)


def _attn_params(cfg: ModelConfig, kg, dtype):
    a = cfg.attn
    d = cfg.d_model
    p, ax = {}, {}
    p["wq"] = _dense(kg(), (d, a.n_heads * a.head_dim), dtype)
    ax["wq"] = ("d_model", "heads_x_dim")
    p["wk"] = _dense(kg(), (d, a.n_kv_heads * a.head_dim), dtype)
    ax["wk"] = ("d_model", "kv_x_dim")
    p["wv"] = _dense(kg(), (d, a.n_kv_heads * a.head_dim), dtype)
    ax["wv"] = ("d_model", "kv_x_dim")
    p["wo"] = _dense(kg(), (a.n_heads * a.head_dim, d), dtype)
    ax["wo"] = ("heads_x_dim", "d_model")
    if a.qk_norm:
        p["q_norm"] = jnp.zeros((a.head_dim,), dtype)
        p["k_norm"] = jnp.zeros((a.head_dim,), dtype)
        ax["q_norm"] = ("head_dim",)
        ax["k_norm"] = ("head_dim",)
    return p, ax


def _mla_params(cfg: ModelConfig, kg, dtype):
    m, a, d = cfg.mla, cfg.attn, cfg.d_model
    h = a.n_heads
    qk = m.nope_head_dim + m.rope_head_dim
    p, ax = {}, {}
    p["w_dq"] = _dense(kg(), (d, m.q_lora_rank), dtype)
    ax["w_dq"] = ("d_model", "lora")
    p["q_norm"] = jnp.zeros((m.q_lora_rank,), dtype)
    ax["q_norm"] = ("lora",)
    p["w_uq"] = _dense(kg(), (m.q_lora_rank, h * qk), dtype, fan_in=m.q_lora_rank)
    ax["w_uq"] = ("lora", "heads_x_dim")
    p["w_dkv"] = _dense(kg(), (d, m.kv_lora_rank), dtype)
    ax["w_dkv"] = ("d_model", "lora")
    p["kv_norm"] = jnp.zeros((m.kv_lora_rank,), dtype)
    ax["kv_norm"] = ("lora",)
    p["w_kr"] = _dense(kg(), (d, m.rope_head_dim), dtype)
    ax["w_kr"] = ("d_model", "head_dim")
    p["w_uk"] = _dense(kg(), (m.kv_lora_rank, h * m.nope_head_dim), dtype,
                       fan_in=m.kv_lora_rank)
    ax["w_uk"] = ("lora", "heads_x_dim")
    p["w_uv"] = _dense(kg(), (m.kv_lora_rank, h * m.v_head_dim), dtype,
                       fan_in=m.kv_lora_rank)
    ax["w_uv"] = ("lora", "heads_x_dim")
    p["wo"] = _dense(kg(), (h * m.v_head_dim, d), dtype)
    ax["wo"] = ("heads_x_dim", "d_model")
    return p, ax


def _mlp_params(cfg: ModelConfig, kg, dtype, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.act in ("swiglu", "geglu")
    p, ax = {}, {}
    p["w_up"] = _dense(kg(), (d, f), dtype)
    ax["w_up"] = ("d_model", "d_ff")
    if gated:
        p["w_gate"] = _dense(kg(), (d, f), dtype)
        ax["w_gate"] = ("d_model", "d_ff")
    p["w_down"] = _dense(kg(), (f, d), dtype)
    ax["w_down"] = ("d_ff", "d_model")
    return p, ax


def _moe_params(cfg: ModelConfig, kg, dtype):
    mo: MoEConfig = cfg.moe
    d, e, fe = cfg.d_model, mo.num_experts, mo.d_expert
    gated = cfg.act in ("swiglu", "geglu")
    p, ax = {}, {}
    p["router"] = _dense(kg(), (d, e), dtype)
    ax["router"] = ("d_model", "experts")
    p["w_up"] = _dense(kg(), (e, d, fe), dtype, fan_in=d)
    ax["w_up"] = ("experts", "d_model", "d_ff")
    if gated:
        p["w_gate"] = _dense(kg(), (e, d, fe), dtype, fan_in=d)
        ax["w_gate"] = ("experts", "d_model", "d_ff")
    p["w_down"] = _dense(kg(), (e, fe, d), dtype, fan_in=fe)
    ax["w_down"] = ("experts", "d_ff", "d_model")
    if mo.num_shared:
        sp, sax = _mlp_params(cfg, kg, dtype, d_ff=fe * mo.num_shared)
        p["shared"] = sp
        ax["shared"] = sax
    return p, ax


def _ssm_dims(cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return s, d_inner, dt_rank


def _mamba1_params(cfg: ModelConfig, kg, dtype):
    # Projections are split (not fused) so each matrix shards cleanly on its
    # own logical axis (DESIGN.md §6: mamba TP slices d_inner over `model`).
    s, di, dtr = _ssm_dims(cfg)
    d, n = cfg.d_model, s.d_state
    p, ax = {}, {}
    p["w_xm"] = _dense(kg(), (d, di), dtype)
    ax["w_xm"] = ("d_model", "d_ff")
    p["w_z"] = _dense(kg(), (d, di), dtype)
    ax["w_z"] = ("d_model", "d_ff")
    p["conv_w"] = _dense(kg(), (s.d_conv, di), dtype, fan_in=s.d_conv)
    ax["conv_w"] = (None, "d_ff")
    p["conv_b"] = jnp.zeros((di,), dtype)
    ax["conv_b"] = ("d_ff",)
    p["w_x"] = _dense(kg(), (di, dtr + 2 * n), dtype)
    ax["w_x"] = ("d_ff", None)
    p["w_dt"] = _dense(kg(), (dtr, di), dtype)
    ax["w_dt"] = (None, "d_ff")
    dt_init = jnp.exp(jax.random.uniform(
        kg(), (di,), jnp.float32, minval=math.log(1e-3), maxval=math.log(1e-1)))
    p["dt_bias"] = jnp.log(jnp.expm1(dt_init)).astype(dtype)
    ax["dt_bias"] = ("d_ff",)
    p["a_log"] = jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))).astype(dtype)
    ax["a_log"] = ("d_ff", None)
    p["d_skip"] = jnp.ones((di,), dtype)
    ax["d_skip"] = ("d_ff",)
    p["w_out"] = _dense(kg(), (di, d), dtype)
    ax["w_out"] = ("d_ff", "d_model")
    return p, ax


def _mamba2_params(cfg: ModelConfig, kg, dtype):
    s, di, _ = _ssm_dims(cfg)
    d, n, g = cfg.d_model, s.d_state, s.n_groups
    heads = di // s.head_dim
    p, ax = {}, {}
    p["w_xm"] = _dense(kg(), (d, di), dtype)
    ax["w_xm"] = ("d_model", "d_ff")
    p["w_z"] = _dense(kg(), (d, di), dtype)
    ax["w_z"] = ("d_model", "d_ff")
    p["w_B"] = _dense(kg(), (d, g * n), dtype)
    ax["w_B"] = ("d_model", None)
    p["w_C"] = _dense(kg(), (d, g * n), dtype)
    ax["w_C"] = ("d_model", None)
    p["w_dtin"] = _dense(kg(), (d, heads), dtype)
    ax["w_dtin"] = ("d_model", "heads")
    p["conv_w"] = _dense(kg(), (s.d_conv, di), dtype, fan_in=s.d_conv)
    ax["conv_w"] = (None, "d_ff")
    p["conv_b"] = jnp.zeros((di,), dtype)
    ax["conv_b"] = ("d_ff",)
    p["conv_w_bc"] = _dense(kg(), (s.d_conv, 2 * g * n), dtype, fan_in=s.d_conv)
    ax["conv_w_bc"] = (None, None)
    p["conv_b_bc"] = jnp.zeros((2 * g * n,), dtype)
    ax["conv_b_bc"] = (None,)
    p["dt_bias"] = jnp.zeros((heads,), dtype)
    ax["dt_bias"] = ("heads",)
    p["a_log"] = jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(dtype)
    ax["a_log"] = ("heads",)
    p["d_skip"] = jnp.ones((heads,), dtype)
    ax["d_skip"] = ("heads",)
    p["out_norm"] = jnp.zeros((di,), dtype)
    ax["out_norm"] = ("d_ff",)
    p["w_out"] = _dense(kg(), (di, d), dtype)
    ax["w_out"] = ("d_ff", "d_model")
    return p, ax


def _block_params(btype: str, cfg: ModelConfig, kg, dtype):
    """(params, axes) for one block of type ``btype``."""
    p, ax = {"norm1": None}, {"norm1": None}
    p["norm1"], ax["norm1"] = _norm_param(cfg.d_model, dtype)

    if btype in ("attn", "local", "attn_dense", "attn_moe", "shared_attn",
                 "xattn", "enc"):
        if cfg.mla is not None:
            p["attn"], ax["attn"] = _mla_params(cfg, kg, dtype)
        else:
            p["attn"], ax["attn"] = _attn_params(cfg, kg, dtype)
        p["norm2"], ax["norm2"] = _norm_param(cfg.d_model, dtype)
        if btype == "xattn":
            p["xattn"], ax["xattn"] = _attn_params(cfg, kg, dtype)
            p["norm_x"], ax["norm_x"] = _norm_param(cfg.d_model, dtype)
        if btype == "attn_moe":
            p["mlp"], ax["mlp"] = _moe_params(cfg, kg, dtype)
        else:
            p["mlp"], ax["mlp"] = _mlp_params(cfg, kg, dtype)
    elif btype == "mamba1":
        p["mixer"], ax["mixer"] = _mamba1_params(cfg, kg, dtype)
    elif btype == "mamba2":
        p["mixer"], ax["mixer"] = _mamba2_params(cfg, kg, dtype)
    else:
        raise ValueError(btype)
    return p, ax


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _prepend_axis(axes_tree, name="layers"):
    return jax.tree.map(
        lambda ax: (name, *ax) if isinstance(ax, tuple) else ax, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    """Returns (params, axes) twin pytrees for the full model."""
    kg = _KeyGen(key)
    d = cfg.d_model
    params: dict = {}
    axes: dict = {}

    params["embed"] = _dense(kg(), (cfg.padded_vocab, d), dtype, fan_in=1) * 0.02
    axes["embed"] = ("vocab", "d_model")
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(kg(), (d, cfg.padded_vocab), dtype)
        axes["lm_head"] = ("d_model", "vocab")
    if cfg.pos_embed == "learned":
        params["pos_embed"] = _dense(kg(), (cfg.max_position, d), dtype, fan_in=1) * 0.02
        axes["pos_embed"] = (None, "d_model")

    # decoder (or unique) stack: scan groups
    groups = plan_layer_groups(cfg.layer_program)
    gp, gax = [], []
    shared_built = False
    for unit, k in groups:
        unit_p, unit_ax = [], []
        for btype in unit:
            if btype == "shared_attn":
                if not shared_built:
                    params["shared_block"], axes["shared_block"] = \
                        _block_params("attn", cfg, kg, dtype)
                    shared_built = True
                # per-position: no unit-varying params (weight-tied)
                unit_p.append({})
                unit_ax.append({})
            else:
                reps = [_block_params(btype, cfg, kg, dtype) for _ in range(k)]
                unit_p.append(_stack([r[0] for r in reps]))
                unit_ax.append(_prepend_axis(reps[0][1]))
        gp.append(unit_p)
        gax.append(unit_ax)
    params["groups"] = gp
    axes["groups"] = gax

    params["final_norm"], axes["final_norm"] = _norm_param(d, dtype)

    if cfg.is_encdec:
        enc = cfg.encoder
        enc_groups = []
        enc_axes = []
        reps = [_block_params("enc", cfg, kg, dtype) for _ in range(enc.n_layers)]
        enc_groups.append([_stack([r[0] for r in reps])])
        enc_axes.append([_prepend_axis(reps[0][1])])
        params["encoder"] = {
            "groups": enc_groups,
            "final_norm": _norm_param(d, dtype)[0],
            "pos_embed": _dense(kg(), (enc.n_frames, d), dtype, fan_in=1) * 0.02,
        }
        axes["encoder"] = {
            "groups": enc_axes,
            "final_norm": ("d_model",),
            "pos_embed": (None, "d_model"),
        }

    if cfg.mtp_depth:
        mtp_p, mtp_ax = [], []
        for _ in range(cfg.mtp_depth):
            bp, bax = _block_params(cfg.layer_program[-1], cfg, kg, dtype)
            proj = _dense(kg(), (2 * d, d), dtype)
            mtp_p.append({"proj": proj, "block": bp,
                          "norm": _norm_param(d, dtype)[0]})
            mtp_ax.append({"proj": ("d_model", "d_model"), "block": bax,
                           "norm": ("d_model",)})
        params["mtp"] = mtp_p
        axes["mtp"] = mtp_ax

    return params, axes


# ---------------------------------------------------------------------------
# analytic parameter counts (for 6·N·D)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    gated = cfg.act in ("swiglu", "geglu")

    def attn_count():
        if cfg.mla is not None:
            m, h = cfg.mla, cfg.attn.n_heads
            qk = m.nope_head_dim + m.rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank * h * qk
                    + d * m.kv_lora_rank + d * m.rope_head_dim
                    + m.kv_lora_rank * h * (m.nope_head_dim + m.v_head_dim)
                    + h * m.v_head_dim * d)
        a = cfg.attn
        return d * a.head_dim * (a.n_heads * 2 + a.n_kv_heads * 2)

    def mlp_count(f):
        return d * f * (3 if gated else 2)

    def moe_count():
        mo = cfg.moe
        e = mo.top_k if active_only else mo.num_experts
        total = d * mo.num_experts  # router always loaded
        total += e * mo.d_expert * d * (3 if gated else 2)
        if mo.num_shared:
            total += mlp_count(mo.d_expert * mo.num_shared)
        return total

    def ssm_count(kind):
        s, di, dtr = _ssm_dims(cfg)
        n, g = s.d_state, s.n_groups
        if kind == "mamba1":
            return (d * 2 * di + s.d_conv * di + di
                    + di * (dtr + 2 * n) + dtr * di + di + di * n + di
                    + di * d)
        heads = di // s.head_dim
        return (d * (2 * di + 2 * g * n + heads)
                + s.d_conv * (di + 2 * g * n) + di + 2 * g * n
                + 3 * heads + di + di * d)

    per_block = {
        "attn": lambda: attn_count() + mlp_count(cfg.d_ff) + 2 * d,
        "local": lambda: attn_count() + mlp_count(cfg.d_ff) + 2 * d,
        "attn_dense": lambda: attn_count() + mlp_count(cfg.d_ff) + 2 * d,
        "attn_moe": lambda: attn_count() + (moe_count() if cfg.moe else 0) + 2 * d,
        "mamba1": lambda: ssm_count("mamba1") + d if cfg.ssm else 0,
        "mamba2": lambda: ssm_count("mamba2") + d if cfg.ssm else 0,
        "shared_attn": lambda: 0,  # counted once below
        "xattn": lambda: 2 * attn_count() + mlp_count(cfg.d_ff) + 3 * d,
        "enc": lambda: attn_count() + mlp_count(cfg.d_ff) + 2 * d,
    }
    total = sum(per_block[b]() for b in cfg.layer_program)
    if "shared_attn" in cfg.layer_program:
        total += attn_count() + mlp_count(cfg.d_ff) + 2 * d
    total += cfg.padded_vocab * d  # embed
    if not cfg.tie_embeddings:
        total += cfg.padded_vocab * d
    if cfg.pos_embed == "learned":
        total += cfg.max_position * d
    if cfg.is_encdec:
        total += cfg.encoder.n_layers * per_block["enc"]()
        total += cfg.encoder.n_frames * d + d
    if cfg.mtp_depth:
        total += cfg.mtp_depth * (per_block[cfg.layer_program[-1]]() + 2 * d * d + d)
    total += d  # final norm
    return int(total)
