"""State-space mixers: Mamba-1 (falcon-mamba) and Mamba-2 SSD (zamba2).

Memory discipline is the whole game for SSMs at scale:

* **Mamba-1 train/prefill** — chunked scan: ``lax.scan`` over time chunks,
  associative scan *within* a chunk (rematerialised), so nothing of size
  L·d_inner·N is ever live.  On the Pallas backends the scan runs as a
  targetDP site kernel over channels (:mod:`repro.kernels.lm`), state in
  VMEM per channel chunk.
* **Mamba-2 train/prefill** — the SSD chunked matmul formulation (MXU
  friendly): intra-chunk (Q×Q decay-masked score GEMMs) + inter-chunk
  state recurrence over chunk boundaries only.
* **decode** — O(1) recurrent state update per token for both.

TP: d_inner (and mamba2 heads) shard over the model axis; the only
cross-shard contractions are x_proj (mamba1, psum of a 288-wide vector)
and the output projection psum — GSPMD inserts both from the param
shardings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import ModelConfig, SSMConfig
from .context import ExecContext
from .params import _ssm_dims


def _causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv, kernel size k (static, small).

    x: (B, L, C); w: (k, C); b: (C,).  With ``state`` (B, k-1, C) the conv
    continues from a decode/prefill boundary; returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    ext = jnp.concatenate([state, x], axis=1)          # (B, k-1+L, C)
    y = b
    for j in range(k):
        y = y + ext[:, j:j + x.shape[1], :] * w[j]
    new_state = ext[:, -(k - 1):, :] if k > 1 else state
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def _mamba1_inner(p, xm, cfg: ModelConfig, ctx: ExecContext, *, conv_state=None,
                  ssm_state=None, decode=False):
    """Shared pre/post machinery around the scan; xm: (B, L, di)."""
    s, di, dtr = _ssm_dims(cfg)
    n = s.d_state
    xc, new_conv = _causal_conv(xm, p["conv_w"], p["conv_b"], state=conv_state)
    xc = jax.nn.silu(xc)
    xdbl = xc @ p["w_x"]                               # (B,L,dtr+2N), psum'd by GSPMD
    dt_r, bmat, cmat = jnp.split(xdbl, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["w_dt"] + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))       # (di, N)

    if decode:
        # single step: h' = h·exp(dt·A) + (dt·x)·B ; y = h'·C + D·x
        decay = jnp.exp(dt[:, 0, :, None] * a[None])   # (B, di, N)
        h = ssm_state * decay + (dt[:, 0] * xc[:, 0])[..., None] * bmat[:, 0][:, None, :]
        y = (h * cmat[:, 0][:, None, :]).sum(-1) + p["d_skip"] * xc[:, 0]
        return y[:, None, :].astype(xm.dtype), new_conv, h

    if ctx.backend in ("pallas", "pallas_interpret"):
        y, h_fin = ops.mamba_scan(xc, dt.astype(xc.dtype), bmat, cmat, a,
                                  p["d_skip"].astype(jnp.float32),
                                  backend=ctx.backend,
                                  block_d=ctx.scan_block_d,
                                  block_t=ctx.scan_block_t)
    else:
        y, h_fin = _chunked_scan(xc, dt, bmat, cmat, a,
                                 p["d_skip"].astype(jnp.float32),
                                 chunk=s.chunk)
    return y.astype(xm.dtype), new_conv, h_fin


def _chunked_scan(x, dt, bmat, cmat, a, d_skip, *, chunk):
    """Chunked associative scan; only chunk-boundary states persist.

    x, dt: (B, L, di); bmat/cmat: (B, L, N); a: (di, N).
    """
    batch, L, di = x.shape
    n = a.shape[-1]
    q = min(chunk, L)
    l_pad = -(-L // q) * q
    pad = lambda t: jnp.pad(t, ((0, 0), (0, l_pad - L), (0, 0)))
    xs = (pad(x).reshape(batch, -1, q, di).swapaxes(0, 1),
          pad(dt).reshape(batch, -1, q, di).swapaxes(0, 1),
          pad(bmat).reshape(batch, -1, q, n).swapaxes(0, 1),
          pad(cmat).reshape(batch, -1, q, n).swapaxes(0, 1))

    @jax.checkpoint
    def chunk_body(h0, inp):
        xq, dtq, bq, cq = (t.astype(jnp.float32) for t in inp)
        da = jnp.exp(dtq[..., None] * a)               # (B,Q,di,N)
        u = (dtq * xq)[..., None] * bq[:, :, None, :]  # (B,Q,di,N)

        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, b2 + a2 * b1

        da_c, h_c = jax.lax.associative_scan(combine, (da, u), axis=1)
        h_all = da_c * h0[:, None] + h_c               # (B,Q,di,N)
        y = (h_all * cq[:, :, None, :]).sum(-1) + d_skip * xq
        return h_all[:, -1], y

    h0 = jnp.zeros((batch, di, n), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(batch, l_pad, di)[:, :L]
    return y, h_fin


def mamba1_mixer(p, x, cfg: ModelConfig, ctx: ExecContext, *, cache=None,
                 length=None):
    """Full mixer. x: (B, L, D).  With ``cache`` (decode) L must be 1.

    cache: {"conv": (B, k-1, di), "ssm": (B, di, N)}.
    Returns (out, new_cache) — new_cache is None in train mode.
    """
    xm = x @ p["w_xm"]
    z = x @ p["w_z"]
    if cache is not None:
        y, new_conv, h = _mamba1_inner(p, xm, cfg, ctx,
                                       conv_state=cache["conv"],
                                       ssm_state=cache["ssm"], decode=True)
        new_cache = {"conv": new_conv, "ssm": h}
    else:
        y, new_conv, h = _mamba1_inner(p, xm, cfg, ctx)
        new_cache = {"conv": new_conv, "ssm": h}
    out = (y * jax.nn.silu(z)) @ p["w_out"]
    return out, new_cache


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------

def _segsum(a):
    """Stable segment-sum: S[i, j] = sum_{k in (j, i]} a[k], -inf above diag.

    a: (..., Q) → (..., Q, Q).
    """
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, s, -jnp.inf)


def _ssd_chunked(xh, dt, a_h, bm, cm, d_skip, *, chunk, init_state=None):
    """SSD forward.

    xh: (B, L, H, P); dt: (B, L, H); a_h: (H,) negative; bm/cm: (B, L, G, N)
    broadcast over heads; returns (y (B,L,H,P), final_state (B,H,P,N)).
    """
    batch, L, h, p_dim = xh.shape
    g, n = bm.shape[2], bm.shape[3]
    rep = h // g
    q = min(chunk, L)
    l_pad = -(-L // q) * q
    nc = l_pad // q

    def padt(t):
        return jnp.pad(t, ((0, 0), (0, l_pad - L)) + ((0, 0),) * (t.ndim - 2))

    xq = padt(xh).reshape(batch, nc, q, h, p_dim)
    dtq = padt(dt).reshape(batch, nc, q, h).astype(jnp.float32)
    bq = jnp.repeat(padt(bm).reshape(batch, nc, q, g, n), rep, axis=3)
    cq = jnp.repeat(padt(cm).reshape(batch, nc, q, g, n), rep, axis=3)

    adt = dtq * a_h                                     # (B,nc,Q,H) negative
    xdt = xq.astype(jnp.float32) * dtq[..., None]       # ∆-weighted input

    @jax.checkpoint
    def chunk_body(state, inp):
        xc, adtc, bc, cc = inp                          # (B,Q,H,P),(B,Q,H),(B,Q,H,N)
        seg = _segsum(adtc.swapaxes(1, 2))              # (B,H,Q,Q)
        l_mat = jnp.exp(seg)
        scores = jnp.einsum("bqhn,bkhn->bhqk", cc, bc) * l_mat
        y_diag = jnp.einsum("bhqk,bkhp->bqhp", scores, xc)

        cum = jnp.cumsum(adtc, axis=1)                  # (B,Q,H)
        total = cum[:, -1]                              # (B,H)
        # state contribution into this chunk
        decay_in = jnp.exp(cum)                         # decay from chunk start
        y_off = jnp.einsum("bqhn,bhpn->bqhp", cc * decay_in[..., None], state)
        # new state: decay old + inject inputs decayed to chunk end
        decay_out = jnp.exp(total[:, None] - cum)       # (B,Q,H)
        state_new = state * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bqhn,bqhp->bhpn", bc * decay_out[..., None], xc)
        return state_new, y_diag + y_off

    xs = (xdt.swapaxes(0, 1), adt.swapaxes(0, 1),
          bq.astype(jnp.float32).swapaxes(0, 1),
          cq.astype(jnp.float32).swapaxes(0, 1))
    state0 = (jnp.zeros((batch, h, p_dim, n), jnp.float32)
              if init_state is None else init_state)
    state_f, ys = jax.lax.scan(chunk_body, state0, xs)
    y = ys.swapaxes(0, 1).reshape(batch, l_pad, h, p_dim)[:, :L]
    y = y + d_skip * xh.astype(jnp.float32)   # d_skip (H,1) ⊕ (B,L,H,P)
    return y, state_f


def mamba2_mixer(p, x, cfg: ModelConfig, ctx: ExecContext, *, cache=None,
                 length=None):
    """Mamba-2 mixer. x: (B, L, D); cache {"conv","conv_bc","ssm"} for decode."""
    s, di, _ = _ssm_dims(cfg)
    n, g = s.d_state, s.n_groups
    hd = s.head_dim
    heads = di // hd
    b, L, _ = x.shape

    xm = x @ p["w_xm"]
    z = x @ p["w_z"]
    bc = jnp.concatenate([x @ p["w_B"], x @ p["w_C"]], axis=-1)
    dt_in = x @ p["w_dtin"]                                  # (B,L,H)

    conv_state = cache["conv"] if cache is not None else None
    conv_state_bc = cache["conv_bc"] if cache is not None else None
    xc, new_conv = _causal_conv(xm, p["conv_w"], p["conv_b"], state=conv_state)
    bcc, new_conv_bc = _causal_conv(bc, p["conv_w_bc"], p["conv_b_bc"],
                                    state=conv_state_bc)
    xc = jax.nn.silu(xc)
    bcc = jax.nn.silu(bcc)
    bmat = bcc[..., :g * n].reshape(b, L, g, n)
    cmat = bcc[..., g * n:].reshape(b, L, g, n)

    dt = jax.nn.softplus(dt_in.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,L,H)
    a_h = -jnp.exp(p["a_log"].astype(jnp.float32))            # (H,)
    xh = xc.reshape(b, L, heads, hd)
    d_skip = p["d_skip"].astype(jnp.float32)[:, None]         # (H,1)

    if cache is not None:
        # O(1) decode step
        state = cache["ssm"]                                  # (B,H,P,N)
        rep = heads // g
        b1 = jnp.repeat(bmat[:, 0], rep, axis=1).astype(jnp.float32)  # (B,H,N)
        c1 = jnp.repeat(cmat[:, 0], rep, axis=1).astype(jnp.float32)
        dt1 = dt[:, 0]                                        # (B,H)
        decay = jnp.exp(dt1 * a_h)[..., None, None]           # (B,H,1,1)
        inject = jnp.einsum("bhn,bhp->bhpn", b1,
                            (xh[:, 0].astype(jnp.float32)
                             * dt1[..., None]))
        state = state * decay + inject
        y = jnp.einsum("bhpn,bhn->bhp", state, c1) + d_skip * xh[:, 0]
        y = y.reshape(b, 1, di)
        new_cache = {"conv": new_conv, "conv_bc": new_conv_bc, "ssm": state}
    else:
        y, state_f = _ssd_chunked(xh, dt, a_h, bmat, cmat, d_skip,
                                  chunk=s.chunk)
        y = y.reshape(b, L, di)
        new_cache = {"conv": new_conv, "conv_bc": new_conv_bc, "ssm": state_f}

    # gated RMSNorm then out-projection
    gated = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    inv = jax.lax.rsqrt(jnp.mean(gated * gated, -1, keepdims=True) + 1e-6)
    yn = (gated * inv * (1.0 + p["out_norm"].astype(jnp.float32))).astype(x.dtype)
    return yn @ p["w_out"], new_cache
