"""Attention: GQA, sliding window, softcap, qk-norm, cross-attn, KV cache.

Three execution paths, one weight layout:

* **train/prefill** — flash-attention kernel (Pallas) or jnp oracle,
  selected by ``ctx.backend``;
* **decode (heads-local)** — single-token einsum attention over the cache;
* **decode (sequence-sharded)** — the KV cache is sharded over the model
  axis along the *sequence* dimension; each shard computes partial
  (out·softmax-numerator, logsumexp) and the exact result is reassembled
  with two ``psum``s (flash-decoding).  This is what makes 32k×128 and
  500k-token caches fit: no chip ever holds the full KV.

Cache layout per layer: ``{"k": (B, Hkv, S_max, Dh), "v": ..., }`` with a
scalar ``length`` carried beside the tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.kernels import ops
from .config import AttnConfig, ModelConfig
from .context import ExecContext
from . import layers


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


def _qk_normalize(p, q, k, ctx):
    """Per-head RMSNorm of q and k (gemma3)."""
    def nrm(w, t):
        tf = t.astype(jnp.float32)
        inv = jax.lax.rsqrt(jnp.mean(tf * tf, axis=-1, keepdims=True) + 1e-6)
        return (tf * inv * (1.0 + w.astype(jnp.float32))).astype(t.dtype)
    return nrm(p["q_norm"], q), nrm(p["k_norm"], k)


def project_qkv(p, x, a: AttnConfig, ctx: ExecContext, rope=None):
    """x: (B, S, D) → q (B,S,H,dh), k/v (B,S,Hkv,dh), rope applied."""
    q = _split_heads(x @ p["wq"], a.n_heads, a.head_dim)
    k = _split_heads(x @ p["wk"], a.n_kv_heads, a.head_dim)
    v = _split_heads(x @ p["wv"], a.n_kv_heads, a.head_dim)
    if a.qk_norm:
        q, k = _qk_normalize(p, q, k, ctx)
    if rope is not None:
        cos, sin = rope
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
    return q, k, v


def _use_seq_parallel(ctx: ExecContext, a: AttnConfig, s: int) -> bool:
    """Sequence-parallel attention: when the head count doesn't divide the
    model axis, GSPMD would replicate the whole attention across it (a
    measured TP×-FLOP waste on phi3/qwen2-vl/gemma2).  Instead shard the
    *query sequence* over the model axis: each chip runs the flash kernel
    on S/TP query rows against full K/V, masks offset by its shard index.
    Exact, collective-free in forward (K/V already replicated), one psum
    of dK/dV in backward (inserted by shard_map's transpose)."""
    if not (ctx.seq_parallel_attn and ctx.mesh is not None
            and ctx.model_axis and ctx.backend == "xla"
            and ctx.attn_impl == "chunked"):
        return False
    tp = ctx.mesh.shape[ctx.model_axis]
    if a.n_heads % tp == 0:       # heads shard fine — TP handles it
        return False
    return s % tp == 0


def _seq_parallel_attention(qT, kT, vT, a: AttnConfig, ctx: ExecContext, *,
                            causal, window):
    mesh, axis = ctx.mesh, ctx.model_axis
    tp = mesh.shape[axis]
    s = qT.shape[2]
    s_local = s // tp
    bspec = _batch_subspec(ctx, qT.shape[0])

    def body(q_l, k_f, v_f):
        return ops.flash_attention(
            q_l, k_f, v_f, causal=causal, window=window, softcap=a.softcap,
            scale=a.scale, backend=ctx.backend,
            block_q=min(ctx.attn_block_q, s_local),
            impl="chunked", q_offset=(axis, s_local))

    fn = compat.shard_map(
        body, mesh=ctx.shard_map_mesh,
        in_specs=(P(bspec, None, axis, None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None)),
        out_specs=P(bspec, None, axis, None), check_vma=False)
    return fn(qT, kT, vT)


def full_attention(p, x, a: AttnConfig, ctx: ExecContext, *, rope=None,
                   causal=True, window=0, kv_override=None):
    """Bidirectional/causal full-sequence attention (train, prefill, encoder).

    kv_override: (k, v) already projected — used by cross-attention.
    Returns (out (B,S,D), (k, v)) so prefill can seed the cache.
    """
    if kv_override is None:
        q, k, v = project_qkv(p, x, a, ctx, rope=rope)
    else:
        q = _split_heads(x @ p["wq"], a.n_heads, a.head_dim)
        if a.qk_norm:
            q, _ = _qk_normalize(p, q, q, ctx)
        if rope is not None:
            q = layers.apply_rope(q, *rope)
        k, v = kv_override

    qT, kT, vT = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
    if _use_seq_parallel(ctx, a, qT.shape[2]):
        o = _seq_parallel_attention(qT, kT, vT, a, ctx, causal=causal,
                                    window=window)
    else:
        o = ops.flash_attention(
            qT, kT, vT,
            causal=causal, window=window, softcap=a.softcap, scale=a.scale,
            backend=ctx.backend, block_q=ctx.attn_block_q,
            block_k=ctx.attn_block_k, impl=ctx.attn_impl)
    b, s = x.shape[:2]
    out = o.transpose(0, 2, 1, 3).reshape(b, s, a.n_heads * a.head_dim)
    return out @ p["wo"], (k, v)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode_scores_to_out(q, k, v, a: AttnConfig, length, window=0,
                          key_positions=None):
    """Single-token attention over a cache; all-local math.

    q: (B, H, 1, dh); k/v: (B, Hkv, S, dh).  Masks positions >= length and,
    for sliding-window layers, positions <= length-1-window.
    ``key_positions``: per-slot global positions (ring buffers); default
    ``arange(S)``; negative positions = never-written slots.
    Returns (out (B,H,1,dh) *unnormalised*, lse-style stats) so callers can
    combine shards exactly: out_num = sum(p̃·v), denom = sum(p̃), with
    p̃ = exp(s - m), plus the local max m.
    """
    group = a.n_heads // a.n_kv_heads
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    scale = a.scale if a.scale is not None else a.head_dim ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    if a.softcap > 0:
        s = a.softcap * jnp.tanh(s / a.softcap)
    pos = jnp.arange(k.shape[2]) if key_positions is None else key_positions
    mask = (pos[None, None, None, :] < length) & \
        (pos[None, None, None, :] >= 0)
    if window > 0:
        mask = mask & (pos[None, None, None, :] > length - 1 - window)
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)                       # (B,H,1,1)
    # guard fully-masked shards
    m_safe = jnp.where(m <= -1e29, 0.0, m)
    pt = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    num = jnp.einsum("bhqk,bhkd->bhqd", pt, vr.astype(jnp.float32))
    den = pt.sum(-1, keepdims=True)                              # (B,H,1,1)
    return num, den, m_safe


def decode_attention(p, x, a: AttnConfig, ctx: ExecContext, cache, length, *,
                     rope=None, window=0, cross=False):
    """One-token attention step.

    x: (B, 1, D); cache: {"k","v"} (B, Hkv, S_max, dh) (sharded along S over
    the model axis when ctx.seq_shard_decode).  Returns (out, new_cache).
    """
    b = x.shape[0]
    key_positions = None
    if cross:
        q = _split_heads(x @ p["wq"], a.n_heads, a.head_dim)
        if rope is not None:
            q = layers.apply_rope(q, *rope)
        k, v, new_cache = cache["k"], cache["v"], cache
    else:
        q, k_new, v_new = project_qkv(p, x, a, ctx, rope=rope)
        k_new = k_new.transpose(0, 2, 1, 3)                      # (B,Hkv,1,dh)
        v_new = v_new.transpose(0, 2, 1, 3)
        w_cache = cache["k"].shape[2]
        ring = window > 0 and w_cache == window
        # ring buffers (local layers, window-sized cache): write at
        # length mod W; slot i then holds global position
        # length - ((slot - i) mod W), negative = never written.
        write_at = (jnp.mod(jnp.asarray(length), w_cache) if ring
                    else length)
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), write_at, axis=2)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), write_at, axis=2)
        new_cache = {"k": k, "v": v}
        if ring:
            idx = jnp.arange(w_cache)
            key_positions = length - jnp.mod(write_at - idx, w_cache)

    qt = q.transpose(0, 2, 1, 3)                                 # (B,H,1,dh)
    # cross-attention attends to the full (static-length) encoder memory
    new_len = k.shape[2] if cross else length + 1

    if key_positions is None and _can_seq_shard(ctx, k.shape[2]):
        out = _seq_sharded_decode(qt, k, v, a, ctx, new_len, window)
    else:
        num, den, _ = _decode_scores_to_out(qt, k, v, a, new_len, window,
                                            key_positions=key_positions)
        out = num / jnp.maximum(den, 1e-30)
    out = out.astype(x.dtype).transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return out @ p["wo"], new_cache


def _can_seq_shard(ctx: ExecContext, smax: int) -> bool:
    """Flash-decoding applies only when the cache's sequence extent divides
    the model axis (whisper's 1500-frame cross cache, e.g., does not)."""
    if not (ctx.seq_shard_decode and ctx.mesh is not None and ctx.model_axis):
        return False
    return smax % ctx.mesh.shape[ctx.model_axis] == 0


def _batch_subspec(ctx: ExecContext, b: int):
    """Batch dim mesh axes, dropped when the batch doesn't divide them
    (long_500k decodes batch=1 on a 16-wide data axis → replicate)."""
    if not ctx.batch_axes:
        return None
    n = 1
    for ax in ctx.batch_axes:
        n *= ctx.mesh.shape[ax]
    return ctx.batch_axes if b % n == 0 else None


def _seq_sharded_decode(q, k, v, a: AttnConfig, ctx: ExecContext, length,
                        window):
    """Flash-decoding over a sequence-sharded cache.

    Runs under ``shard_map``: every model-axis shard holds a contiguous
    S_max/TP slice of the cache; partial (num, den) are combined with psum
    after rescaling by the global max — exact softmax, 2 small collectives.
    """
    axis = ctx.model_axis
    mesh = ctx.mesh
    smax = k.shape[2]
    tp = mesh.shape[axis]

    def body(q_l, k_l, v_l, length_l):
        shard = jax.lax.axis_index(axis)
        offset = shard * (smax // tp)
        # local positions → global positions for masking
        pos = offset + jnp.arange(k_l.shape[2])
        group = a.n_heads // a.n_kv_heads
        kr = jnp.repeat(k_l, group, axis=1)
        vr = jnp.repeat(v_l, group, axis=1)
        scale = a.scale if a.scale is not None else a.head_dim ** -0.5
        s = jnp.einsum("bhqd,bhkd->bhqk", q_l.astype(jnp.float32),
                       kr.astype(jnp.float32)) * scale
        if a.softcap > 0:
            s = a.softcap * jnp.tanh(s / a.softcap)
        mask = pos[None, None, None, :] < length_l
        if window > 0:
            mask = mask & (pos[None, None, None, :] > length_l - 1 - window)
        s = jnp.where(mask, s, -1e30)
        m_loc = jnp.max(s, axis=-1, keepdims=True)
        m_glob = jax.lax.pmax(m_loc, axis)
        m_safe = jnp.where(m_glob <= -1e29, 0.0, m_glob)
        pt = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        num = jnp.einsum("bhqk,bhkd->bhqd", pt, vr.astype(jnp.float32))
        den = pt.sum(-1, keepdims=True)
        num = jax.lax.psum(num, axis)
        den = jax.lax.psum(den, axis)
        return num / jnp.maximum(den, 1e-30)

    # Specs: batch stays on its axes; cache sequence axis is sharded on the
    # model axis; q is replicated over the model axis.
    bspec = _batch_subspec(ctx, q.shape[0])
    in_specs = (P(bspec, None, None, None),
                P(bspec, None, axis, None),
                P(bspec, None, axis, None),
                P())
    out_spec = P(bspec, None, None, None)
    fn = compat.shard_map(body, mesh=ctx.shard_map_mesh, in_specs=in_specs,
                       out_specs=out_spec, check_vma=False)
    return fn(q, k, v, jnp.asarray(length))
