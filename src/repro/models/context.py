"""Execution context: backend switch, mesh wiring, tuning knobs.

The targetDP contract at framework scale: model code is written once; the
``ExecContext`` decides *how* it runs — which kernel backend (jnp oracle vs
Pallas), which mesh axes carry tokens vs weights (TLP), and the block/VVL
tuning parameters (ILP).  The dry-run and the TPU deployment differ only in
this object.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax
from jax.sharding import Mesh


@dataclass(frozen=True)
class ExecContext:
    backend: str = "xla"                 # "xla" | "pallas" | "pallas_interpret"
    mesh: Optional[Mesh] = None
    batch_axes: tuple[str, ...] = ()     # mesh axes sharding tokens/batch
    model_axis: Optional[str] = None     # mesh axis carrying tensor parallelism
    remat: str = "none"                  # "none" | "block"
    # tuning knobs (the VVL family)
    vvl: int = 256                       # pointwise-kernel token block
    attn_block_q: int = 512
    attn_block_k: int = 512
    scan_block_d: int = 128
    scan_block_t: int = 128
    # attention options
    attn_impl: str = "ref"               # "ref" | "chunked" (xla oracle path)
    seq_parallel_attn: bool = True       # shard q-seq over model when heads
                                         # don't divide TP (see attention.py)
    seq_sharded_residual: bool = False   # Megatron-SP-style: keep the
                                         # residual stream S-sharded over the
                                         # model axis; only K/V (small) and
                                         # the TP matmuls gather/scatter
    # decode options
    seq_shard_decode: bool = False       # shard KV over model axis (flash-decode)
    # moe options
    moe_impl: str = "capacity"           # "capacity" | "ragged" | "a2a"

    def with_(self, **kw) -> "ExecContext":
        return replace(self, **kw)

    @property
    def shard_map_mesh(self):
        """Mesh to hand nested ``shard_map``s.

        Inside a partial-manual region (the pod-manual gradient-
        compression wrapper) the tracing context carries an AbstractMesh
        with the manual axes marked; a nested shard_map must receive
        *that* mesh, not the original all-Auto one, or jax rejects the
        mismatch.  Outside any manual region this returns ``self.mesh``.
        """
        try:
            am = jax.sharding.get_abstract_mesh()
            if am is not None and am.shape_tuple:
                return am
        except Exception:  # noqa: BLE001 — fall back to the concrete mesh
            pass
        return self.mesh

    def constrain_batch(self, x):
        """Pin an activation's leading (batch) dim to the batch mesh axes.

        GSPMD propagation is ambiguous when FSDP shards weights' d_model
        over the same axis that carries the batch: left alone it can pick
        a D-sharded/batch-replicated activation layout (a measured 16×
        FLOP replication on the non-TP-divisible archs).  Production
        frameworks pin the residual stream explicitly; so do we.
        """
        if self.mesh is None or not self.batch_axes or x.ndim < 2:
            return x
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        if x.shape[0] % n != 0:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P
        dims = [None] * (x.ndim - 1)
        if (self.seq_sharded_residual and x.ndim == 3 and self.model_axis
                and x.shape[1] % self.mesh.shape[self.model_axis] == 0):
            dims[0] = self.model_axis
        spec = P(self.batch_axes, *dims)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n
