"""Common layers: norms, rotary embeddings, MLPs, embedding/loss.

Site-wise ops (norms, activations, rotations) route through the targetDP
kernel layer (:mod:`repro.kernels.ops`) — single source, backend-switched.
Matmuls stay as jnp einsums so XLA drives the MXU and GSPMD shards them
from the parameter shardings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .config import AttnConfig, ModelConfig
from .context import ExecContext


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(w, x, ctx: ExecContext, *, scale_offset: float = 1.0):
    """RMSNorm with the (1 + w) convention (w init = 0)."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    y = ops.rmsnorm(x2, w, backend=ctx.backend, vvl=ctx.vvl,
                    scale_offset=scale_offset)
    return y.reshape(shp)


def norm(w, x, cfg: ModelConfig, ctx: ExecContext):
    if cfg.norm == "rmsnorm":
        return rmsnorm(w, x, ctx)
    # layernorm (whisper): no bias variant, (1+w) scale
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5) * (1.0 + w.astype(jnp.float32))
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_tables(positions: jax.Array, head_dim: int, theta: float,
                mrope_sections=None):
    """cos/sin tables.

    positions: ``(B, S)`` int32, or ``(3, B, S)`` for M-RoPE (t, h, w).
    Returns cos, sin of shape ``(B, S, head_dim//2)`` in float32.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[0]
        ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (B,S,half)
    else:
        if positions.ndim != 3:
            positions = jnp.broadcast_to(positions[None], (3, *positions.shape))
        sec_id = jnp.repeat(
            jnp.arange(3), jnp.asarray(mrope_sections), total_repeat_length=half)
        pos_f = positions.astype(jnp.float32)                      # (3,B,S)
        pos_per_freq = jnp.take(pos_f, sec_id, axis=0)             # (half,B,S)
        ang = jnp.moveaxis(pos_per_freq, 0, -1) * inv_freq         # (B,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Rotate ``x: (B, S, H, head_dim)`` (split-halves / NeoX convention)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[:, :, None, :].astype(x1.dtype)
    s = sin[:, :, None, :].astype(x1.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp(p, x, cfg: ModelConfig, ctx: ExecContext):
    """Dense MLP: gated (swiglu/geglu) or plain (relu2/gelu)."""
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    up = x2 @ p["w_up"]
    if "w_gate" in p:
        gate = x2 @ p["w_gate"]
        h = ops.gated_act(gate, up, kind=cfg.act, backend=ctx.backend,
                          vvl=ctx.vvl)
    else:
        h = ops.gated_act(up, None, kind=cfg.act, backend=ctx.backend,
                          vvl=ctx.vvl)
    return (h @ p["w_down"]).reshape(shp)


# ---------------------------------------------------------------------------
# embedding / logits / loss
# ---------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def logits_from_hidden(params, x, cfg: ModelConfig):
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = x.astype(jnp.float32) @ head.astype(jnp.float32)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    # mask vocab padding
    if cfg.padded_vocab != cfg.vocab_size:
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)  # broadcasts on last axis
    return logits


def cross_entropy(logits, labels, mask=None):
    """Mean token CE in fp32; labels < vocab_size; mask 1=count."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
