"""Model-level forwards: train loss, prefill, decode — over layer-program
scan groups so lowered HLO stays O(distinct block types).

Batch dict conventions (all synthetic-pipeline & input_specs compatible):
  tokens       (B, S) int32
  labels       (B, S) int32          (train)
  loss_mask    (B, S) float/bool     (optional)
  positions    (B, S) int32          (optional; default arange)
  vision_embed (B, P, D), vision_slot (B, S) int32 (-1 = text)   [vlm stub]
  positions3   (3, B, S) int32                                    [M-RoPE]
  audio_embed  (B, F, D)                                          [whisper]
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import blocks, layers
from .config import ModelConfig, plan_layer_groups
from .context import ExecContext


# ---------------------------------------------------------------------------
# input embedding (incl. modality stubs)
# ---------------------------------------------------------------------------

def embed_inputs(params, batch, cfg: ModelConfig, ctx: ExecContext):
    tokens = batch["tokens"]
    x = layers.embed_tokens(params, tokens, cfg)
    if cfg.vision_stub and "vision_embed" in batch:
        slot = batch["vision_slot"]                       # (B,S), -1 = text
        patches = batch["vision_embed"].astype(x.dtype)   # (B,P,D)
        take = jnp.take_along_axis(
            patches, jnp.maximum(slot, 0)[..., None], axis=1)
        x = jnp.where((slot >= 0)[..., None], take, x)
    if cfg.pos_embed == "learned":
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.arange(tokens.shape[1])[None, :]
        x = x + jnp.take(params["pos_embed"], pos, axis=0).astype(x.dtype)
    return ctx.constrain_batch(x)


def _rope_for(batch, cfg: ModelConfig, seq_len: int, *, positions=None):
    """(global_table, local_table) for the arch; None when unused."""
    a = cfg.attn
    if a is None or cfg.pos_embed not in ("rope", "mrope"):
        return None, None
    if positions is None:
        if cfg.pos_embed == "mrope" and "positions3" in batch:
            positions = batch["positions3"]
        else:
            positions = batch.get("positions")
        if positions is None:
            b = batch["tokens"].shape[0]
            positions = jnp.broadcast_to(
                jnp.arange(seq_len, dtype=jnp.int32)[None], (b, seq_len))
    head_dim = cfg.mla.rope_head_dim if cfg.mla is not None else a.head_dim
    sections = a.mrope_sections if cfg.pos_embed == "mrope" else None
    rope = layers.rope_tables(positions, head_dim, a.rope_theta,
                              mrope_sections=sections)
    rope_local = None
    theta_local = getattr(a, "rope_theta_local", None)
    if theta_local and "local" in cfg.layer_program:
        rope_local = layers.rope_tables(positions, head_dim, theta_local,
                                        mrope_sections=sections)
    return rope, rope_local


# ---------------------------------------------------------------------------
# stack application (full-sequence mode: train / prefill / encoder)
# ---------------------------------------------------------------------------

def _apply_stack(stack_params, program, x, cfg: ModelConfig, ctx: ExecContext,
                 *, rope, rope_local, shared, enc_out=None, caches=None,
                 length=None, collect_cache=False):
    """Run the whole layer program.  Returns (x, caches_out | None).

    caches (decode) / collect_cache (prefill) follow the group structure:
    ``[[per-position stacked cache], ...]``.
    """
    groups = plan_layer_groups(program)
    want_cache = collect_cache or caches is not None
    caches_out: list = []

    for gi, (unit, k) in enumerate(groups):
        gparams = stack_params[gi]                       # list per position
        gcache = caches[gi] if caches is not None else None

        def unit_body(x_in, sliced_params, sliced_cache):
            new_caches = []
            for j, btype in enumerate(unit):
                bc = sliced_cache[j] if sliced_cache is not None else None
                x_in, nc = blocks.apply_block(
                    btype, sliced_params[j], x_in, cfg=cfg, ctx=ctx,
                    shared=shared, rope=rope, rope_local=rope_local,
                    cache=bc, length=length, enc_out=enc_out)
                # pin the residual stream's batch layout (see
                # ExecContext.constrain_batch)
                x_in = ctx.constrain_batch(x_in)
                new_caches.append(nc)
            # train mode: drop caches so scan carries no dead outputs
            return x_in, (new_caches if want_cache else None)

        if ctx.remat == "block":
            unit_body = jax.checkpoint(unit_body)

        if k == 1:
            sliced = [jax.tree.map(lambda t: t[0], p) for p in gparams]
            scache = (None if gcache is None else
                      [jax.tree.map(lambda t: t[0], c) for c in gcache])
            x, ncs = unit_body(x, sliced, scache)
            if want_cache:
                ncs = [jax.tree.map(lambda t: t[None], c) for c in ncs]
                caches_out.append(ncs)
        else:
            if gcache is None:
                def scan_body2(carry, p_sl):
                    return unit_body(carry, p_sl, None)
                x, ncs = jax.lax.scan(scan_body2, x, gparams, length=k)
            else:
                def scan_body(carry, xs):
                    p_sl, c_sl = xs
                    return unit_body(carry, p_sl, c_sl)
                x, ncs = jax.lax.scan(scan_body, x, (gparams, gcache),
                                      length=k)
            if want_cache:
                caches_out.append(ncs)

    return x, (caches_out if want_cache else None)


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------

def encode(params, batch, cfg: ModelConfig, ctx: ExecContext):
    enc = params["encoder"]
    x = batch["audio_embed"].astype(params["embed"].dtype)
    f = x.shape[1]
    x = x + enc["pos_embed"][None, :f].astype(x.dtype)
    program = ("enc",) * cfg.encoder.n_layers
    x, _ = _apply_stack(enc["groups"], program, x, cfg, ctx,
                        rope=None, rope_local=None, shared=None)
    return layers.norm(enc["final_norm"], x, cfg, ctx)


# ---------------------------------------------------------------------------
# train forward + loss
# ---------------------------------------------------------------------------

def forward_hidden(params, batch, cfg: ModelConfig, ctx: ExecContext):
    tokens = batch["tokens"]
    seq_len = tokens.shape[1]
    x = embed_inputs(params, batch, cfg, ctx)
    rope, rope_local = _rope_for(batch, cfg, seq_len)
    enc_out = encode(params, batch, cfg, ctx) if cfg.is_encdec else None
    shared = params.get("shared_block")
    x, _ = _apply_stack(params["groups"], cfg.layer_program, x, cfg, ctx,
                        rope=rope, rope_local=rope_local, shared=shared,
                        enc_out=enc_out)
    return layers.norm(params["final_norm"], x, cfg, ctx), enc_out


def loss_fn(params, batch, cfg: ModelConfig, ctx: ExecContext,
            *, mtp_weight: float = 0.3):
    h, _ = forward_hidden(params, batch, cfg, ctx)
    logits = layers.logits_from_hidden(params, h, cfg)
    mask = batch.get("loss_mask")
    loss = layers.cross_entropy(logits, batch["labels"], mask)
    metrics = {"ce": loss}

    if cfg.mtp_depth and "mtp" in params:
        # DeepSeek MTP: block m predicts token t+1+m from (h, emb(t+m)).
        hm = h
        total_mtp = 0.0
        for m, mp in enumerate(params["mtp"], start=1):
            tok_next = jnp.roll(batch["tokens"], -m, axis=1)
            emb_next = layers.embed_tokens(params, tok_next, cfg)
            cat = jnp.concatenate(
                [layers.rmsnorm(mp["norm"], hm, ctx), emb_next], axis=-1)
            hm = cat @ mp["proj"]
            rope, rope_local = _rope_for(batch, cfg, h.shape[1])
            hm, _ = blocks.apply_block(
                cfg.layer_program[-1], mp["block"], hm, cfg=cfg, ctx=ctx,
                shared=params.get("shared_block"), rope=rope,
                rope_local=rope_local)
            logits_m = layers.logits_from_hidden(params, hm, cfg)
            labels_m = jnp.roll(batch["labels"], -m, axis=1)
            # mask the wrapped tail
            s = batch["labels"].shape[1]
            mtp_mask = (jnp.arange(s) < s - m)[None, :].astype(jnp.float32)
            if mask is not None:
                mtp_mask = mtp_mask * mask
            total_mtp = total_mtp + layers.cross_entropy(
                logits_m, labels_m, mtp_mask)
        loss = loss + mtp_weight * total_mtp / cfg.mtp_depth
        metrics["mtp"] = total_mtp / cfg.mtp_depth

    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(params_shapes, cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, *, local_ring: bool = False):
    """Zeroed cache pytree matching the group structure.

    ``local_ring``: sliding-window (``local``) layers allocate only
    ``window`` slots, written modulo-window at decode time (ring buffer) —
    at 500k context this removes ~84% of gemma3's KV bytes (only the
    global layers keep full-length caches).
    """
    from .params import _ssm_dims
    groups = plan_layer_groups(cfg.layer_program)
    a = cfg.attn
    out = []
    for unit, k in groups:
        unit_caches = []
        for btype in unit:
            blen = max_len
            if local_ring and btype == "local" and a and a.window > 0:
                blen = min(max_len, a.window)
            if btype in ("mamba1", "mamba2"):
                s, di, _ = _ssm_dims(cfg)
                conv = jnp.zeros((k, batch, s.d_conv - 1, di), dtype)
                if btype == "mamba1":
                    c = {"conv": conv,
                         "ssm": jnp.zeros((k, batch, di, s.d_state), jnp.float32)}
                else:
                    heads = di // s.head_dim
                    c = {"conv": conv,
                         "conv_bc": jnp.zeros(
                             (k, batch, s.d_conv - 1,
                              2 * s.n_groups * s.d_state), dtype),
                         "ssm": jnp.zeros(
                             (k, batch, heads, s.head_dim, s.d_state),
                             jnp.float32)}
            elif cfg.mla is not None:
                m = cfg.mla
                c = {"c_kv": jnp.zeros((k, batch, max_len, m.kv_lora_rank), dtype),
                     "k_rope": jnp.zeros((k, batch, max_len, m.rope_head_dim),
                                         dtype)}
            else:
                kv = jnp.zeros((k, batch, a.n_kv_heads, blen, a.head_dim),
                               dtype)
                c = {"k": kv, "v": kv}
                if btype == "xattn":
                    f = cfg.encoder.n_frames
                    xkv = jnp.zeros((k, batch, a.n_kv_heads, f, a.head_dim),
                                    dtype)
                    c = {"self": c, "xk": xkv, "xv": xkv}
            unit_caches.append(c)
        out.append(unit_caches)
    return out


def prefill(params, batch, cfg: ModelConfig, ctx: ExecContext, *,
            cache_len: Optional[int] = None):
    """Full forward that also builds the KV/state cache.

    Returns (last_token_logits, cache, enc_out).  Cache sequence extent is
    the prompt length; pad with :func:`pad_cache_to` for a decode budget.
    """
    tokens = batch["tokens"]
    seq_len = tokens.shape[1]
    x = embed_inputs(params, batch, cfg, ctx)
    rope, rope_local = _rope_for(batch, cfg, seq_len)
    enc_out = encode(params, batch, cfg, ctx) if cfg.is_encdec else None
    shared = params.get("shared_block")
    x, caches = _apply_stack(params["groups"], cfg.layer_program, x, cfg, ctx,
                             rope=rope, rope_local=rope_local, shared=shared,
                             enc_out=enc_out, collect_cache=True)
    h = layers.norm(params["final_norm"], x, cfg, ctx)
    logits = layers.logits_from_hidden(params, h[:, -1:], cfg)
    return logits, caches, enc_out


def decode_step(params, token, caches, length, cfg: ModelConfig,
                ctx: ExecContext, *, positions3=None):
    """One-token decode.  token: (B, 1) int32; length: current cache fill.

    Returns (logits (B, 1, V), new_caches).
    """
    batch = {"tokens": token}
    x = embed_inputs(params, batch, cfg, ctx)
    b = token.shape[0]
    if positions3 is not None:
        pos = positions3
    else:
        pos = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (b, 1))
    rope, rope_local = _rope_for(batch, cfg, 1, positions=pos)
    shared = params.get("shared_block")
    x, new_caches = _apply_stack(params["groups"], cfg.layer_program, x, cfg,
                                 ctx, rope=rope, rope_local=rope_local,
                                 shared=shared, caches=caches, length=length)
    h = layers.norm(params["final_norm"], x, cfg, ctx)
    return layers.logits_from_hidden(params, h, cfg), new_caches
