"""Block-level forward functions: one dispatch for train/prefill/decode.

``apply_block`` is the single entry point the layer-program scan calls; the
block type string selects the mixer (attention variant / MoE / SSM) and the
presence of ``cache`` selects decode vs full-sequence mode.

Cache structure per block type:
  attn family   {"k","v"}: (B, Hkv, S_max, dh)
  MLA           {"c_kv": (B, S_max, R), "k_rope": (B, S_max, rope_dim)}
  xattn         self {"k","v"} + {"xk","xv"} cross K/V (set at prefill)
  mamba1        {"conv": (B, k-1, di), "ssm": (B, di, N)}
  mamba2        {"conv","conv_bc","ssm"}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention, layers, mla, moe, ssm
from .config import ModelConfig
from .context import ExecContext


def _mlp_for(btype, bp, x, cfg, ctx):
    if btype == "attn_moe":
        if ctx.moe_impl == "a2a":
            return moe.moe_a2a(bp["mlp"], x, cfg, ctx)
        return moe.moe_mlp(bp["mlp"], x, cfg, ctx)
    return layers.mlp(bp["mlp"], x, cfg, ctx)


def _attn_for(bp, x, cfg, ctx, *, rope, causal, window, cache, length,
              cross_kv=None):
    """Dispatch attention (standard or MLA) for full or decode mode."""
    if cfg.mla is not None:
        if cache is None:
            out, kv = mla.mla_full(bp, x, cfg, ctx, rope=rope, causal=causal)
            return out, {"c_kv": kv[0], "k_rope": kv[1]}
        out, new_cache = mla.mla_decode(bp, x, cfg, ctx, cache, length,
                                        rope=rope)
        return out, new_cache
    a = cfg.attn
    if cache is None:
        out, kv = attention.full_attention(bp, x, a, ctx, rope=rope,
                                           causal=causal, window=window,
                                           kv_override=cross_kv)
        return out, {"k": kv[0].transpose(0, 2, 1, 3),
                     "v": kv[1].transpose(0, 2, 1, 3)}
    out, new_cache = attention.decode_attention(
        bp, x, a, ctx, cache, length, rope=rope, window=window,
        cross=cross_kv is not None)
    return out, new_cache


def apply_block(btype: str, bp, x, *, cfg: ModelConfig, ctx: ExecContext,
                shared=None, rope=None, rope_local=None, cache=None,
                length=None, enc_out=None):
    """Apply one block; returns (x, new_cache).

    ``rope_local`` is the sliding-window layers' table when the arch uses a
    different local theta (gemma3).  ``enc_out`` feeds cross-attention.
    """
    if btype == "shared_attn":
        bp = shared
        btype = "attn"

    if btype in ("mamba1", "mamba2"):
        mixer = ssm.mamba1_mixer if btype == "mamba1" else ssm.mamba2_mixer
        h = layers.norm(bp["norm1"], x, cfg, ctx)
        out, new_cache = mixer(bp["mixer"], h, cfg, ctx, cache=cache,
                               length=length)
        return x + out, new_cache

    window = cfg.attn.window if (cfg.attn and btype == "local") else 0
    rp = rope_local if (btype == "local" and rope_local is not None) else rope
    causal = btype != "enc"

    h = layers.norm(bp["norm1"], x, cfg, ctx)
    self_cache = cache.get("self") if isinstance(cache, dict) and "self" in cache \
        else cache
    out, new_self = _attn_for(bp["attn"], h, cfg, ctx, rope=rp, causal=causal,
                              window=window, cache=self_cache, length=length)
    x = x + out

    new_cache = new_self
    if btype == "xattn":
        hx = layers.norm(bp["norm_x"], x, cfg, ctx)
        if cache is not None:
            xkv_cache = {"k": cache["xk"], "v": cache["xv"]}
            out, _ = _attn_for(bp["xattn"], hx, cfg, ctx, rope=None,
                               causal=False, window=0, cache=xkv_cache,
                               length=length, cross_kv=((), ()))
        else:
            # prefill/train: project cross K/V from the encoder output
            a = cfg.attn
            k = (enc_out @ bp["xattn"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], a.n_kv_heads, a.head_dim)
            v = (enc_out @ bp["xattn"]["wv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], a.n_kv_heads, a.head_dim)
            out, _ = attention.full_attention(
                bp["xattn"], hx, a, ctx, rope=None, causal=False, window=0,
                kv_override=(k, v))
            new_cache = {"self": new_self,
                         "xk": k.transpose(0, 2, 1, 3),
                         "xv": v.transpose(0, 2, 1, 3)}
        x = x + out
        if cache is not None:
            new_cache = {"self": new_self, "xk": cache["xk"], "xv": cache["xv"]}

    h = layers.norm(bp["norm2"], x, cfg, ctx)
    x = x + _mlp_for(btype, bp, h, cfg, ctx)
    return x, new_cache
