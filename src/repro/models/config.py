"""Model configuration — one dataclass family covering all assigned archs.

A model is described by a *layer program*: a tuple of block-type names of
length ``n_layers`` (e.g. 61×``attn`` for a dense stack, ``local×5,global``
repeating for gemma, ``mamba2×5,shared_attn`` repeating for zamba).  The
program is compiled into scan groups by :func:`plan_layer_groups` so the
lowered HLO stays O(distinct block types), not O(n_layers).

Block types:
  ``attn``         global causal attention + MLP
  ``local``        sliding-window causal attention + MLP
  ``attn_dense``   attention + dense MLP (MoE models' leading dense layers)
  ``attn_moe``     attention + MoE MLP
  ``mamba1``       Mamba-1 selective-scan mixer (no MLP; falcon style)
  ``mamba2``       Mamba-2 SSD mixer (zamba style)
  ``shared_attn``  full transformer block with weight-tied (shared) params
  ``xattn``        decoder block with self- + cross-attention (whisper)
  ``enc``          bidirectional encoder block (whisper encoder)
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

BLOCK_TYPES = ("attn", "local", "attn_dense", "attn_moe", "mamba1", "mamba2",
               "shared_attn", "xattn", "enc")


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    rope_theta_local: float = 0.0   # sliding-window layers' theta (gemma3)
    window: int = 0                 # sliding-window size; 0 = global
    softcap: float = 0.0            # attention logit soft-capping (gemma2)
    qk_norm: bool = False           # RMSNorm on q/k heads (gemma3)
    scale: Optional[float] = None   # softmax scale; None → head_dim**-0.5
    mrope_sections: Optional[tuple[int, int, int]] = None  # qwen2-vl M-RoPE


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                   # per-expert FFN width
    num_shared: int = 0             # shared experts (deepseek: 1)
    router_scale: bool = True       # normalise top-k weights to sum 1
    capacity_factor: float = 0.0    # 0 → dropless (sort + ragged_dot)


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba1"            # "mamba1" | "mamba2"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                # 0 → ceil(d_model/16)
    head_dim: int = 64              # mamba2 only
    n_groups: int = 1               # mamba2 B/C groups
    chunk: int = 128                # SSD / scan chunk length


@dataclass(frozen=True)
class EncoderConfig:
    n_layers: int
    n_frames: int                   # stub frontend: precomputed frames
    d_model: int = 0                # 0 → same as decoder


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    vocab_size: int
    d_ff: int
    layer_program: tuple[str, ...]
    attn: Optional[AttnConfig] = None
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None

    act: str = "swiglu"             # "swiglu" | "relu2" | "gelu" (+gated)
    norm: str = "rmsnorm"           # "rmsnorm" | "layernorm"
    pos_embed: str = "rope"         # "rope" | "mrope" | "learned" | "none"
    max_position: int = 1 << 20     # learned pos-embed table length cap
    tie_embeddings: bool = True
    embed_scale: bool = False       # × sqrt(d_model) at embedding (gemma)
    logit_softcap: float = 0.0      # final-logit capping (gemma2)
    mtp_depth: int = 0              # deepseek multi-token-prediction blocks
    vision_stub: bool = False       # qwen2-vl: merge precomputed patch embeds
    vocab_pad_to: int = 256         # pad vocab to a multiple (sharding)

    def __post_init__(self):
        if len(self.layer_program) != self.n_layers:
            raise ValueError(
                f"{self.name}: layer_program has {len(self.layer_program)} "
                f"entries for n_layers={self.n_layers}")
        unknown = set(self.layer_program) - set(BLOCK_TYPES)
        if unknown:
            raise ValueError(f"{self.name}: unknown block types {unknown}")
        needs_attn = {"attn", "local", "attn_dense", "attn_moe",
                      "shared_attn", "xattn", "enc"}
        if needs_attn & set(self.layer_program) and \
                self.attn is None and self.mla is None:
            raise ValueError(f"{self.name}: attention blocks need attn/mla config")
        if "attn_moe" in self.layer_program and self.moe is None:
            raise ValueError(f"{self.name}: attn_moe blocks need moe config")
        if {"mamba1", "mamba2"} & set(self.layer_program) and self.ssm is None:
            raise ValueError(f"{self.name}: ssm blocks need ssm config")

    # -- derived -------------------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return -(-self.vocab_size // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.encoder is not None

    @property
    def is_attention_free(self) -> bool:
        return not ({"attn", "local", "attn_dense", "attn_moe", "shared_attn",
                     "xattn", "enc"} & set(self.layer_program))

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends globally over the full sequence with
        quadratic prefill cost (SSM / hybrid / mostly-sliding-window)."""
        quad = {"attn", "attn_dense", "attn_moe", "xattn", "enc"}
        n_quad = sum(1 for b in self.layer_program if b in quad)
        return n_quad == 0 or (n_quad / self.n_layers) <= 0.25

    def scaled_down(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def num_params(self) -> int:
        """Approximate parameter count (used for 6·N·D roofline terms)."""
        from . import params as _p  # lazy; avoids import cycle
        return _p.count_params(self)

    def active_params(self) -> int:
        from . import params as _p
        return _p.count_params(self, active_only=True)


def repeat_program(pattern: tuple[str, ...], n_layers: int) -> tuple[str, ...]:
    """Cycle ``pattern`` to length ``n_layers``."""
    reps = -(-n_layers // len(pattern))
    return tuple((list(pattern) * reps)[:n_layers])


def plan_layer_groups(program: tuple[str, ...]) -> list[tuple[tuple[str, ...], int]]:
    """Compile a layer program into scan groups ``[(unit, n_repeats), ...]``.

    Prefers the smallest periodic unit (with remainder groups); falls back to
    maximal same-type runs.  Guarantees ``sum(len(u)*k) == len(program)``.
    """
    n = len(program)
    # periodic-with-remainder: smallest p whose repetition covers >= half
    best = None
    for p in range(1, min(n // 2, 16) + 1):
        unit = program[:p]
        k = 1
        while (k + 1) * p <= n and program[k * p:(k + 1) * p] == unit:
            k += 1
        if k >= 2 and k * p >= n - p:          # at most one unit of remainder
            groups = [(unit, k)]
            rem = program[k * p:]
            if rem:
                groups.append((rem, 1))
            best = groups
            break
    if best is not None:
        return best
    # fallback: maximal runs of identical block type
    groups: list[tuple[tuple[str, ...], int]] = []
    i = 0
    while i < n:
        j = i
        while j < n and program[j] == program[i]:
            j += 1
        groups.append(((program[i],), j - i))
        i = j
    return groups
