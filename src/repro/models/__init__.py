"""LM substrate: composable model definitions for the assigned archs."""
from .config import (AttnConfig, EncoderConfig, MLAConfig, MoEConfig,
                     ModelConfig, SSMConfig, plan_layer_groups,
                     repeat_program)
from .context import ExecContext
from .params import count_params, init_params

__all__ = [
    "AttnConfig", "EncoderConfig", "MLAConfig", "MoEConfig", "ModelConfig",
    "SSMConfig", "ExecContext", "plan_layer_groups", "repeat_program",
    "count_params", "init_params",
]
