"""Multi-head Latent Attention (DeepSeek-V2/V3).

Two paths over one weight set:

* **expanded** (train / prefill): decompress the latent to per-head K/V and
  run ordinary flash attention with qk head_dim = nope+rope (192) and V
  padded to the same width (sliced after) so a single kernel signature
  serves all archs.
* **absorbed** (decode): the cache stores only the 512-dim KV latent plus
  the 64-dim shared rope key per token (*this* is MLA's memory win:
  576 B/token/layer in bf16 instead of 128 heads × 256).  The up-projection
  is absorbed into the query/output sides:
      score(h) = (q_nope(h) Wᵤᵏ(h)ᵀ) · c_kv + q_rope(h) · k_rope
      out(h)   = (softmax · c_kv) Wᵤᵛ(h)
  Optionally sequence-sharded over the model axis (flash-decoding combine),
  since even the latent cache at 500k tokens wants sharding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.kernels import ops
from .config import MLAConfig, ModelConfig
from .context import ExecContext
from . import layers


def _rms(w, x):
    xf = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (xf * inv * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def _project_q(p, x, cfg: ModelConfig):
    m, h = cfg.mla, cfg.attn.n_heads
    b, s, _ = x.shape
    cq = _rms(p["q_norm"], x @ p["w_dq"])
    q = (cq @ p["w_uq"]).reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    return q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]


def _latent_kv(p, x, cfg: ModelConfig, rope):
    """c_kv (B,S,R) and rope'd shared key k_rope (B,S,rope_dim)."""
    m = cfg.mla
    c_kv = _rms(p["kv_norm"], x @ p["w_dkv"])
    k_rope = (x @ p["w_kr"])[:, :, None, :]           # (B,S,1,rope)
    k_rope = layers.apply_rope(k_rope, *rope)[:, :, 0, :]
    return c_kv, k_rope


def mla_full(p, x, cfg: ModelConfig, ctx: ExecContext, *, rope, causal=True):
    """Expanded-path attention; returns (out, (c_kv, k_rope)) for the cache."""
    m, a = cfg.mla, cfg.attn
    h = a.n_heads
    b, s, _ = x.shape
    qk_dim = m.nope_head_dim + m.rope_head_dim

    q_nope, q_rope = _project_q(p, x, cfg)
    q_rope = layers.apply_rope(q_rope, *rope)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)    # (B,S,H,192)

    c_kv, k_rope = _latent_kv(p, x, cfg, rope)
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, h, m.nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, h, m.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, m.rope_head_dim))],
        axis=-1)

    # pad V to the qk width so one flash kernel signature serves both
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    scale = a.scale if a.scale is not None else qk_dim ** -0.5
    o = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v_pad.transpose(0, 2, 1, 3),
        causal=causal, softcap=a.softcap, scale=scale,
        backend=ctx.backend, block_q=ctx.attn_block_q,
        block_k=ctx.attn_block_k, impl=ctx.attn_impl)
    o = o.transpose(0, 2, 1, 3)[..., :m.v_head_dim].reshape(b, s, -1)
    return o @ p["wo"], (c_kv, k_rope)


def mla_decode(p, x, cfg: ModelConfig, ctx: ExecContext, cache, length, *,
               rope):
    """Absorbed-path single-token step over the latent cache.

    cache: {"c_kv": (B, S_max, R), "k_rope": (B, S_max, rope_dim)}.
    """
    m, a = cfg.mla, cfg.attn
    h = a.n_heads
    b = x.shape[0]
    qk_dim = m.nope_head_dim + m.rope_head_dim
    scale = a.scale if a.scale is not None else qk_dim ** -0.5

    q_nope, q_rope = _project_q(p, x, cfg)            # (B,1,H,·)
    q_rope = layers.apply_rope(q_rope, *rope)

    c_new, kr_new = _latent_kv(p, x, cfg, rope)       # (B,1,R), (B,1,rope)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), length, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), length, axis=1)
    new_cache = {"c_kv": c_kv, "k_rope": k_rope}
    new_len = length + 1

    w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    # absorb: q_abs (B,H,R) = q_nope · W_uk(h)ᵀ
    q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))

    from .attention import _can_seq_shard
    if _can_seq_shard(ctx, c_kv.shape[1]):
        o_lat = _mla_seq_sharded(q_abs, q_rope[:, 0], c_kv, k_rope, ctx,
                                 new_len, scale)
    else:
        s = (jnp.einsum("bhr,bsr->bhs", q_abs, c_kv.astype(jnp.float32))
             + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                          k_rope.astype(jnp.float32))) * scale
        pos = jnp.arange(c_kv.shape[1])
        s = jnp.where(pos[None, None, :] < new_len, s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhs,bsr->bhr", pr, c_kv.astype(jnp.float32))

    w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(b, 1, h * m.v_head_dim).astype(x.dtype)
    return o @ p["wo"], new_cache


def _mla_seq_sharded(q_abs, q_rope, c_kv, k_rope, ctx: ExecContext, length,
                     scale):
    """Flash-decoding combine over a latent cache sharded along sequence."""
    from .attention import _batch_subspec
    axis = ctx.model_axis
    smax = c_kv.shape[1]
    tp = ctx.mesh.shape[axis]
    bspec = _batch_subspec(ctx, q_abs.shape[0])

    def body(qa, qr, ck, kr, ln):
        shard = jax.lax.axis_index(axis)
        pos = shard * (smax // tp) + jnp.arange(ck.shape[1])
        s = (jnp.einsum("bhr,bsr->bhs", qa, ck.astype(jnp.float32))
             + jnp.einsum("bhd,bsd->bhs", qr.astype(jnp.float32),
                          kr.astype(jnp.float32))) * scale
        mask = pos[None, None, :] < ln
        s = jnp.where(mask, s, -1e30)
        m_loc = s.max(-1, keepdims=True)
        m_glob = jax.lax.pmax(m_loc, axis)
        m_safe = jnp.where(m_glob <= -1e29, 0.0, m_glob)
        pt = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        num = jnp.einsum("bhs,bsr->bhr", pt, ck.astype(jnp.float32))
        den = pt.sum(-1)[..., None]
        return jax.lax.psum(num, axis) / jnp.maximum(jax.lax.psum(den, axis), 1e-30)

    fn = compat.shard_map(
        body, mesh=ctx.shard_map_mesh,
        in_specs=(P(bspec, None, None), P(bspec, None, None),
                  P(bspec, axis, None), P(bspec, axis, None), P()),
        out_specs=P(bspec, None, None), check_vma=False)
    return fn(q_abs, q_rope, c_kv, k_rope, jnp.asarray(length))
