"""Mixture-of-Experts MLP: three dispatch implementations, one weight set.

* ``capacity``  (default) — tokens sorted by expert and packed into an
  ``(E, cap, D)`` buffer (cap = tokens/expert × capacity_factor); expert
  FFNs run as *batched dense GEMMs* (``einsum("ecd,edf->ecf")``).  This is
  the standard TPU MoE formulation (static shapes for the MXU, ~cf× the
  active FLOPs, overflow tokens dropped).  Its HLO is faithful on every
  backend — the dry-run lowers this path.
* ``ragged`` — dropless sort + ``lax.ragged_dot`` grouped GEMM with a
  custom ragged VJP (the default VJP — and the CPU *forward* lowering —
  densify to ``(E, T·K, ·)`` one-hot expansions; memory_analysis exposed
  an 11× blow-up).  TPU-native path; allclose-tested against capacity/
  dense oracles.
* ``a2a``   — all-to-all expert parallelism: whole experts per chip,
  tokens travel (2 activation all-to-alls) instead of a d_model psum
  (§Perf comparison plan).

Parallelism default is **expert-TP**: every chip holds a ``d_expert/TP``
slice of all experts (`d_ff` rides the model axis), so routing stays local
and the only collective is the down-projection psum a dense TP MLP needs.

DeepSeek-V3 simplifications (documented): softmax+top-8 routing stands in
for sigmoid + group-limited routing; the aux-loss-free bias update is not
modelled (training dynamics, not systems behaviour).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compat
from repro.kernels import ops
from .config import ModelConfig
from .context import ExecContext


# ---------------------------------------------------------------------------
# grouped GEMM with a ragged backward (TPU path)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def grouped_matmul(xs, w, gs):
    """xs (T, D) sorted by expert; w (E, D, F); gs (E,) → (T, F)."""
    return jax.lax.ragged_dot(xs, w, gs)


def _gm_fwd(xs, w, gs):
    return jax.lax.ragged_dot(xs, w, gs), (xs, w, gs)


def _gm_bwd(res, dy):
    xs, w, gs = res
    dxs = jax.lax.ragged_dot(dy, w.transpose(0, 2, 1), gs)
    if hasattr(jax.lax, "RaggedDotDimensionNumbers"):
        dn = jax.lax.RaggedDotDimensionNumbers(
            dot_dimension_numbers=(((0,), (0,)), ((), ())),
            lhs_ragged_dimensions=[0], rhs_group_dimensions=[])
        dw = jax.lax.ragged_dot_general(xs, dy, gs, dn)
    else:
        # 0.4.x fallback: dw[e] = xs_e^T @ dy_e as a one-hot contraction
        # (rows past sum(gs) get group id E → zero one-hot → no
        # contribution, matching ragged_dot's out-of-group treatment).
        # ~E× the ragged dot's dw FLOPs — acceptable only as compat.
        n_exp = w.shape[0]
        starts = jnp.cumsum(gs)
        seg = jnp.searchsorted(starts, jnp.arange(xs.shape[0]), side="right")
        onehot = jax.nn.one_hot(seg, n_exp, dtype=jnp.float32)
        dw = jnp.einsum("te,td,tf->edf", onehot,
                        xs.astype(jnp.float32), dy.astype(jnp.float32))
    return dxs.astype(xs.dtype), dw.astype(w.dtype), None


grouped_matmul.defvjp(_gm_fwd, _gm_bwd)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------

def _route(x2, router_w, moe):
    """tokens (T, D) → (weights (T,K), experts (T,K) int32, router probs)."""
    logits = (x2.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, moe.top_k)
    if moe.router_scale:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return top_w, top_e.astype(jnp.int32), probs


def _act(up, gate, cfg, ctx):
    if gate is not None:
        return ops.gated_act(gate, up, kind=cfg.act, backend=ctx.backend,
                             vvl=ctx.vvl)
    return ops.gated_act(up, None, kind=cfg.act, backend=ctx.backend,
                         vvl=ctx.vvl)


# ---------------------------------------------------------------------------
# capacity-packed batched-GEMM expert application
# ---------------------------------------------------------------------------

def _apply_experts_capacity(xs, e_ids, valid, p, cfg: ModelConfig,
                            ctx: ExecContext, cap: int):
    """Run rows ``xs (N, D)`` through experts ``e_ids (N,)``.

    Rows with ``valid=False`` — and rows beyond ``cap`` per expert — return
    zero contributions.  Static shapes throughout: the (E, cap, D) pack is
    what the MXU wants and what makes the HLO backend-faithful.
    """
    e = p["w_up"].shape[0]
    n, d = xs.shape
    fe = p["w_up"].shape[-1]

    key = jnp.where(valid, e_ids, e)               # invalid rows sort last
    order = jnp.argsort(key)
    es = jnp.clip(key[order], 0, e - 1)
    vs = valid[order]
    seg_start = jnp.searchsorted(key[order], jnp.arange(e), side="left")
    pos = jnp.arange(n) - seg_start[es]
    keep = vs & (pos < cap)
    slot = jnp.where(keep, pos, cap - 1)

    buf = jnp.zeros((e, cap, d), xs.dtype).at[es, slot].add(
        jnp.where(keep[:, None], jnp.take(xs, order, axis=0), 0))

    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    gate = (jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
            if "w_gate" in p else None)
    h2 = _act(up.reshape(e * cap, fe),
              None if gate is None else gate.reshape(e * cap, fe), cfg, ctx)
    down = jnp.einsum("ecf,efd->ecd", h2.reshape(e, cap, fe), p["w_down"])

    contrib_sorted = jnp.where(keep[:, None], down[es, slot], 0)
    inv = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n))
    return jnp.take(contrib_sorted, inv, axis=0)   # unsort → row order of xs


def _expert_ffn_local(x2, top_w, top_e, p, cfg: ModelConfig,
                      ctx: ExecContext):
    """Expert FFN on local tokens with (a slice of) all experts.

    x2: (T, D); returns (T, D) *partial* when d_expert is TP-sliced.
    """
    moe = cfg.moe
    t, d = x2.shape
    k = moe.top_k
    e = moe.num_experts
    flat_e = top_e.reshape(-1)                             # (T·K,)
    tok = jnp.arange(t * k) // k
    w_flat = top_w.reshape(-1)

    if ctx.moe_impl == "ragged":
        order = jnp.argsort(flat_e)                        # stable
        tok_s = order // k
        xs = jnp.take(x2, tok_s, axis=0)                   # (T·K, D)
        gs = jnp.bincount(flat_e, length=e)                # (E,)
        up = grouped_matmul(xs, p["w_up"], gs)
        gate = grouped_matmul(xs, p["w_gate"], gs) if "w_gate" in p else None
        h = _act(up, gate, cfg, ctx)
        down = grouped_matmul(h, p["w_down"], gs)          # (T·K, D)
        w_sorted = jnp.take(w_flat, order)
        out = jnp.zeros((t, d), jnp.float32)
        out = out.at[tok_s].add(down.astype(jnp.float32) * w_sorted[:, None])
        return out.astype(x2.dtype)

    # capacity path (default).  Floor of 8 slots/expert covers hot-expert
    # skew at small T (single-token decode would otherwise round to cap=1
    # and drop colliding tokens); never exceed T·K (dropless upper bound).
    cf = moe.capacity_factor or 1.25
    cap = min(t * k, max(int(-(-t * k * cf // e)), 8))
    xs = jnp.take(x2, tok, axis=0)                         # (T·K, D)
    contrib = _apply_experts_capacity(
        xs, flat_e, jnp.ones((t * k,), bool), p, cfg, ctx, cap)
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[tok].add(contrib.astype(jnp.float32) * w_flat[:, None])
    return out.astype(x2.dtype)


def _shared_ffn(p, x2, cfg, ctx):
    up = x2 @ p["w_up"]
    gate = x2 @ p["w_gate"] if "w_gate" in p else None
    return _act(up, gate, cfg, ctx) @ p["w_down"]


# ---------------------------------------------------------------------------
# expert-TP main path
# ---------------------------------------------------------------------------

def moe_mlp(p, x, cfg: ModelConfig, ctx: ExecContext):
    """MoE MLP over ``x: (B, S, D)``."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)

    if ctx.mesh is None or ctx.model_axis is None:
        top_w, top_e, _ = _route(x2, p["router"], cfg.moe)
        out = _expert_ffn_local(x2, top_w, top_e, p, cfg, ctx)
        if "shared" in p:
            out = out + _shared_ffn(p["shared"], x2, cfg, ctx)
        return out.reshape(b, s, d)

    # expert-TP under shard_map: tokens sharded over batch axes, expert
    # weights sliced over the model axis on d_ff; one psum at the end.
    axis = ctx.model_axis
    bspec = ctx.batch_axes if ctx.batch_axes else None

    def body(x_l, router_w, w_up, w_gate, w_down, shared_p):
        pl = {"w_up": w_up, "w_down": w_down}
        if w_gate is not None:
            pl["w_gate"] = w_gate
        top_w, top_e, _ = _route(x_l, router_w, cfg.moe)
        out = _expert_ffn_local(x_l, top_w, top_e, pl, cfg, ctx)
        if shared_p is not None:
            out = out + _shared_ffn(shared_p, x_l, cfg, ctx)
        return jax.lax.psum(out.astype(jnp.float32), axis).astype(x_l.dtype)

    w_gate = p.get("w_gate")
    shared_p = p.get("shared")
    shared_spec = (None if shared_p is None else
                   {"w_up": P(None, axis), "w_gate": P(None, axis),
                    "w_down": P(axis, None)})
    if shared_p is not None and "w_gate" not in shared_p:
        shared_spec = {"w_up": P(None, axis), "w_down": P(axis, None)}

    fn = compat.shard_map(
        body, mesh=ctx.shard_map_mesh,
        in_specs=(P(bspec, None), P(None, None),
                  P(None, None, axis),
                  (None if w_gate is None else P(None, None, axis)),
                  P(None, axis, None),
                  shared_spec),
        out_specs=P(bspec, None), check_vma=False)
    out = fn(x2, p["router"], p["w_up"], w_gate, p["w_down"], shared_p)
    return out.reshape(b, s, d)


# ---------------------------------------------------------------------------
# alternative: all-to-all expert parallelism (§Perf comparison plan)
# ---------------------------------------------------------------------------

def moe_a2a(p, x, cfg: ModelConfig, ctx: ExecContext, *, capacity_factor=1.25):
    """All-to-all EP: experts partitioned over the model axis (whole
    experts per chip); tokens travel to their experts' chips and back.

    Capacity-bounded in both hops — 2 all-to-alls of activation traffic
    instead of a d_model-wide psum, at the cost of load-imbalance drops.
    """
    axis = ctx.model_axis
    if ctx.mesh is None or axis is None:
        return moe_mlp(p, x, cfg, ctx)
    moe = cfg.moe
    b, s, d = x.shape
    tp = ctx.mesh.shape[axis]
    e_per = moe.num_experts // tp
    bspec = ctx.batch_axes if ctx.batch_axes else None

    def body(x_l, router_w, w_up, w_gate, w_down, shared_p):
        t_l = x_l.shape[0]
        k = moe.top_k
        cap = int(capacity_factor * t_l * k / tp) or 1
        top_w, top_e, _ = _route(x_l, router_w, moe)       # (T,K)
        dest = top_e // e_per                              # destination shard
        flat_dest = dest.reshape(-1)
        flat_tok = jnp.arange(t_l * k) // k
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)

        # slot each (token,choice) into its destination buffer
        order = jnp.argsort(flat_dest)                     # (T·K,)
        sorted_dest = flat_dest[order]
        seg_start = jnp.searchsorted(sorted_dest, jnp.arange(tp), side="left")
        pos_in_group = jnp.arange(t_l * k) - seg_start[sorted_dest]
        keep = pos_in_group < cap                          # capacity drop
        slot = jnp.where(keep, pos_in_group, 0)
        src = order

        # scatter with .add so capacity-dropped entries (all aimed at slot 0)
        # contribute zeros instead of clobbering the real slot-0 entry
        buf_x = jnp.zeros((tp, cap, d), x_l.dtype).at[sorted_dest, slot].add(
            jnp.where(keep[:, None], x_l[flat_tok[src]], 0.0))
        buf_e = jnp.zeros((tp, cap), jnp.int32).at[sorted_dest, slot].add(
            jnp.where(keep, flat_e[src] % e_per, 0))
        buf_valid = jnp.zeros((tp, cap), jnp.int32).at[sorted_dest, slot].add(
            keep.astype(jnp.int32)) > 0

        # exchange: dim0 (destination) splits across shards; received dim0
        # indexes the source shard.
        rx = jax.lax.all_to_all(buf_x, axis, split_axis=0, concat_axis=0)
        re = jax.lax.all_to_all(buf_e, axis, split_axis=0, concat_axis=0)
        rv = jax.lax.all_to_all(buf_valid, axis, split_axis=0, concat_axis=0)
        rx = rx.reshape(tp * cap, d)
        re_f = re.reshape(tp * cap)
        rv_f = rv.reshape(tp * cap)

        pl = {"w_up": w_up, "w_down": w_down}
        if w_gate is not None:
            pl["w_gate"] = w_gate
        cap2 = min(tp * cap,
                   max(int(-(-tp * cap * capacity_factor // e_per)), 8))
        down = _apply_experts_capacity(rx, re_f, rv_f, pl, cfg, ctx, cap2)
        back = jax.lax.all_to_all(down.reshape(tp, cap, d), axis,
                                  split_axis=0, concat_axis=0)
        # back: (tp, cap, d) — results for the tokens this shard dispatched

        out = jnp.zeros((t_l, d), jnp.float32)
        contrib = back[sorted_dest, slot]
        contrib = jnp.where(keep[:, None], contrib, 0.0)
        out = out.at[flat_tok[src]].add(
            contrib.astype(jnp.float32) * flat_w[src][:, None])
        if shared_p is not None:
            shared = _shared_ffn(shared_p, x_l, cfg, ctx)
            shared = jax.lax.psum(shared.astype(jnp.float32), axis)
            out = out + shared
        return out.astype(x_l.dtype)

    x2 = x.reshape(b * s, d)
    w_gate = p.get("w_gate")
    shared_p = p.get("shared")
    shared_spec = None
    if shared_p is not None:
        shared_spec = {k2: P(None, axis) if k2 != "w_down" else P(axis, None)
                       for k2 in shared_p}
    fn = compat.shard_map(
        body, mesh=ctx.shard_map_mesh,
        in_specs=(P(bspec, None), P(None, None),
                  P(axis, None, None),
                  (None if w_gate is None else P(axis, None, None)),
                  P(axis, None, None),
                  shared_spec),
        out_specs=P(bspec, None), check_vma=False)
    return fn(x2, p["router"], p["w_up"], w_gate, p["w_down"],
              shared_p).reshape(b, s, d)
