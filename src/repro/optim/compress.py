"""Gradient compression for the cross-pod (DCN) axis.

Multi-pod training reduces gradients twice: fast ICI reduction inside a pod
(uncompressed — ICI is cheap) and a slow DCN reduction across pods.  The
DCN hop is where compression pays: int8 absmax block quantisation with
**error feedback** (the quantisation residual is carried into the next
step's payload, the classic EF recipe that keeps compressed SGD/Adam
convergent).

Exactness on the wire: per-pod scales differ, so a plain psum of int8 codes
is *not* the true sum.  We instead ``all_gather`` the int8 codes (+ fp32
per-block scales, negligible) and form the weighted sum locally — exact
reconstruction of Σ_p dequant_p, and the HLO carries ``all-gather(s8)``:
n·(P-1)/P bytes per chip vs 2·n·(P-1)/P·4 bytes for an fp32 ring
all-reduce ⇒ ~8× fewer cross-pod bytes (P = pod count).  §Perf measures
the delta on the multi-pod mesh.  For large P a hierarchical
(quantise → reduce-scatter int8 → re-quantise → all-gather) ladder drops
the gather term to 2·n/P·1 B; with P=2 pods the flat gather is already
optimal.

``compressed_psum_mean`` must run *inside* ``shard_map`` where ``axis`` is
a manual axis (see ``repro.runtime.steps``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import compat


def compress_init(grads):
    """Zero error-feedback buffers, twin to the grad tree (fp32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize(x, block: int):
    flat = x.reshape(-1)
    n = flat.shape[0]
    npad = -(-n // block) * block
    fb = jnp.pad(flat, (0, npad - n)).reshape(-1, block)
    scale = jnp.max(jnp.abs(fb), axis=-1, keepdims=True)
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(fb / safe * 127.0), -127, 127).astype(jnp.int8)
    return codes, (scale / 127.0).astype(jnp.float32), n


def compressed_psum_mean(grads, error, axis: str, *, block: int = 1024):
    """EF-int8 mean-all-reduce of a grad tree over manual axis ``axis``.

    Returns ``(mean fp32 grads, new error buffers)``.
    """
    npods = compat.axis_size(axis)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        codes, scale, n = _quantize(x, block)
        sent = (codes.astype(jnp.float32) * scale).reshape(-1)[:n] \
            .reshape(g.shape)
        new_e = x - sent                            # residual → next step
        all_codes = jax.lax.all_gather(codes, axis)     # (P, nb, block) int8
        all_scale = jax.lax.all_gather(scale, axis)     # (P, nb, 1) fp32
        total = (all_codes.astype(jnp.float32) * all_scale).sum(0)
        total = total.reshape(-1)[:n].reshape(g.shape)
        return total / npods, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
