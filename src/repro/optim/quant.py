"""Shape-preserving block-wise 8-bit quantisation for optimizer moments.

Standard absmax block quantisation (cf. 8-bit Adam), blocked along the
**last axis** with the codes keeping the tensor's exact shape:

    codes: int8, same shape as x
    scale: fp32, x.shape[:-1] + (ceil(last/block),)

Shape preservation is the point: the codes take the *parameter's own
NamedSharding* unchanged, so quantise/dequantise are shard-local under
GSPMD.  (A flat re-blocked layout forces a cross-shard reshape that the
partitioner resolves by full replication — a measured 30× temp-memory
blow-up on the 671B config.)

Memory: 1 byte/elem + 4·lead/block ≈ 1.016 bytes/elem at block=256, vs 4
for fp32 moments — the 671B Adam state drops from 5.5 TB to 1.4 TB.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    codes: jax.Array          # int8, shape == original
    scale: jax.Array          # fp32, (*lead, nblocks); scale already /127


def quantize_blockwise(x: jax.Array, block: int = 256) -> QTensor:
    x = x.astype(jnp.float32)
    if x.ndim == 0:
        x = x[None]
        q = quantize_blockwise(x, block)
        return QTensor(q.codes[0], q.scale[0])
    last = x.shape[-1]
    nb = -(-last // min(block, last))
    bs = -(-last // nb)          # dequantize re-derives this from (last, nb)
    pad = nb * bs - last
    xp = jnp.pad(x, ((0, 0),) * (x.ndim - 1) + ((0, pad),)) if pad else x
    xb = xp.reshape(*x.shape[:-1], nb, bs)
    scale = jnp.max(jnp.abs(xb), axis=-1)
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(xb / safe[..., None] * 127.0),
                     -127, 127).astype(jnp.int8)
    codes = codes.reshape(*x.shape[:-1], nb * bs)
    if pad:
        codes = codes[..., :last]
    return QTensor(codes, (scale / 127.0).astype(jnp.float32))


def dequantize_blockwise(q: QTensor, shape, dtype=jnp.float32) -> jax.Array:
    codes, scale = q.codes, q.scale
    if codes.ndim == 0:
        return (codes.astype(jnp.float32) * scale).astype(dtype)
    last = codes.shape[-1]
    nb = scale.shape[-1]
    bs = -(-last // nb)
    pad = nb * bs - last
    cp = jnp.pad(codes, ((0, 0),) * (codes.ndim - 1) + ((0, pad),)) \
        if pad else codes
    xb = cp.reshape(*codes.shape[:-1], nb, bs).astype(jnp.float32)
    out = (xb * scale[..., None]).reshape(*codes.shape[:-1], nb * bs)
    if pad:
        out = out[..., :last]
    return out.reshape(shape).astype(dtype)


def tree_quantize(tree, block: int = 256):
    return jax.tree.map(lambda x: quantize_blockwise(x, block), tree)


def tree_dequantize(qtree, shapes_tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda q, s: dequantize_blockwise(q, s.shape, dtype),
        qtree, shapes_tree,
        is_leaf=lambda x: isinstance(x, QTensor))
