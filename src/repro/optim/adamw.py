"""AdamW with optional block-quantised (8-bit) moments.

State layout (twin pytree to params):
  fp32 moments:   {"m": tree, "v": tree, "step": ()}
  8-bit moments:  {"m": QTensor tree, "v": QTensor tree, "step": ()}

The update is written once over fp32 moments; the 8-bit path de/re-quantises
around it (error stays bounded because absmax block scaling re-fits every
step — the standard 8-bit Adam recipe).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from .quant import QTensor, dequantize_blockwise, quantize_blockwise


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                  # used when schedule not supplied
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0            # 0 disables
    quantize_moments: bool = False
    quant_block: int = 256


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params, cfg: AdamWConfig):
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.quantize_moments:
            return quantize_blockwise(z, cfg.quant_block)
        return z
    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig, *,
                 lr: Optional[jax.Array] = None):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = jnp.asarray(cfg.lr if lr is None else lr, jnp.float32)

    gnorm = global_norm(grads)
    if cfg.clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_q = lambda x: isinstance(x, QTensor)

    def upd_dense(p, g, m, v, decay_ok=True):
        g = g.astype(jnp.float32)
        if is_q(m):
            m = dequantize_blockwise(m, p.shape)
            v = dequantize_blockwise(v, p.shape)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / c1
        vhat = v / c2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and decay_ok:     # decay matrices only
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        if cfg.quantize_moments:
            m = quantize_blockwise(m, cfg.quant_block)
            v = quantize_blockwise(v, cfg.quant_block)
        return newp, m, v

    # Large scan-stacked leaves stream the update one layer-slice at a
    # time (lax.map over dim 0) — otherwise the dequantised fp32 moments
    # of a multi-GB leaf are all live at once (a measured 30+ GiB/chip
    # peak on the 671B expert stacks).
    STREAM_ELEMS = 1 << 26

    def upd(p, g, m, v):
        decay_ok = p.ndim >= 2
        if p.ndim >= 2 and p.shape[0] > 1 and p.size > STREAM_ELEMS:
            def one(args):
                return upd_dense(*args, decay_ok=decay_ok)
            return jax.lax.map(one, (p, g, m, v))
        return upd_dense(p, g, m, v, decay_ok=decay_ok)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]

    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in out])
    new_state = {
        "m": tdef.unflatten([o[1] for o in out]),
        "v": tdef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
