"""Optimizer substrate: AdamW, schedules, quantised state, compression."""
from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedule import warmup_cosine
from .quant import quantize_blockwise, dequantize_blockwise
from .compress import compressed_psum_mean, compress_init

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "global_norm",
    "warmup_cosine", "quantize_blockwise", "dequantize_blockwise",
    "compressed_psum_mean", "compress_init",
]
