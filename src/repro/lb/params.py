"""Physical parameters of the binary-fluid model.

Defaults follow the symmetric-quench (spinodal decomposition) setup used in
Ludwig's binary benchmark family: double-well potential
V(φ) = -A/2 φ² + B/4 φ⁴ with A=B (minima at φ=±1), interfacial term κ/2|∇φ|²,
relaxation times τ (viscosity ν=(τ-1/2)/3) and τ_φ (mobility M=Γ(τ_φ-1/2)).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class LBParams:
    A: float = 0.0625
    B: float = 0.0625
    kappa: float = 0.04
    tau: float = 1.0
    tau_phi: float = 1.0
    gamma: float = 1.0
    rho0: float = 1.0

    @property
    def viscosity(self) -> float:
        return (self.tau - 0.5) / 3.0

    @property
    def interface_width(self) -> float:
        return (2.0 * self.kappa / self.A) ** 0.5

    @property
    def surface_tension(self) -> float:
        return (8.0 * self.kappa * self.A / 9.0) ** 0.5

    def as_kwargs(self) -> dict:
        return dict(A=self.A, B=self.B, kappa=self.kappa, tau=self.tau,
                    tau_phi=self.tau_phi, gamma=self.gamma)
