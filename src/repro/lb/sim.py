"""Binary-fluid simulation driver (the end-to-end Ludwig-style application).

One timestep:
  1. moment pass:   φ = Σ_i g_i                      (site-local)
  2. stencil pass:  ∇φ, ∇²φ                          (nearest-neighbour)
  3. collision:     targetDP kernel (f, g, φ, ∇φ, ∇²φ) → (f', g')   ← hot spot
  4. streaming:     f'_q(x+c_q) ← f'_q(x)            (shift + halo)

Runs single-device (periodic stencil gather) or mesh-sharded (slab
decomposition along X under ``shard_map`` with ``ppermute`` halo exchange).
The collision target (executor + VVL) is a launch-time
:class:`repro.core.Target` switch — the paper's portability contract.

``fused`` selects the hot-loop fusion strategy (all trajectories match
state-for-state):

* ``False`` — the 4-launch unfused pipeline above.
* ``"one_launch"`` (or ``True``) — one stencil launch per step
  (stream → φ moments → ∇φ/∇²φ → collide; no intermediate full-lattice
  arrays), over the radius-2 composed g-neighbourhood.
* ``"two_launch"`` — ROADMAP stencil-memory stage (a): launch A streams
  g's moments into a 1-component φ intermediate, launch B (radius-1
  stencils only) streams/collides against it — the gathered neighbour
  stack shrinks from ``(19+57)·19`` to ``2·19·19 + 7`` rows.

In every fused mode the iterated state is the pre-stream populations
w = collide(u), since (stream∘collide)ⁿ = stream ∘ (collide∘stream)ⁿ⁻¹ ∘
collide — the first collide and last stream run once as separate launches,
so fused and unfused trajectories match state-for-state.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import Target, compat, executor_wants
from repro.kernels import ops
from repro.kernels.lb_collision import NVEL, WEIGHTS
from . import stencil
from .params import LBParams

_FUSED_MODES = (False, "one_launch", "two_launch")


@dataclass
class LBState:
    f: jax.Array          # (19, X, Y, Z)
    g: jax.Array          # (19, X, Y, Z)
    step: int = 0

    @property
    def grid_shape(self) -> tuple[int, int, int]:
        return self.f.shape[1:]


def _collide_flat(f, g, phi, gradphi, del2phi, *, params: LBParams,
                  target: Target):
    """Flatten grids to SoA site arrays, run the collision kernel, restore."""
    gs = f.shape[1:]
    n = int(np.prod(gs))
    fo, go = ops.lb_collision(
        f.reshape(NVEL, n), g.reshape(NVEL, n), phi.reshape(1, n),
        gradphi.reshape(3, n), del2phi.reshape(1, n),
        target=target, **params.as_kwargs())
    return fo.reshape(NVEL, *gs), go.reshape(NVEL, *gs)


class BinaryFluidSim:
    """Spinodal-decomposition / droplet simulation of a binary mixture."""

    def __init__(self, grid_shape=(32, 32, 32), params: LBParams | None = None,
                 *, target: Target | str | None = None,
                 backend: str = "xla", vvl: int = 128,
                 mesh: Mesh | None = None, shard_axis: str = "data",
                 fused: bool | str = False, dtype=jnp.float32):
        self.grid_shape = tuple(int(s) for s in grid_shape)
        self.params = params or LBParams()
        if target is None:
            target = Target(backend, vvl=vvl, mesh=mesh,
                            shard_axis=shard_axis if mesh is not None
                            else None)
        else:
            target = ops.op_target(target, default_vvl=vvl)
            if mesh is None:
                mesh = target.mesh
        self.target = target
        # Stencil-only executors (wants="halo_extended", e.g.
        # pallas_windowed) cannot run the sim's pointwise launches
        # (collision, moments); those fall back to the xla executor at
        # the same VVL while every stencil launch keeps the requested
        # target — the capability contract, applied per launch.
        try:
            stencil_only = executor_wants(target.executor) == "halo_extended"
        except ValueError:
            stencil_only = False    # custom executor registered later
        if stencil_only and not fused:
            # the unfused pipeline is pointwise-dominated (collision) and
            # its stream/gradient launches run on the default executor —
            # a stencil-only target would silently never execute
            raise ValueError(
                f"target executor {target.executor!r} is stencil-only "
                f"(wants='halo_extended'); it only runs the fused stencil "
                f"launches — pass fused='one_launch' or 'two_launch'")
        self.pointwise_target = (target.with_(backend="xla",
                                              interpret=False)
                                 if stencil_only else target)
        self.backend = target.executor          # legacy introspection
        self.vvl = target.resolve_vvl()
        self.mesh = mesh
        self.shard_axis = shard_axis
        if fused is True:
            fused = "one_launch"
        if fused not in _FUSED_MODES:
            raise ValueError(f"fused must be one of {_FUSED_MODES} (or "
                             f"True ≡ 'one_launch'), got {fused!r}")
        self.fused = fused
        self.dtype = dtype
        if mesh is not None:
            nsh = mesh.shape[shard_axis]
            if self.grid_shape[0] % nsh != 0:
                raise ValueError(
                    f"X extent {self.grid_shape[0]} not divisible by "
                    f"mesh axis {shard_axis}={nsh}")
            if fused and self.grid_shape[0] // nsh < 2:
                # the width-2 ghost exchange reads from the nearest
                # neighbour only — each slab must hold the full halo
                raise ValueError(
                    f"fused sharding needs a local X slab >= 2 planes; "
                    f"got {self.grid_shape[0]}/{nsh} = "
                    f"{self.grid_shape[0] // nsh}")
        self._step_fn = self._build_step()
        if fused:
            self._collide_fn, self._fused_fn, self._stream_fn = \
                self._build_fused()

    # -- initialisation ----------------------------------------------------

    def init_spinodal(self, seed: int = 0, noise: float = 0.05) -> LBState:
        """Symmetric quench: φ = small random noise, fluid at rest."""
        rng = np.random.default_rng(seed)
        phi0 = noise * (2.0 * rng.random(self.grid_shape) - 1.0)
        return self._equilibrium_state(phi0)

    def init_droplet(self, radius: float | None = None) -> LBState:
        """A φ=+1 droplet in a φ=-1 bath (surface-tension/Laplace tests)."""
        gs = self.grid_shape
        radius = radius or min(gs) / 4.0
        axes = [np.arange(s) - s / 2.0 + 0.5 for s in gs]
        r = np.sqrt(sum(a ** 2 for a in np.meshgrid(*axes, indexing="ij")))
        width = self.params.interface_width
        phi0 = np.tanh((radius - r) / width)
        return self._equilibrium_state(phi0)

    def _equilibrium_state(self, phi0: np.ndarray) -> LBState:
        w = WEIGHTS.reshape(NVEL, 1, 1, 1)
        f0 = (w * self.params.rho0 * np.ones_like(phi0)[None]).astype(self.dtype)
        g0 = (w * phi0[None]).astype(self.dtype)
        sharding = self._sharding()
        return LBState(jax.device_put(jnp.asarray(f0), sharding),
                       jax.device_put(jnp.asarray(g0), sharding))

    def _sharding(self):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(None, self.shard_axis, None, None))

    # -- one timestep --------------------------------------------------------

    def _build_step(self):
        params, target = self.params, self.pointwise_target

        def step_local(f, g):
            phi = g.sum(0)
            gradphi, del2phi = stencil.gradients(phi)
            f, g = _collide_flat(f, g, phi, gradphi, del2phi,
                                 params=params, target=target)
            return stencil.stream(f), stencil.stream(g)

        if self.mesh is None:
            return jax.jit(step_local)

        axis = self.shard_axis

        def step_sharded(f, g):
            phi = g.sum(0)
            gradphi, del2phi = stencil.gradients_sharded(phi, axis)
            f, g = _collide_flat(f, g, phi, gradphi, del2phi,
                                 params=params, target=target)
            return stencil.stream_sharded(f, axis), stencil.stream_sharded(g, axis)

        spec = P(None, axis, None, None)
        shmapped = compat.shard_map(step_sharded, mesh=self.mesh,
                                 in_specs=(spec, spec), out_specs=(spec, spec))
        return jax.jit(shmapped)

    def _build_fused(self):
        """(collide, fused, stream) jitted fns for the fused regime.

        The hot loop iterates the *pre-stream* state w = collide(u):
        n unfused steps (stream∘collide)ⁿ equal stream ∘ fusedⁿ⁻¹ ∘ collide,
        where ``fused`` is one (or two, in two_launch mode) stencil
        launches with no intermediate full-lattice arrays beyond the
        two_launch φ scalar.
        """
        params, target, mode = self.params, self.target, self.fused
        pw_target = self.pointwise_target
        gs = self.grid_shape
        n = int(np.prod(gs))

        def fused_local(f, g):
            fo, go = ops.lb_fused_step(
                f.reshape(NVEL, n), g.reshape(NVEL, n), grid_shape=gs,
                mode=mode, target=target, **params.as_kwargs())
            return fo.reshape(NVEL, *gs), go.reshape(NVEL, *gs)

        def collide_local(f, g):
            phi = g.sum(0)
            gradphi, del2phi = stencil.gradients(phi)
            return _collide_flat(f, g, phi, gradphi, del2phi,
                                 params=params, target=pw_target)

        def stream_local(f, g):
            return stencil.stream(f), stencil.stream(g)

        if self.mesh is None:
            return (jax.jit(collide_local), jax.jit(fused_local),
                    jax.jit(stream_local))

        axis = self.shard_axis

        def fused_sharded(f, g):
            # 2-plane ppermute halo exchange feeds the radius-2 ghost
            # dependency (one_launch: the composed stencil's window;
            # two_launch: launch A's +1 ring of streamed φ plus launch
            # B's radius-1 stencils).
            fe = stencil._extend_x(f, axis, 2)
            ge = stencil._extend_x(g, axis, 2)
            local = f.shape[1:]
            fo, go = ops.lb_fused_step(
                fe.reshape(NVEL, -1), ge.reshape(NVEL, -1),
                grid_shape=local, halo=(2, 0, 0), mode=mode, target=target,
                **params.as_kwargs())
            return fo.reshape(NVEL, *local), go.reshape(NVEL, *local)

        def collide_sharded(f, g):
            phi = g.sum(0)
            gradphi, del2phi = stencil.gradients_sharded(phi, axis)
            return _collide_flat(f, g, phi, gradphi, del2phi,
                                 params=params, target=pw_target)

        def stream_sharded(f, g):
            return (stencil.stream_sharded(f, axis),
                    stencil.stream_sharded(g, axis))

        spec = P(None, axis, None, None)
        # pallas_call has no shard_map replication rule (0.4.x): drop the
        # check when the fused launch dispatches to a Pallas executor.
        check = self.target.executor == "xla" and \
            self.pointwise_target.executor == "xla"

        def shmap(fn):
            return jax.jit(compat.shard_map(
                fn, mesh=self.mesh, in_specs=(spec, spec),
                out_specs=(spec, spec), check_vma=check))

        return shmap(collide_sharded), shmap(fused_sharded), \
            shmap(stream_sharded)

    def step(self, state: LBState, nsteps: int = 1) -> LBState:
        f, g = state.f, state.g
        if nsteps <= 0:
            return state
        if self.fused:
            f, g = self._collide_fn(f, g)
            for _ in range(nsteps - 1):
                f, g = self._fused_fn(f, g)
            f, g = self._stream_fn(f, g)
        else:
            for _ in range(nsteps):
                f, g = self._step_fn(f, g)
        return LBState(f, g, state.step + nsteps)

    def run_scanned(self, state: LBState, nsteps: int) -> LBState:
        """nsteps under one jitted lax.scan (for benchmarking)."""
        if nsteps <= 0:
            return state
        if self.fused:
            collide, fused, stream_ = \
                self._collide_fn, self._fused_fn, self._stream_fn

            @jax.jit
            def many(f, g):
                f, g = collide(f, g)

                def body(carry, _):
                    return fused(*carry), None
                (f, g), _ = jax.lax.scan(body, (f, g), None,
                                         length=nsteps - 1)
                return stream_(f, g)
        else:
            fn = self._step_fn

            @jax.jit
            def many(f, g):
                def body(carry, _):
                    return fn(*carry), None
                (f, g), _ = jax.lax.scan(body, (f, g), None, length=nsteps)
                return f, g

        f, g = many(state.f, state.g)
        return LBState(f, g, state.step + nsteps)

    # -- observables ---------------------------------------------------------

    def observables(self, state: LBState) -> dict:
        f, g = state.f, state.g
        phi = g.sum(0)
        rho = f.sum(0)
        return {
            "mass": float(rho.sum()),
            "phi_total": float(phi.sum()),
            "phi_min": float(phi.min()),
            "phi_max": float(phi.max()),
            "phi_var": float(phi.var()),
            "rho_min": float(rho.min()),
            "nan": bool(jnp.isnan(f).any() | jnp.isnan(g).any()),
        }
