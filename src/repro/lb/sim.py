"""Binary-fluid simulation driver (the end-to-end Ludwig-style application).

One timestep:
  1. moment pass:   φ = Σ_i g_i                      (site-local)
  2. stencil pass:  ∇φ, ∇²φ                          (nearest-neighbour)
  3. collision:     targetDP kernel (f, g, φ, ∇φ, ∇²φ) → (f', g')   ← hot spot
  4. streaming:     f'_q(x+c_q) ← f'_q(x)            (shift + halo)

Since the ``tdp.Program`` redesign this driver is a *thin assembly*: the
step shapes live in :mod:`repro.lb.programs` as declarative stage graphs
and everything that used to be hand-wired here — per-launch halo
exchange, executor fallbacks for pointwise launches, intermediate
buffers, ``lax.scan`` stepping — is owned by
:class:`repro.core.Program`:

* the halo schedule is back-propagated per step (**one** ghost exchange
  round per field per step, shared across stages, under ``shard_map``);
* pointwise stages route to the ``"xla"`` executor automatically when
  the requested target is stencil-only (``wants="halo_extended"``);
* :meth:`BinaryFluidSim.run` executes n steps under one jitted
  ``lax.scan`` (``donate=True`` ping-pongs the field buffers).

``fused`` selects the hot-loop fusion strategy (all trajectories match
state-for-state):

* ``False`` — the 4-launch unfused pipeline above (one 5-stage Program).
* ``"one_launch"`` (or ``True``) — one stencil stage per step
  (stream → φ moments → ∇φ/∇²φ → collide; no intermediate full-lattice
  arrays), over the radius-2 composed g-neighbourhood.
* ``"two_launch"`` — ROADMAP stencil-memory stage (a): launch A streams
  g's moments into a 1-component φ intermediate, launch B (radius-1
  stencils only) streams/collides against it.

In every fused mode the iterated state is the pre-stream populations
w = collide(u), since (stream∘collide)ⁿ = stream ∘ (collide∘stream)ⁿ⁻¹ ∘
collide — the prologue (collide) and epilogue (stream) run once as their
own Programs, so fused and unfused trajectories match state-for-state.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import Target, executor_wants
from repro.kernels import ops
from repro.kernels.lb_collision import NVEL, WEIGHTS
from . import programs as lbp
from .params import LBParams

_FUSED_MODES = (False, "one_launch", "two_launch")


@dataclass
class LBState:
    f: jax.Array          # (19, X, Y, Z)
    g: jax.Array          # (19, X, Y, Z)
    step: int = 0

    @property
    def grid_shape(self) -> tuple[int, int, int]:
        return self.f.shape[1:]


class BinaryFluidSim:
    """Spinodal-decomposition / droplet simulation of a binary mixture.

    The compiled step graphs are exposed as ``sim.programs`` — a dict of
    :class:`repro.core.CompiledProgram`: ``{"step": ...}`` for the
    unfused regime, ``{"collide": ..., "fused": ..., "stream": ...}``
    for the fused ones (prologue / hot-loop body / epilogue).
    """

    def __init__(self, grid_shape=(32, 32, 32), params: LBParams | None = None,
                 *, target: Target | str | None = None,
                 backend: str = "xla", vvl: int = 128,
                 mesh: Mesh | None = None,
                 shard_axis: str | tuple[str, ...] = "data",
                 overlap: bool | None = None,
                 fused: bool | str = False, dtype=jnp.float32):
        self.grid_shape = tuple(int(s) for s in grid_shape)
        self.params = params or LBParams()
        if target is None:
            target = Target(backend, vvl=vvl, mesh=mesh,
                            shard_axis=shard_axis if mesh is not None
                            else None)
        else:
            target = ops.op_target(target, default_vvl=vvl)
            if mesh is None:
                mesh = target.mesh
        self.target = target
        # Program compilation routes pointwise stages to xla under a
        # stencil-only target, but the *unfused* pipeline is
        # pointwise-dominated (collision) — requesting a stencil-only
        # executor for it would silently benchmark xla, so fail fast.
        try:
            stencil_only = executor_wants(target.executor) == "halo_extended"
        except ValueError:
            stencil_only = False    # custom executor registered later
        if stencil_only and not fused:
            raise ValueError(
                f"target executor {target.executor!r} is stencil-only "
                f"(wants='halo_extended'); it only runs the fused stencil "
                f"launches — pass fused='one_launch' or 'two_launch'")
        self.backend = target.executor          # legacy introspection
        self.vvl = target.resolve_vvl()
        self.mesh = mesh
        self.shard_axis = shard_axis
        if fused is True:
            fused = "one_launch"
        if fused not in _FUSED_MODES:
            raise ValueError(f"fused must be one of {_FUSED_MODES} (or "
                             f"True ≡ 'one_launch'), got {fused!r}")
        self.fused = fused
        self.dtype = dtype

        consts = lbp.collision_consts(dtype=np.dtype(dtype),
                                      **self.params.as_kwargs())
        kw = dict(grid_shape=self.grid_shape, mesh=mesh,
                  shard_axis=shard_axis, overlap=overlap)
        if fused:
            self.programs = {
                "collide": lbp.collide_program(consts).compile(target, **kw),
                "fused": lbp.fused_program(fused, consts).compile(target,
                                                                  **kw),
                "stream": lbp.stream_program().compile(target, **kw),
            }
        else:
            self.programs = {
                "step": lbp.unfused_step_program(consts).compile(target,
                                                                 **kw),
            }

    # -- initialisation ----------------------------------------------------

    def init_spinodal(self, seed: int = 0, noise: float = 0.05) -> LBState:
        """Symmetric quench: φ = small random noise, fluid at rest."""
        rng = np.random.default_rng(seed)
        phi0 = noise * (2.0 * rng.random(self.grid_shape) - 1.0)
        return self._equilibrium_state(phi0)

    def init_droplet(self, radius: float | None = None) -> LBState:
        """A φ=+1 droplet in a φ=-1 bath (surface-tension/Laplace tests)."""
        gs = self.grid_shape
        radius = radius or min(gs) / 4.0
        axes = [np.arange(s) - s / 2.0 + 0.5 for s in gs]
        r = np.sqrt(sum(a ** 2 for a in np.meshgrid(*axes, indexing="ij")))
        width = self.params.interface_width
        phi0 = np.tanh((radius - r) / width)
        return self._equilibrium_state(phi0)

    def _equilibrium_state(self, phi0: np.ndarray) -> LBState:
        w = WEIGHTS.reshape(NVEL, 1, 1, 1)
        f0 = (w * self.params.rho0 * np.ones_like(phi0)[None]).astype(self.dtype)
        g0 = (w * phi0[None]).astype(self.dtype)
        sharding = self._sharding()
        return LBState(jax.device_put(jnp.asarray(f0), sharding),
                       jax.device_put(jnp.asarray(g0), sharding))

    def _sharding(self):
        if self.mesh is None:
            return None
        axes = ((self.shard_axis,) if isinstance(self.shard_axis, str)
                else tuple(self.shard_axis))
        spec = P(*((None,) + axes + (None,) * (3 - len(axes))))
        return NamedSharding(self.mesh, spec)

    # -- stepping ------------------------------------------------------------

    def step(self, state: LBState, nsteps: int = 1) -> LBState:
        """``nsteps`` steps, one jitted Program step per iteration
        (python loop — bit-identical to :meth:`run`'s scan)."""
        if nsteps <= 0:
            return state
        s = {"f": state.f, "g": state.g}
        if self.fused:
            s = self.programs["collide"].step(s)
            for _ in range(nsteps - 1):
                s = self.programs["fused"].step(s)
            s = self.programs["stream"].step(s)
        else:
            for _ in range(nsteps):
                s = self.programs["step"].step(s)
        return LBState(s["f"], s["g"], state.step + nsteps)

    def run(self, state: LBState, nsteps: int, *,
            donate: bool = False) -> LBState:
        """``nsteps`` steps under one jitted ``lax.scan`` per Program.

        ``donate=True`` donates the hot-loop field buffers (ping-pong
        aliasing, no per-step reallocation) — the input state is consumed.
        """
        if nsteps <= 0:
            return state
        s = {"f": state.f, "g": state.g}
        if self.fused:
            s = self.programs["collide"].step(s)
            s = self.programs["fused"].run(s, nsteps - 1, donate=donate)
            s = self.programs["stream"].step(s)
        else:
            s = self.programs["step"].run(s, nsteps, donate=donate)
        return LBState(s["f"], s["g"], state.step + nsteps)

    def run_scanned(self, state: LBState, nsteps: int) -> LBState:
        """Pre-Program spelling of :meth:`run` (kept for callers; see the
        migration table in docs/targetdp_api.md)."""
        return self.run(state, nsteps)

    # -- observables ---------------------------------------------------------

    def observables(self, state: LBState) -> dict:
        f, g = state.f, state.g
        phi = g.sum(0)
        rho = f.sum(0)
        return {
            "mass": float(rho.sum()),
            "phi_total": float(phi.sum()),
            "phi_min": float(phi.min()),
            "phi_max": float(phi.max()),
            "phi_var": float(phi.var()),
            "rho_min": float(rho.min()),
            "nan": bool(jnp.isnan(f).any() | jnp.isnan(g).any()),
        }
