"""The lattice-Boltzmann step graphs — LB specs assembled into
:class:`repro.core.Program`\\ s.

One module owns every LB step shape; :class:`repro.lb.sim.BinaryFluidSim`
and :func:`repro.kernels.ops.lb_fused_step` are thin consumers.  All the
host-side glue the pre-Program driver hand-wired — halo exchange widths,
the streamed-φ intermediate's ghost-ring recompute, pointwise-stage
executor fallbacks, scan stepping — now falls out of the Program
machinery (:mod:`repro.core.program`).

The graphs (fields ``f``/``g`` are the persistent, double-buffered
populations):

* :func:`unfused_step_program` — the 4-launch pipeline as 5 stages:
  moments → gradients → collide → stream f → stream g, with ``phi`` /
  ``gradphi`` / ``del2phi`` and the post-collision populations as
  step-local intermediates.  Its halo schedule back-propagates to
  *one* exchange round of ``{f: 1, g: 2}`` planes — moments and collide
  recompute a ghost ring locally instead of exchanging φ and the
  post-collision state (three exchange rounds in the old driver).
* :func:`fused_program` — the pre-stream iteration body:
  ``one_launch`` (one radius-2 stage) or ``two_launch`` (streamed-φ
  launch A + radius-1 launch B; schedule ``{f: 1, g: 2}``).
* :func:`collide_program` / :func:`stream_program` — the fused regime's
  prologue (u → w = collide(u)) and epilogue (final stream).
"""
from __future__ import annotations

import numpy as np

from repro.core import Program, TargetConst, program, stage
from repro.kernels.lb_collision import CV, WEIGHTS

from .stencil import (
    COLLIDE_SPEC,
    FUSED_SPEC,
    FUSED_TWO_SPEC,
    GRAD6_SPEC,
    MOMENT_SPEC,
    PHI_STREAM_SPEC,
    STREAM_SPEC,
)

FIELDS = ("f", "g")


def collision_consts(dtype=np.float32, **phys) -> dict:
    """The collision stages' ``TARGET_CONST`` bindings: weight vector and
    velocity set (content-hashed :class:`TargetConst`\\ s) plus the
    physical scalars (``A``, ``B``, ``kappa``, ``tau``, ``tau_phi``,
    ``gamma``)."""
    return dict(w=TargetConst(np.asarray(WEIGHTS, dtype=dtype)),
                c=TargetConst(np.asarray(CV, dtype=dtype)), **phys)


def _collide_stages(consts, writes):
    return [
        stage(MOMENT_SPEC, reads="g", writes="phi", name="moments"),
        stage(GRAD6_SPEC, reads="phi", writes=("gradphi", "del2phi"),
              name="gradients"),
        stage(COLLIDE_SPEC, reads=("f", "g", "phi", "gradphi", "del2phi"),
              writes=writes, consts=consts, name="collide"),
    ]


def unfused_step_program(consts) -> Program:
    """One full unfused timestep (moments → ∇φ/∇²φ → collide → stream)."""
    stages = _collide_stages(consts, writes=("fc", "gc")) + [
        stage(STREAM_SPEC, reads="fc", writes="f", name="stream_f"),
        stage(STREAM_SPEC, reads="gc", writes="g", name="stream_g"),
    ]
    return program("lb_step", stages, fields=FIELDS)


def collide_program(consts) -> Program:
    """The fused regime's prologue: u → w = collide(u) (pre-stream)."""
    return program("lb_collide", _collide_stages(consts, writes=FIELDS),
                   fields=FIELDS)


def stream_program() -> Program:
    """The fused regime's epilogue: one streaming pass of both fields."""
    return program("lb_stream", [
        stage(STREAM_SPEC, reads="f", writes="f", name="stream_f"),
        stage(STREAM_SPEC, reads="g", writes="g", name="stream_g"),
    ], fields=FIELDS)


def fused_program(mode, consts) -> Program:
    """The fused hot-loop body w → w' (stream ∘ collide over the
    pre-stream state), in either fusion strategy (bit-identical math):

    * ``"one_launch"`` — one stencil stage over the radius-2 composed
      g-neighbourhood (``FUSED_SPEC``);
    * ``"two_launch"`` — launch A streams g's moments into the
      1-component ``phi_s`` intermediate, launch B (radius-1 stencils)
      streams/collides against it; the halo schedule recomputes
      ``phi_s``'s ghost ring locally (exchange ``{f: 1, g: 2}``, no
      extra communication for the intermediate).
    """
    if mode in (True, "one_launch"):
        return program("lb_fused_one", [
            stage(FUSED_SPEC, reads=FIELDS, writes=FIELDS, consts=consts,
                  name="fused"),
        ], fields=FIELDS)
    if mode == "two_launch":
        return program("lb_fused_two", [
            stage(PHI_STREAM_SPEC, reads="g", writes="phi_s",
                  name="phi_stream"),
            stage(FUSED_TWO_SPEC, reads=("f", "g", "phi_s"), writes=FIELDS,
                  consts=consts, name="fused_two"),
        ], fields=FIELDS)
    raise ValueError(f"mode must be 'one_launch' or 'two_launch', "
                     f"got {mode!r}")
