"""Ludwig-style binary-fluid lattice Boltzmann — the paper's application.

D3Q19 BGK collision of two distributions (fluid f, order parameter g) with
a symmetric free-energy force; streaming with periodic boundaries; halo
exchange over the device mesh via masked pack + ``ppermute``.

The collision hot-spot runs through the targetDP kernel layer
(:mod:`repro.kernels.lb_collision`); :mod:`repro.lb.baseline` keeps the
paper's "original code" structure (AoS, model-dictated innermost extents)
as the measurable Fig.-1 baseline.
"""
from .params import LBParams
from .sim import BinaryFluidSim

__all__ = ["LBParams", "BinaryFluidSim"]
