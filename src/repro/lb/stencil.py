"""Stencils and streaming for the 3-D lattice, on the targetDP stencil layer.

The neighbourhood math is declared once as :class:`repro.core.Stencil`
descriptors attached to :class:`repro.core.KernelSpec` field roles and
executed by :func:`repro.tdp.launch` — the same single-source site
kernels run on every registered executor (paper portability contract,
extended from pointwise to stencil-shaped kernels).

Two execution regimes, one math:

* **single-device** — fully periodic; the stencil gather wraps every
  dimension (``halo=0``);
* **mesh-sharded** — slab decomposition along X over a named mesh axis;
  ghost planes travel by ``lax.ppermute`` (the JAX-native analogue of
  Ludwig's MPI halo swap; the paper's masked-copy machinery packs the
  boundary subset) and feed the stencil's ``halo=(h,0,0)`` window mode.
  Used inside ``shard_map`` by :mod:`repro.lb.sim`.

Gradients use the 6-point nearest-neighbour star:
  ∇φ_d  = (φ(+e_d) - φ(-e_d)) / 2
  ∇²φ   = Σ_d (φ(+e_d) + φ(-e_d)) - 6 φ
(adequate for the symmetric benchmark; ``STENCIL_GRAD_19PT`` declares the
19-point isotropic neighbourhood for a drop-in variant.)

The **fused step** (:data:`FUSED_SPEC`) is the paper-successor's
(1609.01479) key optimisation: one stencil launch computes
stream → φ moments → ∇φ/∇²φ → binary collision with *no* intermediate
full-lattice arrays.  Its g-field neighbourhood is the Minkowski
composition ``grad6 ∘ d3q19-pull`` (radius 2) — each site reads the
pre-stream populations that determine φ at itself and its six gradient
neighbours.  The **two-launch** variant (:data:`PHI_STREAM_SPEC` +
:data:`FUSED_TWO_SPEC`) trades that 57-offset gather for a 1-component
streamed-φ intermediate (ROADMAP stencil-memory stage (a)) while keeping
the identical accumulation order — the trajectories match bit-for-bit.

Every spec here runs unchanged on every registered executor, including
the gather-free ``"pallas_windowed"`` one (stage (b)): its
``wants="halo_extended"`` capability swaps the launch prologue, never
the kernels — offsets the bodies address via the static ``_PULL_IDX`` /
``_FUSED_G_IDX`` slot tables are resolved in-kernel from the same
``Stencil`` descriptors (bit-identity with ``"xla"`` pinned by
``tests/test_windowed.py``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FieldSpec,
    KernelSpec,
    Lattice,
    STENCIL_D3Q19_PULL,
    STENCIL_GRAD_6PT,
    STENCIL_GRAD_19PT,  # noqa: F401 — re-exported config switch
    Target,
    as_target,
    tdp_launch,
)
from repro.kernels.lb_collision import CV, NVEL, collision_site_kernel

# grid arrays are (ncomp, X, Y, Z); spatial axes are 1, 2, 3
_SPATIAL = (1, 2, 3)

_CVI = CV.astype(int)

# slot of the upstream neighbour -c_q in the pull stencil (== q by
# construction; resolved through Stencil.index so the kernels stay correct
# under any offset ordering)
_PULL_IDX = tuple(STENCIL_D3Q19_PULL.index(tuple(-_CVI[q]))
                  for q in range(NVEL))

# gradient star directions, in STENCIL_GRAD_6PT slot order:
# (centre, +x, -x, +y, -y, +z, -z)
_DIRS = STENCIL_GRAD_6PT.offsets

#: g-field neighbourhood of the fused step: populations at d - c_q for every
#: gradient direction d and velocity c_q (radius 2).
STENCIL_FUSED_G = STENCIL_GRAD_6PT.compose(STENCIL_D3Q19_PULL, name="fused_g")

# _FUSED_G_IDX[d][q]: slot of offset (dirs[d] - c_q) in STENCIL_FUSED_G —
# where population q that will stream onto site+dirs[d] sits pre-stream.
_FUSED_G_IDX = tuple(
    tuple(STENCIL_FUSED_G.index(tuple(np.add(d, -_CVI[q])))
          for q in range(NVEL))
    for d in _DIRS)

#: collision TARGET_CONST names shared by the fused specs
_COLLISION_CONSTS = ("w", "c", "A", "B", "kappa", "tau", "tau_phi", "gamma")


# ---------------------------------------------------------------------------
# site kernels (single source; static slot indices — Pallas-legal)
# ---------------------------------------------------------------------------

def stream_site_kernel(f_nb):
    """Pull streaming over one chunk: ``f_nb (19, 19, V)`` neighbour stack
    (slot i = populations at site + pull offset i) → streamed ``(19, V)``."""
    return jnp.stack([f_nb[_PULL_IDX[q], q] for q in range(NVEL)])


def _grad6_from_p(p):
    """∇φ (3, V) and ∇²φ (V,) from φ at the 7 grad-star slots (p[0] =
    centre, then +x,-x,+y,-y,+z,-z).  One accumulation order, shared by the
    plain, fused and two-launch kernels — it must stay bit-identical between
    them (and with the historical roll-based implementation) for the
    fused==unfused trajectory guarantee."""
    grad = 0.5 * jnp.stack([p[1] - p[2], p[3] - p[4], p[5] - p[6]])
    lap = -6.0 * p[0]
    lap = lap + p[1] + p[2]
    lap = lap + p[3] + p[4]
    lap = lap + p[5] + p[6]
    return grad, lap


def grad6_site_kernel(phi_nb):
    """6-point ∇φ and ∇²φ over one chunk: ``phi_nb (7, 1, V)`` →
    ``((3, V), (1, V))``."""
    grad, lap = _grad6_from_p(phi_nb[:, 0])
    return grad, lap[None]


def fused_site_kernel(f_nb, g_nb, *, w=None, c=None, A=0.0625, B=0.0625,
                      kappa=0.04, tau=1.0, tau_phi=1.0, gamma=1.0):
    """Fused stream → moments → gradients → binary collision, one chunk.

    Args:
      f_nb: (19, 19, V) fluid populations at the pull offsets.
      g_nb: (noffsets, 19, V) order-parameter populations at the composed
        ``STENCIL_FUSED_G`` offsets.
      w, c, A..gamma: the collision TARGET_CONSTs (see
        :func:`repro.kernels.lb_collision.collision_site_kernel`).

    Returns post-collision ``(f', g')`` chunks, both (19, V) — the
    *pre-stream* state of the next step.
    """
    f_s = jnp.stack([f_nb[_PULL_IDX[q], q] for q in range(NVEL)])
    g_s = jnp.stack([g_nb[_FUSED_G_IDX[0][q], q] for q in range(NVEL)])

    # φ of the streamed g at the site and its 6 gradient neighbours —
    # φ(x+d) = Σ_q g(x + d - c_q); never materialised outside the chunk.
    def phi_at(d):
        acc = g_nb[_FUSED_G_IDX[d][0], 0]
        for q in range(1, NVEL):
            acc = acc + g_nb[_FUSED_G_IDX[d][q], q]
        return acc

    p = [phi_at(d) for d in range(len(_DIRS))]         # 7 × (V,)
    grad, lap = _grad6_from_p(p)
    return collision_site_kernel(
        f_s, g_s, p[0][None], grad, lap[None], w=w, c=c, A=A, B=B,
        kappa=kappa, tau=tau, tau_phi=tau_phi, gamma=gamma)


fused_site_kernel.__tdp_site_kernel__ = True


def streamed_phi_site_kernel(g_nb):
    """Launch A of the two-launch fused step: φ of the *streamed* g at one
    site, ``g_nb (19, 19, V)`` pull stack → ``(1, V)``.

    Accumulates in ascending q order — the exact order
    :func:`fused_site_kernel`'s ``phi_at`` uses, so both fused modes
    produce bit-identical φ."""
    acc = g_nb[_PULL_IDX[0], 0]
    for q in range(1, NVEL):
        acc = acc + g_nb[_PULL_IDX[q], q]
    return acc[None]


def fused_two_site_kernel(f_nb, g_nb, phis_nb, *, w=None, c=None, A=0.0625,
                          B=0.0625, kappa=0.04, tau=1.0, tau_phi=1.0,
                          gamma=1.0):
    """Launch B of the two-launch fused step: stream + collide, reading the
    pre-streamed φ intermediate through the 7-point gradient star.

    Args:
      f_nb / g_nb: (19, 19, V) populations at the pull offsets.
      phis_nb: (7, 1, V) streamed-φ values at the gradient-star slots
        (launch A's output) — replaces the one-launch kernel's 57-offset
        g gather.
    """
    f_s = jnp.stack([f_nb[_PULL_IDX[q], q] for q in range(NVEL)])
    g_s = jnp.stack([g_nb[_PULL_IDX[q], q] for q in range(NVEL)])
    p = [phis_nb[i, 0] for i in range(len(_DIRS))]
    grad, lap = _grad6_from_p(p)
    return collision_site_kernel(
        f_s, g_s, p[0][None], grad, lap[None], w=w, c=c, A=A, B=B,
        kappa=kappa, tau=tau, tau_phi=tau_phi, gamma=gamma)


def phi_moment_site_kernel(g):
    """Order-parameter moment over one chunk: φ = Σ_q g_q,
    ``g (19, V)`` → ``(1, V)`` (the unfused pipeline's site-local moment
    pass, as a declared pointwise kernel)."""
    return jnp.sum(g, axis=0, keepdims=True)


# ---------------------------------------------------------------------------
# kernel specs — the declarative launch surface (what ops/sim dispatch on)
# ---------------------------------------------------------------------------

STREAM_SPEC = KernelSpec(
    stream_site_kernel,
    fields=(FieldSpec(ncomp=NVEL, stencil=STENCIL_D3Q19_PULL, name="f"),),
    out=NVEL)

GRAD6_SPEC = KernelSpec(
    grad6_site_kernel,
    fields=(FieldSpec(ncomp=1, stencil=STENCIL_GRAD_6PT, name="phi"),),
    out=(3, 1))

FUSED_SPEC = KernelSpec(
    fused_site_kernel,
    fields=(FieldSpec(ncomp=NVEL, stencil=STENCIL_D3Q19_PULL, name="f"),
            FieldSpec(ncomp=NVEL, stencil=STENCIL_FUSED_G, name="g")),
    out=(NVEL, NVEL), consts=_COLLISION_CONSTS)

PHI_STREAM_SPEC = KernelSpec(
    streamed_phi_site_kernel,
    fields=(FieldSpec(ncomp=NVEL, stencil=STENCIL_D3Q19_PULL, name="g"),),
    out=1)

FUSED_TWO_SPEC = KernelSpec(
    fused_two_site_kernel,
    fields=(FieldSpec(ncomp=NVEL, stencil=STENCIL_D3Q19_PULL, name="f"),
            FieldSpec(ncomp=NVEL, stencil=STENCIL_D3Q19_PULL, name="g"),
            FieldSpec(ncomp=1, stencil=STENCIL_GRAD_6PT, name="phi_streamed")),
    out=(NVEL, NVEL), consts=_COLLISION_CONSTS)

MOMENT_SPEC = KernelSpec(
    phi_moment_site_kernel,
    fields=(FieldSpec(ncomp=NVEL, name="g"),),
    out=1)

COLLIDE_SPEC = KernelSpec(
    collision_site_kernel,
    fields=(FieldSpec(ncomp=NVEL, name="f"),
            FieldSpec(ncomp=NVEL, name="g"),
            FieldSpec(ncomp=1, name="phi"),
            FieldSpec(ncomp=3, name="gradphi"),
            FieldSpec(ncomp=1, name="del2phi")),
    out=(NVEL, NVEL), consts=_COLLISION_CONSTS)


# ---------------------------------------------------------------------------
# grid-level wrappers (single device: fully periodic)
# ---------------------------------------------------------------------------

def gradients(phi: jax.Array, *, target: Target | str | None = None,
              vvl: int | None = None) -> tuple[jax.Array, jax.Array]:
    """∇φ and ∇²φ of a scalar grid ``(X, Y, Z)`` → ``(3, X, Y, Z)``, ``(X, Y, Z)``."""
    gs = phi.shape
    lat = Lattice(gs)
    grad, lap = tdp_launch(GRAD6_SPEC, as_target(target, vvl=vvl),
                           phi.reshape(1, lat.nsites), lattice=lat)
    return grad.reshape(3, *gs), lap.reshape(gs)


def stream(dist: jax.Array, *, target: Target | str | None = None,
           vvl: int | None = None) -> jax.Array:
    """Periodic streaming of ``(19, X, Y, Z)``: f_q(x) ← f_q(x - c_q)."""
    gs = dist.shape[1:]
    lat = Lattice(gs)
    out = tdp_launch(STREAM_SPEC, as_target(target, vvl=vvl),
                     dist.reshape(NVEL, lat.nsites), lattice=lat)
    return out.reshape(NVEL, *gs)


# ---------------------------------------------------------------------------
# mesh-sharded path
# ---------------------------------------------------------------------------
#
# The slab-decomposition glue (ppermute ghost exchange + per-launch halo
# widths) that used to live here is owned by the Program layer now:
# repro.core.program back-propagates one exchange schedule per step
# (`Program.schedule`) and performs the exchange in `_exchange_dim0` —
# repro.lb.programs declares the LB step graphs it applies to.


def halo_plane_mask(shape: tuple[int, int, int]) -> np.ndarray:
    """Boolean site mask selecting the X-boundary planes — feeds the paper's
    ``copy*Masked`` functions when staging boundary data through the host."""
    m = np.zeros(shape, dtype=bool)
    m[0, :, :] = True
    m[-1, :, :] = True
    return m.reshape(-1)
