"""Finite-difference stencils and streaming for the 3-D lattice.

Two execution regimes, one math:

* **single-device** — periodic shifts via ``jnp.roll`` (the whole lattice is
  local);
* **mesh-sharded** — slab decomposition along X over a named mesh axis;
  the one-plane halo travels by ``lax.ppermute`` (the JAX-native analogue
  of Ludwig's MPI halo swap; the paper's masked-copy machinery packs the
  boundary subset).  Used inside ``shard_map`` by :mod:`repro.lb.sim`.

Gradients use the 6-point nearest-neighbour stencil:
  ∇φ_d  = (φ(+e_d) - φ(-e_d)) / 2
  ∇²φ   = Σ_d (φ(+e_d) + φ(-e_d)) - 6 φ
(adequate for the symmetric benchmark; the 19-point isotropic variant drops
in site-locally and is left as a config switch.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lb_collision import CV, NVEL

# grid arrays are (ncomp, X, Y, Z); spatial axes are 1, 2, 3
_SPATIAL = (1, 2, 3)


# ---------------------------------------------------------------------------
# single-device (fully periodic, roll-based)
# ---------------------------------------------------------------------------

def gradients(phi: jax.Array) -> tuple[jax.Array, jax.Array]:
    """∇φ and ∇²φ of a scalar grid ``(X, Y, Z)`` → ``(3, X, Y, Z)``, ``(X, Y, Z)``."""
    grads = []
    lap = -6.0 * phi
    for ax in range(3):
        plus = jnp.roll(phi, -1, axis=ax)
        minus = jnp.roll(phi, 1, axis=ax)
        grads.append(0.5 * (plus - minus))
        lap = lap + plus + minus
    return jnp.stack(grads), lap


def stream(dist: jax.Array) -> jax.Array:
    """Periodic streaming of ``(19, X, Y, Z)``: f_q(x) ← f_q(x - c_q)."""
    shifted = [
        jnp.roll(dist[q], shift=tuple(int(c) for c in CV[q]), axis=(0, 1, 2))
        for q in range(NVEL)
    ]
    return jnp.stack(shifted)


# ---------------------------------------------------------------------------
# mesh-sharded (slab decomposition along X; call inside shard_map)
# ---------------------------------------------------------------------------

def _exchange_x_halo(arr: jax.Array, axis_name: str) -> tuple[jax.Array, jax.Array]:
    """Return (left_halo, right_halo) planes for a local block ``(..., Xl, Y, Z)``.

    left_halo  = left neighbour's last plane  (global periodic wrap),
    right_halo = right neighbour's first plane.
    Only the single boundary plane is communicated — the masked-copy idea:
    the transfer set is the boundary subset, never the bulk.
    """
    n = jax.lax.axis_size(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]   # data flows rank i → i+1
    bwd = [(i, (i - 1) % n) for i in range(n)]
    last = arr[..., -1:, :, :]
    first = arr[..., :1, :, :]
    left_halo = jax.lax.ppermute(last, axis_name, fwd)    # from left neighbour
    right_halo = jax.lax.ppermute(first, axis_name, bwd)  # from right neighbour
    return left_halo, right_halo


def gradients_sharded(phi: jax.Array, axis_name: str) -> tuple[jax.Array, jax.Array]:
    """Sharded version of :func:`gradients`; ``phi`` is the local X-slab."""
    lh, rh = _exchange_x_halo(phi[None], axis_name)
    ext = jnp.concatenate([lh[0], phi, rh[0]], axis=0)     # (Xl+2, Y, Z)
    xl = phi.shape[0]
    grads = [0.5 * (ext[2:xl + 2] - ext[0:xl])]            # d/dx via halo
    lap = ext[2:xl + 2] + ext[0:xl] - 6.0 * phi
    for ax in (1, 2):                                      # y, z stay periodic-local
        plus = jnp.roll(phi, -1, axis=ax)
        minus = jnp.roll(phi, 1, axis=ax)
        grads.append(0.5 * (plus - minus))
        lap = lap + plus + minus
    return jnp.stack(grads), lap


def stream_sharded(dist: jax.Array, axis_name: str) -> jax.Array:
    """Sharded streaming of the local slab ``(19, Xl, Y, Z)``."""
    lh, rh = _exchange_x_halo(dist, axis_name)
    ext = jnp.concatenate([lh, dist, rh], axis=1)          # (19, Xl+2, Y, Z)
    xl = dist.shape[1]
    out = []
    for q in range(NVEL):
        cx, cy, cz = (int(c) for c in CV[q])
        # f_new[x] = f_old[x - cx]  → ext slice starting at 1 - cx
        sl = jax.lax.slice_in_dim(ext[q], 1 - cx, 1 - cx + xl, axis=0)
        out.append(jnp.roll(sl, shift=(cy, cz), axis=(1, 2)))
    return jnp.stack(out)


def halo_plane_mask(shape: tuple[int, int, int]) -> np.ndarray:
    """Boolean site mask selecting the X-boundary planes — feeds the paper's
    ``copy*Masked`` functions when staging boundary data through the host."""
    m = np.zeros(shape, dtype=bool)
    m[0, :, :] = True
    m[-1, :, :] = True
    return m.reshape(-1)
