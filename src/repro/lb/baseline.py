"""The paper's "original code" baseline: AoS layout, model-dictated extents.

Before targetDP, Ludwig's collision loops had innermost extents of 19 (the
discrete momenta) or 3 (spatial dimensions) — extents the compiler cannot
map onto vector hardware (Fig. 1's lower bars).  This module reproduces that
structure faithfully in JAX: the lattice field is **AoS** ``(X, Y, Z, 19)``
so every contraction runs over the *minor* axis of extent 19/3 and the
site axis is not exposed as a vectorisable innermost dimension.

It is numerically identical to the targetDP path (tests assert allclose
after layout transposition) and exists purely as the measurable baseline
for ``benchmarks/run.py::bench_fig1``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lb_collision import CV, NVEL, WEIGHTS
from .params import LBParams


@functools.partial(jax.jit, static_argnames=("params",))
def collide_aos(f, g, phi, gradphi, del2phi, params: LBParams):
    """AoS collision: f, g ``(..., 19)``; gradphi ``(..., 3)``; phi, del2phi ``(...)``.

    Contractions deliberately run over the trailing 19-/3-extent axes —
    the exact structure the paper identifies as vector-hostile.
    """
    w = jnp.asarray(WEIGHTS, f.dtype)                    # (19,)
    c = jnp.asarray(CV, f.dtype)                         # (19, 3)
    A, B, kappa = params.A, params.B, params.kappa
    tau, tau_phi, gamma = params.tau, params.tau_phi, params.gamma

    mu = -A * phi + B * phi ** 3 - kappa * del2phi       # (...)
    force = mu[..., None] * gradphi                      # (..., 3)

    rho = f.sum(-1)                                      # (...)
    mom = jnp.einsum("...q,qd->...d", f, c)              # (..., 3)
    u = (mom + 0.5 * force) / rho[..., None]             # (..., 3)

    cu = jnp.einsum("...d,qd->...q", u, c)               # (..., 19)
    usq = (u * u).sum(-1)                                # (...)
    feq = w * rho[..., None] * (1 + 3 * cu + 4.5 * cu ** 2
                                - 1.5 * usq[..., None])
    cf = jnp.einsum("...d,qd->...q", force, c)           # (..., 19)
    uf = (u * force).sum(-1)                             # (...)
    fterm = (1 - 0.5 / tau) * w * (3 * (cf - uf[..., None]) + 9 * cu * cf)
    f_out = f - (f - feq) / tau + fterm

    gt = w * (3 * gamma * mu[..., None] + 3 * phi[..., None] * cu)
    g0 = phi - (gt.sum(-1) - gt[..., 0])
    geq = jnp.concatenate([g0[..., None], gt[..., 1:]], axis=-1)
    g_out = g - (g - geq) / tau_phi
    return f_out, g_out


def stream_aos(dist: jax.Array) -> jax.Array:
    """Streaming for AoS ``(X, Y, Z, 19)``."""
    shifted = [
        jnp.roll(dist[..., q], shift=tuple(int(x) for x in CV[q]), axis=(0, 1, 2))
        for q in range(NVEL)
    ]
    return jnp.stack(shifted, axis=-1)
