"""Spinodal decomposition of a binary fluid — the Ludwig-style application.

A symmetric quench (φ = ±noise) phase-separates into domains; this is the
physics the paper's binary-collision benchmark kernel comes from.  Runs
the full targetDP-structured simulation (moments → stencil → collision →
streaming), each regime a compiled ``tdp.Program`` step graph — the
chunked stepping below goes through ``CompiledProgram.run``'s single
``lax.scan`` (``--donate`` ping-pongs the field buffers) — and prints
conservation + coarsening observables plus the aggregated per-step HBM
estimate from ``ProgramPlan``.

Run:  PYTHONPATH=src python examples/lb_spinodal.py [--steps 400]
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import tdp
from repro.lb.params import LBParams
from repro.lb.sim import BinaryFluidSim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=16)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--chunk", type=int, default=50)
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "pallas_interpret",
                             "pallas_windowed", "pallas_windowed_interpret"),
                    help="pallas_windowed* is stencil-only (gather-free "
                         "windowed executor) — pair it with --fused; the "
                         "sim's pointwise collision falls back to xla")
    ap.add_argument("--vvl", type=int, default=128)
    ap.add_argument("--fused", nargs="?", const="one_launch", default=False,
                    choices=("one_launch", "two_launch"),
                    help="fused stream+gradient+collide stencil launch(es) "
                         "per step (same trajectory): one_launch = radius-2 "
                         "composed stencil; two_launch = streamed-phi "
                         "intermediate (lower gather footprint)")
    ap.add_argument("--donate", action="store_true",
                    help="donate the hot-loop field buffers in each "
                         "scanned chunk (ping-pong aliasing; no per-step "
                         "reallocation)")
    ap.add_argument("--mesh", default=None, metavar="NxM[xK]",
                    help="shard the grid over the process's devices: "
                         "'4' = slab, '2x2' = pencil, '2x2x2' = block "
                         "(mesh axis k shards grid dim k; run under "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N to fake devices on CPU)")
    ap.add_argument("--overlap", action="store_true",
                    help="launch each stage's interior while the ghost "
                         "exchanges are in flight (sharded runs; "
                         "trajectories match to ~1 ULP, not bitwise — "
                         "see docs/targetdp_api.md)")
    args = ap.parse_args()

    mesh = None
    shard_axis = "data"
    if args.mesh:
        from repro.launch.mesh import make_test_mesh
        shape = tuple(int(s) for s in args.mesh.lower().split("x"))
        shard_axis = tuple(f"p{'xyz'[d]}" for d in range(len(shape)))
        mesh = make_test_mesh(shape, shard_axis)
        print(f"[lb_spinodal] mesh {dict(zip(shard_axis, shape))}: "
              f"{'slab pencil block'.split()[len(shape) - 1]} "
              f"decomposition")

    params = LBParams(A=0.125, B=0.125, kappa=0.02)
    sim = BinaryFluidSim((args.grid,) * 3, params=params,
                         target=tdp.Target(args.backend, vvl=args.vvl),
                         fused=args.fused, mesh=mesh, shard_axis=shard_axis,
                         overlap=args.overlap)
    hot = sim.programs["fused" if args.fused else "step"]
    plan = hot.plan()
    print(f"[lb_spinodal] hot-loop Program "
          f"{hot.program.name!r}: stages "
          f"{[r['stage'] + '@' + r['executor'] for r in plan.per_stage()]}, "
          f"est. per-step HBM {plan.hbm_bytes_estimate() / 2**20:.1f} MiB")
    if mesh is not None:
        cs = hot.comm_stats()
        print(f"[lb_spinodal] exchange schedule {hot.exchange_schedule}: "
              f"{cs['exchanged_bytes_per_step'] / 2**10:.1f} KiB and "
              f"{cs['ppermutes_per_step']} ppermutes per step"
              + (f"; overlap interior fraction "
                 f"{cs['interior_fraction']:.2f}" if cs["overlap"] else ""))
    state = sim.init_spinodal(seed=0, noise=0.05)

    obs0 = sim.observables(state)
    print(f"{'step':>6} {'mass':>12} {'phi_total':>12} {'phi_var':>10} "
          f"{'phi_range':>16} {'Msites/s':>9}")

    def report(st, rate=0.0):
        o = sim.observables(st)
        print(f"{st.step:>6} {o['mass']:>12.4f} {o['phi_total']:>12.5f} "
              f"{o['phi_var']:>10.5f} "
              f"[{o['phi_min']:>6.3f},{o['phi_max']:>6.3f}] "
              f"{rate:>9.2f}")
        assert not o["nan"], "NaN in fields"
        return o

    report(state)
    n = sim.grid_shape[0] ** 3
    while state.step < args.steps:
        chunk = min(args.chunk, args.steps - state.step)
        t0 = time.perf_counter()
        state = sim.run(state, chunk, donate=args.donate)
        state.f.block_until_ready()
        dt = time.perf_counter() - t0
        report(state, rate=n * chunk / dt / 1e6)

    o_end = sim.observables(state)
    drift = abs(o_end["mass"] - obs0["mass"]) / obs0["mass"]
    print(f"\n[lb_spinodal] mass drift over {args.steps} steps: {drift:.2e}")
    print(f"[lb_spinodal] φ variance {obs0['phi_var']:.5f} → "
          f"{o_end['phi_var']:.5f} (domains formed)")


if __name__ == "__main__":
    main()
