"""Quickstart: the paper's §III-C scale example, end to end.

Mirrors the paper's host-side call sequence:

    targetMalloc → copyToTarget → copyConstantDoubleToTarget
    → scale TARGET_LAUNCH(N) (t_field) → syncTarget
    → copyFromTarget → targetFree

but through the declarative JAX realisation: the kernel's field roles are
declared once with ``@tdp.kernel`` and the paper's C-vs-CUDA build switch
is an exchangeable ``tdp.Target`` descriptor dispatched through the
executor registry — swap the Target, keep the kernel.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import tdp
from repro.core import (Field, Lattice, copy_constant_to_target,
                        copy_from_target, copy_to_target, sync_target,
                        target_free)


# 1. a site kernel, written once, with its launch roles declared up front
#    (TARGET_ENTRY + field declarations; the body is TARGET_TLP/ILP-shaped)
@tdp.kernel(fields=[tdp.field(3)], out=3, consts=["a"])
def scale(field, a=1.0):
    """The paper's example: scale a 3-vector field by a constant."""
    return a * field


def main():
    # 2. host field (SoA mandated — paper §III-B)
    lattice = Lattice(shape=(32, 32, 32))
    host = Field(lattice, ncomp=3, dtype=np.float64)
    host.data[...] = np.random.default_rng(0).normal(
        size=host.array_shape)

    # 3. host → target (the target here is the CPU device; on a real
    #    deployment it is TPU HBM — same code)
    t_field = copy_to_target(host, dtype=np.float32)
    a = copy_constant_to_target(2.0)          # TARGET_CONST

    # 4. launch under several Targets; tune VVL exactly like the paper
    #    tunes VVL=8 (AVX) / VVL=2 (K40)
    for backend in ("xla", "pallas_interpret"):
        for vvl in (64, 128, 256):
            target = tdp.Target(backend, vvl=vvl)
            out = tdp.launch(scale, target, t_field,
                             lattice=lattice, a=a)
            sync_target(out)
            got = copy_from_target(out)
            assert np.allclose(got, 2.0 * np.asarray(t_field)), (backend, vvl)
        print(f"[quickstart] target={backend:17s} OK (VVL swept 64/128/256)")

    # 5. reductions — the paper's §V planned extension, implemented
    total = tdp.reduce(scale, lattice, [t_field], consts={"a": 1.0},
                       op="sum")
    print(f"[quickstart] reduce(sum) per component: {np.asarray(total)}")

    # 6. the registry is open: one register_executor call adds a new
    #    architecture, no core changes (here: a whole-lattice toy executor)
    def whole_lattice_executor(plan, gathered):
        vals = plan.kernel(*gathered, **plan.consts)
        return vals if isinstance(vals, tuple) else (vals,)

    tdp.register_executor("toy", whole_lattice_executor)
    out = tdp.launch(scale, tdp.Target("toy"), t_field, a=a)
    assert np.allclose(copy_from_target(out), 2.0 * np.asarray(t_field))
    print(f"[quickstart] registered executors: {tdp.list_executors()}")

    target_free(t_field)
    print("[quickstart] single source ran on every executor — done")


if __name__ == "__main__":
    main()
