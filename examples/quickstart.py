"""Quickstart: the paper's §III-C scale example, end to end.

Mirrors the paper's host-side call sequence:

    targetMalloc → copyToTarget → copyConstantDoubleToTarget
    → scale TARGET_LAUNCH(N) (t_field) → syncTarget
    → copyFromTarget → targetFree

but through the JAX realisation, and runs it on both executors (the
paper's C-vs-CUDA build switch is our ``backend=`` argument).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import core as tdp
from repro.core import (Field, Lattice, copy_constant_to_target,
                        copy_from_target, copy_to_target, sync_target,
                        target_free)


# 1. a site kernel, written once (TARGET_ENTRY + TARGET_TLP/ILP body)
@tdp.site_kernel
def scale(field, a=1.0):
    """The paper's example: scale a 3-vector field by a constant."""
    return a * field


def main():
    # 2. host field (SoA mandated — paper §III-B)
    lattice = Lattice(shape=(32, 32, 32))
    host = Field(lattice, ncomp=3, dtype=np.float64)
    host.data[...] = np.random.default_rng(0).normal(
        size=host.array_shape)

    # 3. host → target (the target here is the CPU device; on a real
    #    deployment it is TPU HBM — same code)
    t_field = copy_to_target(host, dtype=np.float32)
    a = copy_constant_to_target(2.0)          # TARGET_CONST

    # 4. launch on both executors; tune VVL exactly like the paper tunes
    #    VVL=8 (AVX) / VVL=2 (K40)
    for backend in ("xla", "pallas_interpret"):
        for vvl in (64, 128, 256):
            out = tdp.launch(scale, lattice, [t_field],
                             consts={"a": a}, vvl=vvl, backend=backend)
            sync_target(out)
            got = copy_from_target(out)
            assert np.allclose(got, 2.0 * np.asarray(t_field)), (backend, vvl)
        print(f"[quickstart] backend={backend:17s} OK (VVL swept 64/128/256)")

    # 5. reductions — the paper's §V planned extension, implemented
    total = tdp.reduce(scale, lattice, [t_field], consts={"a": 1.0},
                       op="sum")
    print(f"[quickstart] reduce(sum) per component: {np.asarray(total)}")

    target_free(t_field)
    print("[quickstart] single source ran on both executors — done")


if __name__ == "__main__":
    main()
