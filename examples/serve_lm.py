"""Batched serving driver: prefill + decode over a request batch.

The brief's serving-side end-to-end example: several requests with a
shared decode budget run through prefill (cache build), then token-by-
token batched decode with greedy/temperature sampling — the same
serve-step builders the 32k/500k dry-run cells lower at mesh scale.

Uses a model trained by examples/train_lm.py when a checkpoint exists
(so continuations follow the synthetic bigram table — verifiable!),
otherwise random weights.

Run:  PYTHONPATH=src python examples/serve_lm.py [--gen 32]
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.data import SyntheticConfig, batch_for_step
from repro.data.synthetic import _successor_table
from repro.models import params as params_lib
from repro.models.config import AttnConfig, ModelConfig, repeat_program
from repro.models.context import ExecContext
from repro.runtime.steps import build_serve_steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    p = dict(d_model=384, n_layers=6, n_heads=6, d_ff=1536, vocab=8192)
    cfg = ModelConfig(
        name="lm-22m", d_model=p["d_model"], n_layers=p["n_layers"],
        vocab_size=p["vocab"], d_ff=p["d_ff"],
        layer_program=repeat_program(("attn",), p["n_layers"]),
        attn=AttnConfig(p["n_heads"], p["n_heads"],
                        p["d_model"] // p["n_heads"]))
    params, _ = params_lib.init_params(cfg, jax.random.PRNGKey(0))

    trained = False
    if latest_step(args.ckpt_dir) is not None:
        tree = {"params": params, "opt": None}
        try:
            got, _, step = restore_checkpoint(
                args.ckpt_dir, {"params": params})
            params = got["params"]
            trained = True
            print(f"[serve_lm] restored trained weights (step {step})")
        except Exception as e:  # noqa: BLE001
            print(f"[serve_lm] checkpoint restore skipped ({e}); "
                  "using random weights")

    data = SyntheticConfig(vocab_size=p["vocab"], seq_len=args.prompt_len,
                           global_batch=args.batch, seed=0, branching=8)
    prompts = batch_for_step(data, step=10_001)   # unseen step → fresh data
    batch = {"tokens": jnp.asarray(prompts["tokens"])}

    ctx = ExecContext()
    max_len = args.prompt_len + args.gen
    prefill_step, decode_step = build_serve_steps(
        cfg, ctx, max_len=max_len, temperature=args.temperature)
    prefill_step = jax.jit(prefill_step)
    decode_step = jax.jit(decode_step, donate_argnums=(2,))

    key = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    tok, caches, length, _ = prefill_step(params, batch, key)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0
    print(f"[serve_lm] prefill {args.batch}×{args.prompt_len} tokens: "
          f"{t_prefill*1e3:.0f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")

    outs = [np.asarray(tok)]
    t1 = time.perf_counter()
    for _ in range(args.gen - 1):
        key, sub = jax.random.split(key)
        tok, caches, length = decode_step(params, tok, caches, length, sub)
        outs.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t1
    gen = np.concatenate(outs, axis=1)
    print(f"[serve_lm] decode {args.gen-1} steps × {args.batch} reqs: "
          f"{t_dec*1e3:.0f} ms "
          f"({(args.gen-1)*args.batch/t_dec:.0f} tok/s, "
          f"{t_dec/(args.gen-1)*1e3:.1f} ms/step)")

    # verify continuations against the bigram table when trained
    table = _successor_table(data)
    ok = total = 0
    for r in range(args.batch):
        prev = prompts["tokens"][r, -1]
        for t in range(args.gen):
            total += 1
            if gen[r, t] in table[prev]:
                ok += 1
            prev = gen[r, t]
    chance = 8 / p["vocab"]
    lift = (ok / total) / chance if total else 0.0
    print(f"[serve_lm] continuations following the bigram table: "
          f"{ok}/{total} ({ok/total:.1%}; chance {chance:.2%} → "
          f"{lift:.0f}× lift)"
          + ("" if trained else "  (random weights)"))
    for r in range(min(3, args.batch)):
        print(f"  req{r}: ...{prompts['tokens'][r, -4:].tolist()} → "
              f"{gen[r, :10].tolist()}")


if __name__ == "__main__":
    main()
