"""End-to-end LM training driver on the synthetic bigram stream.

Exercises the full stack — data pipeline → sharding plan → train step
(grad accumulation, remat) → AdamW → async checkpointing → restart — for a
configurable model size.  The synthetic stream has ~log2(8)=3 bits/token
of structure, so cross-entropy falls from ln(V) toward ~ln(8) as the model
learns the bigram table: a *real* loss curve, not noise.

Defaults fit a CPU budget (~22M params, 300 steps); ``--preset 100m``
selects the ~100M-parameter config used on real hardware (identical code
path; the dry-run validates it at mesh scale).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data import SyntheticConfig
from repro.models.config import AttnConfig, ModelConfig, repeat_program
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig, TrainHParams

PRESETS = {
    # ~22M params: CPU-budget demo (d=384, 6L)
    "22m": dict(d_model=384, n_layers=6, n_heads=6, d_ff=1536, vocab=8192,
                seq=128, batch=16),
    # ~100M params: the brief's end-to-end scale (runs as-is on devices)
    "100m": dict(d_model=768, n_layers=12, n_heads=12, d_ff=3072,
                 vocab=32768, seq=512, batch=32),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="22m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--grad-accum", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--quant-moments", action="store_true")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}", d_model=p["d_model"],
        n_layers=p["n_layers"], vocab_size=p["vocab"], d_ff=p["d_ff"],
        layer_program=repeat_program(("attn",), p["n_layers"]),
        attn=AttnConfig(n_heads=p["n_heads"], n_kv_heads=p["n_heads"],
                        head_dim=p["d_model"] // p["n_heads"]))
    print(f"[train_lm] {cfg.name}: {cfg.num_params()/1e6:.1f}M params, "
          f"seq {p['seq']}, global batch {p['batch']}")

    data = SyntheticConfig(vocab_size=p["vocab"], seq_len=p["seq"],
                           global_batch=p["batch"], seed=0, branching=8)
    hp = TrainHParams(peak_lr=args.lr, warmup_steps=40,
                      total_steps=args.steps, grad_accum=args.grad_accum)
    opt = AdamWConfig(quantize_moments=args.quant_moments)
    tc = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100,
                       log_every=10, hb_dir=args.ckpt_dir + "/hb")

    trainer = Trainer(cfg, None, data, opt, hp, tc)
    hist = trainer.run(args.steps)

    import math
    first = hist[0]["loss"] if hist else float("nan")
    last = hist[-1]["loss"] if hist else float("nan")
    print(f"\n[train_lm] loss {first:.3f} → {last:.3f} "
          f"(uniform={math.log(p['vocab']):.3f}, "
          f"bigram floor≈{math.log(8):.3f})")
    assert last < first, "loss did not decrease"
    print("[train_lm] loss curve (step, ce):")
    for h in hist:
        print(f"  {h['step']:>5} {h['loss']:.4f}")


if __name__ == "__main__":
    main()
