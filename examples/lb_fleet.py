"""Spinodal decomposition as a service — a fleet of binary-fluid
trajectories behind ``tdp.FleetDriver``.

Each "client" submits one quench with its own random seed and its own
mobility (a ``tau_phi`` sweep): the driver batches every request into a
single vmapped fleet step (one jit for the whole sweep — per-member
constants ride along as traced operands, so new parameter values never
recompile), streams progress snapshots back per ticket, and optionally
checkpoints all in-flight trajectories so a killed service resumes every
ticket bit-exactly.

Run:  PYTHONPATH=src python examples/lb_fleet.py [--batch 4 --steps 40]
CI smoke: --batch 4 --steps 2 --grid 8
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import tdp
from repro.lb import programs as lbp
from repro.lb.params import LBParams
from repro.lb.sim import BinaryFluidSim


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid", type=int, default=16)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4,
                    help="fleet slots per bucket (also the number of "
                         "submitted trajectories here)")
    ap.add_argument("--backend", default="xla")
    ap.add_argument("--vvl", type=int, default=128)
    ap.add_argument("--stream-every", type=int, default=0,
                    help="print φ-variance snapshots of ticket 0 every "
                         "k member steps (0 = off)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint all in-flight tickets here "
                         "(kill + rerun with the same dir resumes them)")
    ap.add_argument("--chaos", action="store_true",
                    help="failure drill: guard all trajectories with a "
                         "HealthPolicy and poison one ticket's g field "
                         "mid-run — the driver quarantines exactly that "
                         "member while the rest complete")
    args = ap.parse_args()

    grid = (args.grid,) * 3
    params = LBParams(A=0.125, B=0.125, kappa=0.02)

    # The served step graph: the unfused LB step with tau_phi (mobility)
    # left as a per-ticket sweep value.  Clients bind their own value in
    # params["consts"]; the driver turns the spread into one BatchedConst
    # bucket.
    phys = params.as_kwargs()
    prog = lbp.unfused_step_program(
        lbp.collision_consts(np.float32, **phys))

    # seed states come from the sim helper (equilibrium populations of a
    # noisy quench), one seed per client
    sim = BinaryFluidSim(grid, params=params,
                         target=tdp.Target(args.backend, vvl=args.vvl))

    # resume-or-fresh: the driver creates checkpoint_dir on construction,
    # so "does the dir exist" can't distinguish a prior run — try the
    # restore and fall back when no checkpoint has been written yet.
    drv, resumed = None, {}
    if args.checkpoint_dir:
        try:
            drv = tdp.FleetDriver.restore(args.checkpoint_dir, prog,
                                          batch=args.batch,
                                          checkpoint_every=4)
            resumed = dict(drv._tickets)
            print(f"[lb_fleet] resumed {len(resumed)} ticket(s) from "
                  f"{args.checkpoint_dir}")
        except FileNotFoundError:
            pass
    health = tdp.HealthPolicy(fields=("g",), every=2) if args.chaos \
        else None
    if drv is None:
        drv = tdp.FleetDriver(tdp.Target(args.backend, vvl=args.vvl),
                              batch=args.batch,
                              checkpoint_dir=args.checkpoint_dir,
                              checkpoint_every=4 if args.checkpoint_dir
                              else None,
                              health=health)

    tau_phis = np.linspace(0.8, 1.2, args.batch).astype(np.float32)
    tickets = list(resumed.values())
    if not tickets:
        for i in range(args.batch):
            st = sim.init_spinodal(seed=i, noise=0.05)
            t = drv.submit(prog,
                           {"state": {"f": st.f, "g": st.g},
                            "consts": {"tau_phi": tau_phis[i]}},
                           args.steps)
            tickets.append(t)
            print(f"[lb_fleet] submitted {t.id}: seed {i}, "
                  f"tau_phi {tau_phis[i]:.2f}, {args.steps} steps")

    def phi_var(state):
        phi = np.asarray(state["g"]).sum(axis=0)
        return float(phi.var())

    victim = None
    if args.chaos and len(tickets) >= 2:
        from repro.core import faults
        victim = tickets[1]
        poison_at = max(1, args.steps // 2)
        drv.inject(faults.nan_at_step(victim.id, "g", poison_at))
        print(f"[lb_fleet] chaos: poisoning {victim.id} field 'g' at "
              f"member step {poison_at} (guard: NaN/Inf every 2 steps)")

    t0 = time.perf_counter()
    if args.stream_every:
        for step, snap in drv.stream(tickets[0], every=args.stream_every):
            print(f"[lb_fleet] {tickets[0].id} step {step:>5}: "
                  f"phi_var {phi_var(snap):.5f}")
    final = drv.drain()
    dt = time.perf_counter() - t0

    nsites = args.grid ** 3
    done_steps = sum(t.nsteps for t in tickets)
    print(f"[lb_fleet] {len(tickets)} trajectories x {args.steps} steps "
          f"on {args.grid}^3 in {dt:.2f}s "
          f"({done_steps * nsites / dt / 1e6:.2f} Msites/s aggregate, "
          f"{len(drv._buckets)} bucket jit(s))")
    for t in tickets:
        p = drv.poll(t)
        if victim is not None and t.id == victim.id:
            assert p["status"] == "failed", \
                f"{t.id}: expected quarantine, got {p['status']}"
            assert isinstance(p["error"], tdp.HealthError)
            print(f"[lb_fleet] {t.id}: quarantined -> {p['error']}")
            continue
        assert p["done"] and p["step"] == t.nsteps
        var = phi_var(final[t.id])
        assert np.isfinite(var), f"{t.id}: non-finite fields"
        print(f"[lb_fleet] {t.id}: tau_phi "
              f"{float(np.asarray(t.consts['tau_phi'])):.2f} -> "
              f"phi_var {var:.5f}")
    print("[lb_fleet] OK")


if __name__ == "__main__":
    main()
