"""Perf-regression gate over the committed ``BENCH_*.json`` records.

``benchmarks/run.py --json`` writes one machine-readable record per
bench (median/min wall seconds per variant).  This checker diffs a
*fresh* set of those records against the *baseline* set committed under
``results/bench/`` and exits non-zero when any matching variant's median
regressed by more than the threshold (default 15%).

Two variants match only when their full identity agrees — bench name,
grid, variant key, executor, and tuning-bearing fields (``vvl``,
``mesh``, ``scan_length``, ``batch``); anything else (a regridded bench, a renamed
variant, a retuned sweep point) is reported as *unmatched* and never
gates.  Medians below ``--min-seconds`` are noise on a shared CI host
and are skipped.

Usage (the nightly lane)::

    python -m benchmarks.run --json --out results/bench-nightly
    python -m benchmarks.check_regression \
        --baseline results/bench --fresh results/bench-nightly

Exit codes: 0 ok (including "nothing matched"), 1 regression(s), 2 bad
invocation (missing/empty directories).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

#: record fields that are part of a variant's identity (tuning and
#: shape), not of its measurement — a mismatch means "not comparable".
#: "health" separates guarded fleet variants (HealthPolicy checks
#: between chunks) from unguarded ones: the guard cost is measured on
#: purpose and must never gate the guard-off trajectory.  "layout"
#: separates SoA from AoSoA sweep points (records predating the layout
#: axis are SoA).  "sites" is the launch site count for non-lattice
#: kernels whose record carries no ``grid`` — a quick-lane sweep at a
#: smaller problem size must never compare against the committed
#: full-size medians.
_IDENTITY_KEYS = ("executor", "vvl", "mesh", "scan_length", "batch",
                  "health", "layout", "sites")

#: measurement field preference: run.py's program benches write
#: ``median_s`` (and ``t_s`` aliases it); older records only ``t_s``.
_MEDIAN_KEYS = ("median_s", "t_s")


def load_records(path: str) -> dict[str, dict]:
    """``{bench_name: record}`` from every ``BENCH_*.json`` under
    ``path``.  Unreadable/corrupt files are skipped with a warning —
    one bad artifact must not disable the whole gate."""
    out = {}
    for fn in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        try:
            with open(fn) as fh:
                rec = json.load(fh)
        except (OSError, ValueError) as e:
            print(f"[check_regression] skipping {fn}: {e}",
                  file=sys.stderr)
            continue
        name = rec.get("bench") or os.path.basename(fn)[6:-5]
        out[name] = rec
    return out


def _median(variant: dict):
    for k in _MEDIAN_KEYS:
        v = variant.get(k)
        if isinstance(v, (int, float)) and v > 0:
            return float(v)
    return None


def _identity(bench: str, rec: dict, key: str, variant: dict) -> tuple:
    ident = []
    for k in _IDENTITY_KEYS:
        v = variant.get(k)
        if k == "health" and v is None:
            v = "off"    # records predating the guard field are unguarded
        if k == "layout" and v is None:
            v = "soa"    # records predating the layout axis are SoA
        ident.append((k, v))
    return (bench, tuple(rec.get("grid") or ()), key, tuple(ident))


def compare(baseline: dict[str, dict], fresh: dict[str, dict],
            threshold: float = 0.15, min_seconds: float = 0.0) -> dict:
    """Pure comparison — the unit-testable core.

    Returns ``{"regressions": [...], "improvements": [...],
    "matched": n, "unmatched": [...]}`` where each finding is
    ``(bench, variant, base_s, fresh_s, ratio)`` and ``ratio`` is
    ``fresh/base - 1`` (positive = slower).
    """
    regressions, improvements, unmatched = [], [], []
    warnings = []
    matched = 0
    base_pvm = {}
    base_ids = {}
    for bench, rec in baseline.items():
        for key, var in (rec.get("variants") or {}).items():
            m = _median(var)
            if m is not None:
                ident = _identity(bench, rec, key, var)
                base_ids[ident] = m
                pvm = var.get("predicted_vs_measured")
                if isinstance(pvm, (int, float)):
                    base_pvm[ident] = float(pvm)
    for bench, rec in fresh.items():
        for key, var in (rec.get("variants") or {}).items():
            m = _median(var)
            if m is None:
                continue
            ident = _identity(bench, rec, key, var)
            base = base_ids.get(ident)
            if base is None:
                unmatched.append((bench, key))
                continue
            matched += 1
            if base < min_seconds or m < min_seconds:
                continue
            ratio = m / base - 1.0
            row = (bench, key, base, m, ratio)
            if ratio > threshold:
                regressions.append(row)
            elif ratio < -threshold:
                improvements.append(row)
            # cost-model fidelity drift: warn (never gate) when the
            # fresh predicted-vs-measured error more than doubles the
            # committed record's (with a 10% absolute floor so near-zero
            # baselines don't warn on noise)
            pvm, b_pvm = var.get("predicted_vs_measured"), \
                base_pvm.get(ident)
            if (isinstance(pvm, (int, float)) and b_pvm is not None
                    and abs(pvm) > max(2 * abs(b_pvm), 0.1)):
                warnings.append((bench, key, b_pvm, float(pvm)))
    return {"regressions": regressions, "improvements": improvements,
            "matched": matched, "unmatched": unmatched,
            "warnings": warnings}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when fresh bench medians regress vs committed")
    ap.add_argument("--baseline", default="results/bench",
                    help="directory of committed BENCH_*.json records")
    ap.add_argument("--fresh", required=True,
                    help="directory of freshly produced records")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="median regression ratio that fails the gate "
                         "(0.15 = 15%%)")
    ap.add_argument("--min-seconds", type=float, default=1e-4,
                    help="ignore medians below this (timer noise)")
    args = ap.parse_args(argv)

    if args.threshold <= 0:
        print("[check_regression] --threshold must be positive",
              file=sys.stderr)
        return 2
    baseline = load_records(args.baseline)
    fresh = load_records(args.fresh)
    if not baseline or not fresh:
        which = "baseline" if not baseline else "fresh"
        print(f"[check_regression] no BENCH_*.json records in the "
              f"{which} directory", file=sys.stderr)
        return 2

    rep = compare(baseline, fresh, threshold=args.threshold,
                  min_seconds=args.min_seconds)
    for bench, key, b, f, r in rep["improvements"]:
        print(f"[check_regression] improved  {bench}/{key}: "
              f"{b*1e3:.2f} → {f*1e3:.2f} ms ({r:+.0%})")
    for bench, key in rep["unmatched"]:
        print(f"[check_regression] unmatched {bench}/{key} "
              f"(no comparable baseline variant — not gated)")
    for bench, key, b, f in rep["warnings"]:
        print(f"[check_regression] WARN cost-model drift {bench}/{key}: "
              f"predicted_vs_measured {b:+.0%} → {f:+.0%} "
              f"(>2× the committed record — model fidelity slipping; "
              f"not gated)")
    for bench, key, b, f, r in rep["regressions"]:
        print(f"[check_regression] REGRESSED {bench}/{key}: "
              f"{b*1e3:.2f} → {f*1e3:.2f} ms ({r:+.0%} > "
              f"{args.threshold:.0%})")
    print(f"[check_regression] {rep['matched']} variant(s) compared, "
          f"{len(rep['regressions'])} regression(s), "
          f"{len(rep['improvements'])} improvement(s), "
          f"{len(rep['unmatched'])} unmatched, "
          f"{len(rep['warnings'])} drift warning(s)")
    return 1 if rep["regressions"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
