"""Benchmark harness — one benchmark per paper table/figure.

Paper artefacts reproduced:

* **Fig. 1** (`bench_fig1`): Ludwig binary-collision runtime, *original*
  (AoS, model-dictated innermost extents 19/3) vs *targetDP* (SoA,
  VVL-chunked sites) — on the CPU host, plus the Pallas-interpret backend
  to demonstrate the single-source portability contract.
* **VVL tuning curve** (`bench_vvl`): the paper's central claim — a
  *tunable* ILP extent exposes performance the compiler cannot find from
  model-dictated loops.  We sweep VVL exactly as §IV tunes 8 (CPU) / 2
  (GPU).
* **Masked transfers** (`bench_masked_copy`): §III-B's compressed copies
  vs full-lattice copies at several subset densities.
* **Fused stream+collide** (`bench_fused_step`): the follow-up paper's
  (1609.01479) fusion claim — one stencil launch per LB timestep
  (stream → ∇φ → collide, no intermediate full-lattice arrays) vs the
  unfused moment/stencil/collide/stream pipeline, per-site wall cost;
  plus the `tdp.Program` variant (the whole step as a compiled graph,
  scanned under one `lax.scan` with donated ping-pong buffers).
* **Streaming / gradient launches** (`bench_stream`, `bench_grad`): the
  two building-block stencil launches across executors — the per-launch
  records the fused numbers decompose into.
* **LM token throughput** (`bench_lm_step`): the token-lattice pointwise
  family (rmsnorm / gated-act) through the same tdp backends — the
  framework-integration claim (DESIGN.md §4).

Wall-times here are CPU numbers (this container); they demonstrate the
*tuning structure* (relative effects), while the TPU roofline lives in
benchmarks/roofline.py (static analysis of the dry-run artifacts).

Usage: ``PYTHONPATH=src python -m benchmarks.run [--quick]
[--only a,b,...] [--json] [--sweep plane_block=1,2,4]``

``--json`` additionally writes one machine-readable
``BENCH_<name>.json`` per benchmark that ran (median/min wall times,
grid size, executor per variant) under ``--out`` — the cross-PR perf
trajectory; the nightly CI lane uploads them as artifacts.

``--sweep key=v1,v2,...`` re-runs the windowed-executor variants of the
stencil benches once per value of any ``Target.tuning`` knob the
executor *declares* (``tdp.executor_tunables``; e.g. ``plane_block``)
and records the per-value medians into the bench JSON under
``"sweep"``.  A knob the executor ignores exits 2 up front — a silently
ignored sweep would read as "ran".

``--autotune`` closes the tuning loop: ``tdp.autotune`` runs over
``bench_fused_step``'s fused Program (windowed target), the chosen
tuning + full ``TuneReport`` land in ``BENCH_fused_step.json`` under
the ``"tuning"`` / ``"autotune"`` keys (extending, not replacing, the
PR 3/4 record schema), and the measured choice persists in the
``results/tuning/`` cache — a re-run reproduces it without measuring.
``--grid N`` / ``--steps K`` shrink the lattice / timing repetitions
for smoke runs (the CI fast lane runs ``--autotune --grid 8 --steps
2``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS = {}

#: per-bench machine-readable records (written by --json): name →
#: {"grid": ..., "variants": {label: {"median_s", "min_s", "executor"}}}
BENCH_RECORDS = {}

#: ``--sweep key=v1,v2,...`` values (parsed by main); benches with a
#: windowed-executor variant consult this and record one extra variant
#: per value under the bench record's "sweep" key.
SWEEPS: dict[str, list] = {}

#: the executor the sweep consumers retune — sweep keys are validated
#: against its declared tunables (``tdp.executor_tunables``) in main().
SWEEP_EXECUTOR = "pallas_windowed"

#: display/record abbreviations for sweep-variant keys (keeps the
#: PR 4 ``fused_windowed_pb<N>`` JSON spelling stable).
_KNOB_ABBREV = {"plane_block": "pb"}

#: --grid N / --steps K overrides (None → bench defaults).
GRID_OVERRIDE: int | None = None
REPS_OVERRIDE: int | None = None

#: --autotune: run tdp.autotune over bench_fused_step's Program and
#: record the choice + report into its BENCH JSON.
AUTOTUNE = False
TUNING_CACHE = "results/tuning"

#: --top-k K: predictor-guided autotune — rank the candidate space by
#: the cost-model's predicted time and measure only the base target plus
#: the K best-predicted candidates (None → measure everything).
TOP_K: int | None = None

#: --predict: annotate each fused_step variant with the cost model's
#: predicted step time (predicted_s / predicted_vs_measured /
#: bottleneck) so the bench JSON tracks model fidelity over time.
PREDICT = False


def _grid(default: tuple) -> tuple:
    if GRID_OVERRIDE is not None:
        return (GRID_OVERRIDE,) * len(default)
    return default


def _sweep_variants(base_target):
    """``(knob, value, record_suffix, display_suffix, target)`` per swept
    knob value — the generic spelling of the old plane_block-only loop."""
    out = []
    for key, vals in SWEEPS.items():
        short = _KNOB_ABBREV.get(key, f"{key}_")
        for v in vals:
            out.append((key, v, f"{short}{v}", f"{key}={v}",
                        base_target.with_tuning({key: v})))
    return out


def _time_stats(fn, *args, reps=5, warmup=2):
    """{"median_s", "min_s"} over ``reps`` timed calls."""
    if REPS_OVERRIDE is not None:
        reps, warmup = REPS_OVERRIDE, min(warmup, 1)
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return {"median_s": float(np.median(ts)), "min_s": float(np.min(ts))}


def _time(fn, *args, reps=5, warmup=2):
    return _time_stats(fn, *args, reps=reps, warmup=warmup)["median_s"]


def _table(title, rows, headers):
    out = [f"\n### {title}\n", "| " + " | ".join(headers) + " |",
           "|" + "---|" * len(headers)]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    text = "\n".join(out)
    print(text, flush=True)
    return text


# ---------------------------------------------------------------------------
# Fig. 1 — original vs targetDP, CPU + pallas-interpret
# ---------------------------------------------------------------------------

def bench_fig1(quick=False):
    from repro.lb import baseline, stencil
    from repro.lb.params import LBParams
    from repro.kernels import ops
    from repro.kernels.lb_collision import NVEL

    grid = (24, 24, 24) if quick else (32, 32, 32)
    n = int(np.prod(grid))
    p = LBParams()
    rng = np.random.default_rng(0)
    f = jnp.asarray(0.05 * rng.normal(size=(NVEL, n)) + 1 / 19., jnp.float32)
    g = jnp.asarray(0.05 * rng.normal(size=(NVEL, n)), jnp.float32)
    phi = g.sum(0, keepdims=True)
    gp = jnp.asarray(0.01 * rng.normal(size=(3, n)), jnp.float32)
    d2 = jnp.asarray(0.01 * rng.normal(size=(1, n)), jnp.float32)

    # original: AoS layout, innermost extents 19/3
    f_aos, g_aos = f.T, g.T
    gp_aos = gp.T

    t_orig = _time(jax.jit(
        lambda *a: baseline.collide_aos(*a, p)), f_aos, g_aos, phi[0],
        gp_aos, d2[0])

    from repro import tdp

    best = {}
    for backend in ("xla", "pallas_interpret"):
        vvls = (64, 128) if quick else (32, 64, 128, 256, 512)
        times = {}
        for vvl in vvls:
            tgt = tdp.Target(backend, vvl=vvl)
            fn = jax.jit(lambda *a, t=tgt: ops.lb_collision(
                *a, target=t, **p.as_kwargs()))
            times[vvl] = _time(fn, f, g, phi, gp, d2)
        best[backend] = min(times.items(), key=lambda kv: kv[1])
        RESULTS[f"fig1_vvl_{backend}"] = times

    msites = n / 1e6
    rows = [("original (AoS, extents 19/3)", "-",
             f"{t_orig*1e3:.2f}", f"{msites/t_orig:.1f}", "1.00×")]
    for backend, (vvl, t) in best.items():
        rows.append((f"targetDP [{backend}]", vvl, f"{t*1e3:.2f}",
                     f"{msites/t:.1f}", f"{t_orig/t:.2f}×"))
    RESULTS["fig1"] = {"grid": grid, "t_original_s": t_orig,
                       "best": {k: {"vvl": v[0], "t_s": v[1]}
                                for k, v in best.items()}}
    BENCH_RECORDS["fig1"] = {
        "grid": list(grid),
        "variants": {"original_aos": {"median_s": t_orig, "executor": "xla"},
                     **{f"targetdp_{k}": {"median_s": v[1], "executor": k,
                                          "vvl": v[0]}
                        for k, v in best.items()}}}
    return _table(
        f"Fig. 1 — binary collision, {grid} lattice ({n} sites)",
        rows, ["implementation", "VVL", "ms/step", "Msites/s", "speedup"])


# ---------------------------------------------------------------------------
# VVL tuning curve
# ---------------------------------------------------------------------------

def bench_vvl(quick=False):
    times = RESULTS.get("fig1_vvl_xla")
    if times is None:
        bench_fig1(quick)
        times = RESULTS["fig1_vvl_xla"]
    tmin = min(times.values())
    rows = [(v, f"{t*1e3:.2f}", f"{t/tmin:.2f}×")
            for v, t in sorted(times.items())]
    RESULTS["vvl_curve"] = {str(k): v for k, v in times.items()}
    BENCH_RECORDS["vvl"] = {
        "variants": {f"vvl{v}": {"median_s": t, "executor": "xla", "vvl": v}
                     for v, t in sorted(times.items())}}
    return _table("VVL tuning curve (xla backend, paper §IV methodology)",
                  rows, ["VVL", "ms/step", "vs best"])


# ---------------------------------------------------------------------------
# masked vs full copies (paper §III-B)
# ---------------------------------------------------------------------------

def bench_masked_copy(quick=False):
    from repro.core import (Field, Lattice, copy_from_target,
                            copy_from_target_masked, copy_to_target)

    side = 48 if quick else 64
    lat = Lattice((side, side, side))
    f = Field(lat, ncomp=19, dtype=np.float32)
    rng = np.random.default_rng(1)
    f.data[...] = rng.normal(size=f.array_shape).astype(np.float32)
    t = copy_to_target(f)
    jax.block_until_ready(t)

    # On-host wall time cannot show the paper's win (device_get of a local
    # CPU array is a memcpy); the §III-B claim is about *link* traffic
    # (PCIe then, ICI/DCN now).  Report wire bytes + modelled link time at
    # 16 GB/s alongside the measured pack cost.
    LINK = 16e9
    t_full = _time(lambda: np.asarray(jax.device_get(t)), reps=3)
    full_bytes = f.data.nbytes
    rows = [("full lattice", "100%", f"{full_bytes/2**20:.1f}",
             f"{full_bytes/LINK*1e3:.2f}", f"{t_full*1e3:.2f}", "1.00×")]
    for frac in (0.01, 0.1, 0.5):
        mask = rng.random(lat.nsites) < frac
        host = Field(lat, 19, np.float32)
        tm = _time(lambda m=mask, h=host: copy_from_target_masked(t, m, h),
                   reps=3)
        wire = int(mask.sum()) * 19 * 4
        rows.append(("masked subset", f"{frac:.0%}", f"{wire/2**20:.1f}",
                     f"{wire/LINK*1e3:.2f}", f"{tm*1e3:.2f}",
                     f"{full_bytes/wire:.1f}×"))
    RESULTS["masked_copy"] = {"t_full_s": t_full, "full_bytes": full_bytes}
    BENCH_RECORDS["masked_copy"] = {
        "grid": [side] * 3,
        "variants": {"full": {"median_s": t_full, "bytes": full_bytes,
                              "executor": "host"}}}
    return _table(
        f"Masked (compressed) transfers, {side}³ × 19 comp (§III-B)",
        rows, ["transfer", "subset", "wire MiB", "link ms @16GB/s",
               "measured pack ms", "wire reduction"])


# ---------------------------------------------------------------------------
# fused vs unfused LB timestep (stencil-aware launch)
# ---------------------------------------------------------------------------

#: subprocess body for the sharded pencil variant: this process owns the
#: single-device benches, so the multi-device run gets its own
#: interpreter with forced host devices (same pattern as
#: tests/test_distributed.py).  Prints one JSON doc on the last line.
_SHARDED_BENCH_SRC = r"""
import json, os, sys, time
import jax, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.lb.params import LBParams
from repro.lb.sim import BinaryFluidSim

grid, reps, steps = json.loads(sys.argv[1])
grid = tuple(grid)
p = LBParams(A=0.125, B=0.125, kappa=0.02)
mesh = make_test_mesh((2, 2), ("px", "py"))

def median_step_s(sim):
    st = sim.init_spinodal(seed=0, noise=0.05)
    ws = sim.programs["collide"].step({"f": st.f, "g": st.g})
    exe = sim.programs["fused"]
    run = lambda: jax.block_until_ready(exe.run(dict(ws), steps))
    run()                                    # compile + warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) / steps, exe

out = {}
for key, overlap in (("fused_pencil_2x2", False),
                     ("fused_pencil_2x2_overlap", True)):
    sim = BinaryFluidSim(grid, params=p, fused="two_launch", mesh=mesh,
                         shard_axis=("px", "py"), overlap=overlap)
    t, exe = median_step_s(sim)
    cs = exe.comm_stats()
    out[key] = {"median_s": t, "overlap": cs["overlap"],
                "decomposition": cs["decomposition"],
                "interior_fraction": cs["interior_fraction"],
                "exchanged_bytes_per_step": cs["exchanged_bytes_per_step"],
                "ppermutes_per_step": cs["ppermutes_per_step"]}
print(json.dumps(out))
"""


def _bench_sharded_fused(grid, reps, steps):
    """Run the 2×2-pencil fused two_launch bench in a 4-fake-device
    subprocess; returns the per-variant records (or None on failure —
    the sharded lane is additive, never fatal to the bench)."""
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env.setdefault("JAX_PLATFORMS", "cpu")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src")]
        + env.get("PYTHONPATH", "").split(os.pathsep))
    try:
        res = subprocess.run(
            [sys.executable, "-c", _SHARDED_BENCH_SRC,
             json.dumps([list(grid), reps, steps])],
            capture_output=True, text=True, timeout=1200, env=env)
    except (subprocess.TimeoutExpired, OSError) as e:
        print(f"[benchmarks] sharded fused bench skipped: {e}",
              file=sys.stderr)
        return None
    if res.returncode != 0:
        print(f"[benchmarks] sharded fused bench failed:\n{res.stderr}",
              file=sys.stderr)
        return None
    return json.loads(res.stdout.strip().splitlines()[-1])


def bench_fused_step(quick=False):
    import warnings

    from repro import tdp
    from repro.lb.params import LBParams
    from repro.lb.sim import BinaryFluidSim

    grid = _grid((16, 16, 16) if quick else (24, 24, 24))
    n = int(np.prod(grid))
    p = LBParams(A=0.125, B=0.125, kappa=0.02)

    # Time the jitted hot-loop body of each regime — since the tdp.Program
    # redesign every regime *is* a compiled Program: the whole unfused
    # timestep (5 stages), the fused stencil stage(s) that replace it —
    # one_launch (radius-2 composed gather), two_launch (streamed-φ
    # intermediate, gather stage (a)) and the gather-free pallas_windowed
    # executor (stage (b); runs in interpret mode on this CPU container,
    # so its wall time measures the Pallas *interpreter*, not the kernel —
    # the claim it carries is the memory structure, reported as the
    # ProgramPlan's aggregated est. HBM bytes).  The extra
    # "fused_program_scan" variant runs K steps under one lax.scan with
    # donated ping-pong field buffers (CompiledProgram.run).
    wt = tdp.Target("pallas_windowed", interpret=True)
    sim_u = BinaryFluidSim(grid, params=p)
    sim_f = BinaryFluidSim(grid, params=p, fused="one_launch")
    sim_f2 = BinaryFluidSim(grid, params=p, fused="two_launch")
    sim_w = BinaryFluidSim(grid, params=p, fused="one_launch", target=wt)
    st = sim_u.init_spinodal(seed=0, noise=0.05)
    # pre-stream fused state w = collide(u)
    ws = sim_f.programs["collide"].step({"f": st.f, "g": st.g})

    hbm = {
        "unfused": sim_u.programs["step"].plan().hbm_bytes_estimate(),
        "fused": sim_f.programs["fused"].plan().hbm_bytes_estimate(),
        "fused_two": sim_f2.programs["fused"].plan().hbm_bytes_estimate(),
        "fused_windowed":
            sim_w.programs["fused"].plan().hbm_bytes_estimate(),
    }

    variants = [
        ("unfused pipeline (Program, 5 stages)", "unfused", "xla",
         sim_u.programs["step"].step, ({"f": st.f, "g": st.g},)),
        ("fused (one launch)", "fused", "xla",
         sim_f.programs["fused"].step, (ws,)),
        ("fused (two launches, φ intermediate)", "fused_two", "xla",
         sim_f2.programs["fused"].step, (ws,)),
        ("fused (windowed, gather-free, interpret)", "fused_windowed",
         "pallas_windowed", sim_w.programs["fused"].step, (ws,)),
    ]
    progs = {
        "unfused": sim_u.programs["step"],
        "fused": sim_f.programs["fused"],
        "fused_two": sim_f2.programs["fused"],
        "fused_windowed": sim_w.programs["fused"],
    }
    sweep_keys = {}
    for knob, v, rec_sfx, disp_sfx, s_tgt in _sweep_variants(wt):
        sim_pb = BinaryFluidSim(grid, params=p, fused="one_launch",
                                target=s_tgt)
        key = f"fused_windowed_{rec_sfx}"
        sweep_keys[key] = (knob, v)
        progs[key] = sim_pb.programs["fused"]
        variants.append(
            (f"fused (windowed, {disp_sfx})", key, "pallas_windowed",
             sim_pb.programs["fused"].step, (ws,)))

    rows, rec = [], {"grid": list(grid), "variants": {}}
    base_t = None
    for label, key, executor, fn, args in variants:
        ts = _time_stats(fn, *args,
                         reps=3 if executor == "pallas_windowed" else 5)
        t = ts["median_s"]
        per_site_ns = t / n * 1e9
        rec["variants"][key] = {
            "t_s": t, "ns_per_site_step": per_site_ns, "executor": executor,
            **ts, **({"hbm_bytes_estimate": hbm[key]} if key in hbm else {}),
        }
        if PREDICT and key in progs:
            try:
                est = tdp.predict(progs[key])
            except Exception as e:  # noqa: BLE001 — fidelity tracking
                # must never fail the measurement it annotates
                rec["variants"][key]["predict_error"] = (
                    f"{type(e).__name__}: {e}")
            else:
                rec["variants"][key].update(
                    predicted_s=est.seconds,
                    predicted_vs_measured=(est.seconds - t) / t,
                    predicted_bottleneck=est.bottleneck)
        if key in sweep_keys:
            knob, v = sweep_keys[key]
            rec.setdefault("sweep", {}).setdefault(knob, {})[
                str(v)] = {"median_s": t, **ts}
        if base_t is None:
            base_t = t
        rows.append((label, f"{t*1e3:.2f}", f"{per_site_ns:.1f}",
                     f"{n/t/1e6:.1f}", f"{base_t/t:.2f}×",
                     f"{hbm[key]/2**20:.1f}" if key in hbm else "-"))

    if AUTOTUNE:
        # Close the tuning loop over the fused Program: the default
        # space (windowed plane_block divisor sweep + the xla fallback)
        # measured under the real wall-clock timer; the winner and the
        # full per-candidate report extend this bench's JSON record, and
        # the choice persists in results/tuning/ (a re-run with a warm
        # cache reports cache_hit=True without re-measuring).
        tuned, rep = tdp.autotune(
            sim_w.programs["fused"], example_state=ws,
            measure_steps=1, reps=REPS_OVERRIDE or 3, warmup=1,
            top_k=TOP_K, cache_dir=TUNING_CACHE)
        rec["tuning"] = {"backend": tuned.backend,
                         "interpret": tuned.interpret,
                         **tuned.tuning_dict()}
        rec["autotune"] = rep.as_dict()
        rows.append((f"autotuned → {rep.best.label}"
                     f"{' (cache hit)' if rep.cache_hit else ''}",
                     f"{rep.best_median_s*1e3:.2f}",
                     f"{rep.best_median_s/n*1e9:.1f}",
                     f"{n/rep.best_median_s/1e6:.1f}",
                     f"{rep.default_median_s/rep.best_median_s:.2f}×",
                     "-"))

    # Program-driven scanned variant: K steps in one jitted lax.scan with
    # donated (ping-pong aliased) field buffers; per-step cost amortises
    # the per-call dispatch the .step variants pay.  Donation is a no-op
    # on the CPU backend (XLA warns and falls back) but exercises the
    # real TPU path; each call feeds on the previous call's output.
    K = 10
    exe = sim_f2.programs["fused"]
    holder = {"s": dict(ws)}
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", message="Some donated buffers")

        def scan_k():
            holder["s"] = exe.run(holder["s"], K, donate=True)
            return holder["s"]

        ts = _time_stats(scan_k, reps=5)
    t = ts["median_s"] / K
    rec["variants"]["fused_program_scan"] = {
        "t_s": t, "ns_per_site_step": t / n * 1e9, "executor": "xla",
        "median_s": t, "min_s": ts["min_s"] / K, "scan_length": K,
        "donated": True, "hbm_bytes_estimate": hbm["fused_two"],
    }
    rows.append((f"fused_two, Program scan×{K} (donated)",
                 f"{t*1e3:.2f}", f"{t/n*1e9:.1f}", f"{n/t/1e6:.1f}",
                 f"{base_t/t:.2f}×", f"{hbm['fused_two']/2**20:.1f}"))

    # Sharded lane: the 2×2-pencil decomposition of the same fused_two
    # step on 4 forced host devices (own subprocess), overlap off vs on.
    # The record carries the analytic exchange budget (comm_stats) and
    # the achieved overlap — the fraction of the no-overlap step the
    # interior/boundary split hides.  These CPU numbers demonstrate the
    # *schedule* (collectives per step, bytes on the wire); wall-clock
    # gains need real inter-chip links.
    sharded = _bench_sharded_fused(grid, reps=REPS_OVERRIDE or 3, steps=5)
    if sharded is not None:
        for key, v in sharded.items():
            rec["variants"][key] = {
                **v, "t_s": v["median_s"],
                "ns_per_site_step": v["median_s"] / n * 1e9,
                "executor": "xla", "mesh": "2x2",
            }
            rows.append((f"{key.replace('_', ' ')} (4 host devices)",
                         f"{v['median_s']*1e3:.2f}",
                         f"{v['median_s']/n*1e9:.1f}",
                         f"{n/v['median_s']/1e6:.1f}",
                         f"{base_t/v['median_s']:.2f}×", "-"))
        t_off = sharded["fused_pencil_2x2"]["median_s"]
        t_on = sharded["fused_pencil_2x2_overlap"]["median_s"]
        rec["sharded"] = {
            "mesh": [2, 2], "decomposition": "pencil",
            "exchanged_bytes_per_step":
                sharded["fused_pencil_2x2"]["exchanged_bytes_per_step"],
            "ppermutes_per_step":
                sharded["fused_pencil_2x2"]["ppermutes_per_step"],
            "achieved_overlap": 1.0 - t_on / t_off,
        }

    RESULTS["fused_step"] = rec
    BENCH_RECORDS["fused_step"] = rec
    return _table(
        f"Fused vs unfused LB timestep, {grid} lattice ({n} sites)",
        rows, ["implementation", "ms/step", "ns/site·step", "Msites/s",
               "speedup", "est. step HBM MiB (ProgramPlan)"])


# ---------------------------------------------------------------------------
# building-block stencil launches (stream / gradients) across executors
# ---------------------------------------------------------------------------

def _bench_stencil_launch(name, spec, make_input, quick):
    """Shared harness for the single-launch stencil benches: one variant
    per executor (+ optional plane_block sweep for the windowed one),
    with the per-launch HBM estimates alongside."""
    import jax as _jax

    from repro import tdp
    from repro.core import Lattice, launch_plan

    grid = _grid((16, 16, 16) if quick else (24, 24, 24))
    lat = Lattice(grid)
    n = lat.nsites
    x = make_input(lat)

    wt = tdp.Target("pallas_windowed", interpret=True)
    targets = [("xla", None, tdp.Target("xla", vvl=128)),
               ("pallas_interpret", None,
                tdp.Target("pallas_interpret", vvl=128)),
               ("pallas_windowed", None, wt)]
    for knob, v, rec_sfx, _disp, s_tgt in _sweep_variants(wt):
        targets.append((f"pallas_windowed_{rec_sfx}", (knob, v), s_tgt))

    rows, rec = [], {"grid": list(grid), "variants": {}}
    for key, swept, tgt in targets:
        fn = _jax.jit(lambda a, t=tgt: tdp.launch(spec, t, a, lattice=lat))
        ts = _time_stats(fn, x, reps=3 if "windowed" in key else 5)
        t = ts["median_s"]
        hbm = launch_plan(spec, tgt, lattice=lat).hbm_bytes_estimate()
        rec["variants"][key] = {
            "t_s": t, "ns_per_site": t / n * 1e9,
            "executor": tgt.executor, **ts, "hbm_bytes_estimate": hbm,
        }
        if swept is not None:
            knob, v = swept
            rec.setdefault("sweep", {}).setdefault(knob, {})[
                str(v)] = {"median_s": t, **ts}
        rows.append((key, f"{t*1e3:.3f}", f"{t/n*1e9:.1f}",
                     f"{n/t/1e6:.1f}", f"{hbm/2**20:.2f}"))
    RESULTS[name] = rec
    BENCH_RECORDS[name] = rec
    return _table(
        f"{name} launch, {grid} lattice ({n} sites)",
        rows, ["executor", "ms/launch", "ns/site", "Msites/s",
               "est. HBM MiB"])


def bench_stream(quick=False):
    """D3Q19 pull streaming (`STREAM_SPEC`) — the pure-gather launch."""
    import jax.numpy as _jnp

    from repro.kernels.lb_collision import NVEL
    from repro.lb.stencil import STREAM_SPEC

    def make(lat):
        rng = np.random.default_rng(0)
        return _jnp.asarray(
            0.05 * rng.normal(size=(NVEL, lat.nsites)) + 1 / 19.,
            _jnp.float32)

    return _bench_stencil_launch("stream", STREAM_SPEC, make, quick)


def bench_grad(quick=False):
    """6-point ∇φ/∇²φ (`GRAD6_SPEC`) — the small-star stencil launch."""
    import jax.numpy as _jnp

    from repro.lb.stencil import GRAD6_SPEC

    def make(lat):
        rng = np.random.default_rng(1)
        return _jnp.asarray(rng.normal(size=(1, lat.nsites)), _jnp.float32)

    return _bench_stencil_launch("grad", GRAD6_SPEC, make, quick)


# ---------------------------------------------------------------------------
# fleet — batched ensemble throughput (steps/sec/device vs batch)
# ---------------------------------------------------------------------------

def bench_fleet(quick=False):
    """Ensemble-execution throughput: one fused LB step graph vmapped
    over batch ∈ {1, 8, 64} (``CompiledProgram.vmap`` — the tdp.fleet
    layer).  The figure of merit is member steps/sec/device.

    On this single-core CPU container the per-member arithmetic cost is
    strictly linear in batch, so the measurable fleet win is the *fixed*
    per-launch cost (host dispatch + XLA prologue) amortised over the
    ensemble — which dominates at service-sized member grids, hence the
    small default lattice.  On a real accelerator the same curve also
    captures idle-parallelism recovery (small members underfill the
    chip), so throughput/device rises with batch until bandwidth
    saturates."""
    from repro import tdp
    from repro.lb.params import LBParams
    from repro.lb.sim import BinaryFluidSim

    grid = _grid((4, 4, 4))
    n = int(np.prod(grid))
    ndev = jax.device_count()
    p = LBParams(A=0.125, B=0.125, kappa=0.02)
    sim = BinaryFluidSim(grid, params=p, fused="two_launch")
    fused = sim.programs["fused"]
    st = sim.init_spinodal(seed=0, noise=0.05)
    ws = sim.programs["collide"].step({"f": st.f, "g": st.g})

    K = 1           # member steps per timed fleet launch
    batches = (1, 8) if quick else (1, 8, 64)
    rows, rec = [], {"grid": list(grid), "scan_length": K,
                     "devices": ndev, "variants": {}}
    for b in batches:
        fleet = fused.vmap(b)
        state = tdp.ProgramState.stack([ws] * b)
        ts = _time_stats(lambda s: fleet.run(s, K), state,
                         reps=REPS_OVERRIDE or 15, warmup=2)
        t = ts["median_s"]
        sps_dev = b * K / t / ndev
        rec["variants"][f"batch{b}"] = {
            **ts, "executor": "xla", "batch": b, "scan_length": K,
            "health": "off",
            "steps_per_s_per_device": sps_dev,
            "msites_per_s": b * K * n / t / 1e6,
        }
        rows.append((b, f"{t*1e3:.2f}", f"{sps_dev:.1f}",
                     f"{b*K*n/t/1e6:.2f}",
                     f"{rec['variants'][f'batch{b}']['steps_per_s_per_device'] / rec['variants']['batch1']['steps_per_s_per_device']:.2f}×"))
    # guard cost: the largest measured batch re-timed with a per-chunk
    # NaN/Inf health check (tdp.HealthPolicy(every=1) — the worst case;
    # every=k amortises this by k).  health_check_overhead is the
    # fractional slowdown vs the unguarded run of the same batch.
    bmax = batches[-1]
    policy = tdp.HealthPolicy(every=1)
    fleet = fused.vmap(bmax)
    state = tdp.ProgramState.stack([ws] * bmax)
    gts = _time_stats(lambda s: fleet.run(s, K, health=policy), state,
                      reps=REPS_OVERRIDE or 15, warmup=2)
    t_off = rec["variants"][f"batch{bmax}"]["median_s"]
    overhead = gts["median_s"] / t_off - 1.0
    rec["variants"][f"batch{bmax}_guarded"] = {
        **gts, "executor": "xla", "batch": bmax, "scan_length": K,
        "health": "every1",
        "steps_per_s_per_device": bmax * K / gts["median_s"] / ndev,
        "msites_per_s": bmax * K * n / gts["median_s"] / 1e6,
        "health_check_overhead": overhead,
    }
    rec["health_check_overhead"] = overhead
    rows.append((f"{bmax} (guarded)", f"{gts['median_s']*1e3:.2f}",
                 f"{bmax*K/gts['median_s']/ndev:.1f}",
                 f"{bmax*K*n/gts['median_s']/1e6:.2f}",
                 f"+{overhead*100:.1f}% guard"))
    RESULTS["fleet"] = rec
    BENCH_RECORDS["fleet"] = rec
    return _table(
        f"Fleet ensemble throughput (fused_two, {grid} lattice, "
        f"{K}-step scans, {ndev} device(s))",
        rows, ["batch", "ms/launch", "member steps/s/device", "Msites/s",
               "throughput/device vs batch=1"])


# ---------------------------------------------------------------------------
# LM pointwise family through tdp backends
# ---------------------------------------------------------------------------

def bench_lm_step(quick=False):
    from repro.kernels import ops

    tokens = 2048 if quick else 8192
    d = 1024
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(tokens, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(tokens, d)), jnp.float32)

    from repro import tdp

    rows = []
    for name, fn in (
        ("rmsnorm", lambda t: jax.jit(
            lambda xx: ops.rmsnorm(xx, w, target=t))),
        ("swiglu", lambda t: jax.jit(
            lambda xx: ops.gated_act(xx, u, kind="swiglu", target=t))),
    ):
        for backend in ("xla", "pallas_interpret"):
            vvl = 256
            t = _time(fn(tdp.Target(backend, vvl=vvl)), x)
            rows.append((name, backend, vvl, f"{t*1e3:.3f}",
                         f"{tokens/t/1e6:.1f}"))
    RESULTS["lm_pointwise"] = True
    BENCH_RECORDS["lm_step"] = {
        "tokens": tokens,
        "variants": {f"{r[0]}_{r[1]}": {"median_s": float(r[3]) / 1e3,
                                        "executor": r[1], "vvl": r[2]}
                     for r in rows}}
    return _table(
        f"Token-lattice pointwise kernels ({tokens} tokens × d={d})",
        rows, ["kernel", "backend", "VVL", "ms", "Mtok/s"])


# ---------------------------------------------------------------------------
# ported LM kernels (rmsnorm / mamba) — layout × vvl sweep (ISSUE 10)
# ---------------------------------------------------------------------------

def _kernels_record() -> dict:
    """The shared ``BENCH_kernels.json`` record — ``bench_rmsnorm`` and
    ``bench_mamba`` both merge their variants into it, so one committed
    file tracks the whole ported-kernel family."""
    return BENCH_RECORDS.setdefault(
        "kernels", {"variants": {}, "layouts": ["soa", "aosoa"]})


def _layout_vvl_points(quick):
    from repro import tdp
    vvls = (64, 256) if quick else (64, 256, 1024)
    return [(layout, vvl) for layout in tdp.LAYOUTS for vvl in vvls]


def bench_rmsnorm(quick=False):
    """RMSNorm through ``tdp.launch`` (site = token) across
    layout × vvl on the xla executor, plus a ``tdp.autotune`` run over
    the same spec — the record carries the tuner's chosen candidate and
    its default-vs-best medians (the acceptance check that the layout
    axis never costs performance: candidate 0 *is* the SoA default and
    wins ties)."""
    from repro import tdp
    from repro.kernels import lm, ops

    tokens = 2048 if quick else 8192
    d = 1024
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(tokens, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(d,)), jnp.float32)

    rec = _kernels_record()
    rec["rmsnorm"] = {"tokens": tokens, "d": d}
    rows = []
    for layout, vvl in _layout_vvl_points(quick):
        tgt = tdp.Target("xla", vvl=vvl, layout=layout)
        fn = jax.jit(lambda xx, t=tgt: ops.rmsnorm(xx, w, target=t))
        ts = _time_stats(fn, x)
        key = f"rmsnorm_xla_{layout}_vvl{vvl}"
        rec["variants"][key] = {**ts, "executor": "xla", "vvl": vvl,
                                "layout": layout, "kernel": "rmsnorm",
                                "sites": tokens}
        rows.append(("rmsnorm", layout, vvl, f"{ts['median_s']*1e3:.3f}",
                     f"{tokens/ts['median_s']/1e6:.1f}"))

    spec = lm.rmsnorm_spec(d)
    consts = {"weight": w, "eps": 1e-6, "scale_offset": 0.0}
    tuned, rep = tdp.autotune(
        spec, tdp.Target("xla", vvl=256), (x.T,), consts=consts,
        reps=REPS_OVERRIDE or 3, warmup=1, cache_dir=TUNING_CACHE)
    rec["autotune_rmsnorm"] = {
        "best": rep.best.label,
        "default_median_s": rep.default_median_s,
        "best_median_s": rep.best_median_s,
        "layout": tuned.layout, "vvl": tuned.vvl,
    }
    rows.append((f"rmsnorm autotuned → {rep.best.label}", tuned.layout,
                 tuned.vvl or "-", f"{rep.best_median_s*1e3:.3f}",
                 f"{rep.default_median_s/rep.best_median_s:.2f}× vs default"))
    return _table(
        f"RMSNorm layout×VVL sweep ({tokens} tokens × d={d}, xla)",
        rows, ["kernel", "layout", "VVL", "ms", "Mtok/s"])


def bench_mamba(quick=False):
    """Selective-scan (site = channel, time on the component axis)
    across layout × vvl on the xla executor — the recurrent member of
    the ported family; the layout axis regroups the *channel* sites."""
    from repro import tdp
    from repro.kernels import ops

    length, d_inner, nstate = (64, 256, 8) if quick else (128, 512, 16)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(1, length, d_inner)), jnp.float32)
    dt = jnp.asarray(
        0.1 + 0.9 * rng.random((1, length, d_inner)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, length, nstate)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(1, length, nstate)), jnp.float32)
    a = jnp.asarray(-0.5 - rng.random((d_inner, nstate)), jnp.float32)
    dd = jnp.asarray(rng.normal(size=(d_inner,)), jnp.float32)

    rec = _kernels_record()
    rec["mamba"] = {"length": length, "d_inner": d_inner,
                    "nstate": nstate}
    rows = []
    for layout, vvl in _layout_vvl_points(quick):
        tgt = tdp.Target("xla", vvl=vvl, layout=layout)
        fn = jax.jit(lambda *args, t=tgt: ops.mamba_scan(*args, target=t))
        ts = _time_stats(fn, x, dt, b, c, a, dd)
        key = f"mamba_xla_{layout}_vvl{vvl}"
        rec["variants"][key] = {**ts, "executor": "xla", "vvl": vvl,
                                "layout": layout, "kernel": "mamba_scan",
                                "scan_length": length, "sites": d_inner}
        rows.append(("mamba_scan", layout, vvl,
                     f"{ts['median_s']*1e3:.3f}",
                     f"{length*d_inner/ts['median_s']/1e6:.1f}"))
    return _table(
        f"Mamba selective scan layout×VVL sweep "
        f"(L={length}, d={d_inner}, N={nstate}, xla)",
        rows, ["kernel", "layout", "VVL", "ms", "Mcell/s"])


BENCHES = {
    "fig1": bench_fig1,
    "vvl": bench_vvl,
    "masked_copy": bench_masked_copy,
    "fused_step": bench_fused_step,
    "stream": bench_stream,
    "grad": bench_grad,
    "fleet": bench_fleet,
    "lm_step": bench_lm_step,
    "rmsnorm": bench_rmsnorm,
    "mamba": bench_mamba,
}


def _parse_sweep(text: str) -> dict[str, list]:
    """``"plane_block=1,2,4"`` → ``{"plane_block": [1, 2, 4]}``.

    Any ``Target.tuning`` knob parses (values as ints where possible);
    whether the swept executor actually *consumes* the knob is validated
    against its declared tunables in :func:`main` — a silently ignored
    sweep would read as "ran"."""
    out: dict[str, list] = {}
    for part in text.split(";"):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"--sweep expects key=v1,v2,...; got {part!r}")
        key, vals = part.split("=", 1)
        key = key.strip()
        if not key:
            raise ValueError(f"--sweep has an empty knob name: {part!r}")
        values = []
        for v in vals.split(","):
            v = v.strip()
            if not v:
                continue
            try:
                values.append(int(v))
            except ValueError:
                raise ValueError(
                    f"--sweep {key}= values must be integers, got {v!r}")
        if not values:
            raise ValueError(f"--sweep {key}= has no values")
        out[key] = values
    return out


#: benches that consult SWEEPS — a --sweep whose --only selection hits
#: none of them would silently no-op, so main() rejects that combination.
SWEEP_CONSUMERS = ("fused_step", "stream", "grad")


def main(argv=None):
    global AUTOTUNE, GRID_OVERRIDE, REPS_OVERRIDE, TUNING_CACHE
    global TOP_K, PREDICT
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, metavar="NAME[,NAME...]",
                    help=f"comma-separated subset of {sorted(BENCHES)}")
    ap.add_argument("--out", default="results/bench")
    ap.add_argument("--json", action="store_true",
                    help="also write one BENCH_<name>.json per bench run "
                         "(machine-readable perf trajectory) under --out")
    ap.add_argument("--sweep", default=None, metavar="KEY=V1,V2,...",
                    help="sweep any Target.tuning knob the windowed "
                         "executor declares (e.g. plane_block=1,2,4) over "
                         "its bench variants; per-value medians land in "
                         "the bench JSON under 'sweep'; an undeclared "
                         "knob exits 2")
    ap.add_argument("--autotune", action="store_true",
                    help="run tdp.autotune over bench_fused_step's fused "
                         "Program; the tuned choice + TuneReport extend "
                         "BENCH_fused_step.json ('tuning'/'autotune' "
                         "keys) and persist in the --tuning-cache dir")
    ap.add_argument("--grid", type=int, default=None, metavar="N",
                    help="override the lattice side (N³) for the grid "
                         "benches — smoke runs")
    ap.add_argument("--steps", type=int, default=None, metavar="K",
                    help="override timing repetitions per variant (and "
                         "autotune reps) — smoke runs")
    ap.add_argument("--tuning-cache", default="results/tuning",
                    help="tdp.autotune on-disk cache directory")
    ap.add_argument("--top-k", type=int, default=None, metavar="K",
                    help="with --autotune: measure only the base target "
                         "plus the K best candidates by the cost model's "
                         "predicted time (model-pruned candidates are "
                         "recorded in the report, not dropped)")
    ap.add_argument("--predict", action="store_true",
                    help="annotate bench_fused_step variants with the "
                         "cost model's predicted step time "
                         "(predicted_s / predicted_vs_measured)")
    args = ap.parse_args(argv)

    if args.grid is not None:
        if args.grid <= 0:
            print("[benchmarks] --grid must be positive", file=sys.stderr)
            return 2
        GRID_OVERRIDE = args.grid
    if args.steps is not None:
        if args.steps <= 0:
            print("[benchmarks] --steps must be positive", file=sys.stderr)
            return 2
        REPS_OVERRIDE = args.steps
    AUTOTUNE = bool(args.autotune)
    TUNING_CACHE = args.tuning_cache
    TOP_K = args.top_k
    PREDICT = bool(args.predict)
    if TOP_K is not None and TOP_K <= 0:
        print("[benchmarks] --top-k must be positive", file=sys.stderr)
        return 2
    if TOP_K is not None and not AUTOTUNE:
        print("[benchmarks] --top-k only applies with --autotune",
              file=sys.stderr)
        return 2

    if args.only:
        selected = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(selected) - set(BENCHES))
        if unknown:
            print(f"[benchmarks] unknown bench name(s): "
                  f"{', '.join(unknown)}; available: "
                  f"{', '.join(sorted(BENCHES))}", file=sys.stderr)
            return 2
    else:
        selected = list(BENCHES)

    if AUTOTUNE and "fused_step" not in selected:
        print("[benchmarks] --autotune runs inside bench_fused_step, which "
              "the --only selection excludes", file=sys.stderr)
        return 2

    if args.sweep:
        try:
            SWEEPS.update(_parse_sweep(args.sweep))
        except ValueError as e:
            print(f"[benchmarks] {e}", file=sys.stderr)
            return 2
        from repro.core import executor_tunables
        declared = executor_tunables(SWEEP_EXECUTOR)
        ignored = sorted(set(SWEEPS) - set(declared))
        if ignored:
            print(f"[benchmarks] --sweep knob(s) {', '.join(ignored)} are "
                  f"ignored by executor {SWEEP_EXECUTOR!r}; declared "
                  f"tunables: {', '.join(declared) or '(none)'}",
                  file=sys.stderr)
            return 2
        if not set(selected) & set(SWEEP_CONSUMERS):
            print(f"[benchmarks] --sweep has no effect: none of the "
                  f"selected benches ({', '.join(sorted(selected))}) "
                  f"consume it; sweep-aware benches: "
                  f"{', '.join(SWEEP_CONSUMERS)}", file=sys.stderr)
            return 2

    texts = [fn(args.quick) for name, fn in BENCHES.items()
             if name in selected]

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "bench_results.json"), "w") as fh:
        json.dump({k: v for k, v in RESULTS.items()
                   if not k.startswith("fig1_vvl")}, fh, indent=1,
                  default=str)
    with open(os.path.join(args.out, "bench_tables.md"), "w") as fh:
        fh.write("\n".join(texts))
    if args.json:
        for name, rec in BENCH_RECORDS.items():
            path = os.path.join(args.out, f"BENCH_{name}.json")
            with open(path, "w") as fh:
                json.dump({"bench": name, "quick": args.quick, **rec}, fh,
                          indent=1, default=str)
            print(f"[benchmarks] wrote {path}")
    print(f"\n[benchmarks] tables + JSON written to {args.out}/")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
