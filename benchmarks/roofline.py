"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads ``results/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
derives, per (arch × shape × mesh × variant):

  T_compute    = HLO_FLOPs / peak_FLOPs            (197 TF bf16 / chip)
  T_memory     = HLO_traffic_bytes / HBM_bw        (819 GB/s / chip)
  T_collective = wire_bytes_ici / ICI_bw  (+ DCN)  (50 GB/s/link; DCN 25)

All three inputs are **per-chip** (the post-SPMD module is per-chip) and
**trip-count exact** (see the ``repro.core.costmodel`` HLO walker —
XLA's own cost_analysis undercounts scan bodies by their trip counts).
The arithmetic itself lives in
:func:`repro.core.costmodel.dryrun_record_terms`; this module is the
table/CLI view over it.

Additional columns:
  MODEL_FLOPS        6·N·D (dense) / 6·N_active·D (MoE); 2·N·D serving
  useful ratio       MODEL_FLOPS / (HLO_FLOPs · chips) — remat/masking/
                     capacity-dispatch waste shows up here
  bottleneck         argmax of the three terms
  roofline fraction  T_dominant / ΣT — how balanced the cell is; the §Perf
                     loop drives the dominant term down
  fits               per-chip arguments+temp ≤ 16 GB HBM

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
      [--variant baseline] [--md results/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.core.costmodel import MachineProfile, dryrun_record_terms

# TPU v5e table rates — kept as module constants for scripts that import
# them, but sourced from (and asserted against) the cost model's profile
# table so the two can never drift apart.
_PROFILE = MachineProfile.default("tpu:v5e")
PEAK_FLOPS = _PROFILE.peak_flops   # 197e12  bf16
HBM_BW = _PROFILE.hbm_bw           # 819e9   bytes/s
ICI_BW = _PROFILE.link_bw          # 50e9    bytes/s/link
DCN_BW = _PROFILE.dcn_bw           # 25e9    bytes/s cross-pod
HBM_BYTES = _PROFILE.hbm_bytes     # 16 GiB


def load_records(out_dir="results/dryrun", mesh=None, variant=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        if variant and rec["variant"]["name"] != variant:
            continue
        recs.append(rec)
    return recs


def terms(rec):
    return dryrun_record_terms(rec, _PROFILE)


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def table(recs, *, md=False):
    headers = ["arch", "shape", "mesh", "variant", "T_comp", "T_mem",
               "T_coll", "bottleneck", "useful", "GiB/dev", "fits"]
    rows = []
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                           r["mesh"])):
        t = terms(rec)
        rows.append([
            rec["arch"], rec["shape"], rec["mesh"],
            rec["variant"]["name"],
            fmt_s(t["t_compute"]), fmt_s(t["t_memory"]),
            fmt_s(t["t_collective"]),
            f"{t['dominant']} ({t['frac']:.0%})",
            f"{t['useful_ratio']:.2f}",
            f"{t['bytes_per_dev']/2**30:.1f}",
            "✓" if t["fits"] else "✗",
        ])
    if md:
        out = ["| " + " | ".join(headers) + " |", "|" + "---|" * len(headers)]
        out += ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
    else:
        w = [max(len(str(r[i])) for r in rows + [headers])
             for i in range(len(headers))]
        out = ["  ".join(h.ljust(w[i]) for i, h in enumerate(headers))]
        out += ["  ".join(str(c).ljust(w[i]) for i, c in enumerate(r))
                for r in rows]
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None, choices=(None, "single",
                                                     "multi"))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--md", default=None, help="write markdown table here")
    args = ap.parse_args(argv)

    recs = load_records(args.dir, args.mesh, args.variant)
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun first")
        return 1
    print(table(recs))
    if args.md:
        os.makedirs(os.path.dirname(args.md) or ".", exist_ok=True)
        with open(args.md, "w") as f:
            f.write(table(recs, md=True) + "\n")
        print(f"\nmarkdown table → {args.md}")

    # summary: worst cells by each criterion (the §Perf cell-selection aid)
    singles = [r for r in recs if r["mesh"] == "single"
               and r["variant"]["name"] == "baseline"]
    if singles:
        worst_useful = min(singles, key=lambda r: terms(r)["useful_ratio"])
        most_coll = max(singles, key=lambda r: terms(r)["t_collective"])
        print("\n[selection] worst useful-compute ratio:",
              worst_useful["arch"], worst_useful["shape"],
              f"({terms(worst_useful)['useful_ratio']:.3f})")
        print("[selection] most collective-bound:",
              most_coll["arch"], most_coll["shape"],
              f"({fmt_s(terms(most_coll)['t_collective'])})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
