"""Roofline analysis from the dry-run artifacts (deliverable g).

Reads ``results/dryrun/*.json`` (written by ``repro.launch.dryrun``) and
derives, per (arch × shape × mesh × variant):

  T_compute    = HLO_FLOPs / peak_FLOPs            (197 TF bf16 / chip)
  T_memory     = HLO_traffic_bytes / HBM_bw        (819 GB/s / chip)
  T_collective = wire_bytes_ici / ICI_bw  (+ DCN)  (50 GB/s/link; DCN 25)

All three inputs are **per-chip** (the post-SPMD module is per-chip) and
**trip-count exact** (see ``repro.launch.hlo_analysis`` — XLA's own
cost_analysis undercounts scan bodies by their trip counts).

Additional columns:
  MODEL_FLOPS        6·N·D (dense) / 6·N_active·D (MoE); 2·N·D serving
  useful ratio       MODEL_FLOPS / (HLO_FLOPs · chips) — remat/masking/
                     capacity-dispatch waste shows up here
  bottleneck         argmax of the three terms
  roofline fraction  T_dominant / ΣT — how balanced the cell is; the §Perf
                     loop drives the dominant term down
  fits               per-chip arguments+temp ≤ 16 GB HBM

Usage:
  PYTHONPATH=src python -m benchmarks.roofline [--mesh single]
      [--variant baseline] [--md results/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12        # TPU v5e bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s/link
DCN_BW = 25e9              # bytes/s cross-pod (conservative)
HBM_BYTES = 16 * 2 ** 30


def load_records(out_dir="results/dryrun", mesh=None, variant=None):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        if variant and rec["variant"]["name"] != variant:
            continue
        recs.append(rec)
    return recs


def terms(rec):
    ha = rec["hlo_analysis"]
    t_c = ha["flops"] / PEAK_FLOPS
    t_m = ha["traffic_bytes"] / HBM_BW
    t_x = ha["wire_bytes_ici"] / ICI_BW + ha["wire_bytes_dcn"] / DCN_BW
    chips = rec["n_devices"]
    hlo_total = ha["flops"] * chips
    useful = rec["model_flops"] / hlo_total if hlo_total else 0.0
    mem = rec["memory_analysis"]
    per_dev = (mem.get("argument_size_in_bytes", 0) +
               mem.get("temp_size_in_bytes", 0))
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    total = t_c + t_m + t_x
    return {
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom[0], "t_dominant": dom[1],
        "frac": dom[1] / total if total else 0.0,
        "useful_ratio": useful,
        "bytes_per_dev": per_dev,
        "fits": per_dev <= HBM_BYTES,
    }


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def table(recs, *, md=False):
    headers = ["arch", "shape", "mesh", "variant", "T_comp", "T_mem",
               "T_coll", "bottleneck", "useful", "GiB/dev", "fits"]
    rows = []
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                           r["mesh"])):
        t = terms(rec)
        rows.append([
            rec["arch"], rec["shape"], rec["mesh"],
            rec["variant"]["name"],
            fmt_s(t["t_compute"]), fmt_s(t["t_memory"]),
            fmt_s(t["t_collective"]),
            f"{t['dominant']} ({t['frac']:.0%})",
            f"{t['useful_ratio']:.2f}",
            f"{t['bytes_per_dev']/2**30:.1f}",
            "✓" if t["fits"] else "✗",
        ])
    if md:
        out = ["| " + " | ".join(headers) + " |", "|" + "---|" * len(headers)]
        out += ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
    else:
        w = [max(len(str(r[i])) for r in rows + [headers])
             for i in range(len(headers))]
        out = ["  ".join(h.ljust(w[i]) for i, h in enumerate(headers))]
        out += ["  ".join(str(c).ljust(w[i]) for i, c in enumerate(r))
                for r in rows]
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None, choices=(None, "single",
                                                     "multi"))
    ap.add_argument("--variant", default=None)
    ap.add_argument("--md", default=None, help="write markdown table here")
    args = ap.parse_args(argv)

    recs = load_records(args.dir, args.mesh, args.variant)
    if not recs:
        print("no dry-run records found — run repro.launch.dryrun first")
        return 1
    print(table(recs))
    if args.md:
        os.makedirs(os.path.dirname(args.md) or ".", exist_ok=True)
        with open(args.md, "w") as f:
            f.write(table(recs, md=True) + "\n")
        print(f"\nmarkdown table → {args.md}")

    # summary: worst cells by each criterion (the §Perf cell-selection aid)
    singles = [r for r in recs if r["mesh"] == "single"
               and r["variant"]["name"] == "baseline"]
    if singles:
        worst_useful = min(singles, key=lambda r: terms(r)["useful_ratio"])
        most_coll = max(singles, key=lambda r: terms(r)["t_collective"])
        print("\n[selection] worst useful-compute ratio:",
              worst_useful["arch"], worst_useful["shape"],
              f"({terms(worst_useful)['useful_ratio']:.3f})")
        print("[selection] most collective-bound:",
              most_coll["arch"], most_coll["shape"],
              f"({fmt_s(terms(most_coll)['t_collective'])})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
