"""Optimizer substrate: AdamW math, 8-bit moments, schedule, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, adamw_init, adamw_update, global_norm,
                         dequantize_blockwise, quantize_blockwise,
                         warmup_cosine)
from repro.optim.quant import QTensor


class TestQuant:
    @pytest.mark.parametrize("n,block", [(1000, 128), (256, 256), (7, 4)])
    def test_roundtrip_error_bounded(self, n, block, rng):
        """Global elementwise bound: |x − deq(quant(x))| ≤ max|x|/127
        (each block's error is ≤ its own absmax/127 ≤ the global one)."""
        x = jnp.asarray(rng.normal(size=(n,)) * 3, jnp.float32)
        q = quantize_blockwise(x, block)
        xr = dequantize_blockwise(q, x.shape)
        bound = float(jnp.abs(x).max()) / 127.0 * 1.01 + 1e-9
        assert float(jnp.abs(x - xr).max()) <= bound

    def test_zero_block(self):
        q = quantize_blockwise(jnp.zeros((64,)), 32)
        assert float(jnp.abs(dequantize_blockwise(q, (64,))).max()) == 0.0

    def test_shapes(self):
        """Codes keep the tensor's shape (sharding-transparent layout)."""
        q = quantize_blockwise(jnp.ones((10, 7)), 16)
        assert q.codes.shape == (10, 7) and q.scale.shape == (10, 1)
        q2 = quantize_blockwise(jnp.ones((4, 600)), 256)
        assert q2.codes.shape == (4, 600) and q2.scale.shape == (4, 3)


class TestAdamW:
    def _setup(self, quant):
        params = {"w": jnp.ones((16, 16)), "b": jnp.zeros((16,))}
        grads = {"w": jnp.full((16, 16), 0.5), "b": jnp.full((16,), 0.5)}
        cfg = AdamWConfig(lr=1e-2, quantize_moments=quant, quant_block=32,
                          weight_decay=0.0, clip_norm=0.0)
        return params, grads, cfg

    def test_first_step_is_lr_sized(self):
        params, grads, cfg = self._setup(False)
        st = adamw_init(params, cfg)
        p2, st2, m = adamw_update(params, grads, st, cfg)
        # bias-corrected first Adam step ≈ -lr·sign(g)
        np.testing.assert_allclose(p2["w"], 1.0 - 1e-2, rtol=1e-3)
        assert int(st2["step"]) == 1

    def test_quantized_tracks_fp32(self):
        """8-bit moments stay within a few % of the fp32 trajectory."""
        paths = {}
        for quant in (False, True):
            params, grads, cfg = self._setup(quant)
            st = adamw_init(params, cfg)
            p = params
            for i in range(10):
                g = jax.tree.map(
                    lambda x: x * (1.0 + 0.1 * np.sin(i)), grads)
                p, st, _ = adamw_update(p, g, st, cfg)
            paths[quant] = p
        np.testing.assert_allclose(paths[True]["w"], paths[False]["w"],
                                   rtol=0.05, atol=5e-3)

    def test_clipping(self):
        params, grads, cfg = self._setup(False)
        cfg2 = AdamWConfig(lr=1e-2, clip_norm=0.1, weight_decay=0.0)
        st = adamw_init(params, cfg2)
        _, _, metrics = adamw_update(params, grads, st, cfg2)
        assert float(metrics["grad_norm"]) > 0.1  # reported pre-clip

    def test_weight_decay_only_matrices(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
        zero_g = jax.tree.map(jnp.zeros_like, params)
        cfg = AdamWConfig(lr=1.0, weight_decay=0.5, clip_norm=0.0)
        st = adamw_init(params, cfg)
        p2, _, _ = adamw_update(params, zero_g, st, cfg)
        assert float(p2["w"][0, 0]) < 1.0          # decayed
        np.testing.assert_allclose(p2["b"], 1.0)   # vectors not decayed


class TestSchedule:
    def test_warmup_then_decay(self):
        lr = warmup_cosine(jnp.array([0, 10, 20, 60, 100]),
                           peak_lr=1e-3, warmup_steps=20, total_steps=100)
        lr = np.asarray(lr)
        assert lr[0] == 0.0
        assert lr[1] == pytest.approx(5e-4)
        assert lr[2] == pytest.approx(1e-3)
        assert lr[3] < lr[2]
        assert lr[4] == pytest.approx(1e-4, rel=1e-3)  # min_ratio·peak


class TestGlobalNorm:
    def test_matches_numpy(self, rng):
        tree = {"a": jnp.asarray(rng.normal(size=(8, 3)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(5,)), jnp.float32)}
        want = np.sqrt(sum((np.asarray(v) ** 2).sum()
                           for v in jax.tree.leaves(tree)))
        np.testing.assert_allclose(global_norm(tree), want, rtol=1e-6)
