"""The roofline's static HLO analyzer: exactness on known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_module, _multipliers


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


S = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)


class TestFlops:
    def test_plain_matmul(self):
        r = analyze(_hlo(lambda a, b: a @ b, S((512, 256)), S((256, 128))))
        assert r["flops"] == pytest.approx(2 * 512 * 256 * 128)

    def test_scan_multiplies_by_trip_count(self):
        def f(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, None, length=7)[0]
        r = analyze(_hlo(f, S((128, 128)), S((128, 128))))
        assert r["flops"] == pytest.approx(7 * 2 * 128 ** 3)

    def test_nested_scan(self):
        def f(x, w):
            def inner(c, _):
                return c @ w, None
            def outer(c, _):
                y, _ = jax.lax.scan(inner, c, None, length=5)
                return jnp.tanh(y), None
            return jax.lax.scan(outer, x, None, length=3)[0]
        r = analyze(_hlo(f, S((64, 64)), S((64, 64))))
        assert r["flops"] == pytest.approx(15 * 2 * 64 ** 3)

    def test_grad_roughly_triples(self):
        def loss(x, w):
            return jnp.sum((x @ w) ** 2)
        base = analyze(_hlo(loss, S((128, 64)), S((64, 32))))["flops"]
        g = analyze(_hlo(jax.grad(loss, argnums=1),
                         S((128, 64)), S((64, 32))))["flops"]
        assert 1.9 * base < g < 3.5 * base


class TestTraffic:
    def test_elementwise_chain_fuses(self):
        """y = tanh(x)+1 reads x once, writes y once (one fusion)."""
        n = 1 << 20
        r = analyze(_hlo(lambda x: jnp.tanh(x) + 1.0, S((n,))))
        assert r["traffic_bytes"] <= 2 * n * 4 * 1.1

    def test_scan_slice_charges_window_not_stack(self):
        """Per-iteration dynamic-slice of a stacked weight must charge the
        slice, not the stack (the granite 10× overcount regression)."""
        L, d = 16, 64
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            return jax.lax.scan(body, x, ws)[0]
        r = analyze(_hlo(f, S((d, d)), S((L, d, d))))
        # weights traffic ≈ L · d² · 4 (each layer read once) — allow
        # activations + overhead but far below L · (L·d²)
        assert r["traffic_bytes"] < 4 * L * d * d * 4 + 4e6


class TestMultiDevice:
    @pytest.mark.slow
    def test_collectives_counted_and_classified(self):
        import subprocess, sys, os, textwrap
        code = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.hlo_analysis import analyze
            from repro.launch.mesh import make_test_mesh
            mesh = make_test_mesh((2, 4), ("pod", "model"))
            def f(x, w):
                return x @ w
            xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
            ws = jax.ShapeDtypeStruct((128, 64), jnp.float32)
            jf = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                          NamedSharding(mesh, P("model", None))),
                         out_shardings=NamedSharding(mesh, P()))
            r = analyze(jf.lower(xs, ws).compile().as_text(), pod_stride=4)
            ar = r["collectives"]["all-reduce"]
            assert ar["count"] >= 1, r
            assert ar["operand_bytes"] >= 64*64*4
            print("OK", ar["count"])
        """)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                         "src")
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=300)
        assert out.returncode == 0, out.stderr
        assert "OK" in out.stdout


class TestParser:
    def test_tuple_types_with_index_comments(self):
        text = """
HloModule m

ENTRY %main (a: f32[8]) -> f32[8] {
  %a = f32[8]{0} parameter(0)
  %t = (f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, f32[8]{0}, /*index=5*/f32[8]{0}) tuple(%a, %a, %a, %a, %a, /*index=5*/%a)
  ROOT %r = f32[8]{0} get-tuple-element(%t), index=0
}
"""
        comps, entry = parse_module(text)
        assert entry == "main"
        assert comps["main"].instrs["t"].opcode == "tuple"
        assert len(comps["main"].instrs["t"].out_shapes) == 6
