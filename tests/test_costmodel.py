"""``tdp.costmodel`` — the analytical performance model.

What must hold:

* **monotonicity** — :func:`roofline_seconds` is non-decreasing in every
  one of flops / hbm_bytes / vmem_bytes / comm_bytes (seeded random
  sweeps, no wall clock anywhere);
* **bottleneck attribution** — compute vs hbm vs vmem-spill vs comm
  picked by the dominant term, spill only above the VMEM capacity;
* **profile cache** — round-trips through ``machine-<device>.json``,
  corrupt/mismatched files are misses (never errors), interpret
  profiles live under a separate key and can never answer for compiled
  plans (the honest-profile rule);
* **FLOP counting** — :func:`kernel_flops` is exact on a hand-countable
  kernel (jaxpr-traced, not estimated);
* **predict dispatch** — LaunchPlan / Program / ProgramPlan /
  CompiledProgram all answer, ``source="hlo"`` only for compiled
  programs, per-stage rows sum to the total;
* **compat shims** — ``repro.launch.hlo_analysis`` re-exports the
  absorbed walker; ``dryrun_record_terms`` matches the roofline CLI.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro import tdp
from repro.core import costmodel as cm
from repro.core.costmodel import (
    CostEstimate,
    MachineProfile,
    kernel_flops,
    load_profile,
    machine_profile,
    predict,
    profile_path,
    roofline_seconds,
    store_profile,
)
from repro.lb import programs as lbp
from repro.lb.params import LBParams

GRID = (8, 8, 8)
PARAMS = LBParams(A=0.125, B=0.125, kappa=0.02)
WT = tdp.Target("pallas_windowed", interpret=True)

#: fixed rates so every expectation below is hand-computable
PROF = MachineProfile(device="test", peak_flops=1e9, hbm_bw=1e8,
                     vmem_bytes=1024, link_bw=1e7, source="test")
IPROF = dataclasses.replace(PROF, interpret=True)


def fused_prog(mode="two_launch"):
    return lbp.fused_program(
        mode, lbp.collision_consts(**PARAMS.as_kwargs()))


def lb_state(grid=GRID, seed=0):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    f = jnp.asarray(0.05 * rng.normal(size=(19,) + grid) + 1 / 19.,
                    jnp.float32)
    g = jnp.asarray(0.05 * rng.normal(size=(19,) + grid), jnp.float32)
    return {"f": f, "g": g}


class TestRoofline:
    """The pure arithmetic core — seeded sweeps, no measurement."""

    def test_hand_computed_terms(self):
        est = roofline_seconds(1e9, 1e8, profile=PROF)
        assert est.t_compute == pytest.approx(1.0)
        assert est.t_hbm == pytest.approx(1.0)
        assert est.seconds == pytest.approx(1.0)
        assert est.bottleneck == "compute"    # ties go to compute

    def test_bottleneck_attribution(self):
        assert roofline_seconds(1e10, 1e6, profile=PROF).bottleneck \
            == "compute"
        assert roofline_seconds(1e3, 1e8, profile=PROF).bottleneck == "hbm"
        assert roofline_seconds(
            1e3, 1e8, vmem_bytes=4096, profile=PROF).bottleneck \
            == "vmem-spill"
        assert roofline_seconds(
            1e3, 1e3, comm_bytes=1e8, profile=PROF).bottleneck == "comm"

    def test_vmem_spill_derates_hbm(self):
        base = roofline_seconds(0, 1e8, profile=PROF)
        spilled = roofline_seconds(0, 1e8, vmem_bytes=2048, profile=PROF)
        assert spilled.t_hbm == pytest.approx(2 * base.t_hbm)

    @pytest.mark.parametrize("axis", ["flops", "hbm_bytes", "vmem_bytes",
                                      "comm_bytes"])
    def test_monotone_in_each_input(self, axis):
        rng = np.random.default_rng(hash(axis) % 2**32)
        for _ in range(50):
            kw = {"flops": float(rng.uniform(0, 1e12)),
                  "hbm_bytes": float(rng.uniform(0, 1e10)),
                  "vmem_bytes": float(rng.uniform(0, 1e7)),
                  "comm_bytes": float(rng.uniform(0, 1e9))}
            lo = dict(kw)
            hi = dict(kw)
            hi[axis] = kw[axis] * (1 + float(rng.uniform(0, 3)))
            f_lo, f_hi = lo.pop("flops"), hi.pop("flops")
            h_lo, h_hi = lo.pop("hbm_bytes"), hi.pop("hbm_bytes")
            s_lo = roofline_seconds(f_lo, h_lo, profile=PROF, **lo)
            s_hi = roofline_seconds(f_hi, h_hi, profile=PROF, **hi)
            assert s_hi.seconds >= s_lo.seconds

    def test_estimate_serializes(self):
        est = roofline_seconds(1e6, 1e6, profile=PROF)
        d = est.as_dict()
        assert d["bottleneck"] == est.bottleneck
        assert d["seconds"] == est.seconds
        json.dumps(d)    # JSON-safe throughout


class TestMachineProfile:
    """The calibrated-rates cache under results/tuning/."""

    def test_cache_round_trip(self, tmp_path):
        p = store_profile(str(tmp_path), PROF)
        assert p == profile_path(str(tmp_path), "test", False)
        back = load_profile(str(tmp_path), "test", False)
        assert back is not None
        assert back.peak_flops == PROF.peak_flops
        assert back.hbm_bw == PROF.hbm_bw
        assert back.source == "cached"

    def test_corrupt_file_is_a_miss(self, tmp_path):
        path = profile_path(str(tmp_path), "test", False)
        with open(path, "w") as fh:
            fh.write("{not json")
        assert load_profile(str(tmp_path), "test", False) is None

    def test_device_mismatch_is_a_miss(self, tmp_path):
        store_profile(str(tmp_path), PROF)
        path = profile_path(str(tmp_path), "test", False)
        d = json.load(open(path))
        d["device"] = "other"
        json.dump(d, open(path, "w"))
        assert load_profile(str(tmp_path), "test", False) is None

    def test_interpret_profiles_are_keyed_separately(self, tmp_path):
        store_profile(str(tmp_path), PROF)
        store_profile(str(tmp_path), IPROF)
        assert profile_path(str(tmp_path), "test", True) \
            != profile_path(str(tmp_path), "test", False)
        assert load_profile(str(tmp_path), "test", True).interpret
        assert not load_profile(str(tmp_path), "test", False).interpret

    def test_machine_profile_hits_disk_cache(self, tmp_path):
        dev = "fake-dev"
        prof = dataclasses.replace(PROF, device=dev)
        store_profile(str(tmp_path), prof)
        got = machine_profile(dev, cache_dir=str(tmp_path))
        assert got.source == "cached"
        assert got.peak_flops == PROF.peak_flops
        # the memo answers the second call even if the file vanishes
        os.remove(profile_path(str(tmp_path), dev, False))
        assert machine_profile(dev, cache_dir=str(tmp_path)) is got

    def test_default_table_without_calibration(self, tmp_path):
        got = machine_profile("nosuch-dev", cache_dir=str(tmp_path),
                              calibrate_if_missing=False)
        assert got.source == "default"
        assert not os.listdir(tmp_path)    # store=False never writes

    def test_honest_profile_rule(self):
        prog = fused_prog("one_launch")
        plan = prog.plan(WT, grid_shape=GRID)
        with pytest.raises(ValueError, match="interpret"):
            predict(plan, profile=PROF)        # compiled rates, interpret plan
        est = predict(plan, profile=IPROF)     # matching flag answers
        assert est.seconds > 0


class TestKernelFlops:
    """jaxpr-traced FLOPs — exact on a hand-countable kernel."""

    def test_pointwise_exact(self):
        @tdp.kernel(fields=[tdp.field(2)], out=2)
        def double2(x):
            return x + x                       # 1 add × 2 comp × nsites

        plan = tdp.launch_plan(double2, tdp.Target("xla", vvl=64),
                               lattice=tdp.Lattice(GRID))
        nsites = int(np.prod(GRID))
        assert kernel_flops(plan) == pytest.approx(2 * nsites)

    def test_scales_with_ops(self):
        @tdp.kernel(fields=[tdp.field(1)], out=1)
        def three_ops(x):
            return (x + x) * x + x             # add + mul + add

        plan = tdp.launch_plan(three_ops, tdp.Target("xla", vvl=64),
                               lattice=tdp.Lattice(GRID))
        assert kernel_flops(plan) == pytest.approx(3 * np.prod(GRID))


class TestPredict:
    """Dispatch over the four subject kinds + the two backends."""

    def test_launch_plan(self):
        @tdp.kernel(fields=[tdp.field(2)], out=2)
        def double2(x):
            return x + x

        plan = tdp.launch_plan(double2, tdp.Target("xla", vvl=64),
                               lattice=tdp.Lattice(GRID))
        est = predict(plan, profile=PROF)
        assert isinstance(est, CostEstimate)
        assert est.seconds > 0
        assert len(est.per_stage) == 1
        assert est.source == "analytic"

    def test_program_and_plan_agree(self):
        prog = fused_prog("two_launch")
        est_prog = predict(prog, WT, IPROF, grid_shape=GRID)
        est_plan = predict(prog.plan(WT, grid_shape=GRID), profile=IPROF)
        assert est_prog.seconds == pytest.approx(est_plan.seconds)
        assert [r["stage"] for r in est_prog.per_stage] \
            == ["phi_stream", "fused_two"]
        # stage rows + comm sum to the total
        assert est_prog.seconds == pytest.approx(
            sum(r["seconds"] for r in est_prog.per_stage)
            + est_prog.t_comm)

    def test_compiled_program(self):
        exe = fused_prog("two_launch").compile(
            tdp.Target("xla"), grid_shape=GRID)
        est = predict(exe, profile=PROF)
        assert est.flops > 0
        assert est.hbm_bytes > 0

    @pytest.mark.slow
    def test_hlo_backend(self):
        exe = fused_prog("two_launch").compile(
            tdp.Target("xla"), grid_shape=GRID)
        est = predict(exe, profile=PROF, source="hlo")
        assert est.source == "hlo"
        assert est.flops > 0
        assert est.hbm_bytes > 0
        assert est.per_stage[0]["stage"] == "<step>"

    def test_hlo_needs_compiled_program(self):
        with pytest.raises(ValueError, match="hlo"):
            predict(fused_prog("one_launch"), WT, IPROF,
                    grid_shape=GRID, source="hlo")

    def test_bad_source_rejected(self):
        with pytest.raises(ValueError, match="source"):
            predict(fused_prog("one_launch"), WT, IPROF,
                    grid_shape=GRID, source="vibes")

    def test_comm_term_from_override(self):
        prog = fused_prog("one_launch")
        plan = prog.plan(WT, grid_shape=GRID)
        quiet = predict(plan, profile=IPROF)
        chatty = predict(plan, profile=IPROF,
                         comm={"exchanged_bytes_per_step": 10**9})
        assert chatty.seconds > quiet.seconds
        assert chatty.comm_bytes == 10**9


class TestAbsorbedAnalysis:
    """The HLO walker + dry-run terms moved here; shims must hold."""

    def test_hlo_analysis_shim(self):
        from repro.launch import hlo_analysis as shim
        assert shim.analyze is cm.analyze
        assert shim.parse_module is cm.parse_module
        assert shim._multipliers is cm._multipliers

    def test_collective_bytes_empty(self):
        got = cm.collective_bytes("")
        assert got["total_bytes"] == 0
        assert all(v == 0 for v in got["bytes"].values())

    def test_dryrun_record_terms(self):
        rec = {"hlo_analysis": {"flops": 1e15, "traffic_bytes": 1e12,
                                "wire_bytes_ici": 1e10,
                                "wire_bytes_dcn": 0},
               "n_devices": 4, "model_flops": 2e15,
               "memory_analysis": {"argument_size_in_bytes": 2 ** 30,
                                   "temp_size_in_bytes": 2 ** 30}}
        t = cm.dryrun_record_terms(rec)
        tpu = MachineProfile.default("tpu:v5e")
        assert t["t_compute"] == pytest.approx(1e15 / tpu.peak_flops)
        assert t["t_memory"] == pytest.approx(1e12 / tpu.hbm_bw)
        assert t["dominant"] == "compute"
        assert t["useful_ratio"] == pytest.approx(0.5)
        assert t["fits"] is True
        # and the roofline CLI's terms() is the same arithmetic
        from benchmarks.roofline import terms
        assert terms(rec) == t
