"""Sharding rules engine: spec resolution, divisibility fallbacks, plans."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs as C
from repro.sharding import (logical_axis_sizes, make_plan, spec_for_axes)


class FakeMesh:
    """Just enough Mesh interface for spec resolution (no devices)."""

    def __init__(self, shape: dict):
        self._shape = shape
        self.axis_names = tuple(shape)

    @property
    def shape(self):
        return self._shape

    @property
    def devices(self):
        import numpy as np
        return np.zeros(tuple(self._shape.values()))


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _plan(arch, **kw):
    return make_plan(C.get_config(arch), **kw)


class TestSpecResolution:
    def test_vocab_and_dff_shard_model(self):
        plan = _plan("nemotron_4_15b")
        assert spec_for_axes(("vocab", "d_model"), plan, MESH) == \
            P("model", "data")
        assert spec_for_axes(("layers", "d_model", "d_ff"), plan, MESH) == \
            P(None, "data", "model")

    def test_heads_divisible(self):
        plan = _plan("deepseek_v3_671b")   # 128 heads % 16 == 0
        assert spec_for_axes(("layers", "d_model", "heads_x_dim"),
                             plan, MESH)[2] == "model"

    def test_kv_fallback_replicates(self):
        plan = _plan("phi3_medium_14b")    # kv=10: not divisible by 16
        spec = spec_for_axes(("layers", "d_model", "kv_x_dim"), plan, MESH)
        assert len(spec) < 3 or spec[2] is None
        # 40 q heads also not divisible → replicated too (documented)
        spec_q = spec_for_axes(("layers", "d_model", "heads_x_dim"),
                               plan, MESH)
        assert len(spec_q) < 3 or spec_q[2] is None

    def test_mesh_axis_used_once_per_tensor(self):
        plan = _plan("gemma3_27b")
        spec = spec_for_axes(("d_ff", "d_ff"), plan, MESH)
        flat = [s for s in spec if s is not None]
        assert flat.count("model") <= 1

    def test_layers_never_sharded(self):
        plan = _plan("gemma2_2b")
        spec = spec_for_axes(("layers", "d_ff", "d_model"), plan, MESH)
        assert spec[0] is None

    def test_expert_tp_plan(self):
        plan = _plan("deepseek_v3_671b", mode="train", fsdp=True)
        spec = spec_for_axes(("layers", "experts", "d_model", "d_ff"),
                             plan, MESH)
        assert spec == P(None, "data", None, "model")

    def test_a2a_plan_moves_experts_to_model(self):
        plan = _plan("deepseek_v3_671b", moe_impl="a2a")
        spec = spec_for_axes(("layers", "experts", "d_model", "d_ff"),
                             plan, MESH)
        assert spec[1] == "model"
        assert len(spec) < 4 or spec[3] != "model"  # model used once

    def test_serve_plan_spreads_weights(self):
        plan = _plan("gemma3_27b", mode="serve")
        spec = spec_for_axes(("layers", "d_model", "d_ff"), plan, MESH)
        assert spec == P(None, "data", "model")


class TestLogicalSizes:
    def test_unit_counts(self):
        cfg = C.get_config("deepseek_v3_671b")
        sizes = logical_axis_sizes(cfg)
        assert sizes["heads_x_dim"] == 128
        assert sizes["experts"] == 256
        assert sizes["vocab"] == cfg.padded_vocab
        assert sizes["vocab"] % 256 == 0

    def test_all_archs_have_positive_sizes(self):
        for arch in C.ARCHS:
            sizes = logical_axis_sizes(C.get_config(arch))
            assert all(v >= 1 for v in sizes.values()), arch


class TestDevicePlacement:
    """End-to-end placement on the real (1-device) mesh degenerates to
    replication but must not error for any arch."""

    def test_single_device_mesh(self):
        import jax
        from repro.models import params as params_lib
        from repro.sharding import sharding_for_tree
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((1, 1), ("data", "model"))
        cfg = C.get_smoke("granite_moe_1b_a400m")
        params, axes = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        sh = sharding_for_tree(axes, make_plan(cfg), mesh)
        placed = jax.device_put(params, sh)
        assert jax.tree.leaves(placed)[0].sharding is not None
