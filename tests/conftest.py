"""Shared test config.

Tests run on the default 1-CPU-device jax (never set
xla_force_host_platform_device_count here — the dry-run owns that flag).
Multi-device behaviour is tested via subprocesses (test_distributed.py).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
