"""targetDP stencil executor: descriptors, backend parity, halo mode.

The contract under test (docs/stencil.md): a stencil site kernel written
once against ``(noffsets, ncomp, VVL)`` neighbour chunks produces allclose
results on the jnp executor and the Pallas executor (interpret mode on this
CPU container), for periodic (roll) gathers and for caller-supplied ghost
planes (``halo=``), including site counts that are not a VVL multiple.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Lattice,
    Stencil,
    STENCIL_D3Q19_PULL,
    STENCIL_GRAD_6PT,
    STENCIL_GRAD_19PT,
    launch_stencil,
)
from repro.kernels.lb_collision import CV, NVEL
from repro.kernels.tdp_stencil import vmem_bytes_estimate
from repro.lb import stencil as lbst


class TestStencilDescriptor:
    def test_d3q19_matches_cv(self):
        np.testing.assert_array_equal(
            np.array([list(o) for o in STENCIL_D3Q19_PULL.offsets]),
            -CV.astype(int))
        assert STENCIL_D3Q19_PULL.radius == 1
        assert STENCIL_GRAD_6PT.noffsets == 7
        assert STENCIL_GRAD_19PT.noffsets == 19

    def test_index_lookup(self):
        assert STENCIL_GRAD_6PT.index((0, 0, 0)) == 0
        assert STENCIL_GRAD_6PT.index((1, 0, 0)) == 1
        with pytest.raises(KeyError):
            STENCIL_GRAD_6PT.index((2, 0, 0))

    def test_compose_radius_and_dedup(self):
        s = STENCIL_GRAD_6PT.compose(STENCIL_D3Q19_PULL)
        assert s.radius == 2
        assert s.noffsets == len(set(s.offsets))
        # every d - c_q offset is addressable
        for d in STENCIL_GRAD_6PT.offsets:
            for c in CV.astype(int):
                s.index(tuple(np.add(d, -c)))

    def test_validation(self):
        with pytest.raises(ValueError):
            Stencil("dup", ((0, 0), (0, 0)))
        with pytest.raises(ValueError):
            Stencil("empty", ())

    def test_vmem_estimate_counts_halo_rows(self):
        flat = vmem_bytes_estimate([19], [19], 128)
        halo = vmem_bytes_estimate([19], [19], 128, in_noffsets=[19])
        assert halo - flat == (19 * 19 - 19) * 128 * 4


class TestBackendParity:
    """xla vs pallas_interpret on the same single-source kernels —
    including a site count that is not a VVL multiple (padding path)."""

    @pytest.mark.parametrize("shape", [(4, 4, 4), (3, 4, 5)])
    def test_gradient_kernel(self, rng, shape):
        lat = Lattice(shape)
        phi = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
        outs = {}
        for backend in ("xla", "pallas_interpret"):
            g, l = launch_stencil(
                lbst.grad6_site_kernel, lat, [phi],
                stencil=STENCIL_GRAD_6PT, out_ncomp=(3, 1), vvl=64,
                backend=backend)
            outs[backend] = (np.asarray(g), np.asarray(l))
        np.testing.assert_allclose(*[o[0] for o in outs.values()], rtol=1e-6)
        np.testing.assert_allclose(*[o[1] for o in outs.values()], rtol=1e-6)

    @pytest.mark.parametrize("shape", [(4, 4, 4), (3, 4, 5)])
    def test_streaming_kernel(self, rng, shape):
        lat = Lattice(shape)
        f = jnp.asarray(rng.normal(size=(NVEL, lat.nsites)), jnp.float32)
        outs = []
        for backend in ("xla", "pallas_interpret"):
            outs.append(np.asarray(launch_stencil(
                lbst.stream_site_kernel, lat, [f],
                stencil=STENCIL_D3Q19_PULL, out_ncomp=NVEL, vvl=64,
                backend=backend)))
        np.testing.assert_array_equal(outs[0], outs[1])
        # cross-check against the grid-level roll semantics
        grid = np.asarray(f).reshape(NVEL, *shape)
        want = np.stack([np.roll(grid[q], shift=tuple(CV[q].astype(int)),
                                 axis=(0, 1, 2)) for q in range(NVEL)])
        np.testing.assert_array_equal(outs[0].reshape(NVEL, *shape), want)

    def test_fused_kernel_parity(self, rng):
        lat = Lattice((4, 4, 5))
        from repro.kernels import ops
        f = jnp.asarray(0.05 * rng.normal(size=(NVEL, lat.nsites)) + 1 / 19.,
                        jnp.float32)
        g = jnp.asarray(0.05 * rng.normal(size=(NVEL, lat.nsites)),
                        jnp.float32)
        a = ops.lb_fused_step(f, g, grid_shape=lat.shape, backend="xla",
                              vvl=64)
        b = ops.lb_fused_step(f, g, grid_shape=lat.shape,
                              backend="pallas_interpret", vvl=64)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-5, atol=2e-6)


class TestHaloMode:
    """Ghost planes supplied by the caller (the sharded path's contract)
    reproduce the periodic gather when the ghosts hold the wrapped data."""

    @pytest.mark.parametrize("halo_x", [1, 2])
    def test_ghost_planes_match_periodic(self, rng, halo_x):
        shape = (4, 4, 4)
        lat = Lattice(shape)
        stc = (STENCIL_GRAD_6PT if halo_x == 1
               else STENCIL_GRAD_6PT.compose(STENCIL_GRAD_6PT))
        phi = np.asarray(rng.normal(size=(1, *shape)), np.float32)
        ext = np.concatenate(
            [phi[:, -halo_x:], phi, phi[:, :halo_x]], axis=1)

        def centre_sum(p_nb):
            acc = p_nb[0, 0]
            for i in range(1, stc.noffsets):
                acc = acc + p_nb[i, 0]
            return acc[None]

        a = launch_stencil(centre_sum, lat, [jnp.asarray(phi.reshape(1, -1))],
                           stencil=stc, out_ncomp=1, vvl=32)
        b = launch_stencil(centre_sum, lat, [jnp.asarray(ext.reshape(1, -1))],
                           stencil=stc, out_ncomp=1, vvl=32,
                           halo=(halo_x, 0, 0))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_halo_too_small_rejected(self, rng):
        lat = Lattice((4, 4, 4))
        stc = STENCIL_GRAD_6PT.compose(STENCIL_GRAD_6PT)   # radius 2
        ext = jnp.zeros((1, 6 * 4 * 4), jnp.float32)       # halo 1 only
        with pytest.raises(ValueError, match="radius"):
            launch_stencil(lambda p: p[0], lat, [ext], stencil=stc,
                           out_ncomp=1, halo=(1, 0, 0))

    def test_wrong_extent_rejected(self):
        lat = Lattice((4, 4, 4))
        with pytest.raises(ValueError, match="extent"):
            launch_stencil(lambda p: p[0], lat,
                           [jnp.zeros((1, 60), jnp.float32)],
                           stencil=STENCIL_GRAD_6PT, out_ncomp=1)

    def test_mixed_pointwise_and_stencil_inputs(self, rng):
        """Pointwise inputs ride along at interior extent."""
        lat = Lattice((4, 4, 4))
        phi = jnp.asarray(rng.normal(size=(1, 64)), jnp.float32)
        scale = jnp.asarray(rng.normal(size=(1, 64)), jnp.float32)

        def k(p_nb, s):
            return s * (p_nb[1] - p_nb[2])

        for backend in ("xla", "pallas_interpret"):
            out = launch_stencil(k, lat, [phi, scale],
                                 stencil=(STENCIL_GRAD_6PT, None),
                                 out_ncomp=1, vvl=32, backend=backend)
            want = np.asarray(scale) * (
                np.roll(np.asarray(phi).reshape(1, 4, 4, 4), -1, axis=1)
                - np.roll(np.asarray(phi).reshape(1, 4, 4, 4), 1, axis=1)
            ).reshape(1, 64)
            np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6,
                                       atol=1e-6)
