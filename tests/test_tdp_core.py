"""targetDP core: lattice, fields, memory model, execution model.

These pin the paper's contract: single-source site kernels, SoA layout,
VVL chunking, host/target memory distinction, masked transfers, constants,
reductions (the paper's §V extension).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as tdp
from repro.core import (Field, Lattice, TargetConst, copy_from_target,
                        copy_from_target_masked, copy_to_target,
                        copy_to_target_masked, sync_target, target_free,
                        target_malloc, token_lattice)


@tdp.site_kernel
def scale(field, a=1.0):
    return a * field


@tdp.site_kernel
def saxpy(x, y, a=1.0):
    return a * x + y


@tdp.site_kernel
def two_out(x):
    return 2.0 * x, x * x


class TestLattice:
    def test_basic(self):
        lat = Lattice((4, 6, 8))
        assert lat.nsites == 192
        assert lat.nsites_with_halo == 192

    def test_halo(self):
        lat = Lattice((4, 4, 4), halo=1)
        assert lat.halo_shape == (6, 6, 6)
        assert lat.nsites_with_halo == 216

    def test_vvl_padding(self):
        lat = Lattice((10,))
        assert lat.padded_nsites(4) == 12
        assert lat.nchunks(4) == 3
        assert lat.padded_nsites(10) == 10

    def test_token_lattice(self):
        lat = token_lattice(8, 128)
        assert lat.nsites == 1024 and lat.halo == 0

    def test_validation(self):
        with pytest.raises(ValueError):
            Lattice(())
        with pytest.raises(ValueError):
            Lattice((0, 4))
        with pytest.raises(ValueError):
            Lattice((4,), halo=-1)


class TestField:
    def test_layouts_roundtrip(self, rng):
        lat = Lattice((4, 4))
        f = Field(lat, ncomp=3, dtype=np.float32)
        f.data[...] = rng.normal(size=f.array_shape)
        g = f.to_layout("aos")
        assert g.array_shape == (16, 3)
        np.testing.assert_array_equal(g.to_layout("soa").data, f.data)

    def test_interior_view(self):
        lat = Lattice((2, 2), halo=1)
        f = Field(lat, ncomp=1)
        f.grid_view()[0, 1:3, 1:3] = 7.0
        assert (f.interior() == 7.0).all()
        assert f.interior().shape == (1, 2, 2)
        assert f.data.sum() == 4 * 7.0


class TestMemoryModel:
    def test_malloc_and_free(self):
        arr = target_malloc((3, 64))
        assert arr.shape == (3, 64) and float(arr.sum()) == 0.0
        target_free(arr)
        with pytest.raises(RuntimeError):
            _ = np.asarray(arr)

    def test_copy_roundtrip(self, rng):
        lat = Lattice((8, 8))
        f = Field(lat, 3, np.float32)
        f.data[...] = rng.normal(size=f.array_shape)
        t = copy_to_target(f)
        back = copy_from_target(t, Field(lat, 3, np.float32))
        np.testing.assert_allclose(back.data, f.data)

    def test_masked_roundtrip(self, rng):
        """pack → copy → unpack == direct subset copy (paper §III-B)."""
        lat = Lattice((16,))
        f = Field(lat, 2, np.float32)
        f.data[...] = rng.normal(size=f.array_shape)
        t = copy_to_target(f)
        mask = np.zeros(16, bool)
        mask[[1, 5, 6, 11]] = True

        host_new = Field(lat, 2, np.float32)
        copy_from_target_masked(t, mask, host_new)
        np.testing.assert_allclose(host_new.data[:, mask], f.data[:, mask])
        assert (host_new.data[:, ~mask] == 0).all()

        # upload a modified subset
        f2 = f.copy()
        f2.data[:, mask] = -1.0
        t2 = copy_to_target_masked(t, f2, mask)
        got = copy_from_target(t2)
        assert (got[:, mask] == -1.0).all()
        np.testing.assert_allclose(got[:, ~mask], f.data[:, ~mask])

    def test_masked_empty(self):
        lat = Lattice((4,))
        t = target_malloc((1, 4))
        out = copy_from_target_masked(t, np.zeros(4, bool))
        assert out.shape == (1, 0)

    def test_target_const_hashing(self):
        a = TargetConst(np.arange(3.0))
        b = TargetConst(np.arange(3.0))
        c = TargetConst(np.arange(4.0))
        assert a == b and hash(a) == hash(b) and a != c

    def test_sync(self):
        x = jnp.ones((4,))
        sync_target(x)
        sync_target()


class TestExecution:
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("vvl", [8, 32, 128])
    def test_scale_all_backends_vvls(self, backend, vvl, rng):
        """Single source × {backends} × {VVLs} — the paper's Fig. 1 axes."""
        lat = Lattice((6, 7))  # 42 sites: not a VVL multiple → padding path
        x = jnp.asarray(rng.normal(size=(3, lat.nsites)), jnp.float32)
        y = tdp.launch(scale, lat, [x], consts={"a": 2.5}, vvl=vvl,
                       backend=backend)
        np.testing.assert_allclose(y, 2.5 * x, rtol=1e-6)

    def test_multi_input(self, rng):
        lat = Lattice((32,))
        x = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
        out = tdp.launch(saxpy, lat, [x, y], consts={"a": 3.0}, vvl=8)
        # rtol covers FMA-vs-separate rounding differences across fusions
        np.testing.assert_allclose(out, 3.0 * x + y, rtol=1e-5, atol=1e-6)

    def test_multi_output(self, rng):
        lat = Lattice((16,))
        x = jnp.asarray(rng.normal(size=(1, 16)), jnp.float32)
        a, b = tdp.launch(two_out, lat, [x], out_ncomp=(1, 1), vvl=8)
        np.testing.assert_allclose(a, 2 * x, rtol=1e-6)
        np.testing.assert_allclose(b, x * x, rtol=1e-6)

    def test_site_index_kernel(self):
        @tdp.site_kernel
        def pos(x, site_idx):
            return x + site_idx[None, :].astype(jnp.float32)

        lat = Lattice((10,))
        x = jnp.zeros((1, 10))
        y = tdp.launch(pos, lat, [x], vvl=4, with_site_index=True)
        np.testing.assert_allclose(y[0], np.arange(10.0))

    def test_target_const_array(self, rng):
        @tdp.site_kernel
        def project(x, w):
            return jnp.einsum("c,cv->v", w, x)[None]

        lat = Lattice((12,))
        x = jnp.asarray(rng.normal(size=(3, 12)), jnp.float32)
        w = TargetConst(np.array([1.0, -1.0, 0.5], np.float32))
        y = tdp.launch(project, lat, [x], out_ncomp=1,
                       consts={"w": w}, vvl=4)
        np.testing.assert_allclose(
            y[0], (np.asarray(x) * np.array([1, -1, .5])[:, None]).sum(0),
            rtol=1e-6)

    def test_validation_errors(self):
        lat = Lattice((8,))
        x = jnp.zeros((1, 8))
        with pytest.raises(ValueError):
            tdp.launch(scale, lat, [], vvl=4)
        with pytest.raises(ValueError):
            tdp.launch(scale, lat, [jnp.zeros((1, 9))], vvl=4)
        with pytest.raises(ValueError):
            tdp.launch(scale, lat, [x], backend="cuda")
        with pytest.raises(ValueError):
            tdp.launch(scale, None, [jnp.zeros((8,))])

    @pytest.mark.parametrize("op", ["sum", "max", "min"])
    def test_reduce(self, op, rng):
        lat = Lattice((5, 7))  # 35 sites → padding must not pollute result
        x = jnp.asarray(rng.normal(size=(2, 35)), jnp.float32)
        got = tdp.reduce(scale, lat, [x], consts={"a": 1.0}, op=op, vvl=16)
        want = getattr(np, op)(np.asarray(x), axis=-1)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_reduce_interpret_backend(self, rng):
        lat = Lattice((33,))
        x = jnp.asarray(rng.normal(size=(1, 33)), jnp.float32)
        got = tdp.reduce(scale, lat, [x], consts={"a": 2.0}, op="sum",
                         vvl=16, backend="pallas_interpret")
        np.testing.assert_allclose(got, 2 * np.asarray(x).sum(-1), rtol=1e-5)

    def test_default_vvl_switch(self):
        old = tdp.default_vvl()
        try:
            tdp.set_default_vvl(64)
            assert tdp.default_vvl() == 64
            with pytest.raises(ValueError):
                tdp.set_default_vvl(0)
        finally:
            tdp.set_default_vvl(old)
