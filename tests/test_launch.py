"""Launch layer: cell builder, dry-run record pipeline, elastic restore
across mesh shapes (subprocess-isolated where device counts differ)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


class TestCellBuilder:
    def test_dryrun_cell_end_to_end(self, tmp_path):
        """One real dry-run cell on the production mesh: lower, compile,
        analyse, JSON record — the full deliverable-(e) pipeline."""
        out = run_sub(f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import run_cell
from repro.launch.cells import Variant
rec = run_cell("granite_moe_1b_a400m", "decode_32k", "single",
               Variant(), {str(tmp_path)!r}, force=True)
assert rec["status"] == "ok", rec.get("error")
assert rec["n_devices"] == 256
ha = rec["hlo_analysis"]
assert ha["flops"] > 0 and ha["traffic_bytes"] > 0
assert rec["memory_analysis"]["temp_size_in_bytes"] > 0
print("CELL_OK", round(ha["flops"]/1e9, 2))
""", devices=512)
        assert "CELL_OK" in out
        files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
        assert len(files) == 1
        rec = json.load(open(os.path.join(tmp_path, files[0])))
        assert rec["arch"] == "granite_moe_1b_a400m"

    def test_variant_overrides_reach_context(self):
        from repro.launch.cells import Variant
        v = Variant(name="x", grad_accum=4, seq_over_data=True)
        assert v.with_(grad_accum=8).grad_accum == 8
        assert v.name == "x" and v.seq_over_data

    def test_mesh_factories(self):
        """Factories are pure descriptions until called (no import-time
        device access) — validated by signature + the dryrun itself."""
        import inspect
        from repro.launch import mesh
        sig = inspect.signature(mesh.make_production_mesh)
        assert "multi_pod" in sig.parameters


class TestElasticRestore:
    def test_checkpoint_crosses_mesh_shapes(self, tmp_path):
        """Train on a (2,4) mesh, checkpoint, restore onto (8,1) and
        (1,1): the elastic-scaling story end to end, loss continues."""
        run_sub(f"""
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig, AttnConfig, repeat_program
from repro.data import SyntheticConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig, TrainHParams
from repro.launch.mesh import make_test_mesh

cfg = ModelConfig(name="t", d_model=32, n_layers=2, vocab_size=64, d_ff=64,
    layer_program=repeat_program(("attn",), 2), attn=AttnConfig(2, 2, 16))
data = SyntheticConfig(64, 16, 8)
hp = TrainHParams(warmup_steps=2, total_steps=50)

mesh_a = make_test_mesh((2, 4), ("data", "model"))
tc = TrainerConfig(ckpt_dir={str(tmp_path)!r}, ckpt_every=5,
                   log_every=100, log=lambda *_: None)
tr = Trainer(cfg, mesh_a, data, AdamWConfig(), hp, tc)
tr.train_steps(5)
tr.ckpt.wait()
ref = np.asarray(jax.device_get(jax.tree.leaves(tr.params)[0]))

for shape in ((8, 1), (1, 1)):
    mesh_b = make_test_mesh(shape, ("data", "model"))
    tr2 = Trainer(cfg, mesh_b, data, AdamWConfig(), hp, tc)
    assert tr2.restore_latest() and tr2.step == 5
    got = np.asarray(jax.device_get(jax.tree.leaves(tr2.params)[0]))
    np.testing.assert_array_equal(got, ref)     # bit-exact across meshes
    tr2.train_steps(2)                          # and it keeps training
    assert tr2.step == 7
print("ELASTIC_OK")
""")
