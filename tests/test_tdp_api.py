"""The declarative targetDP API: KernelSpec + Target + executor registry.

Pins the redesign's contract (docs/targetdp_api.md):

* one ``tdp.launch(spec, target, *arrays, **consts)`` entry point for
  pointwise and stencil kernels;
* ``Target`` replaces the stringly backend/vvl plumbing and participates
  in the plan cache key (the ``set_default_vvl`` staleness regression);
* the executor table is open — a mock executor registered via
  ``register_executor`` runs end-to-end pointwise *and* stencil launches
  without touching core;
* the deprecated ``launch``/``launch_stencil`` shims warn and produce
  bit-identical outputs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tdp
from repro.core import launch as legacy_launch
from repro.core import launch_stencil as legacy_launch_stencil
from repro.core import Lattice, STENCIL_GRAD_6PT, TargetConst


@tdp.kernel(fields=[tdp.field(2)], out=2, consts=["a"])
def scale2(x, a=1.0):
    return a * x


GRAD_SPEC = tdp.KernelSpec(
    lambda p: (p[1] - p[2], p[0, 0][None]),
    fields=(tdp.field(1, stencil=STENCIL_GRAD_6PT),),
    out=(1, 1), name="grad_pair")


class TestTarget:
    def test_coercion(self):
        assert tdp.as_target(None) == tdp.Target("xla")
        assert tdp.as_target("pallas").backend == "pallas"
        t = tdp.as_target("xla", vvl=64)
        assert t.vvl == 64
        with pytest.raises(TypeError):
            tdp.as_target(123)

    def test_pallas_interpret_canonicalises(self):
        t = tdp.Target("pallas_interpret")
        assert t.backend == "pallas" and t.interpret
        assert t.executor == "pallas_interpret"
        assert t == tdp.Target("pallas", interpret=True)

    def test_tuning_is_hashable_and_ordered(self):
        a = tdp.Target("pallas", tuning={"block_f": 256, "block_q": 64})
        b = tdp.Target("pallas", tuning={"block_q": 64, "block_f": 256})
        assert a == b and hash(a) == hash(b)
        assert a.tune("block_f") == 256
        assert a.tune("missing", 7) == 7

    def test_validation(self):
        with pytest.raises(ValueError):
            tdp.Target("xla", vvl=0)
        with pytest.raises(ValueError):
            tdp.Target("")

    def test_with_updates(self):
        t = tdp.Target("xla").with_(vvl=32)
        assert t.vvl == 32 and t.backend == "xla"


class TestKernelSpec:
    def test_decorator_builds_spec(self):
        assert isinstance(scale2, tdp.KernelSpec)
        assert scale2.name == "scale2"
        assert scale2.out == (2,)
        assert scale2.fields[0].role == "pointwise"
        # the spec stays callable as its body
        np.testing.assert_allclose(
            scale2(jnp.ones((2, 4)), a=3.0), 3.0 * np.ones((2, 4)))

    def test_field_coercions(self):
        spec = tdp.KernelSpec(lambda x, y: x, fields=(STENCIL_GRAD_6PT, 3),
                              out=1)
        assert spec.fields[0].stencil is STENCIL_GRAD_6PT
        assert spec.fields[1].ncomp == 3 and spec.fields[1].stencil is None

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            tdp.KernelSpec(lambda x: x, fields=())
        with pytest.raises(TypeError):
            tdp.KernelSpec("not callable", fields=(tdp.field(),))
        with pytest.raises(ValueError):
            tdp.field(stencil=None, halo="ghost")
        with pytest.raises(ValueError):
            tdp.field(halo="sometimes")


class TestLaunchErrors:
    """The error paths the redesign is contractually required to catch."""

    def test_non_spec_first_argument(self):
        with pytest.raises(TypeError, match="KernelSpec"):
            tdp.launch(lambda x: x, None, jnp.zeros((1, 8)))

    def test_role_vs_rank_mismatch(self):
        with pytest.raises(ValueError, match="rank"):
            tdp.launch(scale2, None, jnp.zeros((8,)))
        with pytest.raises(ValueError, match="rank"):
            tdp.launch(scale2, None, jnp.zeros((1, 2, 8)))

    def test_declared_ncomp_mismatch(self):
        with pytest.raises(ValueError, match="ncomp"):
            tdp.launch(scale2, None, jnp.zeros((3, 8)))

    def test_field_count_mismatch(self):
        with pytest.raises(ValueError, match="field"):
            tdp.launch(scale2, None, jnp.zeros((2, 8)), jnp.zeros((2, 8)))

    def test_unknown_executor_name(self):
        with pytest.raises(ValueError, match="unknown executor"):
            tdp.launch(scale2, "cuda", jnp.zeros((2, 8)))
        with pytest.raises(ValueError, match="unknown executor"):
            tdp.get_executor("definitely_not_registered")

    def test_stencil_missing_lattice(self):
        x = jnp.zeros((1, 64), jnp.float32)
        with pytest.raises(ValueError, match="missing a lattice"):
            tdp.launch(GRAD_SPEC, None, x)

    def test_undeclared_const_rejected(self):
        with pytest.raises(ValueError, match="const"):
            tdp.launch(scale2, None, jnp.ones((2, 8)), b=2.0)

    def test_halo_policy_enforced(self):
        spec = tdp.KernelSpec(lambda p: p[0], fields=(
            tdp.field(1, stencil=STENCIL_GRAD_6PT, halo="ghost"),), out=1)
        lat = Lattice((4, 4, 4))
        with pytest.raises(ValueError, match="ghost"):
            tdp.launch(spec, None, jnp.zeros((1, 64), jnp.float32),
                       lattice=lat)

    def test_duplicate_executor_registration(self):
        tdp.register_executor("dup_exec", lambda plan, g: g)
        try:
            with pytest.raises(ValueError, match="already registered"):
                tdp.register_executor("dup_exec", lambda plan, g: g)
            # overwrite=True is the sanctioned replacement path
            tdp.register_executor("dup_exec", lambda plan, g: g,
                                  overwrite=True)
        finally:
            tdp.unregister_executor("dup_exec")
        with pytest.raises(ValueError):
            tdp.unregister_executor("dup_exec")


class TestMockExecutor:
    """register_executor alone suffices for end-to-end pointwise AND
    stencil launches — no core/execute.py (or core/api.py) edits."""

    @staticmethod
    def _whole_lattice_executor(plan, gathered):
        # One "chunk" spanning the whole lattice: site kernels are shape-
        # polymorphic in V, so the body runs unchanged with V = nsites.
        args = list(gathered)
        if plan.with_site_index:
            args.append(jnp.arange(gathered[0].shape[-1], dtype=jnp.int32))
        vals = plan.kernel(*args, **plan.consts)
        return vals if isinstance(vals, tuple) else (vals,)

    def test_pointwise_and_stencil_end_to_end(self, rng):
        tdp.register_executor("mock", self._whole_lattice_executor)
        try:
            x = jnp.asarray(rng.normal(size=(2, 42)), jnp.float32)
            got = tdp.launch(scale2, tdp.Target("mock"), x, a=2.0)
            want = tdp.launch(scale2, tdp.Target("xla", vvl=16), x, a=2.0)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-6)

            lat = Lattice((4, 4, 4))
            phi = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
            ga, gb = tdp.launch(GRAD_SPEC, tdp.Target("mock"), phi,
                                lattice=lat)
            wa, wb = tdp.launch(GRAD_SPEC, tdp.Target("xla", vvl=16), phi,
                                lattice=lat)
            np.testing.assert_allclose(np.asarray(ga), np.asarray(wa),
                                       rtol=1e-6)
            np.testing.assert_allclose(np.asarray(gb), np.asarray(wb),
                                       rtol=1e-6)
        finally:
            tdp.unregister_executor("mock")

    def test_custom_executor_drives_fused_lb_op(self, rng):
        """ops.lb_fused_step dispatches through the registry — a custom
        executor runs the full fused LB step with no ops/core edits."""
        from repro.kernels import ops
        from repro.kernels.lb_collision import NVEL
        tdp.register_executor("mock_lb", self._whole_lattice_executor)
        try:
            shape = (4, 4, 4)
            n = 64
            f = jnp.asarray(0.05 * rng.normal(size=(NVEL, n)) + 1 / 19.,
                            jnp.float32)
            g = jnp.asarray(0.05 * rng.normal(size=(NVEL, n)), jnp.float32)
            got = ops.lb_fused_step(f, g, grid_shape=shape,
                                    target=tdp.Target("mock_lb"))
            want = ops.lb_fused_step(f, g, grid_shape=shape, backend="xla",
                                     vvl=32)
            for x, y in zip(got, want):
                np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                           rtol=1e-5, atol=1e-6)
        finally:
            tdp.unregister_executor("mock_lb")

    def test_reregistration_invalidates_cached_plans(self, rng):
        x = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
        tdp.register_executor("flip", lambda plan, g: (g[0],))
        try:
            first = tdp.launch(scale2, tdp.Target("flip"), x, a=2.0)
            np.testing.assert_allclose(np.asarray(first), np.asarray(x))
            tdp.register_executor("flip", lambda plan, g: (-g[0],),
                                  overwrite=True)
            second = tdp.launch(scale2, tdp.Target("flip"), x, a=2.0)
            np.testing.assert_allclose(np.asarray(second), -np.asarray(x))
        finally:
            tdp.unregister_executor("flip")


@tdp.kernel(fields=[tdp.field(1)], out=1)
def chunk_width(x):
    """Reports the VVL the compiled closure was built with — padding lanes
    included, so any stale closure is immediately visible."""
    return jnp.full_like(x, x.shape[-1])


class TestVVLStaleness:
    """Regression: two launches of one kernel under different *default*
    VVLs must not reuse one closure (the old global-mutation bug class)."""

    def test_set_default_vvl_rebuilds_closure(self):
        x = jnp.zeros((1, 256), jnp.float32)
        old = tdp.default_vvl()
        try:
            tdp.set_default_vvl(32)
            a = tdp.launch(chunk_width, None, x)   # Target(vvl=None)
            assert float(a[0, 0]) == 32.0
            tdp.set_default_vvl(64)
            b = tdp.launch(chunk_width, None, x)
            assert float(b[0, 0]) == 64.0, "stale closure reused"
        finally:
            tdp.set_default_vvl(old)

    def test_explicit_vvl_wins_over_default(self):
        x = jnp.zeros((1, 256), jnp.float32)
        old = tdp.default_vvl()
        try:
            tdp.set_default_vvl(32)
            a = tdp.launch(chunk_width, tdp.Target("xla", vvl=128), x)
            assert float(a[0, 0]) == 128.0
        finally:
            tdp.set_default_vvl(old)

    def test_legacy_shim_also_tracks_default(self):
        x = jnp.zeros((1, 256), jnp.float32)
        old = tdp.default_vvl()
        try:
            tdp.set_default_vvl(32)
            with pytest.warns(DeprecationWarning):
                a = legacy_launch(chunk_width.fn, None, [x])
            tdp.set_default_vvl(64)
            with pytest.warns(DeprecationWarning):
                b = legacy_launch(chunk_width.fn, None, [x])
            assert float(a[0, 0]) == 32.0 and float(b[0, 0]) == 64.0
        finally:
            tdp.set_default_vvl(old)


class TestShimEquivalence:
    """launch / launch_stencil warn, then delegate — bit-identical."""

    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_pointwise_bit_identical(self, backend, rng):
        lat = Lattice((6, 7))
        x = jnp.asarray(rng.normal(size=(2, lat.nsites)), jnp.float32)
        a = TargetConst(np.float32(1.5))
        new = tdp.launch(scale2, tdp.Target(backend, vvl=16), x,
                         lattice=lat, a=a)
        with pytest.warns(DeprecationWarning):
            old = legacy_launch(scale2.fn, lat, [x], consts={"a": a},
                                vvl=16, backend=backend)
        np.testing.assert_array_equal(np.asarray(new), np.asarray(old))

    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_stencil_bit_identical(self, backend, rng):
        from repro.lb import stencil as lbst
        lat = Lattice((3, 4, 5))
        phi = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
        gn, ln = tdp.launch(lbst.GRAD6_SPEC, tdp.Target(backend, vvl=32),
                            phi, lattice=lat)
        with pytest.warns(DeprecationWarning):
            go, lo = legacy_launch_stencil(
                lbst.grad6_site_kernel, lat, [phi],
                stencil=STENCIL_GRAD_6PT, out_ncomp=(3, 1), vvl=32,
                backend=backend)
        np.testing.assert_array_equal(np.asarray(gn), np.asarray(go))
        np.testing.assert_array_equal(np.asarray(ln), np.asarray(lo))

    def test_shims_are_thin(self):
        import inspect
        from repro.core import execute

        for fn in (execute.launch, execute.launch_stencil):
            src = inspect.getsource(fn)
            body = src.split('stacklevel=2)', 1)[1]
            stmts = [l for l in body.splitlines()
                     if l.strip() and not l.strip().startswith("#")]
            assert len(stmts) <= 15, f"{fn.__name__} is not a thin shim"


class TestOpsTargets:
    """kernels/ops.py accepts Target objects; strings only coerce through
    as_target (via op_target)."""

    def test_target_and_backend_are_exclusive(self):
        from repro.kernels import ops
        with pytest.raises(ValueError, match="not both"):
            ops.op_target(tdp.Target("xla"), "xla", None)

    def test_op_accepts_target_and_string(self, rng):
        from repro.kernels import ops
        x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
        a = ops.rmsnorm(x, w, target=tdp.Target("pallas_interpret", vvl=64))
        b = ops.rmsnorm(x, w, backend="pallas_interpret", vvl=64)
        c = ops.rmsnorm(x, w, target="pallas_interpret", vvl=64)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    def test_tuning_dict_feeds_block_sizes(self, rng):
        from repro.kernels import ops
        u = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
        t = tdp.Target("pallas_interpret", vvl=32,
                       tuning={"block_f": 32})
        got = ops.gated_act(u, v, target=t)
        want = ops.gated_act(u, v, backend="xla")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-6)
