"""The gather-free windowed stencil executor (``pallas_windowed``).

ROADMAP stencil-memory stage (b), pinned here (docs/stencil.md):

* ``pallas_windowed`` (interpret mode on this CPU container) is
  **bit-identical** to the ``xla`` executor on every LB stencil spec —
  STREAM, GRAD6, and both fused modes — including the 10-step fused
  trajectory at 16³ and caller-supplied ghost planes;
* the executor is registered through the *public*
  ``register_executor(..., wants="halo_extended")`` capability surface:
  a mock capability-declaring executor runs end-to-end with zero core
  edits, and feeding one a pointwise spec fails fast;
* the ``LaunchPlan`` memory models show the ``noffsets×`` HBM term gone:
  the windowed estimate depends only on the stencil *radius*, never on
  its offset count.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tdp
from repro.core import (
    Lattice,
    STENCIL_GRAD_6PT,
    STENCIL_GRAD_19PT,
    halo_extend,
    launch_plan,
)
from repro.kernels.lb_collision import NVEL
from repro.lb import stencil as lbst
from repro.lb.params import LBParams
from repro.lb.sim import BinaryFluidSim

WINDOWED = tdp.Target("pallas_windowed", interpret=True)


def _rand_f(rng, n):
    return jnp.asarray(0.05 * rng.normal(size=(NVEL, n)) + 1 / 19.,
                       jnp.float32)


def _rand_g(rng, n):
    return jnp.asarray(0.05 * rng.normal(size=(NVEL, n)), jnp.float32)


class TestWindowedParity:
    """Bit-equivalence with the xla executor on the single-source LB
    specs — the portability contract extended to the gather-free path."""

    @pytest.mark.parametrize("shape", [(16, 16, 16), (5, 4, 3)])
    def test_stream_bit_identical(self, rng, shape):
        lat = Lattice(shape)
        f = _rand_f(rng, lat.nsites)
        a = tdp.launch(lbst.STREAM_SPEC, WINDOWED, f, lattice=lat)
        b = tdp.launch(lbst.STREAM_SPEC, tdp.Target("xla", vvl=64), f,
                       lattice=lat)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_grad6_bit_identical(self, rng):
        lat = Lattice((16, 16, 16))
        phi = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
        ga, la = tdp.launch(lbst.GRAD6_SPEC, WINDOWED, phi, lattice=lat)
        gb, lb = tdp.launch(lbst.GRAD6_SPEC, tdp.Target("xla", vvl=64), phi,
                            lattice=lat)
        np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    @pytest.mark.parametrize("mode", ["one_launch", "two_launch"])
    def test_fused_step_bit_identical(self, rng, mode):
        from repro.kernels import ops
        lat = Lattice((16, 16, 16))
        f, g = _rand_f(rng, lat.nsites), _rand_g(rng, lat.nsites)
        a = ops.lb_fused_step(f, g, grid_shape=lat.shape, mode=mode,
                              target=WINDOWED)
        b = ops.lb_fused_step(f, g, grid_shape=lat.shape, mode=mode,
                              backend="xla", vvl=64)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    @pytest.mark.parametrize("plane_block", [2, 3])
    def test_plane_block_tuning_bit_identical(self, rng, plane_block):
        """plane_block > 1 (and X not a multiple of it) only changes the
        TLP chunking, never the numbers."""
        lat = Lattice((7, 4, 5))
        f = _rand_f(rng, lat.nsites)
        t = WINDOWED.with_(tuning={"plane_block": plane_block})
        a = tdp.launch(lbst.STREAM_SPEC, t, f, lattice=lat)
        b = tdp.launch(lbst.STREAM_SPEC, "xla", f, lattice=lat)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_ghost_halo_mode_bit_identical(self, rng):
        """Caller-filled ghost planes (the sharded contract, width 2 for
        the radius-2 fused neighbourhood) reproduce the periodic gather."""
        from repro.kernels import ops
        shape = (8, 8, 8)
        n = 512
        f, g = _rand_f(rng, n), _rand_g(rng, n)
        fg = np.asarray(f).reshape(NVEL, *shape)
        gg = np.asarray(g).reshape(NVEL, *shape)

        def ext2(x):
            return np.concatenate([x[:, -2:], x, x[:, :2]], axis=1)

        fe = jnp.asarray(ext2(fg).reshape(NVEL, -1))
        ge = jnp.asarray(ext2(gg).reshape(NVEL, -1))
        a = ops.lb_fused_step(fe, ge, grid_shape=shape, halo=(2, 0, 0),
                              mode="one_launch", target=WINDOWED)
        b = ops.lb_fused_step(f, g, grid_shape=shape, mode="one_launch",
                              backend="xla", vvl=64)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_fused_trajectory_bit_identical_to_xla(self):
        """The acceptance pin: 10 fused steps at 16³ on pallas_windowed
        produce the bit-identical trajectory to the same steps on xla."""
        p = LBParams(A=0.125, B=0.125, kappa=0.02)
        a = BinaryFluidSim((16, 16, 16), params=p, fused="one_launch")
        b = BinaryFluidSim((16, 16, 16), params=p, fused="one_launch",
                           target=WINDOWED)
        st0 = a.init_spinodal(seed=3, noise=0.05)
        ua = a.step(st0, 10)
        ub = b.step(st0, 10)
        np.testing.assert_array_equal(np.asarray(ua.f), np.asarray(ub.f))
        np.testing.assert_array_equal(np.asarray(ua.g), np.asarray(ub.g))


class TestHaloExtend:
    def test_periodic_matches_roll(self, rng):
        shape = (4, 5, 6)
        x = jnp.asarray(rng.normal(size=(2, 120)), jnp.float32)
        ext = halo_extend(x, shape, (0, 0, 0), STENCIL_GRAD_6PT)
        assert ext.shape == (2, 6, 7, 8)
        grid = np.asarray(x).reshape(2, *shape)
        want = np.pad(grid, [(0, 0), (1, 1), (1, 1), (1, 1)], mode="wrap")
        np.testing.assert_array_equal(np.asarray(ext), want)

    def test_ghost_planes_trimmed_to_radius(self, rng):
        """A width-2 caller halo feeding a radius-1 stencil keeps exactly
        one ghost layer (the rest is trimmed, not wrapped)."""
        shape = (4, 4, 4)
        grid = rng.normal(size=(1, 8, 4, 4)).astype(np.float32)   # halo 2 in x
        ext = halo_extend(jnp.asarray(grid.reshape(1, -1)), shape,
                          (2, 0, 0), STENCIL_GRAD_6PT)
        assert ext.shape == (1, 6, 6, 6)
        np.testing.assert_array_equal(np.asarray(ext)[:, :, 1:-1, 1:-1],
                                      grid[:, 1:-1])


class TestCapabilitySurface:
    """The executor-capability contract is public: registration declares
    it, the prologue honours it, misuse fails fast."""

    def test_windowed_is_registered_with_capability(self):
        assert "pallas_windowed" in tdp.list_executors()
        assert tdp.executor_wants("pallas_windowed") == "halo_extended"
        assert tdp.executor_wants("xla") == "gathered"
        assert tdp.get_executor_entry("pallas_windowed").wants == \
            "halo_extended"

    def test_windowed_interpret_spelling_canonicalises(self):
        t = tdp.Target("pallas_windowed_interpret")
        assert t.backend == "pallas_windowed" and t.interpret
        assert t.executor == "pallas_windowed"

    def test_invalid_capability_rejected(self):
        with pytest.raises(ValueError, match="capability"):
            tdp.register_executor("bad_caps", lambda plan, g: g,
                                  wants="telepathic")

    def test_pointwise_spec_rejected_on_capability_executor(self):
        """A wants='halo_extended' executor fed a non-stencil spec is a
        contract violation, caught before any compilation."""
        @tdp.kernel(fields=[tdp.field(2)], out=2)
        def scale(x):
            return 2.0 * x

        with pytest.raises(ValueError, match="halo_extended"):
            tdp.launch(scale, WINDOWED, jnp.ones((2, 8), jnp.float32))
        with pytest.raises(ValueError, match="halo_extended"):
            launch_plan(scale, WINDOWED, lattice=Lattice((2, 4)))

    def test_unfused_sim_rejects_stencil_only_target(self):
        """The unfused pipeline never dispatches a stencil-only executor
        (collision is pointwise, stream/gradients run on the default
        target) — silently benchmarking xla instead must be impossible."""
        with pytest.raises(ValueError, match="stencil-only"):
            BinaryFluidSim((8, 8, 8), target=WINDOWED)
        # fused modes are the supported pairing
        BinaryFluidSim((8, 8, 8), target=WINDOWED, fused="two_launch")

    def test_launch_plan_requires_known_out(self):
        """A spec whose output count is only known from the launched
        array cannot be introspected faithfully — fail, don't guess."""
        spec = tdp.KernelSpec(lambda x: x, fields=(tdp.field(),))
        with pytest.raises(ValueError, match="out"):
            launch_plan(spec, tdp.Target("xla"))

    def test_mock_capability_executor_end_to_end(self, rng):
        """register_executor(..., wants='halo_extended') alone suffices:
        a whole-lattice mock resolves offsets from the extended grid and
        matches xla — zero core edits."""
        def mock(plan, prepared):
            chunks = []
            for x, s in zip(prepared, plan.stencils):
                if s is None:
                    chunks.append(x)
                    continue
                r = s.radius_per_dim()
                nb = []
                for off in s.offsets:
                    g = x
                    for d, (o, rd, sd) in enumerate(zip(off, r, plan.shape)):
                        g = jnp.take(g, jnp.arange(rd + o, rd + o + sd),
                                     axis=d + 1)
                    nb.append(g.reshape(x.shape[0], -1))
                chunks.append(jnp.stack(nb))
            vals = plan.kernel(*chunks, **plan.consts)
            return vals if isinstance(vals, tuple) else (vals,)

        tdp.register_executor("mock_windowed", mock, wants="halo_extended")
        try:
            lat = Lattice((4, 4, 4))
            phi = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
            ga, la = tdp.launch(lbst.GRAD6_SPEC, tdp.Target("mock_windowed"),
                                phi, lattice=lat)
            gb, lb = tdp.launch(lbst.GRAD6_SPEC, "xla", phi, lattice=lat)
            np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
        finally:
            tdp.unregister_executor("mock_windowed")

    def test_spec_max_radius_per_dim(self):
        assert lbst.FUSED_SPEC.max_radius_per_dim() == (2, 2, 2)
        assert lbst.STREAM_SPEC.max_radius_per_dim() == (1, 1, 1)
        with pytest.raises(ValueError, match="stencil"):
            tdp.KernelSpec(lambda x: x, fields=(tdp.field(1),),
                           out=1).max_radius_per_dim()


class TestMemoryEstimates:
    """LaunchPlan.hbm_bytes_estimate / vmem_bytes_estimate: the gathered
    path carries the noffsets× term, the windowed path must not."""

    def test_gather_path_has_noffsets_term(self):
        lat = Lattice((16, 16, 16))
        plan = launch_plan(lbst.FUSED_SPEC, tdp.Target("xla", vvl=128),
                           lattice=lat)
        noff = lbst.STENCIL_FUSED_G.noffsets          # 57
        # both stacks materialised: (19 + 57) · 19 rows × nsites
        assert plan.hbm_bytes_estimate() == \
            ((19 + noff) * NVEL + 2 * NVEL) * lat.nsites * 4
        assert plan.vmem_bytes_estimate() == \
            ((19 + noff) * NVEL + 2 * NVEL) * 128 * 4

    def test_windowed_path_has_no_noffsets_term(self):
        """The windowed estimate depends on the stencil *radius* only:
        two stencils of equal radius but 7 vs 19 offsets give the same
        estimate, while the gathered estimates differ by the offset
        count."""
        spec7 = tdp.KernelSpec(lambda p: p[0],
                               fields=(tdp.field(1,
                                                 stencil=STENCIL_GRAD_6PT),),
                               out=1, name="star7")
        spec19 = tdp.KernelSpec(lambda p: p[0],
                                fields=(tdp.field(1,
                                                  stencil=STENCIL_GRAD_19PT),),
                                out=1, name="star19")
        lat = Lattice((16, 16, 16))
        w7 = launch_plan(spec7, WINDOWED, lattice=lat)
        w19 = launch_plan(spec19, WINDOWED, lattice=lat)
        assert w7.hbm_bytes_estimate() == w19.hbm_bytes_estimate()
        assert w7.vmem_bytes_estimate() == w19.vmem_bytes_estimate()
        g7 = launch_plan(spec7, tdp.Target("xla", vvl=64), lattice=lat)
        g19 = launch_plan(spec19, tdp.Target("xla", vvl=64), lattice=lat)
        assert g19.hbm_bytes_estimate() - g7.hbm_bytes_estimate() == \
            (19 - 7) * lat.nsites * 4
        assert g19.vmem_bytes_estimate() - g7.vmem_bytes_estimate() == \
            (19 - 7) * 64 * 4

    def test_windowed_kills_the_amplification(self):
        """The headline: at 64³ the fused gather stack needs ~1.4 GiB,
        the windowed operands stay under 100 MiB (ghost overhead only)."""
        lat = Lattice((64, 64, 64))
        g = launch_plan(lbst.FUSED_SPEC, tdp.Target("xla"), lattice=lat)
        w = launch_plan(lbst.FUSED_SPEC, WINDOWED, lattice=lat)
        assert g.hbm_bytes_estimate() > 1.3 * 2**30
        assert w.hbm_bytes_estimate() < 100 * 2**20
        assert g.hbm_bytes_estimate() / w.hbm_bytes_estimate() > 15

    def test_windowed_vmem_tracks_plane_block(self):
        lat = Lattice((16, 16, 16))
        w1 = launch_plan(lbst.STREAM_SPEC, WINDOWED, lattice=lat)
        w4 = launch_plan(
            lbst.STREAM_SPEC,
            WINDOWED.with_(tuning={"plane_block": 4}), lattice=lat)
        # window depth grows p + 2r: 3 planes → 6 planes of input
        assert w4.vmem_bytes_estimate() > w1.vmem_bytes_estimate()

    def test_estimates_need_geometry(self):
        plan = launch_plan(tdp.KernelSpec(lambda x: x,
                                          fields=(tdp.field(2),), out=2),
                           tdp.Target("xla", vvl=32))
        with pytest.raises(ValueError, match="lattice"):
            plan.hbm_bytes_estimate()
        # the gathered VMEM rule needs no lattice (pure VVL blocks)
        assert plan.vmem_bytes_estimate() == (2 + 2) * 32 * 4
