"""CLI contract of ``benchmarks/run.py`` — subprocess smokes on tiny
grids.

Pins the PR 5 surface:

* ``--sweep`` is *generalized*: any ``key=v1,v2,...`` tuning knob the
  windowed executor declares runs (values land under the record's
  ``"sweep"`` key); a knob the executor ignores exits 2 up front
  (a silently ignored sweep would read as "ran");
* ``--autotune`` extends ``BENCH_fused_step.json`` with the
  ``"tuning"`` / ``"autotune"`` keys (schema *extension* — the PR 3/4
  variant records stay intact) and persists the choice in the tuning
  cache, so a re-run reproduces it via ``cache_hit`` without
  re-measuring;
* ``--json`` schema stability for the pre-existing keys.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_bench(*argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *argv],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=timeout)


class TestSweepCLI:
    def test_generalized_sweep_records_per_value_medians(self, tmp_path):
        out = str(tmp_path / "bench")
        r = run_bench("--only", "stream", "--grid", "6", "--steps", "1",
                      "--json", "--sweep", "plane_block=1,2",
                      "--out", out)
        assert r.returncode == 0, r.stderr
        rec = json.load(open(os.path.join(out, "BENCH_stream.json")))
        # pre-existing schema intact …
        assert rec["bench"] == "stream"
        assert rec["grid"] == [6, 6, 6]
        for key in ("xla", "pallas_interpret", "pallas_windowed"):
            v = rec["variants"][key]
            assert {"median_s", "min_s", "executor",
                    "hbm_bytes_estimate"} <= set(v)
        # … and the sweep landed, keyed knob → value → median
        assert set(rec["sweep"]["plane_block"]) == {"1", "2"}
        for v in rec["sweep"]["plane_block"].values():
            assert v["median_s"] > 0
        # per-value variants ride along under the stable pb spelling
        assert "pallas_windowed_pb1" in rec["variants"]
        assert "pallas_windowed_pb2" in rec["variants"]

    def test_ignored_knob_exits_2(self, tmp_path):
        r = run_bench("--only", "stream", "--sweep", "bogus_knob=1,2",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "bogus_knob" in r.stderr
        assert "pallas_windowed" in r.stderr      # names the executor
        assert "plane_block" in r.stderr          # … and what IS declared

    def test_malformed_sweep_exits_2(self, tmp_path):
        r = run_bench("--only", "stream", "--sweep", "plane_block",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "key=v1,v2" in r.stderr

    def test_non_integer_sweep_value_exits_2(self, tmp_path):
        """Bad values fail fast at parse time, not deep inside plan
        construction mid-bench."""
        r = run_bench("--only", "stream", "--sweep", "plane_block=abc",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "must be integers" in r.stderr

    def test_sweep_with_no_consuming_bench_exits_2(self, tmp_path):
        r = run_bench("--only", "lm_step", "--sweep", "plane_block=1",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "no effect" in r.stderr


def _write_bench(d, name, grid, variants):
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"BENCH_{name}.json"), "w") as fh:
        json.dump({"bench": name, "grid": list(grid),
                   "variants": variants}, fh)


class TestCheckRegression:
    """``benchmarks/check_regression.py`` — the nightly perf gate."""

    def test_compare_matches_on_full_identity(self):
        from benchmarks.check_regression import compare
        base = {"b": {"grid": [8, 8], "variants": {
            "v": {"median_s": 1.0, "executor": "xla", "vvl": 128},
            "w": {"median_s": 1.0, "executor": "xla"}}}}
        fresh = {"b": {"grid": [8, 8], "variants": {
            # same identity, 30% slower → regression
            "v": {"median_s": 1.3, "executor": "xla", "vvl": 128},
            # retuned (vvl changed) → unmatched, not gated
            "w": {"median_s": 9.9, "executor": "xla", "vvl": 64}}}}
        rep = compare(base, fresh, threshold=0.15)
        assert [(r[0], r[1]) for r in rep["regressions"]] == [("b", "v")]
        assert rep["regressions"][0][4] == pytest.approx(0.3)
        assert ("b", "w") in rep["unmatched"]
        assert rep["matched"] == 1

    def test_compare_threshold_and_improvements(self):
        from benchmarks.check_regression import compare
        base = {"b": {"grid": [8], "variants": {
            "v": {"median_s": 1.0, "executor": "xla"},
            "u": {"median_s": 1.0, "executor": "xla"}}}}
        fresh = {"b": {"grid": [8], "variants": {
            "v": {"median_s": 1.10, "executor": "xla"},    # within 15%
            "u": {"median_s": 0.5, "executor": "xla"}}}}   # faster
        rep = compare(base, fresh)
        assert rep["regressions"] == []
        assert [(r[0], r[1]) for r in rep["improvements"]] == [("b", "u")]

    def test_compare_grid_change_never_gates(self):
        from benchmarks.check_regression import compare
        base = {"b": {"grid": [8, 8], "variants": {
            "v": {"median_s": 1.0, "executor": "xla"}}}}
        fresh = {"b": {"grid": [16, 16], "variants": {
            "v": {"median_s": 99.0, "executor": "xla"}}}}
        rep = compare(base, fresh)
        assert rep["regressions"] == [] and rep["matched"] == 0
        assert rep["unmatched"] == [("b", "v")]

    def test_compare_warns_on_cost_model_drift_without_gating(self):
        """predicted_vs_measured drifting >2× the committed record (with
        a 10% absolute floor) prints a warning but never regresses."""
        from benchmarks.check_regression import compare
        base = {"b": {"grid": [8], "variants": {
            "v": {"median_s": 1.0, "executor": "xla",
                  "predicted_vs_measured": -0.05},
            "u": {"median_s": 1.0, "executor": "xla",
                  "predicted_vs_measured": -0.2},
            "w": {"median_s": 1.0, "executor": "xla"}}}}
        fresh = {"b": {"grid": [8], "variants": {
            "v": {"median_s": 1.0, "executor": "xla",
                  "predicted_vs_measured": -0.4},    # >2× and >10% → warn
            "u": {"median_s": 1.0, "executor": "xla",
                  "predicted_vs_measured": -0.3},    # within 2× → quiet
            "w": {"median_s": 1.0, "executor": "xla",
                  "predicted_vs_measured": 0.5}}}}   # no baseline → quiet
        rep = compare(base, fresh)
        assert rep["warnings"] == [("b", "v", -0.05, -0.4)]
        assert rep["regressions"] == []              # never gates

    def test_compare_health_identity(self):
        """Guarded fleet variants never gate unguarded ones, and a
        baseline predating the ``health`` field still matches fresh
        guard-off records (absent normalises to "off")."""
        from benchmarks.check_regression import compare
        base = {"fleet": {"grid": [4], "variants": {
            "batch8": {"median_s": 1.0, "executor": "xla", "batch": 8}}}}
        fresh = {"fleet": {"grid": [4], "variants": {
            "batch8": {"median_s": 1.0, "executor": "xla", "batch": 8,
                       "health": "off"},
            "batch8_guarded": {"median_s": 3.0, "executor": "xla",
                               "batch": 8, "health": "every1"}}}}
        rep = compare(base, fresh)
        assert rep["matched"] == 1 and rep["regressions"] == []
        assert ("fleet", "batch8_guarded") in rep["unmatched"]

    def test_compare_layout_identity(self):
        """AoSoA sweep points never gate SoA ones, and a baseline
        predating the ``layout`` field still matches fresh SoA records
        (absent normalises to "soa")."""
        from benchmarks.check_regression import compare
        base = {"kernels": {"variants": {
            "rms_vvl64": {"median_s": 1.0, "executor": "xla",
                          "vvl": 64}}}}
        fresh = {"kernels": {"variants": {
            "rms_vvl64": {"median_s": 1.0, "executor": "xla", "vvl": 64,
                          "layout": "soa"},
            "rms_aosoa": {"median_s": 5.0, "executor": "xla", "vvl": 64,
                          "layout": "aosoa"}}}}
        rep = compare(base, fresh)
        assert rep["matched"] == 1 and rep["regressions"] == []
        assert ("kernels", "rms_aosoa") in rep["unmatched"]

    def test_compare_min_seconds_skips_timer_noise(self):
        from benchmarks.check_regression import compare
        base = {"b": {"grid": [], "variants": {
            "v": {"median_s": 2e-5, "executor": "xla"}}}}
        fresh = {"b": {"grid": [], "variants": {
            "v": {"median_s": 6e-5, "executor": "xla"}}}}
        assert compare(base, fresh)["regressions"] != []       # 3× slower
        assert compare(base, fresh,
                       min_seconds=1e-4)["regressions"] == []

    def _run_checker(self, *argv, timeout=120):
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.join(REPO, "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        return subprocess.run(
            [sys.executable, "-m", "benchmarks.check_regression", *argv],
            cwd=REPO, env=env, capture_output=True, text=True,
            timeout=timeout)

    def test_cli_exit_codes(self, tmp_path):
        base, fresh = str(tmp_path / "base"), str(tmp_path / "fresh")
        _write_bench(base, "b", (8,),
                     {"v": {"median_s": 1.0, "executor": "xla"}})
        _write_bench(fresh, "b", (8,),
                     {"v": {"median_s": 1.05, "executor": "xla"}})
        r = self._run_checker("--baseline", base, "--fresh", fresh)
        assert r.returncode == 0, r.stderr + r.stdout
        assert "1 variant(s) compared" in r.stdout

        _write_bench(fresh, "b", (8,),
                     {"v": {"median_s": 2.0, "executor": "xla"}})
        r = self._run_checker("--baseline", base, "--fresh", fresh)
        assert r.returncode == 1
        assert "REGRESSED b/v" in r.stdout

        # a looser threshold passes the same pair
        r = self._run_checker("--baseline", base, "--fresh", fresh,
                              "--threshold", "1.5")
        assert r.returncode == 0

        # empty dirs are an invocation error, not a silent pass
        r = self._run_checker("--baseline", base,
                              "--fresh", str(tmp_path / "nothing"))
        assert r.returncode == 2

    def test_cli_gates_the_committed_records_against_themselves(self):
        """The committed results/bench baseline compared to itself is 0
        regressions — the nightly wiring's happy path."""
        r = self._run_checker("--baseline", "results/bench",
                              "--fresh", "results/bench")
        assert r.returncode == 0, r.stderr + r.stdout
        assert "0 regression(s)" in r.stdout


class TestAutotuneCLI:
    def test_autotune_needs_fused_step_selected(self, tmp_path):
        r = run_bench("--only", "stream", "--autotune",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "fused_step" in r.stderr

    @pytest.mark.slow
    def test_autotune_extends_schema_and_caches(self, tmp_path):
        """Two runs: the first measures and writes the tuning cache, the
        second reproduces the choice from disk (cache_hit) — the
        'tuning'/'autotune' keys EXTEND the PR 3/4 record schema."""
        out = str(tmp_path / "bench")
        cache = str(tmp_path / "tuning")
        argv = ("--only", "fused_step", "--autotune", "--grid", "6",
                "--steps", "1", "--json", "--out", out,
                "--tuning-cache", cache)
        r = run_bench(*argv)
        assert r.returncode == 0, r.stderr
        rec = json.load(open(os.path.join(out, "BENCH_fused_step.json")))
        # PR 3/4 schema intact
        for key in ("unfused", "fused", "fused_two", "fused_windowed",
                    "fused_program_scan"):
            assert "median_s" in rec["variants"][key]
        # the new keys
        assert rec["tuning"]["backend"] in ("pallas_windowed", "xla")
        at = rec["autotune"]
        assert at["cache_hit"] is False
        assert at["best"]["median_s"] <= at["default_median_s"]
        assert at["candidates"][0]["label"] == "pallas_windowed_interpret"
        cached = os.listdir(cache)
        assert len(cached) == 1 and cached[0].endswith(".json")

        r2 = run_bench(*argv)
        assert r2.returncode == 0, r2.stderr
        rec2 = json.load(open(os.path.join(out, "BENCH_fused_step.json")))
        assert rec2["autotune"]["cache_hit"] is True
        assert rec2["autotune"]["best"] == at["best"]
        assert rec2["tuning"] == rec["tuning"]
