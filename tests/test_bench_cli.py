"""CLI contract of ``benchmarks/run.py`` — subprocess smokes on tiny
grids.

Pins the PR 5 surface:

* ``--sweep`` is *generalized*: any ``key=v1,v2,...`` tuning knob the
  windowed executor declares runs (values land under the record's
  ``"sweep"`` key); a knob the executor ignores exits 2 up front
  (a silently ignored sweep would read as "ran");
* ``--autotune`` extends ``BENCH_fused_step.json`` with the
  ``"tuning"`` / ``"autotune"`` keys (schema *extension* — the PR 3/4
  variant records stay intact) and persists the choice in the tuning
  cache, so a re-run reproduces it via ``cache_hit`` without
  re-measuring;
* ``--json`` schema stability for the pre-existing keys.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_bench(*argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *argv],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=timeout)


class TestSweepCLI:
    def test_generalized_sweep_records_per_value_medians(self, tmp_path):
        out = str(tmp_path / "bench")
        r = run_bench("--only", "stream", "--grid", "6", "--steps", "1",
                      "--json", "--sweep", "plane_block=1,2",
                      "--out", out)
        assert r.returncode == 0, r.stderr
        rec = json.load(open(os.path.join(out, "BENCH_stream.json")))
        # pre-existing schema intact …
        assert rec["bench"] == "stream"
        assert rec["grid"] == [6, 6, 6]
        for key in ("xla", "pallas_interpret", "pallas_windowed"):
            v = rec["variants"][key]
            assert {"median_s", "min_s", "executor",
                    "hbm_bytes_estimate"} <= set(v)
        # … and the sweep landed, keyed knob → value → median
        assert set(rec["sweep"]["plane_block"]) == {"1", "2"}
        for v in rec["sweep"]["plane_block"].values():
            assert v["median_s"] > 0
        # per-value variants ride along under the stable pb spelling
        assert "pallas_windowed_pb1" in rec["variants"]
        assert "pallas_windowed_pb2" in rec["variants"]

    def test_ignored_knob_exits_2(self, tmp_path):
        r = run_bench("--only", "stream", "--sweep", "bogus_knob=1,2",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "bogus_knob" in r.stderr
        assert "pallas_windowed" in r.stderr      # names the executor
        assert "plane_block" in r.stderr          # … and what IS declared

    def test_malformed_sweep_exits_2(self, tmp_path):
        r = run_bench("--only", "stream", "--sweep", "plane_block",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "key=v1,v2" in r.stderr

    def test_non_integer_sweep_value_exits_2(self, tmp_path):
        """Bad values fail fast at parse time, not deep inside plan
        construction mid-bench."""
        r = run_bench("--only", "stream", "--sweep", "plane_block=abc",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "must be integers" in r.stderr

    def test_sweep_with_no_consuming_bench_exits_2(self, tmp_path):
        r = run_bench("--only", "lm_step", "--sweep", "plane_block=1",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "no effect" in r.stderr


class TestAutotuneCLI:
    def test_autotune_needs_fused_step_selected(self, tmp_path):
        r = run_bench("--only", "stream", "--autotune",
                      "--out", str(tmp_path / "bench"))
        assert r.returncode == 2
        assert "fused_step" in r.stderr

    @pytest.mark.slow
    def test_autotune_extends_schema_and_caches(self, tmp_path):
        """Two runs: the first measures and writes the tuning cache, the
        second reproduces the choice from disk (cache_hit) — the
        'tuning'/'autotune' keys EXTEND the PR 3/4 record schema."""
        out = str(tmp_path / "bench")
        cache = str(tmp_path / "tuning")
        argv = ("--only", "fused_step", "--autotune", "--grid", "6",
                "--steps", "1", "--json", "--out", out,
                "--tuning-cache", cache)
        r = run_bench(*argv)
        assert r.returncode == 0, r.stderr
        rec = json.load(open(os.path.join(out, "BENCH_fused_step.json")))
        # PR 3/4 schema intact
        for key in ("unfused", "fused", "fused_two", "fused_windowed",
                    "fused_program_scan"):
            assert "median_s" in rec["variants"][key]
        # the new keys
        assert rec["tuning"]["backend"] in ("pallas_windowed", "xla")
        at = rec["autotune"]
        assert at["cache_hit"] is False
        assert at["best"]["median_s"] <= at["default_median_s"]
        assert at["candidates"][0]["label"] == "pallas_windowed_interpret"
        cached = os.listdir(cache)
        assert len(cached) == 1 and cached[0].endswith(".json")

        r2 = run_bench(*argv)
        assert r2.returncode == 0, r2.stderr
        rec2 = json.load(open(os.path.join(out, "BENCH_fused_step.json")))
        assert rec2["autotune"]["cache_hit"] is True
        assert rec2["autotune"]["best"] == at["best"]
        assert rec2["tuning"] == rec["tuning"]
