"""``tdp.Program`` — declarative multi-launch step graphs.

Pins the redesign's contracts:

* **construction validation** — name dataflow (read-before-write, dead
  intermediates, ncomp consistency) fails fast, before any compilation;
* **the halo schedule** — back-propagated ghost requirements match the
  hand-derived widths of every LB step shape (one exchange round per
  field per step);
* **bit-identity with the pre-Program driver** — Program trajectories
  (10 steps @16³) are bit-identical to the PR 3 ``BinaryFluidSim``
  step sequences (reconstructed here from the same jitted launch
  pipeline the old driver hard-wired) across ``xla``,
  ``pallas_interpret`` and ``pallas_windowed_interpret``, and the
  python-loop :meth:`step` path is bit-identical to the
  :meth:`run`/``lax.scan`` path;
* **per-stage target routing** — pointwise stages under a stencil-only
  target dispatch to xla, stencil stages keep the target;
* **plan aggregation** — ``Program.plan(target)`` sums the per-stage
  HBM models (gather-free under the windowed executor) and maxes VMEM;
* **deprecation shims** — ``core/execute.py``'s ``launch`` /
  ``launch_stencil`` warn exactly once per call site.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tdp
from repro.core import Lattice, STENCIL_GRAD_6PT
from repro.kernels import ops
from repro.kernels.lb_collision import NVEL
from repro.lb import programs as lbp
from repro.lb import stencil as lbst
from repro.lb.params import LBParams
from repro.lb.sim import BinaryFluidSim

GRID = (16, 16, 16)
N = int(np.prod(GRID))
PARAMS = LBParams(A=0.125, B=0.125, kappa=0.02)
WINDOWED = tdp.Target("pallas_windowed", interpret=True)

OPEN_X = (True, False, False)


# ---------------------------------------------------------------------------
# toy specs for construction tests
# ---------------------------------------------------------------------------

@tdp.kernel(fields=[tdp.field(2)], out=2)
def double2(x):
    return 2.0 * x


@tdp.kernel(fields=[tdp.field(1, stencil=STENCIL_GRAD_6PT)], out=1)
def star_sum(p):
    acc = p[0, 0]
    for i in range(1, 7):
        acc = acc + p[i, 0]
    return acc[None]


class TestConstruction:
    def test_unknown_read_name(self):
        with pytest.raises(ValueError, match="unknown name 'b'"):
            tdp.program("p", [tdp.stage(double2, reads="b", writes="a")],
                        fields=("a",))

    def test_read_before_write(self):
        with pytest.raises(ValueError, match="before any stage writes"):
            tdp.program("p", [
                tdp.stage(double2, reads="tmp", writes="tmp"),
                tdp.stage(double2, reads="a", writes="a"),
            ], fields=("a",), intermediates=("tmp",))

    def test_dead_intermediate(self):
        with pytest.raises(ValueError, match="written but never read"):
            tdp.program("p", [tdp.stage(double2, reads="a", writes="tmp")],
                        fields=("a",))

    def test_declared_intermediates_must_match(self):
        with pytest.raises(ValueError, match="intermediates"):
            tdp.program("p", [tdp.stage(double2, reads="a", writes="a")],
                        fields=("a",), intermediates=("ghost",))

    def test_ncomp_conflict(self):
        with pytest.raises(ValueError, match="inconsistent ncomp"):
            tdp.program("p", [
                tdp.stage(double2, reads="a", writes="b"),        # b: 2
                tdp.stage(star_sum, reads="b", writes="a"),       # b: 1
            ], fields=("a", "b"))

    def test_spec_without_out_rejected(self):
        anon = tdp.KernelSpec(lambda x: x, fields=(tdp.field(1),))
        with pytest.raises(ValueError, match="declare out="):
            tdp.stage(anon, reads="a", writes="a")

    def test_binding_arity_mismatch(self):
        with pytest.raises(ValueError, match="read"):
            tdp.stage(double2, reads=("a", "b"), writes="c")
        with pytest.raises(ValueError, match="write"):
            tdp.stage(double2, reads="a", writes=("c", "d"))

    def test_duplicate_fields(self):
        with pytest.raises(ValueError, match="duplicate"):
            tdp.program("p", [tdp.stage(double2, reads="a", writes="a")],
                        fields=("a", "a"))

    def test_needs_at_least_one_stage(self):
        with pytest.raises(ValueError, match="at least one stage"):
            tdp.program("p", [], fields=("a",))


class TestHaloSchedule:
    """The one-exchange-per-step schedule, against hand-derived widths."""

    def consts(self):
        return lbp.collision_consts(**PARAMS.as_kwargs())

    def test_one_launch(self):
        w, geo = lbp.fused_program("one_launch", self.consts()).schedule(
            3, OPEN_X)
        # single radius-2 stage: both fields exchanged at the launch halo
        assert w == {"f": (2, 0, 0), "g": (2, 0, 0)}
        assert geo == [((0, 0, 0), (2, 0, 0))]

    def test_two_launch(self):
        w, geo = lbp.fused_program("two_launch", self.consts()).schedule(
            3, OPEN_X)
        # launch A recomputes the streamed-φ ghost ring locally (ext_out
        # 1) from g's width-2 exchange; f needs only launch B's radius.
        assert w == {"f": (1, 0, 0), "g": (2, 0, 0)}
        assert geo == [((1, 0, 0), (1, 0, 0)), ((0, 0, 0), (1, 0, 0))]

    def test_unfused(self):
        w, geo = lbp.unfused_step_program(self.consts()).schedule(3, OPEN_X)
        # moments recompute φ on a 2-ring, collide on a 1-ring: the old
        # driver's three exchange rounds (φ, f', g') collapse into one
        # {f: 1, g: 2} round at step start.
        assert w == {"f": (1, 0, 0), "g": (2, 0, 0)}
        exts = [e[0] for e, _ in geo]
        halos = [h[0] for _, h in geo]
        assert exts == [2, 1, 1, 0, 0]     # moments, grads, collide, streams
        assert halos == [0, 1, 0, 1, 1]

    def test_closed_dims_need_nothing(self):
        w, geo = lbp.fused_program("one_launch", self.consts()).schedule(
            3, (False, False, False))
        assert all(v == (0, 0, 0) for v in w.values())
        assert geo == [((0, 0, 0), (0, 0, 0))]


# ---------------------------------------------------------------------------
# PR 3 reconstruction: the pre-Program BinaryFluidSim step pipeline,
# jitted exactly as the old driver built it.
# ---------------------------------------------------------------------------

def _pr3_fns(target, pw_target, mode):
    def collide_flat(f, g, phi, gp, d2):
        fo, go = ops.lb_collision(
            f.reshape(NVEL, N), g.reshape(NVEL, N), phi.reshape(1, N),
            gp.reshape(3, N), d2.reshape(1, N), target=pw_target,
            **PARAMS.as_kwargs())
        return fo.reshape(NVEL, *GRID), go.reshape(NVEL, *GRID)

    @jax.jit
    def step_local(f, g):
        phi = g.sum(0)
        gp, d2 = lbst.gradients(phi)
        f, g = collide_flat(f, g, phi, gp, d2)
        return lbst.stream(f), lbst.stream(g)

    @jax.jit
    def collide_local(f, g):
        phi = g.sum(0)
        gp, d2 = lbst.gradients(phi)
        return collide_flat(f, g, phi, gp, d2)

    @jax.jit
    def fused_local(f, g):
        fo, go = ops.lb_fused_step(
            f.reshape(NVEL, N), g.reshape(NVEL, N), grid_shape=GRID,
            mode=mode, target=target, **PARAMS.as_kwargs())
        return fo.reshape(NVEL, *GRID), go.reshape(NVEL, *GRID)

    @jax.jit
    def stream_local(f, g):
        return lbst.stream(f), lbst.stream(g)

    return step_local, collide_local, fused_local, stream_local


def _pr3_trajectory(st, nsteps, target, pw_target, mode):
    step_l, collide_l, fused_l, stream_l = _pr3_fns(target, pw_target,
                                                    mode or "one_launch")
    f, g = st.f, st.g
    if mode:
        f, g = collide_l(f, g)
        for _ in range(nsteps - 1):
            f, g = fused_l(f, g)
        return stream_l(f, g)
    for _ in range(nsteps):
        f, g = step_l(f, g)
    return f, g


@pytest.fixture(scope="module")
def spinodal_state():
    return BinaryFluidSim(GRID, params=PARAMS).init_spinodal(seed=3,
                                                             noise=0.05)


class TestTrajectoryBitIdentity:
    """The acceptance pin: Program trajectories over 10 steps @16³ are
    bit-identical to the PR 3 driver on every executor, and the scanned
    path is bit-identical to the python loop."""

    CASES = [
        ("xla", tdp.Target("xla", vvl=128), tdp.Target("xla", vvl=128),
         False),
        ("xla", tdp.Target("xla", vvl=128), tdp.Target("xla", vvl=128),
         "one_launch"),
        ("xla", tdp.Target("xla", vvl=128), tdp.Target("xla", vvl=128),
         "two_launch"),
        ("pallas_interpret", tdp.Target("pallas_interpret", vvl=128),
         tdp.Target("pallas_interpret", vvl=128), False),
        ("pallas_interpret", tdp.Target("pallas_interpret", vvl=128),
         tdp.Target("pallas_interpret", vvl=128), "one_launch"),
        ("pallas_interpret", tdp.Target("pallas_interpret", vvl=128),
         tdp.Target("pallas_interpret", vvl=128), "two_launch"),
        # the old driver routed the windowed sim's pointwise prologue to
        # xla (the capability fallback Program now applies per stage)
        ("pallas_windowed_interpret", WINDOWED,
         tdp.Target("xla", vvl=128), "one_launch"),
        ("pallas_windowed_interpret", WINDOWED,
         tdp.Target("xla", vvl=128), "two_launch"),
    ]

    @pytest.mark.parametrize("name,target,pw,mode",
                             CASES, ids=[f"{c[0]}-{c[3]}" for c in CASES])
    def test_matches_pr3_driver(self, spinodal_state, name, target, pw,
                                mode):
        sim = BinaryFluidSim(GRID, params=PARAMS, target=target, fused=mode)
        out = sim.step(spinodal_state, 10)
        rf, rg = _pr3_trajectory(spinodal_state, 10, target, pw, mode)
        np.testing.assert_array_equal(np.asarray(out.f), np.asarray(rf))
        np.testing.assert_array_equal(np.asarray(out.g), np.asarray(rg))

    @pytest.mark.parametrize("mode", [False, "one_launch", "two_launch"])
    def test_loop_matches_scan(self, spinodal_state, mode):
        sim = BinaryFluidSim(GRID, params=PARAMS, fused=mode)
        a = sim.step(spinodal_state, 10)
        b = sim.run(spinodal_state, 10)
        np.testing.assert_array_equal(np.asarray(a.f), np.asarray(b.f))
        np.testing.assert_array_equal(np.asarray(a.g), np.asarray(b.g))

    def test_run_donated_matches_undonated(self, spinodal_state):
        sim = BinaryFluidSim(GRID, params=PARAMS, fused="two_launch")
        a = sim.run(spinodal_state, 6)
        st = BinaryFluidSim(GRID, params=PARAMS).init_spinodal(seed=3,
                                                               noise=0.05)
        with warnings.catch_warnings():
            # donation is a no-op on the CPU backend (XLA warns)
            warnings.filterwarnings("ignore",
                                    message="Some donated buffers")
            b = sim.run(st, 6, donate=True)
        np.testing.assert_array_equal(np.asarray(a.f), np.asarray(b.f))
        np.testing.assert_array_equal(np.asarray(a.g), np.asarray(b.g))


class TestExecute:
    """Program.execute — eager stepping with caller-managed ghosts (the
    surface ops.lb_fused_step runs on)."""

    def test_ghost_mode_matches_periodic(self, rng):
        consts = lbp.collision_consts(**PARAMS.as_kwargs())
        prog = lbp.fused_program("one_launch", consts)
        shape = (8, 8, 8)
        f = jnp.asarray(0.05 * rng.normal(size=(NVEL,) + shape) + 1 / 19.,
                        jnp.float32)
        g = jnp.asarray(0.05 * rng.normal(size=(NVEL,) + shape),
                        jnp.float32)
        ref = prog.execute("xla", {"f": f, "g": g}, grid_shape=shape)
        fe = jnp.concatenate([f[:, -2:], f, f[:, :2]], axis=1)
        ge = jnp.concatenate([g[:, -2:], g, g[:, :2]], axis=1)
        got = prog.execute("xla", {"f": fe, "g": ge}, grid_shape=shape,
                           halo=(2, 0, 0))
        for k in ("f", "g"):
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(ref[k]))

    def test_insufficient_ghosts_fail_fast(self, rng):
        consts = lbp.collision_consts(**PARAMS.as_kwargs())
        prog = lbp.fused_program("two_launch", consts)
        shape = (8, 8, 8)
        g1 = jnp.zeros((NVEL, 10, 8, 8), jnp.float32)
        with pytest.raises(ValueError, match="ghost layer"):
            prog.execute("xla", {"f": g1, "g": g1}, grid_shape=shape,
                         halo=(1, 0, 0))

    def test_missing_field(self):
        consts = lbp.collision_consts(**PARAMS.as_kwargs())
        prog = lbp.fused_program("one_launch", consts)
        with pytest.raises(ValueError, match="missing field 'g'"):
            prog.execute("xla", {"f": jnp.zeros((NVEL, 4, 4, 4))},
                         grid_shape=(4, 4, 4))


class TestCompiledProgram:
    def test_stage_target_routing_stencil_only(self):
        """Pointwise stages route to xla under a stencil-only target;
        stencil stages keep it (generalises the old sim fallback)."""
        consts = lbp.collision_consts(**PARAMS.as_kwargs())
        exe = lbp.collide_program(consts).compile(WINDOWED,
                                                  grid_shape=GRID)
        by_name = {st.name: t for st, t in zip(exe.program.stages,
                                               exe.stage_targets)}
        assert by_name["moments"].executor == "xla"
        assert by_name["collide"].executor == "xla"
        assert by_name["gradients"].executor == "pallas_windowed"

    def test_stage_target_keeps_pointwise_capable_executor(self):
        consts = lbp.collision_consts(**PARAMS.as_kwargs())
        exe = lbp.collide_program(consts).compile(
            tdp.Target("pallas_interpret", vvl=64), grid_shape=GRID)
        assert all(t.executor == "pallas_interpret"
                   for t in exe.stage_targets)

    def test_passthrough_field(self, rng):
        prog = tdp.program("p", [tdp.stage(double2, reads="a", writes="a")],
                           fields=("a", "b"))
        exe = prog.compile("xla", grid_shape=(4, 4))
        a = jnp.asarray(rng.normal(size=(2, 4, 4)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(2, 4, 4)), jnp.float32)
        out = exe.step({"a": a, "b": b})
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      2.0 * np.asarray(a))
        np.testing.assert_array_equal(np.asarray(out["b"]), np.asarray(b))

    def test_state_validation(self):
        prog = tdp.program("p", [tdp.stage(double2, reads="a", writes="a")],
                           fields=("a",))
        exe = prog.compile("xla", grid_shape=(4, 4))
        with pytest.raises(ValueError, match="missing field"):
            exe.step({})
        with pytest.raises(ValueError, match="field 'a'"):
            exe.step({"a": jnp.zeros((3, 4, 4))})      # wrong ncomp
        with pytest.raises(ValueError, match="field 'a'"):
            exe.step({"a": jnp.zeros((2, 5, 4))})      # wrong grid

    def test_run_zero_steps_is_identity(self):
        prog = tdp.program("p", [tdp.stage(double2, reads="a", writes="a")],
                           fields=("a",))
        exe = prog.compile("xla", grid_shape=(4, 4))
        a = jnp.ones((2, 4, 4))
        out = exe.run({"a": a}, 0)
        assert out["a"] is a

    def test_sharded_compile_validates_grid_vs_width(self):
        """Slabs thinner than the exchange width are fine (multi-hop
        ppermute), but a *global* X extent the schedule's width cannot
        fit in is a construction error."""
        consts = lbp.collision_consts(**PARAMS.as_kwargs())

        class FakeMesh:
            shape = {"data": 2}
        with pytest.raises(ValueError, match="ghost exchange"):
            lbp.fused_program("one_launch", consts).compile(
                "xla", grid_shape=(2, 8, 8), mesh=FakeMesh(),
                shard_axis="data")
        # slab (1 plane) < width (2) is NOT an error: the exchange hops
        # ranks (multi-hop ppermute) — trajectory pinned by the 4-way
        # slab=1 subprocess test in test_distributed.py
        w, _ = lbp.fused_program("one_launch", consts).schedule(
            3, (True, False, False))
        assert w == {"f": (2, 0, 0), "g": (2, 0, 0)}


def _fake_exchange(shards, dim, width):
    """Run :func:`exchange_ghosts` over stacked shards on one device.

    ``shards`` is ``(nranks, ncomp, *local)``; the injected permute
    reindexes the leading rank axis the way ``ppermute``'s
    ``(src, dst)`` pairs would route buffers, so the hop plan is
    exercised exactly as compiled — minus the mesh."""
    import importlib
    P = importlib.import_module("repro.core.program")
    n = shards.shape[0]

    def permute(x, pairs):
        idx = np.zeros(n, int)
        for src, dst in pairs:
            idx[dst] = src
        return x[jnp.asarray(idx)]

    # dim d of the *shard* is axis d+2 of the stack; exchange_ghosts
    # slices axis dim+1, so shift dim by one to skip the rank axis.
    return P.exchange_ghosts(shards, dim + 1, width, n, permute)


class TestPencilExchange:
    """The generalized (any-dim, any-hop-count) exchange round and the
    overlap partition — single-device unit pins; the end-to-end pencil /
    block / thin-pencil trajectories live in test_distributed.py."""

    def _prog_module(self):
        import importlib
        return importlib.import_module("repro.core.program")

    def test_exchange_hop_plan(self):
        P = self._prog_module()
        assert P._exchange_hops(2, 8) == [(1, 2)]       # neighbour covers
        assert P._exchange_hops(8, 8) == [(1, 8)]       # exactly one shard
        assert P._exchange_hops(3, 2) == [(1, 2), (2, 1)]
        assert P._exchange_hops(5, 2) == [(1, 2), (2, 2), (3, 1)]
        assert P._exchange_hops(2, 1) == [(1, 1), (2, 1)]
        assert sum(t for _, t in P._exchange_hops(5, 2)) == 5

    @pytest.mark.parametrize("nranks,loc,width", [
        (2, 4, 1), (2, 4, 3), (4, 2, 2),
        (4, 1, 2),            # thin pencil: 2 hops
        (3, 2, 5),            # width > 2 shards: 3 hops
        (8, 1, 4),            # maximal decomposition
    ])
    def test_exchange_matches_global_reference(self, nranks, loc, width,
                                               rng):
        """Enumerated fallback for the hypothesis property: the exchanged
        shard equals the wrap-indexed global array for every hop count —
        ghost planes concatenate in global-coordinate order."""
        glob = nranks * loc
        g = rng.normal(size=(2, glob)).astype(np.float32)
        shards = jnp.asarray(
            np.stack([g[:, i * loc:(i + 1) * loc] for i in range(nranks)]))
        got = np.asarray(_fake_exchange(shards, 0, width))
        assert got.shape == (nranks, 2, loc + 2 * width)
        for i in range(nranks):
            want = g[:, np.arange(i * loc - width,
                                  (i + 1) * loc + width) % glob]
            np.testing.assert_array_equal(got[i], want)

    def test_exchange_2d_shard_any_dim(self, rng):
        """Same reference check when the exchanged dim is not dim 0."""
        nranks, loc = 4, 2
        g = rng.normal(size=(1, 3, nranks * loc)).astype(np.float32)
        shards = jnp.asarray(np.stack(
            [g[:, :, i * loc:(i + 1) * loc] for i in range(nranks)]))
        got = np.asarray(_fake_exchange(shards, 1, 3))   # 2 hops
        for i in range(nranks):
            want = g[:, :, np.arange(i * loc - 3,
                                     (i + 1) * loc + 3) % (nranks * loc)]
            np.testing.assert_array_equal(got[i], want)

    @pytest.mark.parametrize("local,W,shard_dims", [
        ((8, 8, 16), (1, 1, 0), (0, 1)),
        ((8, 8, 16), (2, 2, 0), (0, 1)),
        ((4, 4, 4), (1, 1, 1), (0, 1, 2)),
        ((8, 4, 8), (2, 0, 0), (0, 1)),      # dim 1 unexchanged
        ((6, 8), (2, 1), (0, 1)),
    ])
    def test_overlap_regions_tile_exactly_once(self, local, W, shard_dims):
        """Interior + boundary slabs partition the local domain: every
        site covered exactly once (corners belong to the lowest exchanged
        dim's slabs)."""
        P = self._prog_module()
        (i_start, i_shape), bounds = P._overlap_regions(local, W,
                                                        shard_dims)
        cover = np.zeros(local, np.int32)

        def mark(start, shape):
            cover[tuple(slice(s, s + n) for s, n in zip(start, shape))] += 1

        mark(i_start, i_shape)
        for d, lo, hi in bounds:
            mark(*lo)
            mark(*hi)
        assert (cover == 1).all()
        # interior sits W away from every exchanged face
        for d in shard_dims:
            if W[d]:
                assert i_start[d] == W[d]
                assert i_shape[d] == local[d] - 2 * W[d]

    def test_exchange_stats_arithmetic(self):
        P = self._prog_module()
        cs = P.exchange_stats({"f": (1, 1, 0), "g": (2, 2, 0)},
                              {"f": 19, "g": 19}, (8, 8, 16), (0, 1))
        f = cs["per_field"]["f"]
        # dim 0: 2*1*(8*16) planes; dim 1 spans the dim-0-extended
        # extent: 2*1*(10*16)
        assert f["bytes"] == (2 * 8 * 16 + 2 * 10 * 16) * 19 * 4
        assert f["ppermutes"] == 4
        g = cs["per_field"]["g"]
        assert g["bytes"] == (2 * 2 * 8 * 16 + 2 * 2 * 12 * 16) * 19 * 4
        assert cs["exchanged_bytes_per_step"] == f["bytes"] + g["bytes"]
        assert cs["ppermutes_per_step"] == 8
        # a thin dim multiplies ppermutes (multi-hop), not bytes
        th = P.exchange_stats({"g": (2,)}, {"g": 1}, (1,), (0,))
        assert th["per_field"]["g"]["ppermutes"] == 2 * 2
        assert th["per_field"]["g"]["bytes"] == 2 * 2 * 1 * 4

    # -- compile-time validation (the bugfix sweep) ------------------------

    def consts(self):
        return lbp.collision_consts(**PARAMS.as_kwargs())

    class _Mesh2x2:
        shape = {"px": 2, "py": 2}

    def test_pencil_divisibility_error_names_dim_and_axis(self):
        with pytest.raises(ValueError, match=r"Y extent 9 not divisible "
                                             r"by mesh axis py=2"):
            lbp.fused_program("one_launch", self.consts()).compile(
                "xla", grid_shape=(8, 9, 8), mesh=self._Mesh2x2(),
                shard_axis=("px", "py"))

    def test_pencil_unknown_and_duplicate_axes(self):
        prog = lbp.fused_program("one_launch", self.consts())
        with pytest.raises(ValueError, match="not a mesh axis"):
            prog.compile("xla", grid_shape=(8, 8, 8), mesh=self._Mesh2x2(),
                         shard_axis=("px", "pz"))
        with pytest.raises(ValueError, match="duplicate shard axes"):
            prog.compile("xla", grid_shape=(8, 8, 8), mesh=self._Mesh2x2(),
                         shard_axis=("px", "px"))
        with pytest.raises(ValueError, match="at most 2"):
            prog.compile("xla", grid_shape=(8, 8), mesh=self._Mesh2x2(),
                         shard_axis=("px", "py", "px2"))

    def test_pencil_width_vs_global_extent_any_dim(self):
        """The slab-era width check now runs per sharded dim: a dim-1
        global extent the schedule cannot fit fails at compile."""
        with pytest.raises(ValueError, match="ghost exchange in dim 1"):
            lbp.fused_program("one_launch", self.consts()).compile(
                "xla", grid_shape=(8, 2, 8), mesh=self._Mesh2x2(),
                shard_axis=("px", "py"))

    def test_closed_dim_thinner_than_radius_fails_at_compile(self):
        """An *unsharded* stencil-read dim wraps periodically inside each
        launch — a radius-2 schedule meeting an extent-1 closed dim must
        fail at compile with the decomposition named, not deep inside
        lax.scan."""
        prog = lbp.fused_program("one_launch", self.consts())

        class Slab:
            shape = {"data": 2}
        with pytest.raises(ValueError,
                           match=r"unsharded \(periodic\) extent 1"):
            prog.compile("xla", grid_shape=(8, 8, 1), mesh=Slab(),
                         shard_axis="data")
        # unsharded compiles hit the same guard
        with pytest.raises(ValueError, match="shard dim 2 with a mesh"):
            prog.compile("xla", grid_shape=(8, 8, 1))

    def test_halo_extend_wrap_thinner_than_radius(self):
        """Satellite pin for the halo_extend bugfix: the periodic path
        refuses a wrap wider than one period, naming dim/radius/extent."""
        from repro.core import halo_extend
        from repro.lb.stencil import FUSED_SPEC
        stc = max((s for s in FUSED_SPEC.stencils if s is not None),
                  key=lambda s: max(s.radius_per_dim()))
        assert max(stc.radius_per_dim()) == 2
        x = jnp.ones((1, 8 * 8 * 1), jnp.float32)
        with pytest.raises(ValueError, match="radius 2 in dim 2 exceeds "
                                             "the periodic extent 1"):
            halo_extend(x, (8, 8, 1), (0, 0, 0), stc)
        # and the launch-level guard fires before tracing
        with pytest.raises(ValueError, match="cannot wrap-pad"):
            tdp.launch_plan(lbst.FUSED_SPEC, WINDOWED,
                            lattice=Lattice((8, 8, 1)))


class TestProgramPlan:
    """Program.plan aggregates the PR 3 memory models across stages."""

    def consts(self):
        return lbp.collision_consts(**PARAMS.as_kwargs())

    def test_sum_and_max_aggregation(self):
        from repro.core import launch_plan
        prog = lbp.fused_program("two_launch", self.consts())
        plan = prog.plan(tdp.Target("xla", vvl=128), grid_shape=GRID)
        lat = Lattice(GRID)
        a = launch_plan(lbst.PHI_STREAM_SPEC, tdp.Target("xla", vvl=128),
                        lattice=lat)
        b = launch_plan(lbst.FUSED_TWO_SPEC, tdp.Target("xla", vvl=128),
                        lattice=lat, consts=self.consts())
        assert plan.hbm_bytes_estimate() == (a.hbm_bytes_estimate()
                                             + b.hbm_bytes_estimate())
        assert plan.vmem_bytes_estimate() == max(a.vmem_bytes_estimate(),
                                                 b.vmem_bytes_estimate())
        assert [r["stage"] for r in plan.per_stage()] == ["phi_stream",
                                                          "fused_two"]

    def test_windowed_plan_is_gather_free(self):
        """The acceptance pin: the fused step's aggregated per-step HBM
        footprint under the windowed target carries no noffsets× term."""
        prog = lbp.fused_program("one_launch", self.consts())
        g = prog.plan(tdp.Target("xla"), grid_shape=(64, 64, 64))
        w = prog.plan(WINDOWED, grid_shape=(64, 64, 64))
        assert g.hbm_bytes_estimate() > 1.3 * 2 ** 30
        assert w.hbm_bytes_estimate() < 100 * 2 ** 20
        assert all(r["wants"] == "halo_extended" for r in w.per_stage())

    def test_plan_routes_pointwise_stages(self):
        plan = lbp.collide_program(self.consts()).plan(WINDOWED,
                                                       grid_shape=GRID)
        ex = {r["stage"]: r["executor"] for r in plan.per_stage()}
        assert ex["moments"] == "xla" and ex["collide"] == "xla"
        assert ex["gradients"] == "pallas_windowed"

    def test_compiled_plan_reports_halo_schedule(self):
        sim = BinaryFluidSim((16, 8, 8), params=PARAMS, fused="two_launch")
        assert sim.programs["fused"].halo_schedule == {}    # unsharded
        consts = lbp.collision_consts(**PARAMS.as_kwargs())
        w, _ = lbp.fused_program("two_launch", consts).schedule(3, OPEN_X)
        assert {k: v[0] for k, v in w.items()} == {"f": 1, "g": 2}


class TestShimWarningsOncePerCallSite:
    """core/execute.py's deprecation shims use the standard warnings
    machinery: with the default filter each *call site* warns exactly
    once, however many times it executes."""

    def _collect(self, fn, warmup):
        with warnings.catch_warnings():
            # jit compilation inside the first call mutates the global
            # warning filters (invalidating the per-call-site registry);
            # warm the launch cache first so the measurement below sees
            # stable filter state.
            warnings.simplefilter("ignore")
            warmup()
        with warnings.catch_warnings(record=True) as rec:
            warnings.resetwarnings()
            warnings.simplefilter("default")
            fn()
        return [w for w in rec if issubclass(w.category,
                                             DeprecationWarning)]

    def test_launch_once_per_call_site(self):
        from repro.core.execute import launch as legacy_launch
        x = jnp.ones((2, 8), jnp.float32)

        def warmup():
            legacy_launch(double2.fn, None, [x], out_ncomp=2)

        def body():
            for _ in range(3):
                legacy_launch(double2.fn, None, [x], out_ncomp=2)

        assert len(self._collect(body, warmup)) == 1

        def two_sites():
            legacy_launch(double2.fn, None, [x], out_ncomp=2)
            legacy_launch(double2.fn, None, [x], out_ncomp=2)

        assert len(self._collect(two_sites, warmup)) == 2

    def test_launch_stencil_once_per_call_site(self):
        from repro.core.execute import launch_stencil as legacy_stencil
        lat = Lattice((4, 4, 4))
        phi = jnp.ones((1, lat.nsites), jnp.float32)

        def warmup():
            legacy_stencil(star_sum.fn, lat, [phi],
                           stencil=STENCIL_GRAD_6PT, out_ncomp=1)

        def body():
            for _ in range(3):
                legacy_stencil(star_sum.fn, lat, [phi],
                               stencil=STENCIL_GRAD_6PT, out_ncomp=1)

        assert len(self._collect(body, warmup)) == 1
