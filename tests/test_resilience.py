"""tdp.resilience: chaos suite — seeded fault schedules against the
fleet service.

Every fault here is *deterministic* (an explicit schedule from
:mod:`repro.core.faults`: fail the executor's k-th invocation, poison a
named field at member step s, damage checkpoint step n, kill the pump
thread), so each test proves one recovery contract:

* health guards diagnose the field / kind / member / step range, and a
  quarantined member never perturbs the others — healthy trajectories
  stay **bit-identical** to a fault-free run;
* a fault while pumping a shared bucket fails only the offending
  ticket(s): blame is attributed by batch-1 replays (traced consts, so
  replays are bit-exact, and a one-shot fault recovers *every* ticket);
* failed tickets retry up to ``max_retries``, rolling back to their
  last snapshot and finishing bit-exactly;
* background pump-thread exceptions surface through
  ``drain``/``stream``/``stop``/``poll`` instead of vanishing;
* restore falls back past a corrupted newest snapshot to the newest
  checksum-valid one under keep-last-K retention.
"""
import time
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import tdp
from repro.checkpoint.store import checkpoint_steps, latest_step
from repro.core import faults


# ---------------------------------------------------------------------------
# fixtures (the test_fleet.py demo program: 2 stages, sweepable tau)
# ---------------------------------------------------------------------------

@tdp.kernel(fields=[tdp.field(2)], out=2)
def _relax(x, tau=1.0, w=None):
    return x - (x - w[:, None]) / tau


@tdp.kernel(fields=[tdp.field(2), tdp.field(2)], out=2)
def _mix(x, y, eps=0.1):
    return x + eps * (y - x)


GRID = (6, 5)
W = tdp.TargetConst(np.array([0.25, 0.75], np.float32))
TAUS = np.array([0.7, 1.0, 1.3], np.float32)


def make_prog(tau_const, name="demo"):
    return tdp.Program(name, [
        tdp.stage(_relax, ["a"], ["tmp"],
                  consts={"tau": tau_const, "w": W}),
        tdp.stage(_mix, ["a", "tmp"], ["a"], consts={"eps": 0.05}),
    ], fields=["a"])


def members(n, seed=0, grid=GRID):
    rng = np.random.default_rng(seed)
    return [{"a": jnp.asarray(
        rng.normal(size=(2,) + grid).astype(np.float32))}
        for _ in range(n)]


PROG = make_prog(tdp.TargetConst(np.float32(1.0)))


def fault_free_reference(ms, nsteps=8):
    """Final states of a fault-free swept fleet run (the bit-identity
    reference every chaos test compares healthy members against)."""
    drv = tdp.FleetDriver("xla", batch=len(ms))
    ts = [drv.submit(PROG, {"state": ms[i], "consts": {"tau": TAUS[i]}},
                     nsteps) for i in range(len(ms))]
    final = drv.drain()
    return [np.asarray(final[t.id]["a"]) for t in ts]


# ---------------------------------------------------------------------------
# HealthPolicy / diagnose / guarded runs
# ---------------------------------------------------------------------------

class TestHealthPolicy:
    def test_validation(self):
        with pytest.raises(ValueError, match="every must be >= 1"):
            tdp.HealthPolicy(every=0)
        with pytest.raises(ValueError, match="max_norm must be positive"):
            tdp.HealthPolicy(max_norm=-1.0)
        with pytest.raises(ValueError, match="enables no checks"):
            tdp.HealthPolicy(nan=False, inf=False)
        with pytest.raises(ValueError, match="'b'.*does not carry"):
            tdp.HealthPolicy(fields=("b",)).select_fields(["a"])

    def test_diagnose_kinds_and_members(self):
        from repro.core.health import diagnose
        pol = tdp.HealthPolicy(max_norm=10.0)
        st = {"a": np.array([[1.0, 2.0], [np.nan, 1.0],
                             [np.inf, 1.0], [99.0, 1.0]], np.float32)}
        diag = diagnose(pol, st, ensemble=4)
        assert set(diag) == {1, 2, 3}
        assert diag[1].kind == "nan" and diag[2].kind == "inf"
        assert diag[3].kind == "norm" and diag[3].value == 99.0
        # single-member states report under index 0
        assert diagnose(pol, {"a": np.float32([np.nan])})[0].kind == "nan"
        assert diagnose(pol, {"a": np.float32([1.0])}) == {}
        with pytest.raises(ValueError, match="leading extent"):
            diagnose(pol, st, ensemble=3)

    def test_error_carries_diagnosis(self):
        from repro.core.health import check
        pol = tdp.HealthPolicy(every=2)
        with pytest.raises(tdp.HealthError) as ei:
            check(pol, {"g": np.float32([[np.nan]])}, ensemble=1,
                  step_range=(4, 6), where="unit")
        e = ei.value
        assert (e.field, e.kind, e.member, e.step_range) == \
            ("g", "nan", 0, (4, 6))
        assert "field 'g' contains NaN" in str(e)
        assert "steps [4, 6)" in str(e)

    def test_guarded_run_bit_identical_and_raises(self):
        cp = PROG.compile("xla", grid_shape=GRID)
        m = members(1)[0]
        pol = tdp.HealthPolicy(every=3)
        guarded = cp.run(dict(m), 8, health=pol)
        plain = cp.run(dict(m), 8)
        np.testing.assert_array_equal(np.asarray(guarded["a"]),
                                      np.asarray(plain["a"]))
        with pytest.raises(tdp.HealthError, match="steps \\[0, 3\\)"):
            cp.run({"a": m["a"].at[(0,) * 3].set(np.nan)}, 8, health=pol)
        with pytest.raises(ValueError, match="does not carry"):
            cp.run(dict(m), 2, health=tdp.HealthPolicy(fields=("nope",)))

    def test_guarded_fleet_run_attributes_member(self):
        fleet = PROG.compile("xla", grid_shape=GRID).vmap(3)
        ms = members(3)
        s = tdp.ProgramState.stack(ms)
        pol = tdp.HealthPolicy(every=2)
        out = fleet.run(s, 6, health=pol)
        ref = fleet.run(s, 6)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(ref["a"]))
        poisoned = s.replace(a=s["a"].at[(1,) + (0,) * 3].set(np.inf))
        with pytest.raises(tdp.HealthError) as ei:
            fleet.run(poisoned, 6, health=pol)
        # the seeded Inf turns into NaN through the relax arithmetic
        # (inf - inf); either way member 1 is the one attributed
        assert ei.value.member == 1 and ei.value.kind in ("nan", "inf")
        assert ei.value.step_range == (0, 2)


# ---------------------------------------------------------------------------
# ticket lifecycle + NaN quarantine
# ---------------------------------------------------------------------------

class TestQuarantine:
    def test_status_walk_and_poll_keys(self):
        drv = tdp.FleetDriver("xla", batch=2)
        t = drv.submit(PROG, {"state": members(1)[0]}, 3)
        assert t.status == "running" and not t.finished
        drv.drain()
        p = drv.poll(t)
        assert p["status"] == "done" and p["retries"] == 0
        assert p["error"] is None and p["traceback"] is None
        assert "status='done'" in repr(t)

    def test_nan_member_quarantined_healthy_members_exact(self):
        ms = members(3)
        refs = fault_free_reference(ms, 8)
        drv = tdp.FleetDriver("xla", batch=3,
                              health=tdp.HealthPolicy(every=2))
        ts = [drv.submit(PROG, {"state": ms[i],
                                "consts": {"tau": TAUS[i]}}, 8)
              for i in range(3)]
        drv.inject(faults.nan_at_step(ts[1].id, "a", 4))
        final = drv.drain()
        p = drv.poll(ts[1])
        assert p["status"] == "failed"
        err = p["error"]
        assert isinstance(err, tdp.HealthError)
        assert err.ticket == ts[1].id and err.field == "a"
        assert err.kind == "nan" and err.step_range is not None
        assert "HealthError" in p["traceback"]
        # the survivors are bit-identical to the fault-free run
        for i in (0, 2):
            assert drv.poll(ts[i])["status"] == "done"
            np.testing.assert_array_equal(
                np.asarray(final[ts[i].id]["a"]), refs[i])
        # the freed slot is reusable: a new ticket completes in-bucket
        t_new = drv.submit(PROG, {"state": ms[0],
                                  "consts": {"tau": TAUS[0]}}, 8)
        final2 = drv.drain()
        np.testing.assert_array_equal(
            np.asarray(final2[t_new.id]["a"]), refs[0])

    def test_every1_failed_state_stays_healthy(self):
        """With a per-chunk guard (``every=1``) no unchecked advance
        exists, so the failed ticket's stored state is its last healthy
        chunk (the drain() entry is finite)."""
        drv = tdp.FleetDriver("xla", batch=2,
                              health=tdp.HealthPolicy(every=1))
        t = drv.submit(PROG, {"state": members(1)[0]}, 8)
        drv.inject(faults.nan_at_step(t.id, "a", 4))
        final = drv.drain()
        assert drv.poll(t)["status"] == "failed" and t.step == 4
        assert np.isfinite(np.asarray(final[t.id]["a"])).all()

    def test_stream_raises_failed_tickets_cause(self):
        drv = tdp.FleetDriver("xla", batch=2,
                              health=tdp.HealthPolicy(every=1))
        t = drv.submit(PROG, {"state": members(1)[0]}, 10)
        drv.inject(faults.nan_at_step(t.id, "a", 2))
        with pytest.raises(tdp.HealthError):
            for _ in drv.stream(t, every=2):
                pass

    def test_driver_health_validates_fields_at_submit(self):
        drv = tdp.FleetDriver(
            "xla", batch=2, health=tdp.HealthPolicy(fields=("ghost",)))
        with pytest.raises(ValueError, match="'ghost'.*does not step"):
            drv.submit(PROG, {"state": members(1)[0]}, 2)

    def test_solo_fallback_quarantine(self):
        """The unbucketed (per-member) path fails through the same
        lifecycle."""
        drv = tdp.FleetDriver("xla", batch=2, grid_shapes=[GRID],
                              health=tdp.HealthPolicy(every=1))
        odd = (4, 4)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            t = drv.submit(PROG, {"state": {
                "a": jnp.ones((2,) + odd, np.float32)}}, 6)
        drv.inject(faults.nan_at_step(t.id, "a", 2))
        drv.drain()
        assert drv.poll(t)["status"] == "failed"
        assert isinstance(t.error, tdp.HealthError)


# ---------------------------------------------------------------------------
# executor faults: blame attribution via batch-1 replays
# ---------------------------------------------------------------------------

class TestExecutorFaults:
    def test_one_shot_fault_recovers_every_ticket(self):
        ms = members(3)
        refs = fault_free_reference(ms, 8)
        handle = faults.register_failing_executor(
            "flaky1", base="xla", fail_on=1, times=1)
        try:
            drv = tdp.FleetDriver("flaky1", batch=3)
            ts = [drv.submit(PROG, {"state": ms[i],
                                    "consts": {"tau": TAUS[i]}}, 8)
                  for i in range(3)]
            final = drv.drain()
            assert handle.calls > 1          # the fault actually fired
            for i in range(3):
                assert drv.poll(ts[i])["status"] == "done"
                np.testing.assert_array_equal(
                    np.asarray(final[ts[i].id]["a"]), refs[i])
        finally:
            faults.unregister_failing_executor("flaky1")

    def test_persistent_fault_fails_with_cause(self):
        faults.register_failing_executor(
            "dead1", base="xla", fail_on=1, times=float("inf"))
        try:
            drv = tdp.FleetDriver("dead1", batch=2)
            t = drv.submit(PROG, {"state": members(1)[0]}, 4)
            final = drv.drain()               # terminates, doesn't hang
            p = drv.poll(t)
            assert p["status"] == "failed"
            assert isinstance(p["error"], tdp.InjectedFault)
            assert "InjectedFault" in p["traceback"]
            assert t.id in final              # last healthy state returned
        finally:
            faults.unregister_failing_executor("dead1")

    def test_failing_executor_schedule_validation(self):
        with pytest.raises(ValueError, match="1-based"):
            faults.register_failing_executor("x", fail_on=0)
        with pytest.raises(ValueError, match="times"):
            faults.register_failing_executor("x", times=0)


# ---------------------------------------------------------------------------
# retry with rollback
# ---------------------------------------------------------------------------

class TestRetry:
    def test_one_shot_nan_retries_bit_exact(self):
        ms = members(3)
        refs = fault_free_reference(ms, 8)
        drv = tdp.FleetDriver("xla", batch=3,
                              health=tdp.HealthPolicy(every=1),
                              max_retries=1)
        ts = [drv.submit(PROG, {"state": ms[i],
                                "consts": {"tau": TAUS[i]}}, 8)
              for i in range(3)]
        drv.inject(faults.nan_at_step(ts[1].id, "a", 3))
        final = drv.drain()
        p = drv.poll(ts[1])
        assert p["status"] == "done" and p["retries"] == 1
        assert p["error"] is not None         # kept for observability
        for i in range(3):
            np.testing.assert_array_equal(
                np.asarray(final[ts[i].id]["a"]), refs[i])

    def test_retry_resumes_from_last_checkpoint(self, tmp_path):
        """The rollback point tracks the checkpoint cadence: a fault
        after a snapshot retries from the snapshot, not from submit."""
        ms = members(2)
        refs = fault_free_reference(ms, 10)
        drv = tdp.FleetDriver("xla", batch=2,
                              checkpoint_dir=str(tmp_path / "ck"),
                              checkpoint_every=2,
                              health=tdp.HealthPolicy(every=1),
                              max_retries=1)
        ts = [drv.submit(PROG, {"state": ms[i],
                                "consts": {"tau": TAUS[i]}}, 10)
              for i in range(2)]
        drv.pump(6)                           # cadence refreshed at 2,4,6
        assert ts[0]._retry_ckpt[0] == 6
        drv.inject(faults.nan_at_step(ts[0].id, "a", 8))
        final = drv.drain()
        assert drv.poll(ts[0])["status"] == "done"
        assert drv.poll(ts[0])["retries"] == 1
        for i in range(2):
            np.testing.assert_array_equal(
                np.asarray(final[ts[i].id]["a"]), refs[i])

    def test_persistent_divergence_exhausts_retries(self):
        drv = tdp.FleetDriver("xla", batch=2,
                              health=tdp.HealthPolicy(every=1),
                              max_retries=2)
        # NaN in the *submitted* state: every retry rolls back to a
        # poisoned snapshot and re-diverges deterministically
        bad = {"a": members(1)[0]["a"].at[(0,) * 3].set(np.nan)}
        t = drv.submit(PROG, {"state": bad}, 4)
        drv.drain()
        p = drv.poll(t)
        assert p["status"] == "failed" and p["retries"] == 2

    def test_retry_backoff_gates_and_completes(self):
        drv = tdp.FleetDriver("xla", batch=2,
                              health=tdp.HealthPolicy(every=1),
                              max_retries=1, retry_backoff=0.05)
        t = drv.submit(PROG, {"state": members(1)[0]}, 6)
        drv.inject(faults.nan_at_step(t.id, "a", 2))
        t0 = time.perf_counter()
        drv.drain()                           # sleeps through the gate
        assert drv.poll(t)["status"] == "done"
        assert time.perf_counter() - t0 >= 0.05


# ---------------------------------------------------------------------------
# background-thread error surfacing (the satellite bugfix)
# ---------------------------------------------------------------------------

class TestLoopErrorSurfacing:
    def test_drain_reraises_pump_thread_crash(self):
        drv = tdp.FleetDriver("xla", batch=2)
        drv.submit(PROG, {"state": members(1)[0]}, 1000)
        drv.inject(faults.raise_in_pump(at_pump=2))
        drv.start()
        with pytest.raises(tdp.InjectedFault, match="pump round 2"):
            drv.drain()
        drv.stop()                            # already surfaced: no raise

    def test_poll_reports_driver_error_nonraising(self):
        drv = tdp.FleetDriver("xla", batch=2)
        t = drv.submit(PROG, {"state": members(1)[0]}, 1000)
        drv.inject(faults.raise_in_pump(at_pump=1))
        drv.start()
        deadline = time.perf_counter() + 10
        while "driver_error" not in drv.poll(t):
            assert time.perf_counter() < deadline, "error never surfaced"
            time.sleep(0.01)
        assert isinstance(drv.poll(t)["driver_error"], tdp.InjectedFault)
        with pytest.raises(tdp.InjectedFault):
            drv.stop()
        drv.stop()                            # idempotent after surfacing

    def test_inline_pump_chaos_raises_to_caller(self):
        drv = tdp.FleetDriver("xla", batch=2)
        drv.submit(PROG, {"state": members(1)[0]}, 4)
        drv.inject(faults.raise_in_pump(at_pump=1))
        with pytest.raises(tdp.InjectedFault):
            drv.drain()                       # no thread: raises directly


# ---------------------------------------------------------------------------
# checkpoint integrity: verify-on-load, retention, restore fallback
# ---------------------------------------------------------------------------

class TestRestoreFallback:
    def _two_snapshots(self, tmp_path, ms):
        drv = tdp.FleetDriver("xla", batch=2,
                              checkpoint_dir=str(tmp_path / "ck"),
                              checkpoint_keep=5)
        ts = [drv.submit(PROG, {"state": ms[i],
                                "consts": {"tau": TAUS[i]}}, 10)
              for i in range(2)]
        drv.pump(4)
        drv.checkpoint()                      # valid snapshot @ step 4
        drv.pump(2)
        drv.checkpoint()                      # newest snapshot @ step 6
        return str(tmp_path / "ck"), ts

    @pytest.mark.parametrize("mode", ["flip", "truncate", "manifest"])
    def test_corrupt_newest_falls_back_to_valid(self, tmp_path, mode):
        ms = members(2)
        refs = fault_free_reference(ms, 10)
        ck, ts = self._two_snapshots(tmp_path, ms)
        assert len(checkpoint_steps(ck)) == 2
        faults.corrupt_checkpoint(ck, mode=mode)
        with pytest.warns(RuntimeWarning, match="integrity"):
            drv2 = tdp.FleetDriver.restore(ck, PROG)
        assert drv2._tickets[ts[0].id].step == 4   # the older snapshot
        final = drv2.drain()
        for i in range(2):                    # resume is still bit-exact
            np.testing.assert_array_equal(
                np.asarray(final[ts[i].id]["a"]), refs[i])

    def test_all_corrupt_raises_ioerror(self, tmp_path):
        ck, _ = self._two_snapshots(tmp_path, members(2))
        for step in checkpoint_steps(ck):
            faults.corrupt_checkpoint(ck, step=step, mode="flip")
        with pytest.raises(IOError, match="failed integrity"):
            tdp.FleetDriver.restore(ck, PROG)

    def test_restore_checkpoint_verifies_by_default(self, tmp_path):
        from repro.checkpoint.store import (restore_checkpoint,
                                            save_checkpoint)
        tree = {"w": np.arange(8.0, dtype=np.float32)}
        save_checkpoint(str(tmp_path), 1, tree)
        faults.corrupt_checkpoint(str(tmp_path), mode="flip")
        with pytest.raises(IOError, match="integrity"):
            restore_checkpoint(str(tmp_path), tree)
        got, _, _ = restore_checkpoint(str(tmp_path), tree, verify=False)
        assert got["w"].shape == (8,)         # best-effort read still works

    def test_failed_ticket_restores_failed(self, tmp_path):
        ck = str(tmp_path / "ck")
        drv = tdp.FleetDriver("xla", batch=2, checkpoint_dir=ck,
                              health=tdp.HealthPolicy(every=1))
        t_ok = drv.submit(PROG, {"state": members(1)[0]}, 4)
        t_bad = drv.submit(PROG, {"state": {
            "a": members(1, seed=1)[0]["a"].at[(0,) * 3].set(np.nan)}}, 4)
        drv.drain()
        drv.checkpoint()
        drv2 = tdp.FleetDriver.restore(ck, PROG)
        assert drv2._tickets[t_ok.id].status == "done"
        rbad = drv2._tickets[t_bad.id]
        assert rbad.status == "failed"
        assert "health check failed" in str(rbad.error)
        drv2.drain()                          # failed is terminal: no hang

    def test_kill_pump_thread_then_restore_resumes(self, tmp_path):
        ck = str(tmp_path / "ck")
        drv = tdp.FleetDriver("xla", batch=2, checkpoint_dir=ck,
                              checkpoint_every=2)
        t = drv.submit(PROG, {"state": members(1)[0]}, 5000)
        drv.start()
        deadline = time.perf_counter() + 60
        while latest_step(ck) is None:
            assert time.perf_counter() < deadline, "no checkpoint written"
            time.sleep(0.01)
        faults.kill_pump_thread(drv)          # SIGKILL stand-in: no flush
        drv2 = tdp.FleetDriver.restore(ck, PROG)
        r = drv2._tickets[t.id]
        assert not r.finished and 0 < r.step < 5000
