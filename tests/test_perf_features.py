"""Correctness pins for the §Perf optimizations (beyond-paper features).

Every hillclimb change ships with an exactness test: the optimization may
only move bytes/FLOPs, never results.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention
from repro.models.config import AttnConfig
from repro.models.context import ExecContext
from repro.kernels import ref


class TestRingCache:
    @pytest.mark.parametrize("window,T", [(6, 20), (4, 4), (8, 7)])
    def test_ring_equals_full_cache_decode(self, window, T):
        a = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16, window=window)
        d = 32
        p = {k: jax.random.normal(jax.random.PRNGKey(i), s) * 0.2
             for i, (k, s) in enumerate(
                 {"wq": (d, 64), "wk": (d, 32), "wv": (d, 32),
                  "wo": (64, d)}.items())}
        ctx = ExecContext()
        xs = jax.random.normal(jax.random.PRNGKey(9), (1, T, d))
        full = {"k": jnp.zeros((1, 2, T, 16)), "v": jnp.zeros((1, 2, T, 16))}
        ring = {"k": jnp.zeros((1, 2, min(window, T), 16)),
                "v": jnp.zeros((1, 2, min(window, T), 16))}
        cos = jnp.ones((1, 1, 8))
        sin = jnp.zeros((1, 1, 8))
        for t in range(T):
            x = xs[:, t:t + 1]
            of, full = attention.decode_attention(
                p, x, a, ctx, full, t, rope=(cos, sin), window=window)
            orr, ring = attention.decode_attention(
                p, x, a, ctx, ring, t, rope=(cos, sin), window=window)
            np.testing.assert_allclose(np.asarray(of), np.asarray(orr),
                                       rtol=2e-4, atol=2e-5, err_msg=f"t={t}")

    def test_ring_cache_sizes(self):
        from repro import configs as C
        from repro.models import lm
        cfg = C.get_config("gemma3_27b")
        full = jax.eval_shape(lambda: lm.init_cache(None, cfg, 1, 16384))
        ring = jax.eval_shape(lambda: lm.init_cache(None, cfg, 1, 16384,
                                                    local_ring=True))
        nb = lambda t: sum(np.prod(l.shape) * l.dtype.itemsize
                           for l in jax.tree.leaves(t))
        assert nb(ring) < 0.25 * nb(full)       # 52/62 layers shrink


class TestFlashBackward:
    def test_grad_matches_dense_oracle(self, rng):
        q = jnp.asarray(rng.normal(size=(2, 4, 64, 16)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, 2, 64, 16)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, 2, 64, 16)), jnp.float32)
        f1 = lambda *a: (ref.attention_chunked_ref(*a, causal=True,
                                                   block_q=16) ** 2).sum()
        f2 = lambda *a: (ref.attention_ref(*a, causal=True) ** 2).sum()
        g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
        for x, y in zip(g1, g2):
            np.testing.assert_allclose(x, y, rtol=2e-3, atol=2e-4)

    def test_no_s2_residuals(self):
        """The backward must not save S²-sized probability tensors."""
        q = jax.ShapeDtypeStruct((1, 2, 1024, 32), jnp.float32)

        def loss(q_, k_, v_):
            return (ref.attention_chunked_ref(q_, k_, v_, causal=True,
                                              block_q=128) ** 2).sum()

        jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=0))(q, q, q)
        # residual tensors between fwd and bwd live in the jaxpr's eqn
        # outputs; no saved tensor may have S·S = 1M+ elements per head
        for eqn in jaxpr.jaxpr.eqns:
            for var in eqn.outvars:
                shape = getattr(var.aval, "shape", ())
                big = [d for d in shape if d >= 1024]
                assert big.count(1024) < 2 or np.prod(shape) < 2 * 1024 * 1024, \
                    f"S²-sized tensor materialised: {shape}"


class TestMicrobatchStriding:
    def test_strided_rows(self):
        from repro.runtime.steps import _microbatch
        x = jnp.arange(8)[:, None] * jnp.ones((1, 3))
        mb = _microbatch({"tokens": x}, 2)["tokens"]
        # microbatch j = rows {i·2 + j}: spread across contiguous shards
        np.testing.assert_array_equal(np.asarray(mb[0, :, 0]), [0, 2, 4, 6])
        np.testing.assert_array_equal(np.asarray(mb[1, :, 0]), [1, 3, 5, 7])

    def test_positions3_batch_dim(self):
        from repro.runtime.steps import _microbatch
        p3 = jnp.zeros((3, 8, 5), jnp.int32)
        mb = _microbatch({"positions3": p3}, 4)["positions3"]
        assert mb.shape == (4, 3, 2, 5)


class TestSeqParallelGating:
    def test_disabled_without_mesh(self):
        a = AttnConfig(n_heads=6, n_kv_heads=2, head_dim=16)
        assert not attention._use_seq_parallel(ExecContext(), a, 64)

    def test_disabled_when_heads_divide(self):
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((1, 1), ("data", "model"))
        ctx = ExecContext(mesh=mesh, batch_axes=("data",),
                          model_axis="model", attn_impl="chunked")
        a = AttnConfig(n_heads=4, n_kv_heads=4, head_dim=16)
        assert not attention._use_seq_parallel(ctx, a, 64)
