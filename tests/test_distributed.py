"""Multi-device behaviour, via subprocesses with 8 fake CPU devices.

Each test launches a fresh interpreter with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the main test
process must keep seeing 1 device), runs a scenario on a (2,2,2) or (2,4)
mesh, and asserts on printed results.  Scenarios:

* sharded train step == single-device train step (GSPMD correctness),
* expert-TP MoE == local MoE; a2a MoE == expert-TP (generous capacity),
* sequence-sharded flash-decode == local decode,
* EF-int8 compressed pod psum ≈ exact psum, error feedback carries,
* LB slab-decomposed halo-exchange sim == single-device sim.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow   # multi-device subprocess scenarios

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


PRELUDE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
assert len(jax.devices()) == 8, jax.devices()
"""


class TestDistributed:
    def test_sharded_train_step_matches_local(self):
        run_sub(PRELUDE + """
from repro.models.config import ModelConfig, AttnConfig, repeat_program
from repro.models import params as Pm, lm
from repro.models.context import ExecContext
from repro.launch.mesh import make_test_mesh
from repro.sharding import make_plan, sharding_for_tree

cfg = ModelConfig(name="t", d_model=64, n_layers=2, vocab_size=256, d_ff=128,
    layer_program=repeat_program(("attn",), 2), attn=AttnConfig(4, 2, 16))
params, axes = Pm.init_params(cfg, jax.random.PRNGKey(0))
batch = {"tokens": jnp.tile(jnp.arange(32, dtype=jnp.int32)[None], (8, 1)) % 256,
         "labels": jnp.ones((8, 32), jnp.int32)}

l_local = lm.loss_fn(params, batch, cfg, ExecContext())[0]

mesh = make_test_mesh((2, 4), ("data", "model"))
ctx = ExecContext(mesh=mesh, batch_axes=("data",), model_axis="model")
sh = sharding_for_tree(axes, make_plan(cfg), mesh)
params_s = jax.device_put(params, sh)
bsh = NamedSharding(mesh, P("data", None))
batch_s = {k: jax.device_put(v, bsh) for k, v in batch.items()}
l_shard = jax.jit(lambda p, b: lm.loss_fn(p, b, cfg, ctx)[0])(params_s, batch_s)
np.testing.assert_allclose(float(l_local), float(l_shard), rtol=2e-5)
print("SHARDED_OK", float(l_local), float(l_shard))
""")

    def test_moe_expert_tp_and_a2a_match_local(self):
        run_sub(PRELUDE + """
from repro.models.config import ModelConfig, AttnConfig, MoEConfig, repeat_program
from repro.models import params as Pm, moe
from repro.models.context import ExecContext
from repro.launch.mesh import make_test_mesh

cfg = ModelConfig(name="m", d_model=32, n_layers=1, vocab_size=64, d_ff=64,
    layer_program=("attn_moe",), attn=AttnConfig(2, 2, 16),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=16, num_shared=1,
                  capacity_factor=4.0))
params, _ = Pm.init_params(cfg, jax.random.PRNGKey(0))
mp = jax.tree.map(lambda t: t[0], params["groups"][0][0])["mlp"]
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

out_local = moe.moe_mlp(mp, x, cfg, ExecContext())
mesh = make_test_mesh((2, 4), ("data", "model"))
ctx = ExecContext(mesh=mesh, batch_axes=("data",), model_axis="model")
out_tp = jax.jit(lambda m_, x_: moe.moe_mlp(m_, x_, cfg, ctx))(mp, x)
np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_tp),
                           rtol=5e-4, atol=5e-5)
out_a2a = jax.jit(lambda m_, x_: moe.moe_a2a(m_, x_, cfg, ctx,
                                             capacity_factor=8.0))(mp, x)
np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_a2a),
                           rtol=5e-4, atol=5e-5)
print("MOE_OK")
""")

    def test_seq_sharded_decode_matches_local(self):
        run_sub(PRELUDE + """
from repro.models.config import AttnConfig
from repro.models import attention
from repro.models.context import ExecContext
from repro.launch.mesh import make_test_mesh

a = AttnConfig(n_heads=4, n_kv_heads=2, head_dim=16)
p = {"wq": jax.random.normal(jax.random.PRNGKey(0), (32, 64)) * .1,
     "wk": jax.random.normal(jax.random.PRNGKey(1), (32, 32)) * .1,
     "wv": jax.random.normal(jax.random.PRNGKey(2), (32, 32)) * .1,
     "wo": jax.random.normal(jax.random.PRNGKey(3), (64, 32)) * .1}
x = jax.random.normal(jax.random.PRNGKey(4), (2, 1, 32))
cache = {"k": jax.random.normal(jax.random.PRNGKey(5), (2, 2, 64, 16)),
         "v": jax.random.normal(jax.random.PRNGKey(6), (2, 2, 64, 16))}
length = 40
out_local, _ = attention.decode_attention(p, x, a, ExecContext(),
                                          jax.tree.map(jnp.copy, cache), length)
mesh = make_test_mesh((2, 4), ("data", "model"))
ctx = ExecContext(mesh=mesh, batch_axes=("data",), model_axis="model",
                  seq_shard_decode=True)
out_s, _ = jax.jit(lambda p_, x_, c_: attention.decode_attention(
    p_, x_, a, ctx, c_, length))(p, x, cache)
np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_s),
                           rtol=2e-4, atol=2e-5)
print("FLASH_DECODE_OK")
""")

    def test_compressed_pod_psum(self):
        run_sub(PRELUDE + """
from repro.optim.compress import compressed_psum_mean, compress_init
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))

g = {"w": jax.random.normal(jax.random.PRNGKey(0), (2, 64, 32))}
e = {"w": jnp.zeros((64, 32))}

def body(g_l, e_l):
    red, new_e = compressed_psum_mean({"w": g_l["w"]}, {"w": e_l["w"]}, "pod")
    return red["w"], new_e["w"]

from repro.core import compat
fn = compat.shard_map(body, mesh=mesh,
                      in_specs=({"w": P("pod")}, {"w": P()}),
                      out_specs=(P(), P()), check_vma=False)
red, err = jax.jit(fn)(g, e)
exact = g["w"].mean(0)
rel = float(jnp.abs(red - exact).max() / jnp.abs(exact).max())
assert rel < 0.02, rel                        # int8 quant error bounded
# error feedback buffer carries the residual
assert float(jnp.abs(err).max()) > 0
# second round with EF: cumulative mean converges closer
print("COMPRESS_OK", rel)
""")

    def test_lb_sharded_sim_matches_local(self):
        run_sub(PRELUDE + """
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((8,), ("data",))
s_loc = BinaryFluidSim((16, 8, 8))
s_sh = BinaryFluidSim((16, 8, 8), mesh=mesh, shard_axis="data")
st0 = s_loc.init_spinodal(seed=1)
st1 = s_sh.init_spinodal(seed=1)
a = s_loc.step(st0, 5)
b = s_sh.step(st1, 5)
np.testing.assert_allclose(np.asarray(a.f), np.asarray(b.f), rtol=1e-4, atol=1e-6)
np.testing.assert_allclose(np.asarray(a.g), np.asarray(b.g), rtol=1e-4, atol=1e-6)
print("LB_HALO_OK")
""")

    def test_lb_fused_sharded_sim_matches_local(self):
        """Fused stream+collide under slab decomposition: the 2-plane
        ppermute halo exchange feeds the radius-2 composed stencil."""
        run_sub(PRELUDE + """
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((8,), ("data",))
s_loc = BinaryFluidSim((16, 8, 8))
s_sh = BinaryFluidSim((16, 8, 8), mesh=mesh, shard_axis="data", fused=True)
st0 = s_loc.init_spinodal(seed=1)
st1 = s_sh.init_spinodal(seed=1)
a = s_loc.step(st0, 5)
b = s_sh.step(st1, 5)
np.testing.assert_allclose(np.asarray(a.f), np.asarray(b.f), rtol=2e-4, atol=2e-6)
np.testing.assert_allclose(np.asarray(a.g), np.asarray(b.g), rtol=2e-4, atol=2e-6)
print("LB_FUSED_HALO_OK")
""")

    def test_lb_windowed_sharded_sim_matches_local(self):
        """Fused step on the gather-free pallas_windowed executor under
        slab decomposition: the same 2-plane ppermute exchange feeds the
        halo_extend prologue (ghost planes trimmed to each stencil's
        radius, y/z wrap-padded) instead of the offset gather — the
        trajectory still matches the single-device xla sim."""
        run_sub(PRELUDE + """
from repro import tdp
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4,), ("data",))
wt = tdp.Target("pallas_windowed", interpret=True)
s_loc = BinaryFluidSim((16, 8, 8))
s_sh = BinaryFluidSim((16, 8, 8), mesh=mesh, shard_axis="data", fused=True,
                      target=wt)
st0 = s_loc.init_spinodal(seed=1)
st1 = s_sh.init_spinodal(seed=1)
a = s_loc.step(st0, 5)
b = s_sh.step(st1, 5)
np.testing.assert_allclose(np.asarray(a.f), np.asarray(b.f), rtol=2e-4, atol=2e-6)
np.testing.assert_allclose(np.asarray(a.g), np.asarray(b.g), rtol=2e-4, atol=2e-6)
print("LB_WINDOWED_HALO_OK")
""")

    def test_lb_two_launch_sharded_sim_matches_local(self):
        """Two-launch fused step under slab decomposition: launch A
        recomputes the streamed-φ ghost ring locally from the width-2
        exchange — no extra communication for the intermediate."""
        run_sub(PRELUDE + """
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((8,), ("data",))
s_loc = BinaryFluidSim((16, 8, 8))
s_sh = BinaryFluidSim((16, 8, 8), mesh=mesh, shard_axis="data",
                      fused="two_launch")
st0 = s_loc.init_spinodal(seed=1)
st1 = s_sh.init_spinodal(seed=1)
a = s_loc.step(st0, 5)
b = s_sh.step(st1, 5)
np.testing.assert_allclose(np.asarray(a.f), np.asarray(b.f), rtol=2e-4, atol=2e-6)
np.testing.assert_allclose(np.asarray(a.g), np.asarray(b.g), rtol=2e-4, atol=2e-6)
print("LB_TWO_LAUNCH_HALO_OK")
""")

    def test_lb_program_sharded_4way_matches_local(self):
        """The tdp.Program sharded step: one ghost-exchange round per
        step at the back-propagated widths ({f: 1, g: 2} for the
        two-launch graph — f travels *one* plane, not the old blanket
        two), bit-identical to the single-device trajectory on a 4-way
        slab decomposition."""
        run_sub(PRELUDE + """
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4,), ("data",))
s_loc = BinaryFluidSim((16, 8, 8), fused="two_launch")
s_sh = BinaryFluidSim((16, 8, 8), mesh=mesh, shard_axis="data",
                      fused="two_launch")
assert s_sh.programs["fused"].halo_schedule == {"f": 1, "g": 2}, \\
    s_sh.programs["fused"].halo_schedule
# the collide prologue has no stream stage: f needs no exchange at all
assert s_sh.programs["collide"].halo_schedule == {"f": 0, "g": 1}
assert s_sh.programs["stream"].halo_schedule == {"f": 1, "g": 1}
st0 = s_loc.init_spinodal(seed=1)
st1 = s_sh.init_spinodal(seed=1)
a = s_loc.step(st0, 5)
b = s_sh.step(st1, 5)
np.testing.assert_array_equal(np.asarray(a.f), np.asarray(b.f))
np.testing.assert_array_equal(np.asarray(a.g), np.asarray(b.g))
c = s_sh.run(st1, 5)
np.testing.assert_array_equal(np.asarray(b.f), np.asarray(c.f))
np.testing.assert_array_equal(np.asarray(b.g), np.asarray(c.g))

# maximal decomposition: a 1-plane slab under the width-2 g schedule
# (the exchange hops two ranks) still matches the local trajectory
t_loc = BinaryFluidSim((4, 8, 8))
t_sh = BinaryFluidSim((4, 8, 8), mesh=mesh, shard_axis="data")
u0 = t_loc.init_spinodal(seed=2)
u1 = t_sh.init_spinodal(seed=2)
ua = t_loc.step(u0, 4)
ub = t_sh.step(u1, 4)
np.testing.assert_array_equal(np.asarray(ua.f), np.asarray(ub.f))
np.testing.assert_array_equal(np.asarray(ua.g), np.asarray(ub.g))
print("LB_PROGRAM_4WAY_OK")
""")

    def test_lb_pencil_2x2_matches_local(self):
        """The tentpole pin: a 2-D pencil decomposition (mesh axes
        (px, py) sharding grid dims 0 and 1) is bit-identical to the
        single-device trajectory over 10 steps at 16³, with one exchange
        round per field per sharded dim — the per-dim widths mirror the
        slab schedule and the lowered HLO carries exactly the analytic
        ppermute count."""
        run_sub(PRELUDE + """
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 2), ("px", "py"))
s_loc = BinaryFluidSim((16, 16, 16), fused="two_launch")
s_sh = BinaryFluidSim((16, 16, 16), mesh=mesh, shard_axis=("px", "py"),
                      fused="two_launch")
exe = s_sh.programs["fused"]
assert exe.exchange_schedule == {"f": {0: 1, 1: 1}, "g": {0: 2, 1: 2}}, \\
    exe.exchange_schedule
assert exe.halo_schedule == {"f": 1, "g": 2}     # legacy dim-0 view
assert s_sh.programs["collide"].exchange_schedule == \\
    {"f": {}, "g": {0: 1, 1: 1}}
cs = exe.comm_stats()
assert cs["decomposition"] == "pencil" and cs["mesh_axis_sizes"] == (2, 2)
assert cs["local_shape"] == (8, 8, 16)
# one round per field per sharded dim, single-hop: 2 ppermutes each
assert cs["ppermutes_per_step"] == 8, cs
assert cs["exchanged_bytes_per_step"] > 0
st0 = s_loc.init_spinodal(seed=3)
st1 = s_sh.init_spinodal(seed=3)
a = s_loc.step(st0, 10)
b = s_sh.step(st1, 10)
np.testing.assert_array_equal(np.asarray(a.f), np.asarray(b.f))
np.testing.assert_array_equal(np.asarray(a.g), np.asarray(b.g))
c = s_sh.run(s_sh.init_spinodal(seed=3), 10)
np.testing.assert_array_equal(np.asarray(a.f), np.asarray(c.f))
np.testing.assert_array_equal(np.asarray(a.g), np.asarray(c.g))
# the per-step exchange count matches the schedule: count the
# collective permutes in the lowered step HLO
txt = jax.jit(exe._core).lower(*exe._as_tuple(
    {"f": st1.f, "g": st1.g})).as_text()
n_cp = txt.count("collective-permute") + txt.count("collective_permute")
assert n_cp == cs["ppermutes_per_step"], (n_cp, cs["ppermutes_per_step"])
print("LB_PENCIL_2X2_OK")
""")

    def test_lb_pencil_overlap_schedule(self):
        """overlap=True splits every stage into interior + boundary
        regions (interior launched off the raw local arrays, no ppermute
        dependency).  The split is data-exact but region-shaped XLA
        codegen reassociates at <=1 ULP, so the pin is allclose at
        float32 tightness plus the schedule introspection."""
        run_sub(PRELUDE + """
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((2, 2), ("px", "py"))
s_loc = BinaryFluidSim((16, 16, 16), fused="two_launch")
s_ov = BinaryFluidSim((16, 16, 16), mesh=mesh, shard_axis=("px", "py"),
                      fused="two_launch", overlap=True)
exe = s_ov.programs["fused"]
assert exe.overlap is True
cs = exe.comm_stats()
assert cs["overlap"] is True
# interior (8-2*2)^2*16 of 8*8*16 local sites
assert abs(cs["interior_fraction"] - 16.0 / 64.0) < 1e-12
a = s_loc.step(s_loc.init_spinodal(seed=3), 10)
b = s_ov.step(s_ov.init_spinodal(seed=3), 10)
np.testing.assert_allclose(np.asarray(a.f), np.asarray(b.f),
                           rtol=1e-5, atol=1e-7)
np.testing.assert_allclose(np.asarray(a.g), np.asarray(b.g),
                           rtol=1e-5, atol=1e-7)
# default compile stays unsplit (bit-identity guarantee)
assert BinaryFluidSim((16, 16, 16), mesh=mesh, shard_axis=("px", "py"),
                      fused="two_launch").programs["fused"].overlap is False
print("LB_PENCIL_OVERLAP_OK")
""")

    def test_lb_block_and_thin_pencil(self):
        """Degenerate 3-D block decomposition and the multi-hop thin
        pencil (1-plane local extent under a width-2 schedule reads from
        ranks ±2 along that mesh axis) both stay bit-identical."""
        run_sub(PRELUDE + """
from repro.lb.sim import BinaryFluidSim
from repro.launch.mesh import make_test_mesh
# 2x2x2 block at 16^3
mb = make_test_mesh((2, 2, 2), ("bx", "by", "bz"))
s_loc = BinaryFluidSim((16, 16, 16), fused="two_launch")
s_bl = BinaryFluidSim((16, 16, 16), mesh=mb,
                      shard_axis=("bx", "by", "bz"), fused="two_launch")
assert s_bl.programs["fused"].comm_stats()["decomposition"] == "block"
a = s_loc.step(s_loc.init_spinodal(seed=3), 5)
b = s_bl.step(s_bl.init_spinodal(seed=3), 5)
np.testing.assert_array_equal(np.asarray(a.f), np.asarray(b.f))
np.testing.assert_array_equal(np.asarray(a.g), np.asarray(b.g))

# thin pencil: mesh (2,4) on (8,4,8) -> local (4,1,8); g's width-2
# exchange in dim 1 needs 2 hops per side (4 ppermutes)
mt = make_test_mesh((2, 4), ("tx", "ty"))
t_loc = BinaryFluidSim((8, 4, 8), fused="two_launch")
t_sh = BinaryFluidSim((8, 4, 8), mesh=mt, shard_axis=("tx", "ty"),
                      fused="two_launch")
cs = t_sh.programs["fused"].comm_stats()
assert cs["per_field"]["g"]["ppermutes"] == 6, cs   # 2 (dim0) + 4 (dim1)
ua = t_loc.step(t_loc.init_spinodal(seed=1), 5)
ub = t_sh.step(t_sh.init_spinodal(seed=1), 5)
np.testing.assert_array_equal(np.asarray(ua.f), np.asarray(ub.f))
np.testing.assert_array_equal(np.asarray(ua.g), np.asarray(ub.g))
print("LB_BLOCK_THIN_OK")
""")

    def test_trainer_on_mesh_with_compression(self):
        run_sub(PRELUDE + """
import tempfile
from repro.models.config import ModelConfig, AttnConfig, repeat_program
from repro.data import SyntheticConfig
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig, TrainHParams
from repro.launch.mesh import make_test_mesh

cfg = ModelConfig(name="t", d_model=32, n_layers=2, vocab_size=64, d_ff=64,
    layer_program=repeat_program(("attn",), 2), attn=AttnConfig(2, 2, 16))
mesh = make_test_mesh((2, 2, 2), ("pod", "data", "model"))
with tempfile.TemporaryDirectory() as d:
    # fsdp=False: FSDP + partial-manual pod shard_map trips an XLA
    # partitioner CHECK (documented in runtime/trainer.py)
    tr = Trainer(cfg, mesh, SyntheticConfig(64, 16, 8),
                 AdamWConfig(),
                 TrainHParams(grad_accum=2, warmup_steps=2, total_steps=20,
                              compress_pod=True),
                 TrainerConfig(ckpt_dir=d, ckpt_every=100, log_every=100,
                               fsdp=False, log=lambda *_: None))
    tr.train_steps(6)
    import math
    losses = [h for h in tr.metrics_history]
    print("TRAINER_MESH_OK", tr.step)
    assert tr.step == 6
""", timeout=900)
